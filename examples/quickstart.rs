//! Quickstart: the fitted-model API end to end — fit a synthetic Gaussian
//! mixture with BanditPAM through the `Fit` builder, predict unseen
//! points, persist the model, and compare against exact PAM.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the core public API: `Fit` (one-stop builder),
//! `KMedoidsModel` (owned medoids, out-of-sample `predict`, `save`/`load`)
//! and the training metadata carried on the model.

use banditpam::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Data: 800 points in 16 dims from 5 well-separated components.
    let data = synthetic::gmm(&mut Rng::seed_from(7), 800, 16, 5, 4.0);
    println!("dataset: {} ({} points)", data.name, data.len());

    // 2. Fit BanditPAM with the paper-default configuration through the
    //    builder facade — backend, rng and config are assembled inside.
    let model = Fit::banditpam().metric(Metric::L2).seed(7).k(5).fit(&data)?;
    let fit = model.clustering();
    println!("\nBanditPAM:");
    println!("  medoids        = {:?}", fit.medoids);
    println!("  loss           = {:.3}", fit.loss);
    println!("  distance evals = {}", fit.stats.distance_evals);
    println!("  swap iters     = {}", fit.stats.swap_iters);

    // 3. The model owns its medoid points: predicting the training set
    //    reproduces the stored assignments bit for bit.
    let again = model.predict(&data.points)?;
    assert_eq!(again, fit.assignments, "training-set predict is bitwise-stable");
    println!("  predict(train) = training assignments (bitwise)");

    // 4. Out-of-sample assignment of genuinely unseen points.
    let unseen = synthetic::gmm(&mut Rng::seed_from(8), 100, 16, 5, 4.0);
    let (assign, dists) = model.predict_with_dists(&unseen.points)?;
    let mean = dists.iter().sum::<f64>() / dists.len() as f64;
    println!(
        "  100 unseen points assigned (mean distance to medoid {mean:.3})"
    );
    assert_eq!(assign.len(), 100);

    // 5. Persistence: save -> load -> serve, with the training data gone.
    let path = std::env::temp_dir().join(format!(
        "banditpam_quickstart_{}.bpmodel",
        std::process::id()
    ));
    model.save(&path)?;
    drop(data);
    let served = KMedoidsModel::load(&path)?;
    let re_assign = served.predict(&unseen.points)?;
    assert_eq!(re_assign, assign, "reloaded model predicts identically");
    println!(
        "  saved -> reloaded -> identical predictions ({} bytes on disk)",
        std::fs::metadata(&path)?.len()
    );
    let _ = std::fs::remove_file(&path);

    // 6. Reference: exact PAM on the same data, same facade.
    let unseen_model = Fit::pam().metric(Metric::L2).seed(7).k(5).fit(&unseen)?;
    let pam_fit = unseen_model.clustering();
    println!("\nPAM (exact, on the unseen batch):");
    println!("  medoids        = {:?}", pam_fit.medoids);
    println!("  loss           = {:.3}", pam_fit.loss);

    // 7. The paper's claim on the training set: same medoids as PAM, far
    //    fewer evaluations.
    let big = synthetic::gmm(&mut Rng::seed_from(7), 800, 16, 5, 4.0);
    let pam_model = Fit::pam().metric(Metric::L2).seed(7).k(5).fit(&big)?;
    println!(
        "\nsame medoids as PAM: {}",
        if model.clustering().same_medoids(pam_model.clustering()) {
            "YES"
        } else {
            "no (rare; loss matches)"
        }
    );
    println!(
        "evaluation ratio   : {:.1}x fewer",
        pam_model.clustering().stats.distance_evals as f64
            / model.clustering().stats.distance_evals as f64
    );

    // 8. Cluster purity against the generator's ground-truth labels.
    if let Some(labels) = &big.labels {
        let k = model.k();
        let mut majority = vec![std::collections::HashMap::new(); k];
        for (i, &a) in model.clustering().assignments.iter().enumerate() {
            *majority[a].entry(labels[i]).or_insert(0usize) += 1;
        }
        let pure: usize = majority
            .iter()
            .map(|m| m.values().max().copied().unwrap_or(0))
            .sum();
        println!(
            "cluster purity     : {:.1}%",
            100.0 * pure as f64 / big.len() as f64
        );
    }
    Ok(())
}
