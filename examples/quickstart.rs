//! Quickstart: cluster a synthetic Gaussian mixture with BanditPAM and
//! compare against exact PAM.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the core public API: build a dataset, wrap it in a
//! distance backend, fit, inspect medoids / loss / evaluation counts.

use banditpam::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Data: 800 points in 16 dims from 5 well-separated components.
    let mut rng = Rng::seed_from(7);
    let data = synthetic::gmm(&mut rng, 800, 16, 5, 4.0);
    println!("dataset: {} ({} points)", data.name, data.len());

    // 2. Backend: native Rust kernels, counting every distance evaluation.
    let backend = NativeBackend::new(&data.points, Metric::L2);

    // 3. Fit BanditPAM with the paper-default configuration
    //    (B = 100, delta = 1/(1000 |S_tar|), per-arm sigma).
    let mut algo = BanditPam::new(BanditPamConfig::default());
    let fit = algo.fit(&backend, 5, &mut rng)?;
    println!("\nBanditPAM:");
    println!("  medoids        = {:?}", fit.medoids);
    println!("  loss           = {:.3}", fit.loss);
    println!("  distance evals = {}", fit.stats.distance_evals);
    println!("  swap iters     = {}", fit.stats.swap_iters);

    // 4. Reference: exact PAM on the same data.
    let pam_backend = NativeBackend::new(&data.points, Metric::L2);
    let pam_fit = Pam::new().fit(&pam_backend, 5, &mut rng)?;
    println!("\nPAM (exact):");
    println!("  medoids        = {:?}", pam_fit.medoids);
    println!("  loss           = {:.3}", pam_fit.loss);
    println!("  distance evals = {}", pam_fit.stats.distance_evals);

    // 5. The paper's claim: identical medoids, far fewer evaluations.
    println!(
        "\nsame medoids as PAM: {}",
        if fit.same_medoids(&pam_fit) { "YES" } else { "no (rare; loss matches)" }
    );
    println!(
        "evaluation ratio   : {:.1}x fewer",
        pam_fit.stats.distance_evals as f64 / fit.stats.distance_evals as f64
    );

    // 6. Cluster purity against the generator's ground-truth labels.
    if let Some(labels) = &data.labels {
        let k = fit.medoids.len();
        let mut majority = vec![std::collections::HashMap::new(); k];
        for (i, &a) in fit.assignments.iter().enumerate() {
            *majority[a].entry(labels[i]).or_insert(0usize) += 1;
        }
        let pure: usize = majority
            .iter()
            .map(|m| m.values().max().copied().unwrap_or(0))
            .sum();
        println!(
            "cluster purity     : {:.1}%",
            100.0 * pure as f64 / data.len() as f64
        );
    }
    Ok(())
}
