//! Cell-type identification on scRNA-seq-like data with l1 distance —
//! the paper's single-cell motivation (§1: "identifying cell types in
//! large-scale single-cell data"; l1 recommended by [37]).
//!
//!     cargo run --release --example scrna_celltypes
//!
//! Clusters zero-inflated log-normal expression profiles (11 cell types),
//! reports the medoid "marker profiles", cluster purity against the
//! generating cell types, and the evaluation savings vs PAM.

use banditpam::algorithms::fastpam1::FastPam1;
use banditpam::data::Points;
use banditpam::prelude::*;

fn main() -> anyhow::Result<()> {
    let n = 1500;
    let genes = 1024;
    let k = 11;
    let mut rng = Rng::seed_from(2024);
    let data = synthetic::scrna_like(&mut rng, n, genes);
    println!("dataset: {} (metric = l1, k = {k})", data.name);

    let threads = banditpam::experiments::harness::default_threads();
    let backend = NativeBackend::new(&data.points, Metric::L1).with_threads(threads);
    let mut algo = BanditPam::new(BanditPamConfig::default());
    let fit = algo.fit(&backend, k, &mut rng)?;

    println!("\nBanditPAM: loss {:.1}, {} distance evals, {} swap iters",
        fit.loss, fit.stats.distance_evals, fit.stats.swap_iters);

    // Medoid expression summaries ("marker profiles").
    if let Points::Dense(m) = &data.points {
        println!("\nmedoid cells (expressed genes / strongest expression):");
        for (pos, &med) in fit.medoids.iter().enumerate() {
            let row = m.row(med);
            let expressed = row.iter().filter(|&&v| v > 0.0).count();
            let maxv = row.iter().cloned().fold(0.0f32, f32::max);
            let members = fit.assignments.iter().filter(|&&a| a == pos).count();
            println!(
                "  medoid {med:>5}: {members:>4} cells, {expressed:>4}/{genes} genes expressed, max {maxv:.2}"
            );
        }
    }

    // Purity against the generating cell types.
    if let Some(labels) = &data.labels {
        let mut majority = vec![std::collections::HashMap::new(); k];
        for (i, &a) in fit.assignments.iter().enumerate() {
            *majority[a].entry(labels[i]).or_insert(0usize) += 1;
        }
        let pure: usize = majority
            .iter()
            .map(|m| m.values().max().copied().unwrap_or(0))
            .sum();
        println!(
            "\ncell-type purity: {:.1}%",
            100.0 * pure as f64 / data.len() as f64
        );
    }

    // PAM reference for the savings claim.
    let pam_backend = NativeBackend::new(&data.points, Metric::L1).with_threads(threads);
    let pam = FastPam1::new().fit(&pam_backend, k, &mut Rng::seed_from(0))?;
    println!(
        "vs PAM/FastPAM1 : loss ratio {:.4}, {:.1}x fewer distance evals",
        fit.loss / pam.loss,
        pam.stats.distance_evals as f64 / fit.stats.distance_evals as f64
    );
    Ok(())
}
