//! Cell-type identification on scRNA-seq-like data with l1 distance —
//! the paper's single-cell motivation (§1: "identifying cell types in
//! large-scale single-cell data"; l1 recommended by [37]) — running the
//! **sparse (CSR) path** end to end: the data is generated directly in
//! compressed sparse row form (as a real 10x `matrix.mtx` would load) and
//! every distance goes through the O(nnz) scatter/gather kernels.
//!
//!     cargo run --release --example scrna_celltypes
//!
//! Clusters zero-inflated log-normal expression profiles (11 cell types)
//! through the `Fit` facade, reports the medoid "marker profiles", cluster
//! purity against the generating cell types, the evaluation savings vs
//! PAM, a parity check against the same data densified (identical
//! medoids), a **model round trip** (save -> load -> predict, bitwise
//! equal to the training assignments, training data not required), and an
//! out-of-core leg: the cells round-trip through a Matrix Market file via
//! the chunked streaming reader, bitwise-identical to in-memory.

use banditpam::prelude::*;

fn main() -> anyhow::Result<()> {
    let n = 1500;
    let genes = 1024;
    let k = 11;
    let seed = 2024u64;
    let data = synthetic::scrna_sparse(&mut Rng::seed_from(seed), n, genes, 0.10);
    let Points::Sparse(csr) = &data.points else { unreachable!() };
    println!(
        "dataset: {} (metric = l1, k = {k}, nnz = {}, density = {:.2}%)",
        data.name,
        csr.nnz(),
        100.0 * csr.density()
    );

    let threads = banditpam::experiments::harness::default_threads();
    let model = Fit::banditpam()
        .metric(Metric::L1)
        .threads(threads)
        .seed(seed)
        .k(k)
        .fit(&data)?;
    let fit = model.clustering();

    println!(
        "\nBanditPAM (sparse): loss {:.1}, {} distance evals, {} swap iters",
        fit.loss, fit.stats.distance_evals, fit.stats.swap_iters
    );

    // Medoid expression summaries ("marker profiles") straight off the CSR.
    println!("\nmedoid cells (expressed genes / strongest expression):");
    for (pos, &med) in fit.medoids.iter().enumerate() {
        let (_, values) = csr.row(med);
        let expressed = values.len();
        let maxv = values.iter().copied().fold(0.0f32, f32::max);
        let members = fit.assignments.iter().filter(|&&a| a == pos).count();
        println!(
            "  medoid {med:>5}: {members:>4} cells, {expressed:>4}/{genes} genes expressed, max {maxv:.2}"
        );
    }

    // Purity against the generating cell types.
    if let Some(labels) = &data.labels {
        let mut majority = vec![std::collections::HashMap::new(); k];
        for (i, &a) in fit.assignments.iter().enumerate() {
            *majority[a].entry(labels[i]).or_insert(0usize) += 1;
        }
        let pure: usize = majority
            .iter()
            .map(|m| m.values().max().copied().unwrap_or(0))
            .sum();
        println!(
            "\ncell-type purity: {:.1}%",
            100.0 * pure as f64 / data.len() as f64
        );
    }

    // Parity: the exact same cells densified, fit through the same facade
    // with the same seed, must give the same medoids — the CSR path
    // changes the arithmetic, not the search.
    let densified = data.to_dense().expect("dense twin");
    let dense_model = Fit::banditpam()
        .metric(Metric::L1)
        .threads(threads)
        .seed(seed)
        .k(k)
        .fit(&densified)?;
    println!(
        "\ndensified parity : medoids {} (loss ratio {:.6})",
        if dense_model.clustering().medoids == fit.medoids { "identical" } else { "DIFFER" },
        fit.loss / dense_model.loss()
    );

    // PAM reference for the savings claim (also on the sparse path).
    let pam_model = Fit::fastpam1()
        .metric(Metric::L1)
        .threads(threads)
        .seed(0)
        .k(k)
        .fit(&data)?;
    let pam = pam_model.clustering();
    println!(
        "vs PAM/FastPAM1 : loss ratio {:.4}, {:.1}x fewer distance evals",
        fit.loss / pam.loss,
        pam.stats.distance_evals as f64 / fit.stats.distance_evals as f64
    );

    // Model round trip: the fitted medoid set is a serving artifact — it
    // saves to the versioned binary format, reloads without the training
    // data, and re-assigns the training cells bitwise-identically.
    let model_path = std::env::temp_dir().join(format!(
        "banditpam_scrna_model_{}.bpmodel",
        std::process::id()
    ));
    model.save(&model_path)?;
    let served = KMedoidsModel::load(&model_path)?.with_threads(threads);
    let re_assign = served.predict(&data.points)?;
    assert_eq!(
        re_assign, fit.assignments,
        "reloaded model must reproduce the training assignments bitwise"
    );
    println!(
        "\nmodel round trip: {} bytes, predict(train) == training assignments",
        std::fs::metadata(&model_path)?.len()
    );
    let _ = std::fs::remove_file(&model_path);

    // Out-of-core parity: the same cells written to a Matrix Market file
    // and streamed back through bounded row-windows (as a real 68k-cell
    // 10x matrix would be) load bitwise-identically to in-memory.
    let mtx = std::env::temp_dir().join(format!(
        "banditpam_scrna_stream_{}.mtx",
        std::process::id()
    ));
    banditpam::data::loader::save_mtx(&data, &mtx)?;
    let opts = banditpam::data::stream::StreamOptions {
        chunk_nnz: (csr.nnz() / 10).max(1),
        ..Default::default()
    };
    let (streamed, stats) = banditpam::data::stream::load_mtx_streamed(&mtx, &opts)?;
    let Points::Sparse(streamed_csr) = &streamed.points else { unreachable!() };
    println!(
        "out-of-core     : {} windows, peak window {} nnz ({:.1}% of total) -> {}",
        stats.windows,
        stats.peak_window_nnz,
        100.0 * stats.peak_window_nnz as f64 / csr.nnz() as f64,
        if streamed_csr == csr { "bitwise identical" } else { "MISMATCH" }
    );
    assert_eq!(streamed_csr, csr, "streamed load must match in-memory bitwise");
    let _ = std::fs::remove_file(&mtx);
    Ok(())
}
