//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//!     make artifacts && cargo run --release --example mnist_clustering
//!
//! This is the repository's composition proof (DESIGN.md "End-to-end
//! validation"): an MNIST-scale workload (2,000 x 784 MNIST-like images)
//! is clustered with BanditPAM **twice** —
//!
//!   1. through the **XLA backend**: every distance block executes the
//!      Pallas pairwise-l2 kernel that was written in Python (L1), wrapped
//!      by the JAX graph (L2), AOT-lowered to HLO text by `make artifacts`,
//!      and compiled/executed here via the PJRT C API — Python is not
//!      running anywhere in this process;
//!   2. through the **native backend**, via the `Fit` facade (which also
//!      yields a `KMedoidsModel` serving out-of-sample assignment).
//!
//! The two runs must produce identical medoids (same RNG seed, same
//! algorithm, numerics agree to fp32 tolerance), and both must match exact
//! PAM (FastPAM1). The headline metrics (distance-evaluation reduction,
//! wall-clock) are printed and recorded in EXPERIMENTS.md.

use banditpam::prelude::*;
use banditpam::runtime::executable::Client;
use banditpam::runtime::manifest::Manifest;
use banditpam::runtime::xla_backend::XlaBackend;

/// BanditPAM through the AOT XLA path. Fails (and the caller downgrades to
/// a skip) when the `xla` feature or the HLO artifacts are unavailable,
/// e.g. in offline CI smoke runs. The XLA backend has no facade entry —
/// it is exercised through the low-level `KMedoids` interface, which
/// remains fully public.
fn fit_via_xla(data: &Dataset, k: usize) -> anyhow::Result<Clustering> {
    let client = Client::cpu()?;
    println!("PJRT platform: {}", client.platform());
    let xla = XlaBackend::new(&client, &Manifest::default_dir(), &data.points, Metric::L2)?;
    println!(
        "artifact: {} (tile {}x{}x{})",
        xla.artifact().name,
        xla.artifact().t,
        xla.artifact().r,
        xla.artifact().d
    );
    let mut algo = BanditPam::new(BanditPamConfig::default());
    let t0 = std::time::Instant::now();
    let fit = algo.fit(&xla, k, &mut Rng::seed_from(99))?;
    let xla_secs = t0.elapsed().as_secs_f64();
    println!(
        "\n[xla   ] medoids {:?}  loss {:.2}  evals {}  PJRT executions {}  {:.2}s",
        fit.medoids,
        fit.loss,
        fit.stats.distance_evals,
        xla.executions(),
        xla_secs
    );
    Ok(fit)
}

fn main() -> anyhow::Result<()> {
    let n = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(2000usize);
    let k = 5;
    let mut rng = Rng::seed_from(123);
    let data = synthetic::mnist_like(&mut rng, n);
    println!("dataset: {} (d = 784, k = {k})", data.name);

    // --- Layer 3 over the AOT XLA path -----------------------------------
    let fit_xla = match fit_via_xla(&data, k) {
        Ok(fit) => Some(fit),
        Err(e) => {
            println!("[xla   ] skipped ({e})");
            None
        }
    };

    // --- Same fit through the native kernels, via the facade --------------
    let threads = banditpam::experiments::harness::default_threads();
    let t0 = std::time::Instant::now();
    let model = Fit::banditpam()
        .metric(Metric::L2)
        .threads(threads)
        .seed(99)
        .k(k)
        .fit(&data)?;
    let native_secs = t0.elapsed().as_secs_f64();
    let fit_native = model.clustering();
    println!(
        "[native] medoids {:?}  loss {:.2}  evals {}  {:.2}s",
        fit_native.medoids, fit_native.loss, fit_native.stats.distance_evals, native_secs
    );

    if let Some(fit_xla) = &fit_xla {
        anyhow::ensure!(
            fit_xla.medoids == fit_native.medoids,
            "XLA and native backends disagree: {:?} vs {:?}",
            fit_xla.medoids,
            fit_native.medoids
        );
        println!("\nXLA == native medoids: YES (three-layer stack composes)");
    }

    // The fitted model serves assignment without the training set.
    let probes = synthetic::mnist_like(&mut Rng::seed_from(321), 64);
    let (assign, dists) = model.predict_with_dists(&probes.points)?;
    println!(
        "out-of-sample : 64 probe images assigned (mean distance {:.2})",
        dists.iter().sum::<f64>() / assign.len() as f64
    );

    // --- Exact PAM reference ----------------------------------------------
    let pam_model = Fit::fastpam1()
        .metric(Metric::L2)
        .threads(threads)
        .seed(0)
        .k(k)
        .fit(&data)?;
    let pam = pam_model.clustering();
    println!(
        "[pam   ] medoids {:?}  loss {:.2}  evals {}",
        pam.medoids, pam.loss, pam.stats.distance_evals
    );
    println!(
        "\nBanditPAM == PAM medoids: {}",
        if fit_native.medoids == pam.medoids { "YES" } else { "no (loss ratio below)" }
    );
    println!("loss ratio vs PAM : {:.5}", fit_native.loss / pam.loss);
    // Paper accounting (§5.2): per-iteration evals vs the analytic
    // PAM (k n^2) / FastPAM1 (n^2) reference lines.
    let per_iter = fit_native.stats.evals_per_iter();
    println!(
        "evals/iteration   : {:.0} (PAM ref {}, FastPAM1 ref {})",
        per_iter,
        k * n * n,
        n * n
    );
    println!(
        "vs PAM            : {:.1}x fewer evals per iteration",
        (k * n * n) as f64 / per_iter
    );
    Ok(())
}
