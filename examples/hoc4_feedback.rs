//! Scalable student feedback on HOC4-like programming submissions — the
//! paper's education use case (Broader Impact: "instructors can choose to
//! provide feedback on just the *medoids* of submitted solutions ...
//! refer individual students to the feedback provided for their closest
//! medoid").
//!
//!     cargo run --release --example hoc4_feedback
//!
//! Clusters block-language ASTs under Zhang–Shasha tree edit distance
//! (an exotic metric no vectorized library handles — exactly where
//! k-medoids beats k-means), prints the medoid programs an instructor
//! would annotate, and shows how many students each annotation reaches.

use banditpam::data::Points;
use banditpam::prelude::*;

fn main() -> anyhow::Result<()> {
    let n = 600;
    let k = 4;
    let data = synthetic::hoc4_like(&mut Rng::seed_from(31337), n);
    println!("dataset: {} (metric = tree edit distance, k = {k})", data.name);

    // Tree edit distance works through the same facade as the vector
    // metrics — the model owns the k medoid ASTs (cloned), so feedback
    // routing keeps working after the submission corpus is dropped. (Tree
    // models are the one kind without an on-disk format.)
    let threads = banditpam::experiments::harness::default_threads();
    let model = Fit::banditpam()
        .metric(Metric::TreeEdit)
        .threads(threads)
        .seed(31337)
        .k(k)
        .fit(&data)?;
    let fit = model.clustering();

    println!(
        "\nBanditPAM: loss {:.1}, {} tree-edit evaluations ({} swap iters)",
        fit.loss, fit.stats.distance_evals, fit.stats.swap_iters
    );
    println!(
        "exhaustive PAM would need ~{} evaluations per SWAP iteration alone",
        k * n * n
    );

    if let Points::Trees(trees) = &data.points {
        println!("\nmedoid submissions (annotate these {k} programs):");
        for (pos, &med) in fit.medoids.iter().enumerate() {
            let members = fit.assignments.iter().filter(|&&a| a == pos).count();
            println!(
                "\n  medoid #{pos} — submission {med}, reaches {members} students \
                 ({:.1}% of class):",
                100.0 * members as f64 / n as f64
            );
            println!("    {}", trees[med].render());
        }

        // Feedback routing: each student's distance to their medoid.
        let mut worst = (0.0f64, 0usize);
        let mut total = 0.0;
        for (i, &a) in fit.assignments.iter().enumerate() {
            let d = banditpam::distance::evaluate(
                Metric::TreeEdit,
                &data.points,
                i,
                fit.medoids[a],
            );
            total += d;
            if d > worst.0 {
                worst = (d, i);
            }
        }
        println!(
            "\nmean edits from assigned medoid: {:.2}; farthest student is \
             submission {} at {} edits",
            total / n as f64,
            worst.1,
            worst.0
        );
        println!("farthest submission: {}", trees[worst.1].render());
    }

    // New submissions arrive after the annotations were written: the model
    // routes them to the existing medoid feedback without refitting.
    let late = synthetic::hoc4_like(&mut Rng::seed_from(777), 25);
    let (routed, edits) = model.predict_with_dists(&late.points)?;
    println!(
        "\n25 late submissions routed to existing feedback (mean {:.1} edits \
         from their medoid)",
        edits.iter().sum::<f64>() / routed.len() as f64
    );
    Ok(())
}
