//! Sparse (CSR) subsystem micro-bench: one-to-many row-kernel throughput
//! sparse vs densified at scRNA-like density, plus an end-to-end fit
//! parity check. Emits `BENCH_sparse.json` for CI.
//!
//! Acceptance target (ISSUE 3): >= 3x block throughput vs the same data
//! densified, at density <= 0.1. The kernels stream O(nnz) per pair
//! instead of O(d), so the expected headroom at density ~0.08 is ~d/nnz
//! ~ 10x minus scatter/format overhead.

use banditpam::bench::bench_fn;
use banditpam::bench::report::{JsonObj, Report};
use banditpam::data::synthetic;
use banditpam::prelude::*;
use banditpam::util::timer::Timer;

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let iters = scale.pick(3, 10, 20);
    println!("== sparse benches ({scale:?}, {iters} iters) ==");

    // --- block throughput: sparse vs densified ----------------------------
    let n = scale.pick(1_200, 4_000, 8_000);
    let genes = 1024;
    let sp = synthetic::scrna_sparse(&mut Rng::seed_from(42), n, genes, 0.10);
    let dn = sp.to_dense().expect("densify");
    let Points::Sparse(csr) = &sp.points else { unreachable!() };
    let density = csr.density();
    println!(
        "dataset: {} nnz={} density={:.4} (d={genes})",
        sp.name,
        csr.nnz(),
        density
    );

    let targets: Vec<usize> = (0..64).collect();
    let refs: Vec<usize> = (64..n.min(64 + 2048)).collect();
    let rn = refs.len();
    let mut out = vec![0.0f64; targets.len() * rn];
    let mut report = Report::new("sparse")
        .scale(scale)
        .params(JsonObj::new().u64("n", n as u64).u64("d", genes as u64).f64("density", density));
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        for threads in [1usize, 4] {
            let dense_backend = NativeBackend::new(&dn.points, metric).with_threads(threads);
            let base = bench_fn(
                &format!("block 64x{rn} {metric} dense threads={threads}"),
                1,
                iters,
                || dense_backend.block(&targets, &refs, &mut out),
            );
            println!("{}", base.line());
            let sparse_backend = NativeBackend::new(&sp.points, metric).with_threads(threads);
            let r = bench_fn(
                &format!("block 64x{rn} {metric} sparse threads={threads}"),
                1,
                iters,
                || sparse_backend.block(&targets, &refs, &mut out),
            );
            println!("{}", r.line());
            let speedup = base.mean_secs / r.mean_secs.max(1e-12);
            println!("    -> {speedup:.2}x vs densified input");
            report.row(
                JsonObj::new()
                    .str("kind", "block")
                    .str("metric", &metric.to_string())
                    .u64("threads", threads as u64)
                    .u64("n", n as u64)
                    .u64("d", genes as u64)
                    .f64("density", density)
                    .f64("dense_secs", base.mean_secs)
                    .f64("sparse_secs", r.mean_secs)
                    .f64("speedup", speedup),
            );
        }
    }

    // --- end-to-end fit parity (sparse vs densified, same seed) -----------
    let nf = scale.pick(300, 1000, 2000);
    let k = 5;
    let genes_fit = scale.pick(256, 512, 1024);
    let sp_fit = synthetic::scrna_sparse(&mut Rng::seed_from(7), nf, genes_fit, 0.10);
    let dn_fit = sp_fit.to_dense().expect("densify");
    let mut results = Vec::new();
    for (name, points) in [("sparse", &sp_fit.points), ("dense", &dn_fit.points)] {
        let backend = NativeBackend::new(points, Metric::L1).with_threads(4);
        let t = Timer::start();
        let fit = BanditPam::new(BanditPamConfig::default())
            .fit(&backend, k, &mut Rng::seed_from(9))
            .expect("fit");
        let secs = t.secs();
        println!(
            "fit {name:>6}: n={nf} k={k} loss={:.3} evals={} {:.3}s",
            fit.loss, fit.stats.distance_evals, secs
        );
        report.row(
            JsonObj::new()
                .str("kind", "fit")
                .str("storage", name)
                .u64("n", nf as u64)
                .u64("k", k as u64)
                .f64("loss", fit.loss)
                .u64("evals", fit.stats.distance_evals)
                .f64("wall_secs", secs),
        );
        results.push(fit);
    }
    let parity = results[0].medoids == results[1].medoids;
    println!(
        "medoid parity sparse vs densified: {}",
        if parity { "identical" } else { "MISMATCH" }
    );
    assert!(parity, "sparse and densified fits must return identical medoids");

    let _ = report.write();
}
