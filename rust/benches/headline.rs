//! Bench target for paper experiment `headline` (see DESIGN.md experiment
//! index). Scale via BANDITPAM_BENCH_SCALE=smoke|quick|paper (default
//! quick). Prints the same rows the paper's figure plots and emits them
//! as `BENCH_headline.json` in the unified envelope (rust/OBS.md).

use banditpam::bench::report::Report;

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let t0 = std::time::Instant::now();
    let mut report = Report::new("headline").scale(scale);
    for table in banditpam::experiments::run("headline", scale, 42).expect("experiment failed") {
        table.print();
        report.table(&table);
    }
    let _ = report.write();
    println!("\n[headline] total {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
