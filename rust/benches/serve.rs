//! Serve load generator: an in-process server (over in-memory pipes,
//! exactly the code path a socket uses) hammered by concurrent client
//! threads with mixed dense/sparse traffic and a deterministic
//! fault-injection fraction. Emits `BENCH_serve.json` (unified envelope,
//! rust/OBS.md) with client-observed p50/p99 latency, throughput and shed
//! rate per scenario, plus server-side admission->reply quantiles from
//! the `serve_request_us` histogram delta each scenario leaves behind.
//!
//! Acceptance (ISSUE 6): the server survives the full fault schedule —
//! every request gets exactly one typed response, healthy responses are
//! bitwise-identical to single-shot `predict`, and the final drain is
//! clean. Scale via BANDITPAM_BENCH_SCALE=smoke|quick|paper.

use banditpam::bench::report::{JsonObj, Report};
use banditpam::data::synthetic;
use banditpam::model::{Fit, KMedoidsModel};
use banditpam::obs::HistogramSnapshot;
use banditpam::serve::faults::{pipe, FaultPlan, PipeReader, PipeWriter};
use banditpam::serve::protocol::{
    encode_request, parse_response, read_frame, ErrorCode, PredictRequest, Request,
    Response,
};
use banditpam::serve::{AdmissionConfig, Registry, ServeOptions, Server};
use banditpam::stats::summary::quantile;
use banditpam::util::rng::Rng;
use std::collections::BTreeMap;
use std::io::Write;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

struct Client {
    w: Option<PipeWriter>,
    r: PipeReader,
    conn: Option<thread::JoinHandle<()>>,
}

impl Client {
    fn connect(server: &Arc<Server>) -> Client {
        let (cw, sr) = pipe();
        let (sw, cr) = pipe();
        let server = Arc::clone(server);
        let conn = thread::spawn(move || server.handle_connection(sr, sw));
        Client { w: Some(cw), r: cr, conn: Some(conn) }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        drop(self.w.take());
        if let Some(h) = self.conn.take() {
            h.join().ok();
        }
    }
}

struct ScenarioResult {
    name: String,
    requests: usize,
    ok: usize,
    shed: usize,
    errors: usize,
    p50_ms: f64,
    p99_ms: f64,
    throughput_rps: f64,
    wall_secs: f64,
}

impl ScenarioResult {
    /// One `data` row: the client-observed fields plus the server-side
    /// admission->reply quantiles from the scenario's `serve_request_us`
    /// histogram delta (micros; log2-bucket upper edges).
    fn row(&self, server_lat: &HistogramSnapshot) -> JsonObj {
        JsonObj::new()
            .str("scenario", &self.name)
            .u64("requests", self.requests as u64)
            .u64("ok", self.ok as u64)
            .u64("shed", self.shed as u64)
            .u64("errors", self.errors as u64)
            .f64("p50_ms", self.p50_ms)
            .f64("p99_ms", self.p99_ms)
            .f64("throughput_rps", self.throughput_rps)
            .f64("shed_rate", self.shed as f64 / self.requests.max(1) as f64)
            .f64("wall_secs", self.wall_secs)
            .u64("server_p50_us", server_lat.quantile(0.50))
            .u64("server_p99_us", server_lat.quantile(0.99))
            .f64("server_mean_us", server_lat.mean())
            .u64("server_count", server_lat.count)
    }

    fn line(&self) -> String {
        format!(
            "{:<28} {:>6} reqs  p50 {:>8.3} ms  p99 {:>8.3} ms  {:>9.1} req/s  \
             shed {:>5.1}%  err {}",
            self.name,
            self.requests,
            self.p50_ms,
            self.p99_ms,
            self.throughput_rps,
            100.0 * self.shed as f64 / self.requests.max(1) as f64,
            self.errors
        )
    }
}

/// One worker: `reqs` sequential request/response round trips on its own
/// connection. Every `fault_every`-th request (if nonzero) is a
/// deliberately corrupted frame whose typed rejection also counts as a
/// measured round trip. Returns (latencies_ms, ok, shed, errors).
#[allow(clippy::too_many_arguments)]
fn worker(
    server: Arc<Server>,
    reference: Arc<BTreeMap<String, KMedoidsModel>>,
    worker_id: u64,
    reqs: usize,
    fault_every: usize,
    sparse_share: usize,
) -> (Vec<f64>, usize, usize, usize) {
    let mut c = Client::connect(&server);
    let mut lat = Vec::with_capacity(reqs);
    let (mut ok, mut shed, mut errors) = (0usize, 0usize, 0usize);
    for i in 0..reqs {
        let sparse = sparse_share > 0 && i % sparse_share == 0;
        let (model, queries) = if sparse {
            let q = synthetic::scrna_like(
                &mut Rng::seed_from(worker_id * 10_000 + i as u64),
                1 + i % 8,
                24,
            )
            .to_sparse()
            .unwrap()
            .points;
            ("cells", q)
        } else {
            let q = synthetic::gmm(
                &mut Rng::seed_from(worker_id * 10_000 + i as u64),
                1 + i % 8,
                6,
                3,
                3.0,
            )
            .points;
            ("gmm", q)
        };
        let req = Request::Predict(PredictRequest {
            id: i as u64,
            model: model.into(),
            deadline_ms: 0,
            queries: queries.clone(),
        });
        let mut frame = encode_request(&req);
        let faulty = fault_every > 0 && i % fault_every == fault_every - 1;
        if faulty {
            // well-framed but body-corrupt (trailing byte past the
            // grammar): the server must answer BadRequest with the
            // echoed id and keep the connection alive
            let body_len = (frame.len() - 8 + 1) as u32;
            frame[4..8].copy_from_slice(&body_len.to_le_bytes());
            frame.push(0);
        }
        let t0 = Instant::now();
        c.w.as_mut().unwrap().write_all(&frame).unwrap();
        let (kind, body) = read_frame(&mut c.r).unwrap().expect("server hung up");
        let resp = parse_response(kind, &body).unwrap();
        lat.push(t0.elapsed().as_secs_f64() * 1e3);
        match resp {
            Response::Assignments { id, assign, dists } => {
                assert_eq!(id, i as u64);
                assert!(!faulty, "a corrupted frame must not produce assignments");
                let (want_a, want_d) =
                    reference[model].predict_with_dists(&queries).unwrap();
                let want_a: Vec<u32> = want_a.iter().map(|&a| a as u32).collect();
                assert_eq!(assign, want_a, "serving must match single-shot predict");
                assert!(
                    dists
                        .iter()
                        .zip(&want_d)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "distances must be bitwise-identical"
                );
                ok += 1;
            }
            Response::Error { code: ErrorCode::Overloaded, .. } => shed += 1,
            Response::Error { .. } => errors += 1,
            other => panic!("unexpected response {other:?}"),
        }
    }
    (lat, ok, shed, errors)
}

fn run_scenario(
    name: &str,
    server: &Arc<Server>,
    reference: &Arc<BTreeMap<String, KMedoidsModel>>,
    clients: usize,
    reqs_per_client: usize,
    fault_every: usize,
    sparse_share: usize,
) -> ScenarioResult {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|w| {
            let server = Arc::clone(server);
            let reference = Arc::clone(reference);
            thread::spawn(move || {
                worker(server, reference, w as u64, reqs_per_client, fault_every, sparse_share)
            })
        })
        .collect();
    let mut lat = Vec::new();
    let (mut ok, mut shed, mut errors) = (0, 0, 0);
    for h in handles {
        let (l, o, s, e) = h.join().expect("worker panicked");
        lat.extend(l);
        ok += o;
        shed += s;
        errors += e;
    }
    let wall = t0.elapsed().as_secs_f64();
    let requests = clients * reqs_per_client;
    assert_eq!(ok + shed + errors, requests, "every request answered exactly once");
    ScenarioResult {
        name: name.to_string(),
        requests,
        ok,
        shed,
        errors,
        p50_ms: quantile(&lat, 0.50),
        p99_ms: quantile(&lat, 0.99),
        throughput_rps: requests as f64 / wall.max(1e-9),
        wall_secs: wall,
    }
}

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let clients = scale.pick(2, 4, 8);
    let reqs = scale.pick(40, 200, 1000);
    println!("== serve benches ({scale:?}: {clients} clients x {reqs} reqs) ==");

    // Fit and persist the served models; keep in-memory twins as the
    // bitwise reference.
    let dir = std::env::temp_dir().join(format!("bp_bench_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let gmm_ds = synthetic::gmm(&mut Rng::seed_from(1), 60, 6, 3, 3.0);
    let gmm = Fit::banditpam().k(3).seed(1).fit(&gmm_ds).unwrap();
    gmm.save(&dir.join("gmm.bpmodel")).unwrap();
    let cells_ds = synthetic::scrna_like(&mut Rng::seed_from(2), 60, 24).to_sparse().unwrap();
    let cells = Fit::banditpam().k(3).seed(2).fit(&cells_ds).unwrap();
    cells.save(&dir.join("cells.bpmodel")).unwrap();
    let mut reference = BTreeMap::new();
    reference.insert("gmm".to_string(), gmm);
    reference.insert("cells".to_string(), cells);
    let reference = Arc::new(reference);

    let open_registry = || {
        Registry::open(&[
            ("gmm".into(), dir.join("gmm.bpmodel")),
            ("cells".into(), dir.join("cells.bpmodel")),
        ])
        .expect("registry")
    };

    // Server-side latency: scenarios run in one process, so each one's
    // contribution is the delta between `serve_request_us` snapshots
    // taken around it.
    let request_hist = banditpam::obs::global().histogram("serve_request_us");
    let mut results: Vec<(ScenarioResult, HistogramSnapshot)> = Vec::new();

    // --- healthy load: mixed dense/sparse, no faults --------------------
    {
        let before = request_hist.snapshot();
        let server = Server::new(
            open_registry(),
            ServeOptions { threads: 2, ..Default::default() },
        );
        let r = run_scenario("healthy-mixed", &server, &reference, clients, reqs, 0, 4);
        assert_eq!(r.errors, 0, "healthy load must not error");
        assert_eq!(r.shed, 0, "default queue bounds must not shed this load");
        println!("{}", r.line());
        results.push((r, request_hist.snapshot().minus(&before)));
        server.begin_shutdown();
        server.join();
    }

    // --- hostile frames riding along ------------------------------------
    {
        let before = request_hist.snapshot();
        let server = Server::new(
            open_registry(),
            ServeOptions { threads: 2, ..Default::default() },
        );
        // every 5th frame per client is corrupted
        let r = run_scenario("with-corrupt-frames", &server, &reference, clients, reqs, 5, 4);
        assert!(r.errors > 0, "the corrupted frames must surface as typed errors");
        assert_eq!(
            r.errors,
            clients * (reqs / 5),
            "exactly the corrupted frames error"
        );
        println!("{}", r.line());
        results.push((r, request_hist.snapshot().minus(&before)));
        server.begin_shutdown();
        server.join();
    }

    // --- forced batch panics (isolation under fire) ---------------------
    {
        let before = request_hist.snapshot();
        let server = Server::new(
            open_registry(),
            ServeOptions {
                threads: 2,
                // high threshold: panics stay isolated, no quarantine —
                // the quarantine path itself is covered by tests
                admission: AdmissionConfig { quarantine_threshold: u32::MAX, ..Default::default() },
                faults: FaultPlan { panic_every: Some(7), ..Default::default() },
            },
        );
        let r = run_scenario("with-batch-panics", &server, &reference, clients, reqs, 0, 4);
        assert!(r.errors > 0, "the injected panics must surface as Internal errors");
        assert!(r.ok > 0, "non-panicked batches keep serving");
        println!("{}", r.line());
        results.push((r, request_hist.snapshot().minus(&before)));
        server.begin_shutdown();
        server.join();
    }

    // --- tight queue: backpressure under concurrency --------------------
    {
        let before = request_hist.snapshot();
        let server = Server::new(
            open_registry(),
            ServeOptions {
                threads: 1,
                admission: AdmissionConfig {
                    max_queue_requests: 2,
                    max_queue_points: 8,
                    ..Default::default()
                },
                faults: FaultPlan { stall_ms: scale.pick(2, 1, 1), ..Default::default() },
            },
        );
        let r = run_scenario(
            "tight-queue-backpressure",
            &server,
            &reference,
            clients.max(2),
            reqs,
            0,
            4,
        );
        println!("{}", r.line());
        results.push((r, request_hist.snapshot().minus(&before)));
        server.begin_shutdown();
        server.join();
    }

    let mut report = Report::new("serve").scale(scale).params(
        JsonObj::new().u64("clients", clients as u64).u64("reqs_per_client", reqs as u64),
    );
    for (r, server_lat) in &results {
        report.row(r.row(server_lat));
    }
    let _ = report.write();
    std::fs::remove_dir_all(&dir).ok();
    println!("[serve] all scenarios drained cleanly");
}
