//! Dist scaling bench: the sharded scoring path (the dominant cost of
//! CLARA/BigFit evaluation) driven over worker pools of 1/2/4/8 workers,
//! plus the cost of recovering from a deterministic worker kill
//! mid-workload. Workers are real worker loops over the real wire codec
//! (threads speaking through in-memory pipes — the exact socket code
//! path, minus the NIC). Emits `BENCH_dist.json` (unified envelope,
//! rust/OBS.md).
//!
//! Acceptance (ISSUE 10): every sharded result — including the run that
//! loses a worker — is bitwise-identical to the single-process fold, and
//! eval counters match exactly. Scale via BANDITPAM_BENCH_SCALE.

use banditpam::bench::report::{JsonObj, Report};
use banditpam::data::{synthetic, Points};
use banditpam::dist::{run_worker, PoolOptions, WorkerOptions, WorkerPool};
use banditpam::distance::counter::DistanceCounter;
use banditpam::distance::Metric;
use banditpam::runtime::backend::{loss_and_assignments, NativeBackend};
use banditpam::serve::faults::{pipe, FaultPlan};
use banditpam::util::rng::Rng;
use std::io::{Read, Write};
use std::thread;
use std::time::Instant;

/// In-process pool over pipe transports; `plans[i]` injects faults into
/// worker `i`.
fn pipe_pool<'d>(
    points: &'d Points,
    metric: Metric,
    workers: usize,
    plans: &[FaultPlan],
) -> WorkerPool<'d> {
    let mut transports: Vec<(Box<dyn Write + Send>, Box<dyn Read + Send>)> = Vec::new();
    for i in 0..workers {
        let (cw, sr) = pipe();
        let (sw, cr) = pipe();
        let opts =
            WorkerOptions { faults: plans.get(i).cloned().unwrap_or_default(), quiet: true };
        thread::spawn(move || {
            let _ = run_worker(sr, sw, &opts);
        });
        transports.push((Box::new(cw), Box::new(cr)));
    }
    WorkerPool::from_transports(points, metric, transports, PoolOptions::default()).unwrap()
}

/// Run `passes` scoring passes over the pool, asserting every pass is
/// bitwise-identical to the single-process fold with the exact eval
/// count. Returns the wall seconds.
fn timed_scores(
    pool: &WorkerPool<'_>,
    medoids: &Points,
    passes: usize,
    want_loss: f64,
    want_assign: &[usize],
    want_evals: u64,
) -> f64 {
    let t0 = Instant::now();
    for pass in 0..passes {
        let counter = DistanceCounter::default();
        let (loss, assign) = pool.score(medoids, &counter).expect("sharded score");
        assert_eq!(loss.to_bits(), want_loss.to_bits(), "pass {pass}: loss bits drifted");
        assert_eq!(assign, want_assign, "pass {pass}: assignments drifted");
        assert_eq!(counter.get(), want_evals, "pass {pass}: eval count drifted");
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let n = scale.pick(240, 2000, 20_000);
    let dim = scale.pick(8, 32, 64);
    let passes = scale.pick(3, 10, 25);
    let k = 5usize;
    println!("== dist benches ({scale:?}: n={n}, dim={dim}, k={k}, {passes} passes) ==");

    let ds = synthetic::gmm(&mut Rng::seed_from(7), n, dim, k, 3.0);
    let medoid_rows: Vec<usize> = (0..k).map(|i| i * n / k).collect();
    let medoids = ds.points.select(&medoid_rows);
    let want_evals = (k * n) as u64;

    // Single-process reference: result bits and baseline wall time.
    let local = NativeBackend::new(&ds.points, Metric::L2);
    let (want_loss, want_assign) = loss_and_assignments(&local, &medoid_rows);
    let t0 = Instant::now();
    for _ in 0..passes {
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let (l, _) = loss_and_assignments(&b, &medoid_rows);
        assert_eq!(l.to_bits(), want_loss.to_bits());
    }
    let local_secs = t0.elapsed().as_secs_f64();
    println!("{:<24} {:>8.3}s  ({} passes)", "single-process", local_secs, passes);

    let mut report = Report::new("dist").scale(scale).params(
        JsonObj::new()
            .u64("n", n as u64)
            .u64("dim", dim as u64)
            .u64("k", k as u64)
            .u64("passes", passes as u64),
    );
    report.row(
        JsonObj::new()
            .str("scenario", "single-process")
            .u64("workers", 0)
            .f64("wall_secs", local_secs)
            .f64("passes_per_sec", passes as f64 / local_secs.max(1e-9))
            .bool("bitwise_ok", true),
    );

    // --- scaling: 1/2/4/8 workers over the wire -------------------------
    let mut one_worker_secs = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let pool = pipe_pool(&ds.points, Metric::L2, workers, &[]);
        let secs =
            timed_scores(&pool, &medoids, passes, want_loss, &want_assign, want_evals);
        if workers == 1 {
            one_worker_secs = secs;
        }
        let speedup = one_worker_secs / secs.max(1e-9);
        println!(
            "{:<24} {:>8.3}s  speedup vs 1 worker {:>5.2}x  retries {}",
            format!("{workers} worker(s)"),
            secs,
            speedup,
            pool.retries()
        );
        report.row(
            JsonObj::new()
                .str("scenario", "scaling")
                .u64("workers", workers as u64)
                .f64("wall_secs", secs)
                .f64("passes_per_sec", passes as f64 / secs.max(1e-9))
                .f64("speedup_vs_one_worker", speedup)
                .f64("overhead_vs_local", secs / local_secs.max(1e-9))
                .u64("retries", pool.retries())
                .bool("bitwise_ok", true),
        );
    }

    // --- worker-kill recovery cost --------------------------------------
    // Same 2-worker workload twice: healthy, then with worker 0 killed
    // deterministically on its 2nd work request. The kill costs one
    // deadline-free detection + shard reassignment; results stay bitwise
    // identical.
    let healthy = pipe_pool(&ds.points, Metric::L2, 2, &[]);
    let healthy_secs =
        timed_scores(&healthy, &medoids, passes, want_loss, &want_assign, want_evals);
    let plans = vec![
        FaultPlan { panic_on_batches: vec![2], ..Default::default() },
        FaultPlan::default(),
    ];
    let wounded = pipe_pool(&ds.points, Metric::L2, 2, &plans);
    let wounded_secs =
        timed_scores(&wounded, &medoids, passes, want_loss, &want_assign, want_evals);
    assert!(wounded.respawns() >= 1, "the injected kill must have been recovered");
    println!(
        "{:<24} {:>8.3}s  healthy {:>8.3}s  recovery overhead {:>5.2}x  respawns {}",
        "2 workers + kill",
        wounded_secs,
        healthy_secs,
        wounded_secs / healthy_secs.max(1e-9),
        wounded.respawns()
    );
    report.row(
        JsonObj::new()
            .str("scenario", "worker-kill-recovery")
            .u64("workers", 2)
            .f64("wall_secs", wounded_secs)
            .f64("healthy_wall_secs", healthy_secs)
            .f64("recovery_overhead", wounded_secs / healthy_secs.max(1e-9))
            .u64("respawns", wounded.respawns())
            .u64("retries", wounded.retries())
            .bool("bitwise_ok", true),
    );

    let _ = report.write();
    println!("[dist] all scenarios bitwise-identical to single-process");
}
