//! Micro-benchmarks of the hot paths (mini-criterion: warmup + repeats,
//! mean ± 95% CI). These are the numbers the §Perf optimization loop in
//! EXPERIMENTS.md tracks.

use banditpam::bench::bench_fn;
use banditpam::coordinator::state::MedoidState;
use banditpam::data::synthetic;
use banditpam::distance::{dense, tree_edit, Metric};
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::util::rng::Rng;

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let iters = scale.pick(3, 20, 50);
    println!("== micro benches ({scale:?}, {iters} iters) ==");

    // --- dense distance kernels -------------------------------------------
    let mut rng = Rng::seed_from(1);
    let a: Vec<f32> = (0..784).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..784).map(|_| rng.normal() as f32).collect();
    for (name, f) in [
        ("dense::l2 d=784", dense::l2 as fn(&[f32], &[f32]) -> f64),
        ("dense::l1 d=784", dense::l1),
        ("dense::cosine d=784", dense::cosine),
    ] {
        let r = bench_fn(name, 100, 10_000.min(iters * 500), || f(&a, &b));
        println!("{}", r.line());
    }

    // --- distance block (the batched arm pull shape) ----------------------
    let ds = synthetic::mnist_like(&mut Rng::seed_from(2), 600);
    let targets: Vec<usize> = (0..64).collect();
    let refs: Vec<usize> = (64..192).collect();
    let mut out = vec![0.0f64; targets.len() * refs.len()];
    for threads in [1usize, 4] {
        let backend = NativeBackend::new(&ds.points, Metric::L2).with_threads(threads);
        let r = bench_fn(
            &format!("native block 64x128 d=784 threads={threads}"),
            2,
            iters,
            || backend.block(&targets, &refs, &mut out),
        );
        println!("{}", r.line());
    }

    // --- tree edit distance ------------------------------------------------
    let trees = synthetic::hoc4_like(&mut Rng::seed_from(3), 50);
    if let banditpam::data::Points::Trees(ts) = &trees.points {
        let r = bench_fn("tree_edit::ted (hoc4 pair)", 10, iters * 50, || {
            tree_edit::ted(&ts[0], &ts[1])
        });
        println!("{}", r.line());
    }

    // --- one full BUILD step (Algorithm 1 call) ----------------------------
    let ds = synthetic::mnist_like(&mut Rng::seed_from(4), scale.pick(200, 1000, 2000));
    let r = bench_fn("BUILD step via Algorithm 1", 1, iters.min(10), || {
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(ds.len());
        banditpam::coordinator::build::build_step(
            &backend,
            &mut state,
            &banditpam::coordinator::config::BanditPamConfig::default(),
            &mut Rng::seed_from(5),
        )
    });
    println!("{}", r.line());

    // --- XLA vs native block (needs artifacts) ------------------------------
    let dir = banditpam::runtime::manifest::Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        match banditpam::runtime::executable::Client::cpu() {
            Ok(client) => {
                let xla = banditpam::runtime::xla_backend::XlaBackend::new(
                    &client,
                    &dir,
                    &ds.points,
                    Metric::L2,
                )
                .expect("xla backend");
                let targets: Vec<usize> = (0..64).collect();
                let refs: Vec<usize> = (64..192).collect();
                let mut out = vec![0.0f64; targets.len() * refs.len()];
                let r = bench_fn("xla block 64x128 d=784 (interpret HLO)", 1, iters.min(10), || {
                    xla.block(&targets, &refs, &mut out)
                });
                println!("{}", r.line());
            }
            Err(e) => println!("xla block: skipped ({e})"),
        }
    } else {
        println!("xla block: skipped (no artifacts; run `make artifacts`)");
    }
}
