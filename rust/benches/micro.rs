//! Micro-benchmarks of the hot paths (mini-criterion: warmup + repeats,
//! mean ± 95% CI). These are the numbers the §Perf optimization loop in
//! EXPERIMENTS.md tracks.

use banditpam::algorithms::KMedoids;
use banditpam::bench::bench_fn;
use banditpam::bench::report::{JsonObj, Report};
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::coordinator::config::BanditPamConfig;
use banditpam::coordinator::state::MedoidState;
use banditpam::data::synthetic;
use banditpam::distance::{dense, tree_edit, Metric};
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::util::rng::Rng;
use banditpam::util::timer::Timer;

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let iters = scale.pick(3, 20, 50);
    println!("== micro benches ({scale:?}, {iters} iters) ==");

    // --- dense distance kernels -------------------------------------------
    let mut rng = Rng::seed_from(1);
    let a: Vec<f32> = (0..784).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..784).map(|_| rng.normal() as f32).collect();
    for (name, f) in [
        ("dense::l2 d=784", dense::l2 as fn(&[f32], &[f32]) -> f64),
        ("dense::l1 d=784", dense::l1),
        ("dense::cosine d=784", dense::cosine),
    ] {
        let r = bench_fn(name, 100, 10_000.min(iters * 500), || f(&a, &b));
        println!("{}", r.line());
    }

    // --- distance block throughput (the batched arm pull shape) -----------
    //
    // Baseline "per-pair dispatch" reproduces the seed's block inner loop:
    // per-pair enum dispatch through `evaluate` plus one counter bump per
    // distance. The pooled rows are the current hot path (PERF.md); the
    // acceptance target is >= 2x at threads=4 for dense L2/cosine and no
    // regression at threads=1.
    let nblk = scale.pick(1_000, 4_000, 10_000);
    let ds = synthetic::mnist_like(&mut Rng::seed_from(2), nblk);
    let targets: Vec<usize> = (0..64).collect();
    let refs: Vec<usize> = (64..nblk.min(64 + 2048)).collect();
    let rn = refs.len();
    let mut out = vec![0.0f64; targets.len() * rn];
    let counter = banditpam::distance::counter::DistanceCounter::new();
    for metric in [Metric::L2, Metric::Cosine] {
        let base = bench_fn(
            &format!("block 64x{rn} d=784 {metric} per-pair dispatch"),
            1,
            iters.min(10),
            || {
                for (ti, &t) in targets.iter().enumerate() {
                    for (ri, &r) in refs.iter().enumerate() {
                        counter.add(1);
                        out[ti * rn + ri] =
                            banditpam::distance::evaluate(metric, &ds.points, t, r);
                    }
                }
            },
        );
        println!("{}", base.line());
        for threads in [1usize, 4] {
            let backend = NativeBackend::new(&ds.points, metric).with_threads(threads);
            let r = bench_fn(
                &format!("block 64x{rn} d=784 {metric} pooled threads={threads}"),
                1,
                iters.min(10),
                || backend.block(&targets, &refs, &mut out),
            );
            println!("{}", r.line());
            println!(
                "    -> {:.2}x vs per-pair dispatch",
                base.mean_secs / r.mean_secs.max(1e-12)
            );
        }
    }

    // --- tree edit distance ------------------------------------------------
    let trees = synthetic::hoc4_like(&mut Rng::seed_from(3), 50);
    if let banditpam::data::Points::Trees(ts) = &trees.points {
        let r = bench_fn("tree_edit::ted (hoc4 pair)", 10, iters * 50, || {
            tree_edit::ted(&ts[0], &ts[1])
        });
        println!("{}", r.line());
    }

    // --- one full BUILD step (Algorithm 1 call) ----------------------------
    let ds = synthetic::mnist_like(&mut Rng::seed_from(4), scale.pick(200, 1000, 2000));
    let r = bench_fn("BUILD step via Algorithm 1", 1, iters.min(10), || {
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(ds.len());
        banditpam::coordinator::build::build_step(
            &backend,
            &mut state,
            &banditpam::coordinator::config::BanditPamConfig::default(),
            &mut Rng::seed_from(5),
        )
    });
    println!("{}", r.line());

    // --- SWAP reuse (BanditPAM++ virtual arms + cross-iteration rows) ------
    //
    // Full fits with the session row cache off vs on; identical medoids by
    // construction (tests/property_swap_reuse.rs), so the comparison is
    // purely evals + wall time. Results land in BENCH_swap.json for CI.
    let nsw = scale.pick(300, 1500, 4800);
    let ksw = 5;
    let ds_swap = synthetic::mnist_like(&mut Rng::seed_from(6), nsw);
    let mut report = Report::new("swap")
        .scale(scale)
        .params(JsonObj::new().u64("n", nsw as u64).u64("k", ksw as u64));
    let mut swap_evals_by_mode = Vec::new();
    for (name, reuse) in [("off", false), ("on", true)] {
        let backend = NativeBackend::new(&ds_swap.points, Metric::L2).with_threads(4);
        let mut algo = BanditPam::new(BanditPamConfig {
            swap_reuse: reuse,
            ..Default::default()
        });
        let t = Timer::start();
        let fit = algo
            .fit(&backend, ksw, &mut Rng::seed_from(7))
            .expect("swap-reuse bench fit");
        let secs = t.secs();
        println!(
            "swap-reuse {name:>3}: swap_evals={} saved={} total={} loss={:.3} {:.3}s",
            fit.stats.swap_evals,
            fit.stats.swap_evals_saved,
            fit.stats.distance_evals,
            fit.loss,
            secs
        );
        swap_evals_by_mode.push(fit.stats.swap_evals);
        report.row(
            JsonObj::new()
                .str("reuse", name)
                .u64("n", nsw as u64)
                .u64("k", ksw as u64)
                .u64("swap_evals", fit.stats.swap_evals)
                .u64("swap_evals_saved", fit.stats.swap_evals_saved)
                .u64("total_evals", fit.stats.distance_evals)
                .f64("loss", fit.loss)
                .f64("wall_secs", secs),
        );
    }
    if swap_evals_by_mode.len() == 2 && swap_evals_by_mode[1] > 0 {
        println!(
            "    -> {:.2}x fewer SWAP evals with reuse",
            swap_evals_by_mode[0] as f64 / swap_evals_by_mode[1] as f64
        );
    }
    let _ = report.write();

    // --- XLA vs native block (needs artifacts) ------------------------------
    let dir = banditpam::runtime::manifest::Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        match banditpam::runtime::executable::Client::cpu() {
            Ok(client) => {
                let xla = banditpam::runtime::xla_backend::XlaBackend::new(
                    &client,
                    &dir,
                    &ds.points,
                    Metric::L2,
                )
                .expect("xla backend");
                let targets: Vec<usize> = (0..64).collect();
                let refs: Vec<usize> = (64..192).collect();
                let mut out = vec![0.0f64; targets.len() * refs.len()];
                let r = bench_fn("xla block 64x128 d=784 (interpret HLO)", 1, iters.min(10), || {
                    xla.block(&targets, &refs, &mut out)
                });
                println!("{}", r.line());
            }
            Err(e) => println!("xla block: skipped ({e})"),
        }
    } else {
        println!("xla block: skipped (no artifacts; run `make artifacts`)");
    }
}
