//! BigFit bench: the bounded-memory CLARA-style outer loop, in-memory vs
//! streamed over the same `.mtx` file. Emits `BENCH_bigfit.json` for CI
//! with the per-sample wall-clock trajectory, peak resident nnz and peak
//! RSS (VmHWM).
//!
//! Acceptance (ISSUE 7): the streamed run is **bitwise identical** to the
//! in-memory outer loop with the same seed (medoids, assignments, loss
//! bits), and its recorded peak resident nnz stays under 25% of the
//! file's total nnz — the bounded-memory claim, asserted here so CI
//! enforces it.

use banditpam::bench::report::{JsonObj, Report};
use banditpam::data::stream::StreamOptions;
use banditpam::data::{loader, synthetic, Points};
use banditpam::prelude::*;
use banditpam::util::timer::Timer;

/// Peak resident set size in KiB from `/proc/self/status` (Linux; 0
/// elsewhere) — the whole-process complement to the nnz accounting.
fn peak_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    println!("== bigfit benches ({scale:?}) ==");

    let n = scale.pick(2_000, 8_000, 20_000);
    let genes = scale.pick(128, 512, 1024);
    let k = 5usize;
    let samples = scale.pick(3, 5, 5);
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(42), n, genes, 0.10);
    let Points::Sparse(csr) = &ds.points else { unreachable!() };
    let total_nnz = csr.nnz();
    let mtx = std::env::temp_dir().join(format!(
        "banditpam_bench_bigfit_{}.mtx",
        std::process::id()
    ));
    loader::save_mtx(&ds, &mtx).expect("write bench .mtx");
    println!("dataset: {} -> {} ({total_nnz} nnz)", ds.name, mtx.display());

    let big = Fit::banditpam().metric(Metric::L1).k(k).seed(7).threads(4).big().samples(samples);

    // --- in-memory outer loop (the reference) --------------------------
    let loaded = loader::load_mtx(&mtx, false, 0).expect("in-memory load");
    let t = Timer::start();
    let (mem_model, mem_stats) = big.fit_with_stats(&loaded).expect("in-memory bigfit");
    let mem_secs = t.secs();
    println!(
        "bigfit in-memory: n={n} k={k} samples={samples} loss={:.3} {mem_secs:.3}s",
        mem_model.loss()
    );

    // --- streamed outer loop over the same file ------------------------
    let chunk = (total_nnz / 16).max(1);
    let opts = StreamOptions { chunk_nnz: chunk, ..Default::default() };
    let t = Timer::start();
    let (st_model, st_stats) = big.fit_streamed(&mtx, &opts).expect("streamed bigfit");
    let st_secs = t.secs();
    println!(
        "bigfit streamed : n={n} k={k} samples={samples} loss={:.3} {st_secs:.3}s \
         (chunk {chunk} nnz)",
        st_model.loss()
    );

    // Bitwise parity: same medoids, same assignments, same loss bits.
    assert_eq!(
        mem_model.clustering().medoids,
        st_model.clustering().medoids,
        "medoid parity"
    );
    assert_eq!(
        mem_model.clustering().assignments,
        st_model.clustering().assignments,
        "assignment parity"
    );
    assert_eq!(
        mem_model.loss().to_bits(),
        st_model.loss().to_bits(),
        "loss bit parity"
    );
    assert_eq!(
        mem_model.clustering().stats.distance_evals,
        st_model.clustering().stats.distance_evals,
        "eval counter parity"
    );
    println!("bigfit parity in-memory vs streamed: identical");

    // Bounded memory: the streamed loop's working set (sample + window /
    // medoids + window) stays well under the full matrix.
    assert!(
        st_stats.peak_resident_nnz * 4 < total_nnz,
        "peak resident {} nnz >= 25% of total {total_nnz}",
        st_stats.peak_resident_nnz
    );
    println!(
        "residency: peak {} of {total_nnz} nnz ({:.1}%), peak window {} nnz, VmHWM {} KiB",
        st_stats.peak_resident_nnz,
        100.0 * st_stats.peak_resident_nnz as f64 / total_nnz as f64,
        st_stats.peak_window_nnz,
        peak_rss_kb()
    );

    let mut report = Report::new("bigfit").scale(scale).params(
        JsonObj::new()
            .u64("n", n as u64)
            .u64("d", genes as u64)
            .u64("k", k as u64)
            .u64("samples", samples as u64)
            .u64("total_nnz", total_nnz as u64)
            .u64("chunk_nnz", chunk as u64),
    );
    for (mode, stats, secs) in
        [("in-memory", &mem_stats, mem_secs), ("streamed", &st_stats, st_secs)]
    {
        report.row(
            JsonObj::new()
                .str("kind", "bigfit")
                .str("mode", mode)
                .u64("n", n as u64)
                .u64("d", genes as u64)
                .u64("k", k as u64)
                .u64("samples", samples as u64)
                .u64("sample_size", stats.sample_size as u64)
                .u64("total_nnz", total_nnz as u64)
                .u64("chunk_nnz", chunk as u64)
                .u64("peak_resident_nnz", stats.peak_resident_nnz as u64)
                .u64("peak_window_nnz", stats.peak_window_nnz as u64)
                .u64("peak_rss_kb", peak_rss_kb())
                .f64("secs", secs),
        );
        for tr in &stats.trajectory {
            report.row(
                JsonObj::new()
                    .str("kind", "trajectory")
                    .str("mode", mode)
                    .u64("sample", tr.sample as u64)
                    .f64("loss", tr.loss)
                    .f64("subsample_secs", tr.subsample_secs)
                    .f64("fit_secs", tr.fit_secs)
                    .f64("eval_secs", tr.eval_secs),
            );
        }
    }

    let _ = report.write();
    let _ = std::fs::remove_file(&mtx);
}
