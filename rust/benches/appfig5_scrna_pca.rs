//! Bench target for paper experiment `appfig5` (see DESIGN.md experiment
//! index). Scale via BANDITPAM_BENCH_SCALE=smoke|quick|paper (default
//! quick). Prints the same rows the paper's figure plots, then runs the
//! raw (un-projected) scRNA workload through the sparse CSR path — the
//! regime the PCA pathology contrasts against, and the one where the
//! O(nnz) kernels apply (the 10-PC projection is inherently dense).

use banditpam::bench::report::{JsonObj, Report};
use banditpam::prelude::*;

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let t0 = std::time::Instant::now();
    let mut report = Report::new("appfig5").scale(scale);
    for table in banditpam::experiments::run("appfig5", scale, 42).expect("experiment failed") {
        table.print();
        report.table(&table);
    }

    // --- sparse end-to-end: raw scRNA under l1, CSR storage ---------------
    let (n, genes) = match scale {
        banditpam::bench::Scale::Smoke => (300, 256),
        banditpam::bench::Scale::Quick => (1000, 512),
        banditpam::bench::Scale::Paper => (4000, 1024),
    };
    let ds = banditpam::data::synthetic::scrna_sparse(&mut Rng::seed_from(42), n, genes, 0.10);
    let Points::Sparse(csr) = &ds.points else { unreachable!() };
    let threads = banditpam::experiments::harness::default_threads();
    let backend = NativeBackend::new(&ds.points, Metric::L1).with_threads(threads);
    let t1 = std::time::Instant::now();
    let fit = BanditPam::new(BanditPamConfig::default())
        .fit(&backend, 5, &mut Rng::seed_from(7))
        .expect("sparse scrna fit");
    println!(
        "\n[sparse scrna l1] n={n} d={genes} density={:.3} loss={:.1} evals={} {:.2}s",
        csr.density(),
        fit.loss,
        fit.stats.distance_evals,
        t1.elapsed().as_secs_f64()
    );
    report.row(
        JsonObj::new()
            .str("kind", "sparse_scrna_l1")
            .u64("n", n as u64)
            .u64("genes", genes as u64)
            .f64("density", csr.density())
            .f64("loss", fit.loss)
            .u64("distance_evals", fit.stats.distance_evals)
            .f64("secs", t1.elapsed().as_secs_f64()),
    );
    let _ = report.write();

    println!(
        "\n[appfig5_scrna_pca] total {:.1}s at {scale:?} scale",
        t0.elapsed().as_secs_f64()
    );
}
