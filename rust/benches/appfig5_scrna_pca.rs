//! Bench target for paper experiment `appfig5` (see DESIGN.md experiment
//! index). Scale via BANDITPAM_BENCH_SCALE=smoke|quick|paper (default
//! quick). Prints the same rows the paper's figure plots.

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let t0 = std::time::Instant::now();
    for table in banditpam::experiments::run("appfig5", scale, 42).expect("experiment failed") {
        table.print();
    }
    println!("\n[appfig5_scrna_pca] total {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
