//! Out-of-core CSR streaming bench: in-memory vs chunked `.mtx` load at
//! several window budgets, the transpose (row-bucketing spill) path, and
//! the streamed-subsample protocol end to end. Emits `BENCH_stream.json`
//! for CI with peak-window nnz and wall times.
//!
//! Acceptance (ISSUE 4): every streamed result is **bitwise identical**
//! to the in-memory path, and the chunked reader's recorded peak-window
//! nnz stays under 25% of the file's total nnz at the default sweep
//! budget — the bounded-memory claim, asserted here so CI enforces it.

use banditpam::bench::bench_fn;
use banditpam::bench::report::{JsonObj, Report};
use banditpam::data::stream::{self, StreamOptions};
use banditpam::data::{loader, synthetic, Points};
use banditpam::prelude::*;
use banditpam::util::timer::Timer;

fn main() {
    let scale = banditpam::bench::Scale::from_env();
    let iters = scale.pick(2, 5, 10);
    println!("== streaming benches ({scale:?}, {iters} iters) ==");

    let n = scale.pick(1_000, 6_000, 20_000);
    let genes = scale.pick(256, 1024, 2048);
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(42), n, genes, 0.10);
    let Points::Sparse(csr) = &ds.points else { unreachable!() };
    let total_nnz = csr.nnz();
    let mtx = std::env::temp_dir().join(format!(
        "banditpam_bench_stream_{}.mtx",
        std::process::id()
    ));
    loader::save_mtx(&ds, &mtx).expect("write bench .mtx");
    let bytes = std::fs::metadata(&mtx).map(|m| m.len()).unwrap_or(0);
    println!("dataset: {} -> {} ({bytes} bytes, {total_nnz} nnz)", ds.name, mtx.display());

    let mut report = Report::new("stream").scale(scale).params(
        JsonObj::new().u64("n", n as u64).u64("d", genes as u64).u64("total_nnz", total_nnz as u64),
    );

    // --- full load: in-memory baseline --------------------------------
    let mem = bench_fn("load mtx in-memory", 1, iters, || {
        loader::load_mtx(&mtx, false, 0).expect("in-memory load")
    });
    println!("{}", mem.line());
    report.row(
        JsonObj::new()
            .str("kind", "load")
            .str("mode", "in-memory")
            .u64("n", n as u64)
            .u64("d", genes as u64)
            .u64("total_nnz", total_nnz as u64)
            .f64("secs", mem.mean_secs),
    );
    let mem_ds = loader::load_mtx(&mtx, false, 0).expect("in-memory load");
    let Points::Sparse(mem_csr) = &mem_ds.points else { unreachable!() };

    // --- full load: streamed at bounded window budgets -----------------
    for denom in [8usize, 32] {
        let chunk = (total_nnz / denom).max(1);
        let opts = StreamOptions { chunk_nnz: chunk, ..Default::default() };
        let r = bench_fn(&format!("load mtx streamed chunk=nnz/{denom}"), 1, iters, || {
            stream::load_mtx_streamed(&mtx, &opts).expect("streamed load").0
        });
        println!("{}", r.line());
        let (st_ds, stats) = stream::load_mtx_streamed(&mtx, &opts).expect("streamed load");
        let Points::Sparse(st_csr) = &st_ds.points else { unreachable!() };
        assert_eq!(st_csr, mem_csr, "streamed load must be bitwise in-memory");
        // Bounded memory: the per-window working set stays well under the
        // full matrix (<25% of total nnz at these budgets).
        assert!(
            stats.peak_window_nnz * 4 < total_nnz,
            "peak window {} nnz >= 25% of total {total_nnz}",
            stats.peak_window_nnz
        );
        println!(
            "    -> {} windows, peak window {} nnz ({:.1}% of total)",
            stats.windows,
            stats.peak_window_nnz,
            100.0 * stats.peak_window_nnz as f64 / total_nnz as f64
        );
        report.row(
            JsonObj::new()
                .str("kind", "load")
                .str("mode", "streamed")
                .u64("n", n as u64)
                .u64("d", genes as u64)
                .u64("total_nnz", total_nnz as u64)
                .u64("chunk_nnz", chunk as u64)
                .u64("windows", stats.windows as u64)
                .u64("peak_window_nnz", stats.peak_window_nnz as u64)
                .bool("spilled", stats.spilled)
                .f64("secs", r.mean_secs),
        );
    }

    // --- transpose: the on-disk row-bucketing spill path ---------------
    {
        let chunk = (total_nnz / 8).max(1);
        let opts = StreamOptions { chunk_nnz: chunk, transpose: true, limit: 0 };
        let t = Timer::start();
        let (st_ds, stats) = stream::load_mtx_streamed(&mtx, &opts).expect("spill load");
        let secs = t.secs();
        let mem_t = loader::load_mtx(&mtx, true, 0).expect("in-memory transpose");
        let (Points::Sparse(a), Points::Sparse(b)) = (&st_ds.points, &mem_t.points) else {
            unreachable!()
        };
        assert_eq!(a, b, "transpose spill must be bitwise in-memory");
        assert!(stats.spilled, "row-major input under transpose must spill");
        println!(
            "load mtx streamed --transpose (spill): {secs:.3}s, {} windows, peak window {} nnz",
            stats.windows, stats.peak_window_nnz
        );
        report.row(
            JsonObj::new()
                .str("kind", "load")
                .str("mode", "streamed-transpose-spill")
                .u64("n", n as u64)
                .u64("d", genes as u64)
                .u64("total_nnz", total_nnz as u64)
                .u64("chunk_nnz", chunk as u64)
                .u64("windows", stats.windows as u64)
                .u64("peak_window_nnz", stats.peak_window_nnz as u64)
                .bool("spilled", true)
                .f64("secs", secs),
        );
    }

    // --- the experimental protocol: subsample + fit --------------------
    let sub_n = (n / 4).max(1);
    let k = 5;
    let mut rng_mem = Rng::seed_from(9);
    let t = Timer::start();
    let sub_mem = mem_ds.subsample(sub_n, &mut rng_mem);
    let mem_secs = t.secs();
    let chunk = (total_nnz / 8).max(1);
    let mut rng_st = Rng::seed_from(9);
    let t = Timer::start();
    let (sub_st, stats) = stream::subsample_mtx_streamed(
        &mtx,
        &StreamOptions { chunk_nnz: chunk, ..Default::default() },
        sub_n,
        &mut rng_st,
    )
    .expect("streamed subsample");
    let st_secs = t.secs();
    {
        let (Points::Sparse(a), Points::Sparse(b)) = (&sub_mem.points, &sub_st.points) else {
            unreachable!()
        };
        assert_eq!(a, b, "streamed subsample must be bitwise in-memory");
        assert!(
            stats.peak_resident_nnz <= a.nnz() + stats.peak_window_nnz,
            "subsample residency bound"
        );
    }
    println!(
        "subsample {sub_n}/{n}: in-memory {mem_secs:.3}s vs streamed {st_secs:.3}s \
         (peak resident {} nnz vs {} total)",
        stats.peak_resident_nnz, total_nnz
    );
    report.row(
        JsonObj::new()
            .str("kind", "subsample")
            .u64("n", n as u64)
            .u64("sub_n", sub_n as u64)
            .u64("total_nnz", total_nnz as u64)
            .u64("chunk_nnz", chunk as u64)
            .u64("peak_resident_nnz", stats.peak_resident_nnz as u64)
            .u64("peak_window_nnz", stats.peak_window_nnz as u64)
            .f64("mem_secs", mem_secs)
            .f64("stream_secs", st_secs),
    );

    let mut fits = Vec::new();
    for (name, points, rng) in
        [("in-memory", &sub_mem.points, &mut rng_mem), ("streamed", &sub_st.points, &mut rng_st)]
    {
        let backend = NativeBackend::new(points, Metric::L1).with_threads(4);
        let t = Timer::start();
        let fit = BanditPam::new(BanditPamConfig::default())
            .fit(&backend, k, rng)
            .expect("fit");
        let secs = t.secs();
        println!(
            "fit {name:>9}: n={sub_n} k={k} loss={:.3} evals={} {secs:.3}s",
            fit.loss, fit.stats.distance_evals
        );
        report.row(
            JsonObj::new()
                .str("kind", "fit")
                .str("source", name)
                .u64("n", sub_n as u64)
                .u64("k", k as u64)
                .f64("loss", fit.loss)
                .u64("evals", fit.stats.distance_evals)
                .f64("wall_secs", secs),
        );
        fits.push(fit);
    }
    assert_eq!(fits[0].medoids, fits[1].medoids, "medoid parity");
    assert_eq!(fits[0].assignments, fits[1].assignments, "assignment parity");
    assert_eq!(
        fits[0].stats.distance_evals, fits[1].stats.distance_evals,
        "eval counter parity"
    );
    println!("fit parity in-memory vs streamed-subsample: identical");

    let _ = report.write();
    let _ = std::fs::remove_file(&mtx);
}
