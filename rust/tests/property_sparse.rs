//! Property suite for the sparse (CSR) subsystem.
//!
//! Three layers of agreement, in decreasing strictness:
//!
//! 1. **Bitwise vs the naive sparse reference.** The optimized merge pair
//!    kernels and the scatter/gather row kernels (through the backend's
//!    `block`) must equal an obviously-correct quadratic-scan reference
//!    bit for bit: both accumulate the cross term sequentially in f64 over
//!    the reference row's stored columns in order, so there is no rounding
//!    excuse — any difference is a logic bug.
//! 2. **Bitwise across execution strategies.** threads 1 vs 8, cache on
//!    vs off, `dist` vs `block`, and `SwapSession` cached prefixes must
//!    all produce identical bits, or caching order would leak into
//!    results.
//! 3. **Tolerance vs the densified dense kernels.** The dense kernels
//!    accumulate in 16 f32 lanes (worst-case relative error ~6e-6 at
//!    d = 784 — see `distance/dense.rs`); the sparse kernels are exact
//!    f64, so agreement is bounded by the *dense* error, checked at
//!    2e-5 * (1 + |d|) like the dense property suite.
//!
//! Grid: metric in {l1, l2, cosine} x d in {7, 31, 784} x density in
//! {0.01, 0.1, 0.5} x threads in {1, 8}, plus a seeded end-to-end fit at
//! scrna-like n ~ 2k asserting sparse and densified runs return identical
//! medoids.

use banditpam::coordinator::config::BanditPamConfig;
use banditpam::coordinator::session::SwapSession;
use banditpam::data::sparse::CsrMatrix;
use banditpam::data::{synthetic, Dataset, Points};
use banditpam::distance::{dense, sparse, Metric};
use banditpam::prelude::*;
use banditpam::prop_assert;
use banditpam::testkit::prop::{check, PropConfig};
use banditpam::util::matrix::Matrix;

const DIMS: &[usize] = &[7, 31, 784];
const DENSITIES: &[f64] = &[0.01, 0.1, 0.5];
const THREADS: &[usize] = &[1, 8];
const METRICS: &[Metric] = &[Metric::L1, Metric::L2, Metric::Cosine];

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

/// Random sparse points with a dense twin holding exactly the same data.
fn random_points(rng: &mut Rng, n: usize, d: usize, density: f64) -> (Dataset, Dataset) {
    let m = Matrix::from_fn(n, d, |_, _| {
        if rng.bool(density) {
            let v = rng.normal() as f32;
            if v == 0.0 {
                1.0
            } else {
                v
            }
        } else {
            0.0
        }
    });
    let sp = Dataset::sparse(CsrMatrix::from_dense(&m), "sparse-twin");
    (sp, Dataset::dense(m, "dense-twin"))
}

/// Obviously-correct quadratic-scan dot: for every stored reference
/// column (in order), linear-search the target row. Accumulation order
/// matches the merge/gather kernels, so equality is bitwise.
fn naive_dot(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for (q, &bj) in bi.iter().enumerate() {
        for (p, &aj) in ai.iter().enumerate() {
            if aj == bj {
                s += av[p] as f64 * bv[q] as f64;
            }
        }
    }
    s
}

/// Quadratic-scan l1 overlap correction (same order argument as
/// [`naive_dot`]).
fn naive_l1_corr(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for (q, &bj) in bi.iter().enumerate() {
        for (p, &aj) in ai.iter().enumerate() {
            if aj == bj {
                s += sparse::l1_term(av[p] as f64, bv[q] as f64);
            }
        }
    }
    s
}

fn naive_abs_sum(v: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for &x in v {
        s += (x as f64).abs();
    }
    s
}

fn naive_sq_norm(v: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for &x in v {
        s += x as f64 * x as f64;
    }
    s
}

/// The naive per-pair sparse distance for `metric`.
fn naive_pair(metric: Metric, m: &CsrMatrix, i: usize, j: usize) -> f64 {
    let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
    match metric {
        Metric::L1 => sparse::l1_from_parts(
            naive_abs_sum(av),
            naive_abs_sum(bv),
            naive_l1_corr(ai, av, bi, bv),
        ),
        Metric::L2 => sparse::l2_from_parts(
            naive_sq_norm(av),
            naive_sq_norm(bv),
            naive_dot(ai, av, bi, bv),
        ),
        Metric::Cosine => dense::cosine_from_parts(
            naive_dot(ai, av, bi, bv),
            naive_sq_norm(av),
            naive_sq_norm(bv),
        ),
        Metric::TreeEdit => unreachable!(),
    }
}

fn dense_pair(metric: Metric, m: &Matrix, i: usize, j: usize) -> f64 {
    match metric {
        Metric::L1 => dense::l1(m.row(i), m.row(j)),
        Metric::L2 => dense::l2(m.row(i), m.row(j)),
        Metric::Cosine => dense::cosine(m.row(i), m.row(j)),
        Metric::TreeEdit => unreachable!(),
    }
}

fn block_of(backend: &dyn DistanceBackend, targets: &[usize], refs: &[usize]) -> Vec<f64> {
    let mut out = vec![0.0; targets.len() * refs.len()];
    backend.block(targets, refs, &mut out);
    out
}

#[test]
fn prop_sparse_pair_kernels_match_naive_reference_bitwise() {
    check("sparse-pair-vs-naive", &cfg(8), |rng| {
        for &d in DIMS {
            for &density in DENSITIES {
                let n = rng.range(6, 14);
                let (sp, _) = random_points(rng, n, d, density);
                let Points::Sparse(m) = &sp.points else { unreachable!() };
                for &metric in METRICS {
                    for i in 0..n {
                        for j in 0..n {
                            let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
                            let got = match metric {
                                Metric::L1 => sparse::l1(ai, av, bi, bv),
                                Metric::L2 => sparse::l2(ai, av, bi, bv),
                                Metric::Cosine => sparse::cosine(ai, av, bi, bv),
                                Metric::TreeEdit => unreachable!(),
                            };
                            let want = naive_pair(metric, m, i, j);
                            prop_assert!(
                                got.to_bits() == want.to_bits(),
                                "{metric} d={d} density={density} ({i},{j}): {got} != {want}"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_kernels_match_densified_dense_kernels() {
    check("sparse-vs-densified", &cfg(8), |rng| {
        for &d in DIMS {
            for &density in DENSITIES {
                let n = rng.range(6, 14);
                let (sp, dn) = random_points(rng, n, d, density);
                let (Points::Sparse(sm), Points::Dense(dm)) = (&sp.points, &dn.points) else {
                    unreachable!()
                };
                for &metric in METRICS {
                    for i in 0..n {
                        for j in 0..n {
                            let ((ai, av), (bi, bv)) = (sm.row(i), sm.row(j));
                            let got = match metric {
                                Metric::L1 => sparse::l1(ai, av, bi, bv),
                                Metric::L2 => sparse::l2(ai, av, bi, bv),
                                Metric::Cosine => sparse::cosine(ai, av, bi, bv),
                                Metric::TreeEdit => unreachable!(),
                            };
                            let want = dense_pair(metric, dm, i, j);
                            let tol = 2e-5 * (1.0 + want.abs());
                            prop_assert!(
                                (got - want).abs() <= tol,
                                "{metric} d={d} density={density} ({i},{j}): \
                                 sparse {got} vs dense {want}"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_block_matches_naive_and_densified_across_threads_and_cache() {
    check("sparse-block-grid", &cfg(4), |rng| {
        for &d in DIMS {
            for &density in DENSITIES {
                let n = rng.range(16, 32);
                let (sp, dn) = random_points(rng, n, d, density);
                let Points::Sparse(sm) = &sp.points else { unreachable!() };
                let tn = rng.range(1, 5);
                let targets = rng.sample_indices(n, tn);
                let rn = rng.range(2, n.min(20));
                let refs = rng.sample_indices(n, rn);
                for &metric in METRICS {
                    // bitwise reference from the naive pair kernel
                    let mut want = vec![0.0; targets.len() * refs.len()];
                    for (ti, &t) in targets.iter().enumerate() {
                        for (ri, &r) in refs.iter().enumerate() {
                            want[ti * refs.len() + ri] = naive_pair(metric, sm, t, r);
                        }
                    }
                    let dense_backend = NativeBackend::new(&dn.points, metric);
                    let dense_out = block_of(&dense_backend, &targets, &refs);
                    for &threads in THREADS {
                        for cached in [false, true] {
                            let mut b = NativeBackend::new(&sp.points, metric)
                                .with_threads(threads)
                                .with_pool_min_work(0); // force pooling
                            if cached {
                                b = b.with_cache(1 << 16);
                            }
                            let got = block_of(&b, &targets, &refs);
                            for (x, (g, w)) in got.iter().zip(&want).enumerate() {
                                prop_assert!(
                                    g.to_bits() == w.to_bits(),
                                    "{metric} d={d} density={density} threads={threads} \
                                     cached={cached} elem {x}: {g} != {w}"
                                );
                            }
                            // eval accounting identical to the dense engine
                            // (the cache dedups symmetric pairs within a
                            // block, so only the uncached count is exact)
                            if !cached {
                                prop_assert!(
                                    b.counter().get() == dense_backend.counter().get(),
                                    "{metric} d={d} threads={threads}: counted {} evals, \
                                     dense counted {}",
                                    b.counter().get(),
                                    dense_backend.counter().get()
                                );
                            }
                            for (g, w) in got.iter().zip(&dense_out) {
                                let tol = 2e-5 * (1.0 + w.abs());
                                prop_assert!(
                                    (g - w).abs() <= tol,
                                    "{metric} d={d} density={density}: block {g} vs dense {w}"
                                );
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// `dist` (merge pair kernel) and `block` (scatter row kernel) must agree
/// bitwise — the DistanceCache stores whichever computes first, so any
/// divergence would make results depend on cache warm-up order.
#[test]
fn prop_sparse_dist_equals_block_bitwise() {
    check("sparse-dist-vs-block", &cfg(6), |rng| {
        let n = 24;
        let (sp, _) = random_points(rng, n, 100, 0.15);
        for &metric in METRICS {
            let b = NativeBackend::new(&sp.points, metric);
            let refs: Vec<usize> = (0..n).collect();
            let got = block_of(&b, &[3, 17], &refs);
            for (ri, &r) in refs.iter().enumerate() {
                prop_assert!(
                    got[ri].to_bits() == b.dist(3, r).to_bits(),
                    "{metric} t=3 r={r}"
                );
                prop_assert!(
                    got[n + ri].to_bits() == b.dist(17, r).to_bits(),
                    "{metric} t=17 r={r}"
                );
            }
        }
        Ok(())
    });
}

/// The SwapSession per-candidate row cache stores permutation-order
/// prefixes whose length is the number of consumed references — nothing
/// about the feature storage — so it must serve sparse points verbatim:
/// cached values bitwise-equal direct evaluation, and re-pulls cost zero.
#[test]
fn swap_session_prefix_rows_are_correct_for_sparse_points() {
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(71), 50, 128, 0.10);
    for cached in [false, true] {
        let mut b = NativeBackend::new(&ds.points, Metric::L1);
        if cached {
            b = b.with_cache(1 << 14);
        }
        let mut s = SwapSession::new(50, 3, &BanditPamConfig::default(), &mut Rng::seed_from(5));
        assert!(s.rows_enabled());
        let first: Vec<usize> = s.shared_perm()[..20].to_vec();
        s.pull_rows(&b, &[2, 31], &first);
        let evals = b.counter().get();
        // identical re-pull is served entirely from the session cache
        s.pull_rows(&b, &[2, 31], &first);
        assert_eq!(b.counter().get(), evals, "cached={cached}");
        assert_eq!(s.evals_saved(), 2 * 20);
        for &p in &[2usize, 31] {
            for (t, &j) in first.iter().enumerate() {
                assert_eq!(
                    s.row(p)[t].to_bits(),
                    b.dist(p, j).to_bits(),
                    "cached={cached} p={p} j={j}"
                );
            }
        }
        s.ensure_full_row(&b, 2, true);
        assert_eq!(s.row(2).len(), 50);
    }
}

/// End-to-end parity: a seeded BanditPAM fit over sparse scRNA-like data
/// must return the same medoids as the identical data run densely. The
/// kernels differ only by the dense engine's f32 lane error, far below
/// the arm-mean gaps of separated cell types.
#[test]
fn banditpam_fit_sparse_equals_densified_medoids() {
    let n = 2000;
    let sp = synthetic::scrna_sparse(&mut Rng::seed_from(2024), n, 256, 0.10);
    let dn = sp.to_dense().unwrap();
    let Points::Sparse(m) = &sp.points else { unreachable!() };
    assert!(m.density() < 0.25, "scrna-like density, got {}", m.density());

    let fit_sp = {
        let backend = NativeBackend::new(&sp.points, Metric::L1).with_threads(2);
        BanditPam::new(BanditPamConfig::default())
            .fit(&backend, 5, &mut Rng::seed_from(9))
            .expect("sparse fit")
    };
    let fit_dn = {
        let backend = NativeBackend::new(&dn.points, Metric::L1).with_threads(2);
        BanditPam::new(BanditPamConfig::default())
            .fit(&backend, 5, &mut Rng::seed_from(9))
            .expect("dense fit")
    };
    assert_eq!(fit_sp.medoids, fit_dn.medoids, "sparse vs densified medoids");
    assert_eq!(fit_sp.assignments, fit_dn.assignments);
    let tol = 1e-6 * (1.0 + fit_dn.loss.abs());
    assert!(
        (fit_sp.loss - fit_dn.loss).abs() <= tol,
        "loss {} vs {}",
        fit_sp.loss,
        fit_dn.loss
    );
}

/// Subsampling a sparse dataset (the paper's per-repetition protocol)
/// selects the same points as subsampling its dense twin.
#[test]
fn sparse_subsample_matches_dense_subsample() {
    let sp = synthetic::scrna_sparse(&mut Rng::seed_from(12), 200, 64, 0.10);
    let dn = sp.to_dense().unwrap();
    let a = sp.subsample(50, &mut Rng::seed_from(3));
    let b = dn.subsample(50, &mut Rng::seed_from(3));
    assert_eq!(a.labels, b.labels);
    let (Points::Sparse(am), Points::Dense(bm)) = (&a.points, &b.points) else {
        unreachable!()
    };
    assert_eq!(am.to_dense().as_slice(), bm.as_slice());
}
