//! CLI contract tests against the real binary
//! (`env!("CARGO_BIN_EXE_banditpam")`): usage errors exit 2 with a
//! one-line typed `error: ...` on stderr (never a debug-formatted
//! internal error), operational failures exit 1, and the `serve --stdio`
//! loop speaks the wire protocol end to end.

use banditpam::serve::protocol::{
    encode_request, parse_response, read_frame, Request, Response,
};
use std::io::{Read, Write};
use std::path::PathBuf;
use std::process::{Command, Stdio};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_banditpam"))
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bp_cli_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train a tiny dense model via the real `cluster` subcommand.
fn trained_model(dir: &PathBuf) -> PathBuf {
    let train = dir.join("train.csv");
    let mut csv = String::new();
    for i in 0..12 {
        let x = f64::from(i % 4);
        let y = f64::from(i / 4);
        csv.push_str(&format!("{x},{y},{}\n", x + y));
    }
    std::fs::write(&train, csv).unwrap();
    let model = dir.join("m.bpmodel");
    let out = bin()
        .args([
            "cluster",
            "--data",
            train.to_str().unwrap(),
            "--k",
            "2",
            "--threads",
            "1",
            "--save-model",
            model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "training run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    model
}

fn stderr_line(out: &std::process::Output) -> String {
    String::from_utf8_lossy(&out.stderr).trim().to_string()
}

#[test]
fn predict_dimension_mismatch_is_a_one_line_usage_error() {
    let dir = tmpdir("dim");
    let model = trained_model(&dir);
    // 5-column queries against the 3-d model
    let wide = dir.join("wide.csv");
    std::fs::write(&wide, "1.0,2.0,3.0,4.0,5.0\n0.5,0.5,0.5,0.5,0.5\n").unwrap();
    let out = bin()
        .args([
            "predict",
            "--model",
            model.to_str().unwrap(),
            "--data",
            wide.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "usage errors exit 2");
    let err = stderr_line(&out);
    assert!(err.starts_with("error: invalid argument:"), "{err}");
    assert!(err.contains("dimension"), "{err}");
    assert_eq!(err.lines().count(), 1, "one line, not a debug dump: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cluster_metric_unsupported_for_storage_is_a_usage_error_not_a_panic() {
    let dir = tmpdir("metric");
    let train = dir.join("train.csv");
    std::fs::write(&train, "1.0,2.0\n3.0,4.0\n5.0,6.0\n").unwrap();
    let out = bin()
        .args(["cluster", "--data", train.to_str().unwrap(), "--metric", "tree", "--k", "2"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_line(&out);
    assert!(err.starts_with("error: invalid argument:"), "{err}");
    assert!(err.contains("does not support"), "{err}");
    assert!(
        !String::from_utf8_lossy(&out.stderr).contains("panicked"),
        "must reject cleanly, not panic"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_model_file_is_an_operational_error_exit_1() {
    let out = bin()
        .args(["predict", "--model", "/nonexistent/m.bpmodel", "--synthetic", "gmm"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1), "operational failures exit 1");
    let err = stderr_line(&out);
    assert!(err.starts_with("error:"), "{err}");
    assert_eq!(err.lines().count(), 1, "{err}");
}

#[test]
fn missing_required_flag_and_unknown_subcommand_exit_2() {
    let out = bin().args(["predict"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_line(&out).contains("--model FILE required"));

    let out = bin().args(["frobnicate"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_line(&out).contains("unknown subcommand"));

    let out = bin().args(["experiment"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_line(&out).contains("usage: banditpam experiment"));
}

#[test]
fn misspelled_options_are_rejected_per_subcommand() {
    // Every subcommand declares its accepted option/flag set; anything the
    // parser accepted but the subcommand never reads used to be silently
    // ignored (`--chunk-nzz 4096` simply did nothing). One misspelling per
    // subcommand, each a usage error naming the offender.
    let cases: &[(&[&str], &str)] = &[
        (&["cluster", "--chunk-nzz", "4096"], "--chunk-nzz"),
        (&["bigfit", "--sample_size", "100"], "--sample_size"),
        (&["predict", "--modle", "m.bpmodel"], "--modle"),
        (&["serve", "--liston", "127.0.0.1:0"], "--liston"),
        (&["experiment", "all", "--scales", "smoke"], "--scales"),
        (&["generate-data", "--densty", "0.2"], "--densty"),
        (&["info", "--frobnicate"], "--frobnicate"),
    ];
    for (argv, bad) in cases {
        let out = bin().args(*argv).output().unwrap();
        assert_eq!(out.status.code(), Some(2), "{argv:?} must exit 2");
        let err = stderr_line(&out);
        assert!(
            err.starts_with("error: invalid argument: unknown option"),
            "{argv:?}: {err}"
        );
        assert!(err.contains(bad), "{argv:?} must name the offender: {err}");
        assert_eq!(err.lines().count(), 1, "one line, not a debug dump: {err}");
    }
}

#[test]
fn misspelled_option_error_suggests_the_accepted_spelling() {
    let out = bin().args(["cluster", "--chunk-nzz", "4096"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_line(&out);
    assert!(err.contains("--chunk-nnz"), "accepted list names the fix: {err}");
    assert!(err.contains("`cluster`"), "{err}");
}

#[test]
fn help_lists_every_registry_arm_including_the_new_ones() {
    let out = bin().args(["help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    for spec in banditpam::algorithms::REGISTRY {
        assert!(text.contains(spec.name), "help must list {}", spec.name);
    }
    assert!(text.contains("fasterpam"), "{text}");
    assert!(text.contains("onebatchpam"), "{text}");
}

#[test]
fn dash_dash_help_on_a_subcommand_prints_usage_and_exits_zero() {
    let out = bin().args(["cluster", "--help"]).output().unwrap();
    assert!(out.status.success(), "--help is never a usage error");
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn predict_happy_path_round_trips_through_the_binary() {
    let dir = tmpdir("happy");
    let model = trained_model(&dir);
    let queries = dir.join("q.csv");
    std::fs::write(&queries, "0.0,0.0,0.0\n3.0,2.0,5.0\n").unwrap();
    let out_csv = dir.join("assign.csv");
    let out = bin()
        .args([
            "predict",
            "--model",
            model.to_str().unwrap(),
            "--data",
            queries.to_str().unwrap(),
            "--out",
            out_csv.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let written = std::fs::read_to_string(&out_csv).unwrap();
    assert!(written.starts_with("point,assignment,medoid_train_index,distance"));
    assert_eq!(written.lines().count(), 3, "header + 2 assignments");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bigfit_onebatchpam_trains_and_predicts_through_the_binary() {
    let dir = tmpdir("obp");
    let train = dir.join("train.csv");
    let mut csv = String::new();
    for i in 0..12 {
        let x = f64::from(i % 4);
        let y = f64::from(i / 4);
        csv.push_str(&format!("{x},{y},{}\n", x + y));
    }
    std::fs::write(&train, csv).unwrap();
    let model = dir.join("obp.bpmodel");
    let out = bin()
        .args([
            "bigfit",
            "--data",
            train.to_str().unwrap(),
            "--k",
            "2",
            "--algo",
            "onebatchpam",
            "--samples",
            "2",
            "--threads",
            "1",
            "--save-model",
            model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "bigfit --algo onebatchpam failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let assign = dir.join("assign.csv");
    let out = bin()
        .args([
            "predict",
            "--model",
            model.to_str().unwrap(),
            "--data",
            train.to_str().unwrap(),
            "--out",
            assign.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let written = std::fs::read_to_string(&assign).unwrap();
    assert_eq!(written.lines().count(), 13, "header + 12 assignments");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_without_models_is_a_usage_error() {
    let out = bin().args(["serve", "--stdio"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_line(&out).contains("at least one model"));
}

#[test]
fn serve_stdio_answers_the_protocol_and_exits_cleanly_on_shutdown() {
    let dir = tmpdir("serve");
    let model = trained_model(&dir);
    let mut child = bin()
        .args([
            "serve",
            "--stdio",
            "--quiet",
            "--threads",
            "1",
            &format!("m={}", model.display()),
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();

    let mut stdin = child.stdin.take().unwrap();
    stdin.write_all(&encode_request(&Request::Ping { id: 1 })).unwrap();
    stdin.write_all(&encode_request(&Request::ListModels { id: 2 })).unwrap();
    stdin.write_all(&encode_request(&Request::Shutdown { id: 3 })).unwrap();
    stdin.flush().unwrap();
    drop(stdin);

    let mut stdout = child.stdout.take().unwrap();
    let mut frames = Vec::new();
    while let Some((kind, body)) = read_frame(&mut stdout).unwrap() {
        frames.push(parse_response(kind, &body).unwrap());
    }
    assert_eq!(frames.len(), 3, "{frames:?}");
    assert!(matches!(frames[0], Response::Pong { id: 1 }));
    let Response::ModelList { text, .. } = &frames[1] else { panic!("{frames:?}") };
    assert!(text.contains("m dense k=2"), "{text}");
    assert!(
        matches!(frames[2], Response::ShutdownAck { id: 3 }),
        "the ack is the last frame"
    );

    let status = child.wait().unwrap();
    let mut errs = String::new();
    child.stderr.take().unwrap().read_to_string(&mut errs).unwrap();
    assert!(status.success(), "serve exited {status:?}: {errs}");
    std::fs::remove_dir_all(&dir).ok();
}
