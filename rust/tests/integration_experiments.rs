//! Smoke-run every registered experiment end to end (tiny scale): the
//! bench/CLI surface must never rot.

use banditpam::bench::Scale;
use banditpam::experiments;

#[test]
fn every_experiment_runs_at_smoke_scale() {
    // The heavier ones have their own dedicated smoke tests in-module;
    // here we go through the public registry exactly as the CLI does.
    for id in ["appfig1", "appfig34", "fig1b"] {
        let tables = experiments::run(id, Scale::Smoke, 5)
            .unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(!tables.is_empty(), "{id} produced no tables");
        for t in &tables {
            assert!(!t.rows.is_empty(), "{id}: empty table {}", t.title);
            let rendered = t.render();
            assert!(rendered.contains("=="), "{id}: bad render");
            assert!(!t.to_csv().is_empty());
        }
    }
}

#[test]
fn unknown_experiment_is_an_error() {
    let err = experiments::run("fig99", Scale::Smoke, 1).unwrap_err();
    assert!(err.to_string().contains("unknown experiment"));
    assert!(err.to_string().contains("fig1a"), "lists available ids");
}

#[test]
fn registry_covers_every_paper_artifact() {
    // DESIGN.md experiment index: one entry per paper figure + extras.
    for id in ["fig1a", "fig1b", "fig2", "fig3", "appfig1", "appfig2",
               "appfig34", "appfig5", "headline", "ablations"] {
        assert!(experiments::ALL.contains(&id), "missing {id}");
    }
}
