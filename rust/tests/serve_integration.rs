//! End-to-end serve tests over in-memory pipes: coalescing parity,
//! deadlines, backpressure, panic isolation + quarantine, hot swap, the
//! fault catalog, and clean drain-on-shutdown.
//!
//! The load-bearing contract: whatever faults hit the neighboring
//! traffic, a healthy request's assignments are bitwise-identical to a
//! single-shot `KMedoidsModel::predict_with_dists` against the same
//! model generation, and the server itself never dies.

use banditpam::data::{synthetic, Points};
use banditpam::model::{Fit, KMedoidsModel};
use banditpam::serve::faults::{pipe, FaultPlan, PipeReader, PipeWriter, SlowWriter};
use banditpam::serve::protocol::{
    encode_request, parse_response, read_frame, ErrorCode, PredictRequest, Request,
    Response,
};
use banditpam::serve::{AdmissionConfig, Registry, ServeOptions, Server};
use banditpam::util::rng::Rng;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

// ---- harness -----------------------------------------------------------

struct TestEnv {
    dir: PathBuf,
    server: Arc<Server>,
}

impl Drop for TestEnv {
    fn drop(&mut self) {
        self.server.begin_shutdown();
        self.server.join();
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn dense_model(seed: u64) -> KMedoidsModel {
    let ds = synthetic::gmm(&mut Rng::seed_from(seed), 40, 6, 3, 3.0);
    Fit::banditpam().k(3).seed(seed).fit(&ds).unwrap()
}

fn sparse_model(seed: u64) -> KMedoidsModel {
    let ds = synthetic::scrna_like(&mut Rng::seed_from(seed), 40, 24)
        .to_sparse()
        .unwrap();
    Fit::banditpam().k(3).seed(seed).fit(&ds).unwrap()
}

/// Spin up a server over freshly fitted dense ("gmm") and sparse
/// ("cells") models saved under a per-test temp dir.
fn start(tag: &str, opts: ServeOptions) -> TestEnv {
    let dir = std::env::temp_dir().join(format!("bp_serve_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dense_model(1).save(&dir.join("gmm.bpmodel")).unwrap();
    sparse_model(2).save(&dir.join("cells.bpmodel")).unwrap();
    let registry = Registry::open(&[
        ("gmm".into(), dir.join("gmm.bpmodel")),
        ("cells".into(), dir.join("cells.bpmodel")),
    ])
    .unwrap();
    TestEnv { dir, server: Server::new(registry, opts) }
}

/// A client over an in-memory pipe pair; the server side runs on its own
/// thread exactly as a TCP connection would.
struct Client {
    w: Option<PipeWriter>,
    r: PipeReader,
    conn: Option<thread::JoinHandle<()>>,
}

impl Client {
    fn connect(server: &Arc<Server>) -> Client {
        let (cw, sr) = pipe(); // client -> server
        let (sw, cr) = pipe(); // server -> client
        let server = Arc::clone(server);
        let conn = thread::spawn(move || server.handle_connection(sr, sw));
        Client { w: Some(cw), r: cr, conn: Some(conn) }
    }

    fn send(&mut self, req: &Request) {
        self.send_raw(&encode_request(req));
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        self.w.as_mut().unwrap().write_all(bytes).unwrap();
    }

    fn recv(&mut self) -> Response {
        self.recv_opt().expect("connection closed early")
    }

    fn recv_opt(&mut self) -> Option<Response> {
        let (kind, body) = read_frame(&mut self.r).unwrap()?;
        Some(parse_response(kind, &body).unwrap())
    }

    /// Hang up the write half and join the server-side reader.
    fn close(mut self) {
        drop(self.w.take());
        if let Some(h) = self.conn.take() {
            h.join().unwrap();
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        // Hang up FIRST so the server-side reader sees EOF and exits —
        // joining before dropping the write half would deadlock.
        drop(self.w.take());
        if let Some(h) = self.conn.take() {
            h.join().ok();
        }
    }
}

fn predict(id: u64, model: &str, queries: Points) -> Request {
    Request::Predict(PredictRequest {
        id,
        model: model.into(),
        deadline_ms: 0,
        queries,
    })
}

fn queries_for(seed: u64, n: usize) -> Points {
    synthetic::gmm(&mut Rng::seed_from(seed), n, 6, 3, 3.0).points
}

fn assert_bitwise(resp: &Response, model: &KMedoidsModel, queries: &Points) {
    let Response::Assignments { assign, dists, .. } = resp else {
        panic!("expected assignments, got {resp:?}")
    };
    let (want_a, want_d) = model.predict_with_dists(queries).unwrap();
    let want_a: Vec<u32> = want_a.iter().map(|&a| a as u32).collect();
    assert_eq!(assign, &want_a);
    let got_bits: Vec<u64> = dists.iter().map(|d| d.to_bits()).collect();
    let want_bits: Vec<u64> = want_d.iter().map(|d| d.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "distances must be bitwise-identical");
}

// ---- tests -------------------------------------------------------------

#[test]
fn coalesced_pipelined_requests_match_single_shot_predict_bitwise() {
    let env = start("parity", ServeOptions { threads: 2, ..Default::default() });
    let gmm = dense_model(1);
    let cells = sparse_model(2);
    let mut c = Client::connect(&env.server);

    // Pipeline a burst so the batcher actually coalesces: distinct query
    // sets per request, mixed dense/sparse targets.
    let dense_qs: Vec<Points> = (0..6).map(|i| queries_for(100 + i, 3 + i as usize)).collect();
    let sparse_q = synthetic::scrna_like(&mut Rng::seed_from(55), 5, 24)
        .to_sparse()
        .unwrap()
        .points;
    for (i, q) in dense_qs.iter().enumerate() {
        c.send(&predict(i as u64, "gmm", q.clone()));
    }
    c.send(&predict(99, "cells", sparse_q.clone()));

    let mut got: BTreeMap<u64, Response> = BTreeMap::new();
    for _ in 0..7 {
        let resp = c.recv();
        got.insert(resp.id(), resp);
    }
    for (i, q) in dense_qs.iter().enumerate() {
        assert_bitwise(&got[&(i as u64)], &gmm, q);
    }
    assert_bitwise(&got[&99], &cells, &sparse_q);
}

#[test]
fn empty_unknown_and_mismatched_predicts_get_typed_rejects() {
    let env = start("rejects", ServeOptions::default());
    let mut c = Client::connect(&env.server);

    // empty query set: answered inline with empty assignments
    c.send(&predict(1, "gmm", queries_for(1, 0)));
    let Response::Assignments { assign, dists, .. } = c.recv() else { panic!() };
    assert!(assign.is_empty() && dists.is_empty());

    // unknown model
    c.send(&predict(2, "nope", queries_for(1, 2)));
    let Response::Error { id, code, .. } = c.recv() else { panic!() };
    assert_eq!((id, code), (2, ErrorCode::UnknownModel));

    // wrong dimension (model is 6-d)
    c.send(&predict(
        3,
        "gmm",
        synthetic::gmm(&mut Rng::seed_from(3), 2, 9, 2, 3.0).points,
    ));
    let Response::Error { id, code, message, .. } = c.recv() else { panic!() };
    assert_eq!((id, code), (3, ErrorCode::BadRequest));
    assert!(message.contains("dimension"), "{message}");

    // wrong storage kind (dense queries against the sparse model)
    c.send(&predict(4, "cells", queries_for(4, 2)));
    let Response::Error { id, code, message, .. } = c.recv() else { panic!() };
    assert_eq!((id, code), (4, ErrorCode::BadRequest));
    assert!(message.contains("storage"), "{message}");

    // ping / list-models still fine afterwards
    c.send(&Request::Ping { id: 5 });
    assert!(matches!(c.recv(), Response::Pong { id: 5 }));
    c.send(&Request::ListModels { id: 6 });
    let Response::ModelList { text, .. } = c.recv() else { panic!() };
    assert!(text.contains("gmm") && text.contains("cells"), "{text}");
}

#[test]
fn deadlines_expire_under_a_stalled_dispatcher() {
    let env = start(
        "deadline",
        ServeOptions {
            faults: FaultPlan { stall_ms: 80, ..Default::default() },
            ..Default::default()
        },
    );
    let mut c = Client::connect(&env.server);
    // 10 ms deadline against an 80 ms injected stall: must expire.
    c.send(&Request::Predict(PredictRequest {
        id: 1,
        model: "gmm".into(),
        deadline_ms: 10,
        queries: queries_for(7, 3),
    }));
    let Response::Error { id, code, .. } = c.recv() else { panic!() };
    assert_eq!((id, code), (1, ErrorCode::DeadlineExceeded));

    // A generous deadline survives the same stall.
    c.send(&Request::Predict(PredictRequest {
        id: 2,
        model: "gmm".into(),
        deadline_ms: 60_000,
        queries: queries_for(7, 3),
    }));
    assert_bitwise(&c.recv(), &dense_model(1), &queries_for(7, 3));
}

#[test]
fn backpressure_sheds_with_retry_after_and_answers_everything() {
    let env = start(
        "shed",
        ServeOptions {
            admission: AdmissionConfig {
                max_queue_requests: 1,
                retry_after_ms: 50,
                ..Default::default()
            },
            faults: FaultPlan { stall_ms: 120, ..Default::default() },
            ..Default::default()
        },
    );
    let mut c = Client::connect(&env.server);
    let q = queries_for(9, 2);
    // Burst while the dispatcher is stalled on the first batch: the
    // 1-deep queue must shed most of the burst.
    for id in 0..8 {
        c.send(&predict(id, "gmm", q.clone()));
    }
    let mut ok = 0;
    let mut shed = 0;
    let mut seen = BTreeMap::new();
    for _ in 0..8 {
        match c.recv() {
            Response::Assignments { id, .. } => {
                ok += 1;
                seen.insert(id, "ok");
            }
            Response::Error { id, code: ErrorCode::Overloaded, retry_after_ms, .. } => {
                assert_eq!(retry_after_ms, 50);
                shed += 1;
                seen.insert(id, "shed");
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(seen.len(), 8, "every request answered exactly once");
    assert!(ok >= 1, "the head of the burst is served");
    assert!(shed >= 1, "the tail of the burst is shed");
}

#[test]
fn batch_panics_are_isolated_quarantine_trips_and_reload_recovers() {
    let env = start(
        "panic",
        ServeOptions {
            admission: AdmissionConfig { quarantine_threshold: 3, ..Default::default() },
            faults: FaultPlan {
                panic_on_batches: vec![1, 2, 3],
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let mut c = Client::connect(&env.server);
    let q = queries_for(11, 3);

    // Three sequential batches, all killed by the injected panic; the
    // server answers each with a typed Internal error and stays up.
    for id in 1..=3u64 {
        c.send(&predict(id, "gmm", q.clone()));
        let Response::Error { id: rid, code, message, .. } = c.recv() else { panic!() };
        assert_eq!((rid, code), (id, ErrorCode::Internal));
        assert!(message.contains("injected fault"), "{message}");
    }

    // The third consecutive failure quarantined the model: fast reject.
    c.send(&predict(4, "gmm", q.clone()));
    let Response::Error { id, code, .. } = c.recv() else { panic!() };
    assert_eq!((id, code), (4, ErrorCode::Quarantined));

    // The other model is untouched by the quarantine.
    let sq = synthetic::scrna_like(&mut Rng::seed_from(66), 4, 24)
        .to_sparse()
        .unwrap()
        .points;
    c.send(&predict(5, "cells", sq.clone()));
    assert_bitwise(&c.recv(), &sparse_model(2), &sq);

    // Reload clears the quarantine and the next batch (seq 5, past the
    // fault schedule) serves bitwise-correct answers again.
    c.send(&Request::Reload { id: 6, name: "gmm".into() });
    let Response::ReloadAck { text, .. } = c.recv() else { panic!() };
    assert!(text.contains("gmm: v2"), "{text}");
    c.send(&predict(7, "gmm", q.clone()));
    assert_bitwise(&c.recv(), &dense_model(1), &q);
}

#[test]
fn hot_swap_is_atomic_and_inflight_batches_finish_on_the_old_model() {
    let env = start(
        "hotswap",
        ServeOptions {
            faults: FaultPlan { stall_ms: 150, ..Default::default() },
            ..Default::default()
        },
    );
    let v1 = dense_model(1);
    let v2 = dense_model(77); // different seed -> different medoids
    let q = queries_for(13, 4);
    let mut c = Client::connect(&env.server);

    // P1 enters the dispatcher, pins generation v1, then stalls 150 ms.
    c.send(&predict(1, "gmm", q.clone()));
    thread::sleep(Duration::from_millis(40));
    // The reload lands mid-stall (the reader thread handles it inline).
    v2.save(&env.dir.join("gmm.bpmodel")).unwrap();
    c.send(&Request::Reload { id: 2, name: "gmm".into() });

    // Ack arrives first (the reload is not blocked by the stalled batch)...
    let Response::ReloadAck { id, text } = c.recv() else { panic!() };
    assert_eq!(id, 2);
    assert!(text.contains("v2"), "{text}");
    // ...then P1 completes on the generation it pinned: the OLD model.
    assert_bitwise(&c.recv(), &v1, &q);
    // New requests see the new generation.
    c.send(&predict(3, "gmm", q.clone()));
    assert_bitwise(&c.recv(), &v2, &q);
    // Sanity: the two generations genuinely disagree somewhere, or this
    // test proves nothing.
    let a1 = v1.predict(&q).unwrap();
    let a2 = v2.predict(&q).unwrap();
    let d1 = v1.predict_with_dists(&q).unwrap().1;
    let d2 = v2.predict_with_dists(&q).unwrap().1;
    assert!(
        a1 != a2 || d1.iter().zip(&d2).any(|(x, y)| x.to_bits() != y.to_bits()),
        "v1 and v2 answer identically; pick different seeds"
    );
}

#[test]
fn corrupt_frames_get_typed_errors_and_the_server_survives() {
    let env = start("hostile", ServeOptions::default());

    // Tier 1: body-grammar corruption is recoverable on the connection.
    let mut c = Client::connect(&env.server);
    let good = encode_request(&predict(21, "gmm", queries_for(17, 2)));
    let mut nan_body = good.clone();
    let n = nan_body.len();
    nan_body[n - 4..].copy_from_slice(&f32::NAN.to_le_bytes());
    c.send_raw(&nan_body);
    let Response::Error { id, code, message, .. } = c.recv() else { panic!() };
    assert_eq!((id, code), (21, ErrorCode::BadRequest));
    assert!(message.contains("non-finite"), "{message}");
    // same connection still serves
    c.send(&predict(22, "gmm", queries_for(17, 2)));
    assert_bitwise(&c.recv(), &dense_model(1), &queries_for(17, 2));

    // Tier 2: framing corruption is connection-fatal but server-safe.
    let mut bad = Client::connect(&env.server);
    let mut mangled = good.clone();
    mangled[0] = b'X';
    bad.send_raw(&mangled);
    let Response::Error { id, code, .. } = bad.recv() else { panic!() };
    assert_eq!((id, code), (0, ErrorCode::BadRequest));
    assert!(bad.recv_opt().is_none(), "framing loss closes the connection");
    bad.close();

    // The server keeps accepting fresh connections afterwards.
    let mut c2 = Client::connect(&env.server);
    c2.send(&Request::Ping { id: 30 });
    assert!(matches!(c2.recv(), Response::Pong { id: 30 }));
}

#[test]
fn slow_loris_fragmented_writes_still_serve_correctly() {
    let env = start("loris", ServeOptions::default());
    let (cw, sr) = pipe();
    let (sw, cr) = pipe();
    let server = Arc::clone(&env.server);
    let conn = thread::spawn(move || server.handle_connection(sr, sw));

    // Dribble the frames 5 bytes at a time with a delay.
    let mut slow = SlowWriter { inner: cw, chunk: 5, delay: Duration::from_millis(1) };
    let q = queries_for(19, 3);
    slow.write_all(&encode_request(&predict(1, "gmm", q.clone()))).unwrap();
    slow.write_all(&encode_request(&Request::Ping { id: 2 })).unwrap();

    let mut r = cr;
    let mut got = Vec::new();
    for _ in 0..2 {
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        got.push(parse_response(kind, &body).unwrap());
    }
    got.sort_by_key(|resp| resp.id());
    assert_bitwise(&got[0], &dense_model(1), &q);
    assert!(matches!(got[1], Response::Pong { id: 2 }));
    drop(slow);
    conn.join().unwrap();
}

#[test]
fn shutdown_drains_admitted_work_and_acks_last() {
    let env = start("drain", ServeOptions::default());
    let mut c = Client::connect(&env.server);
    let qs: Vec<Points> = (0..4).map(|i| queries_for(23 + i, 2)).collect();
    for (i, q) in qs.iter().enumerate() {
        c.send(&predict(i as u64, "gmm", q.clone()));
    }
    c.send(&Request::Shutdown { id: 9 });

    let mut resps = Vec::new();
    while let Some(resp) = c.recv_opt() {
        resps.push(resp);
    }
    // Every admitted predict is answered, and the ack is the very last
    // frame on the wire (the clean-drain guarantee).
    assert!(matches!(resps.last(), Some(Response::ShutdownAck { id: 9 })));
    let answered: Vec<u64> = resps[..resps.len() - 1]
        .iter()
        .map(|resp| {
            assert!(
                matches!(resp, Response::Assignments { .. }),
                "pre-shutdown work drains as answers, got {resp:?}"
            );
            resp.id()
        })
        .collect();
    let mut sorted = answered.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, vec![0, 1, 2, 3]);

    // Post-shutdown predicts are refused with ShuttingDown.
    env.server.join();
    let mut late = Client::connect(&env.server);
    late.send(&predict(50, "gmm", qs[0].clone()));
    let Response::Error { code, .. } = late.recv() else { panic!() };
    assert_eq!(code, ErrorCode::ShuttingDown);
}

#[test]
fn stats_snapshot_counts_the_traffic() {
    let env = start("stats", ServeOptions::default());
    let mut c = Client::connect(&env.server);
    let q = queries_for(29, 2);
    c.send(&predict(1, "gmm", q.clone()));
    c.recv();
    c.send(&predict(2, "nope", q));
    c.recv();
    c.send(&Request::Stats { id: 3 });
    let Response::Stats { text, .. } = c.recv() else { panic!() };
    let json = banditpam::util::json::Json::parse(&text).unwrap();
    assert_eq!(json.get("admitted").and_then(|j| j.as_usize()), Some(1));
    assert_eq!(json.get("served_ok").and_then(|j| j.as_usize()), Some(1));
    assert_eq!(json.get("shed").and_then(|j| j.as_usize()), Some(0));
}
