//! Property suite for the block hot path: the tiled row kernels and the
//! pooled `block` must agree with a naive per-pair distance loop across
//! every metric, odd feature shapes, thread counts, and with/without the
//! pairwise cache — and evaluation counting must be deterministic between
//! the serial and pooled engines.

use banditpam::data::{synthetic, Dataset, Points};
use banditpam::distance::{dense, evaluate, Metric};
use banditpam::prop_assert;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::testkit::prop::{check, gen, PropConfig};
use banditpam::util::rng::Rng;

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

/// The odd/edge feature dimensions the ISSUE calls out, plus remainder
/// shapes around the 16-lane boundary.
const DIMS: &[usize] = &[1, 7, 31, 784];

/// Thread counts exercised for every configuration.
const THREADS: &[usize] = &[1, 2, 8];

fn dense_dataset(rng: &mut Rng, d: usize) -> Dataset {
    let n = rng.range(20, 48);
    synthetic::gmm(rng, n, d, 3, 2.0)
}

/// Naive reference: uncounted per-pair dispatch, exactly the seed's inner
/// loop semantics.
fn naive_block(points: &Points, metric: Metric, targets: &[usize], refs: &[usize]) -> Vec<f64> {
    let mut out = vec![0.0; targets.len() * refs.len()];
    for (ti, &t) in targets.iter().enumerate() {
        for (ri, &r) in refs.iter().enumerate() {
            out[ti * refs.len() + ri] = evaluate(metric, points, t, r);
        }
    }
    out
}

fn block_of(backend: &dyn DistanceBackend, targets: &[usize], refs: &[usize]) -> Vec<f64> {
    let mut out = vec![0.0; targets.len() * refs.len()];
    backend.block(targets, refs, &mut out);
    out
}

/// Pick a random (targets, refs) pair over `n` points, allowing overlap
/// and a single-target shape (which shards along the reference axis).
fn random_request(rng: &mut Rng, n: usize) -> (Vec<usize>, Vec<usize>) {
    let tn = if rng.bool(0.25) { 1 } else { rng.range(2, 12) };
    let rn = rng.range(1, n.min(24));
    let targets = rng.sample_indices(n, tn);
    let refs = rng.sample_indices(n, rn);
    (targets, refs)
}

#[test]
fn prop_dense_block_matches_naive_per_pair_loop() {
    check("dense-block-vs-naive", &cfg(12), |rng| {
        for &d in DIMS {
            let ds = dense_dataset(rng, d);
            let n = ds.len();
            let (targets, refs) = random_request(rng, n);
            for metric in [Metric::L2, Metric::L1, Metric::Cosine] {
                let want = naive_block(&ds.points, metric, &targets, &refs);
                for &threads in THREADS {
                    for cached in [false, true] {
                        let mut backend = NativeBackend::new(&ds.points, metric)
                            .with_threads(threads)
                            .with_pool_min_work(0); // force pooled execution
                        if cached {
                            backend = backend.with_cache(1 << 16);
                        }
                        let got = block_of(&backend, &targets, &refs);
                        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                            let tol = 2e-5 * (1.0 + w.abs());
                            prop_assert!(
                                (g - w).abs() <= tol,
                                "{metric} d={d} threads={threads} cached={cached} \
                                 [{i}]: {g} vs {w}"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tree_edit_block_matches_naive_per_pair_loop() {
    check("tree-block-vs-naive", &cfg(6), |rng| {
        let n_trees = rng.range(12, 24);
        let ds = synthetic::hoc4_like(rng, n_trees);
        let n = ds.len();
        let (targets, refs) = random_request(rng, n);
        let want = naive_block(&ds.points, Metric::TreeEdit, &targets, &refs);
        for &threads in THREADS {
            for cached in [false, true] {
                let mut backend = NativeBackend::new(&ds.points, Metric::TreeEdit)
                    .with_threads(threads)
                    .with_pool_min_work(0);
                if cached {
                    backend = backend.with_cache(1 << 16);
                }
                let got = block_of(&backend, &targets, &refs);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    prop_assert!(
                        g == w,
                        "tree_edit threads={threads} cached={cached} [{i}]: {g} vs {w}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_row_kernels_match_per_pair_kernels() {
    check("row-kernels-vs-pairwise", &cfg(20), |rng| {
        for &d in DIMS {
            let a = gen::vector(rng, d);
            let refs: Vec<Vec<f32>> = (0..rng.range(1, 12)).map(|_| gen::vector(rng, d)).collect();
            let mut out = vec![0.0; refs.len()];

            dense::l2_row(&a, refs.iter().map(Vec::as_slice), &mut out);
            for (o, b) in out.iter().zip(&refs) {
                prop_assert!(*o == dense::l2(&a, b), "l2_row d={d}");
            }
            dense::l1_row(&a, refs.iter().map(Vec::as_slice), &mut out);
            for (o, b) in out.iter().zip(&refs) {
                prop_assert!(*o == dense::l1(&a, b), "l1_row d={d}");
            }
            dense::cosine_row(
                &a,
                dense::sq_norm(&a),
                refs.iter().map(|b| (b.as_slice(), dense::sq_norm(b))),
                &mut out,
            );
            for (o, b) in out.iter().zip(&refs) {
                let want = dense::cosine(&a, b);
                let tol = 2e-5 * (1.0 + want.abs());
                prop_assert!((*o - want).abs() <= tol, "cosine_row d={d}: {o} vs {want}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_counter_totals_identical_serial_vs_pooled() {
    check("counter-determinism", &cfg(10), |rng| {
        let d = *rng.choose(DIMS);
        let ds = dense_dataset(rng, d);
        let n = ds.len();
        // Disjoint unique targets/refs: overlapping ids would share a
        // symmetric cache key, making the miss count depend on timing.
        let tn = rng.range(1, 8);
        let rn = rng.range(1, (n - tn).min(16));
        let mut ids = rng.sample_indices(n, tn + rn);
        let refs = ids.split_off(tn);
        let targets = ids;
        for metric in [Metric::L2, Metric::Cosine] {
            for cached in [false, true] {
                let mut counts = Vec::new();
                for &threads in THREADS {
                    let mut backend = NativeBackend::new(&ds.points, metric)
                        .with_threads(threads)
                        .with_pool_min_work(0);
                    if cached {
                        backend = backend.with_cache(1 << 16);
                    }
                    let _ = block_of(&backend, &targets, &refs);
                    let _ = block_of(&backend, &targets, &refs); // repeat: cache hits
                    counts.push(backend.counter().get());
                }
                prop_assert!(
                    counts.windows(2).all(|w| w[0] == w[1]),
                    "{metric} cached={cached}: counts differ across thread \
                     counts: {counts:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn pooled_build_and_swap_pulls_match_serial_end_to_end() {
    // Integration-flavored determinism check: a full BanditPAM fit must
    // produce identical medoids and identical evaluation counts whether
    // blocks run serially or through the pool.
    use banditpam::algorithms::KMedoids;
    use banditpam::coordinator::banditpam::BanditPam;

    let ds = synthetic::gmm(&mut Rng::seed_from(77), 120, 9, 4, 3.0);
    let mut results = Vec::new();
    for &threads in THREADS {
        let backend = NativeBackend::new(&ds.points, Metric::L2)
            .with_threads(threads)
            .with_pool_min_work(0);
        let fit = BanditPam::default_paper()
            .fit(&backend, 4, &mut Rng::seed_from(5))
            .unwrap();
        results.push((fit.medoids.clone(), fit.loss, backend.counter().get()));
    }
    for pair in results.windows(2) {
        assert_eq!(pair[0].0, pair[1].0, "medoids must not depend on threading");
        assert_eq!(pair[0].1, pair[1].1, "loss must not depend on threading");
        assert_eq!(pair[0].2, pair[1].2, "counts must not depend on threading");
    }
}
