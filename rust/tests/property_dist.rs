//! Acceptance suite for the dist subsystem: **N workers == 1 process,
//! bitwise**. Seeded fits through [`ShardedBackend`] over worker pools of
//! every size must return byte-identical medoids, assignment vectors and
//! loss bits — and the exact same summed eval counters — as the plain
//! single-process [`NativeBackend`] fit, across storage kinds and
//! metrics. Fault tolerance is held to the same bar: a worker killed
//! deterministically mid-fit must recover (reassign/respawn) and still
//! produce identical results.
//!
//! Workers here are real worker loops over the real wire codec: threads
//! speaking through in-memory pipes (the exact socket code path), plus
//! one test that spawns actual `banditpam worker` child processes over
//! stdio pipes.

use banditpam::algorithms::KMedoids;
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::coordinator::config::BanditPamConfig;
use banditpam::data::{synthetic, Dataset, Points};
use banditpam::dist::{run_worker, PoolOptions, ShardedBackend, WorkerOptions, WorkerPool};
use banditpam::distance::Metric;
use banditpam::model::Fit;
use banditpam::runtime::backend::NativeBackend;
use banditpam::serve::faults::{pipe, FaultPlan};
use banditpam::util::rng::Rng;
use std::io::{Read, Write};
use std::thread;

/// In-process pool: each worker is a thread running the real worker loop
/// over the real wire codec. `plans[i]` injects deterministic faults into
/// worker `i` (default: healthy).
fn pipe_pool<'d>(
    points: &'d Points,
    metric: Metric,
    workers: usize,
    plans: &[FaultPlan],
) -> WorkerPool<'d> {
    let mut transports: Vec<(Box<dyn Write + Send>, Box<dyn Read + Send>)> = Vec::new();
    for i in 0..workers {
        let (cw, sr) = pipe();
        let (sw, cr) = pipe();
        let opts =
            WorkerOptions { faults: plans.get(i).cloned().unwrap_or_default(), quiet: true };
        thread::spawn(move || {
            let _ = run_worker(sr, sw, &opts);
        });
        transports.push((Box::new(cw), Box::new(cr)));
    }
    WorkerPool::from_transports(points, metric, transports, PoolOptions::default()).unwrap()
}

/// The two storage kinds under test, from one seeded generator: the
/// sparse dataset is the dense one converted to CSR, so the values (and
/// therefore every distance bit) are pinned by the same draw.
fn datasets() -> Vec<Dataset> {
    let dense = synthetic::gmm(&mut Rng::seed_from(77), 60, 6, 3, 3.0);
    let sparse = dense.to_sparse().expect("dense gmm converts to CSR");
    vec![dense, sparse]
}

fn single_process_fit(
    points: &Points,
    metric: Metric,
    k: usize,
    seed: u64,
) -> banditpam::algorithms::Clustering {
    let backend = NativeBackend::new(points, metric);
    BanditPam::new(BanditPamConfig::default())
        .fit(&backend, k, &mut Rng::seed_from(seed))
        .expect("single-process fit")
}

#[test]
fn sharded_fits_match_single_process_bitwise() {
    for ds in datasets() {
        for metric in [Metric::L2, Metric::L1, Metric::Cosine] {
            let base = single_process_fit(&ds.points, metric, 3, 42);
            for workers in [1usize, 2, 4] {
                let pool = pipe_pool(&ds.points, metric, workers, &[]);
                let backend = ShardedBackend::new(&ds.points, metric, &pool);
                let got = BanditPam::new(BanditPamConfig::default())
                    .fit(&backend, 3, &mut Rng::seed_from(42))
                    .expect("sharded fit");
                let tag = format!("{} metric={metric} workers={workers}", ds.points.kind());
                assert_eq!(got.medoids, base.medoids, "{tag}");
                assert_eq!(got.assignments, base.assignments, "{tag}");
                assert_eq!(got.loss.to_bits(), base.loss.to_bits(), "{tag}");
                assert_eq!(
                    got.stats.distance_evals, base.stats.distance_evals,
                    "{tag}: summed shard eval counters must equal the local count"
                );
                assert_eq!(pool.fallbacks(), 0, "{tag}: healthy pool must never fall back");
            }
        }
    }
}

#[test]
fn worker_killed_at_pinned_request_recovers_identically() {
    let ds = synthetic::gmm(&mut Rng::seed_from(19), 48, 5, 3, 3.0);
    let base = single_process_fit(&ds.points, Metric::L2, 3, 11);
    // Worker 0 dies on its 3rd work request — deterministically, at the
    // same pinned point in the request stream every run. Its shard
    // reassigns to a survivor and the fit must not notice.
    let plans = vec![
        FaultPlan { panic_on_batches: vec![3], ..Default::default() },
        FaultPlan::default(),
    ];
    let pool = pipe_pool(&ds.points, Metric::L2, 2, &plans);
    let backend = ShardedBackend::new(&ds.points, Metric::L2, &pool);
    let got = BanditPam::new(BanditPamConfig::default())
        .fit(&backend, 3, &mut Rng::seed_from(11))
        .expect("fit through a worker kill");
    assert_eq!(got.medoids, base.medoids);
    assert_eq!(got.assignments, base.assignments);
    assert_eq!(got.loss.to_bits(), base.loss.to_bits());
    assert_eq!(got.stats.distance_evals, base.stats.distance_evals);
    assert!(pool.respawns() >= 1, "the kill must have been recovered");
    assert!(pool.retries() >= 1, "the in-flight request must have been retried");
}

#[test]
fn spawned_subprocess_workers_match_single_process() {
    // Real child processes of the real binary over stdio pipes — the
    // exact `cluster --workers N` deployment. `current_exe()` inside a
    // test binary is the test runner, so point the pool at the built CLI.
    let ds = synthetic::gmm(&mut Rng::seed_from(3), 40, 4, 3, 3.0);
    let base = single_process_fit(&ds.points, Metric::L2, 3, 5);
    let opts = PoolOptions {
        program: Some(env!("CARGO_BIN_EXE_banditpam").into()),
        ..PoolOptions::default()
    };
    let pool = WorkerPool::spawn_local(&ds.points, Metric::L2, 2, opts)
        .expect("spawn local workers");
    pool.ping().expect("workers answer ping");
    let backend = ShardedBackend::new(&ds.points, Metric::L2, &pool);
    let got = BanditPam::new(BanditPamConfig::default())
        .fit(&backend, 3, &mut Rng::seed_from(5))
        .expect("subprocess-sharded fit");
    assert_eq!(got.medoids, base.medoids);
    assert_eq!(got.assignments, base.assignments);
    assert_eq!(got.loss.to_bits(), base.loss.to_bits());
    assert_eq!(got.stats.distance_evals, base.stats.distance_evals);
}

#[test]
fn bigfit_with_workers_matches_single_process() {
    // The distributed bigfit path shards the full-dataset scoring pass;
    // the model, loss bits and every eval-count component must match the
    // local run.
    let ds = synthetic::gmm(&mut Rng::seed_from(29), 150, 6, 4, 3.0);
    let fit = || Fit::banditpam().metric(Metric::L2).k(3).seed(13).threads(1);
    let (base_model, base_stats) =
        fit().big().samples(3).fit_with_stats(&ds).expect("local bigfit");

    let pool = pipe_pool(&ds.points, Metric::L2, 3, &[]);
    let (model, stats) = fit()
        .big()
        .samples(3)
        .fit_with_workers(&ds, &pool)
        .expect("sharded bigfit");

    assert_eq!(model.clustering().medoids, base_model.clustering().medoids);
    assert_eq!(model.clustering().assignments, base_model.clustering().assignments);
    assert_eq!(model.loss().to_bits(), base_model.loss().to_bits());
    assert_eq!(
        model.clustering().stats.distance_evals,
        base_model.clustering().stats.distance_evals
    );
    assert_eq!(
        model.clustering().stats.eval_evals,
        base_model.clustering().stats.eval_evals,
        "the sharded scoring pass must count exactly the local evals"
    );
    assert_eq!(stats.samples, base_stats.samples);
    assert_eq!(stats.n_rows, base_stats.n_rows);
}
