//! Golden-fixture tests for the `.mtx` readers: every malformed input
//! under `tests/fixtures/` must produce a clean `Err` — never a panic,
//! never an allocation blow-up — from **both** the in-memory loader and
//! the chunked out-of-core reader, and the good fixtures pin the
//! `--limit`/`--transpose` interaction and the write -> chunked-read ->
//! write roundtrip.

use banditpam::data::stream::{self, CsrChunkReader, StreamOptions};
use banditpam::data::{loader, synthetic, Points};
use banditpam::prelude::*;
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join(name)
}

/// Streamed load via the public reader, surfacing open-time and
/// window-time errors alike.
fn stream_load(path: &Path, opts: StreamOptions) -> anyhow::Result<CsrMatrix> {
    let mut r = CsrChunkReader::open(path, opts)?;
    r.read_all()
}

#[test]
fn malformed_fixtures_err_cleanly_in_both_readers() {
    for name in [
        "malformed_header.mtx",
        "array_format.mtx",
        "symmetric.mtx",
        "out_of_range.mtx",
        "nnz_unparseable.mtx",  // nnz overflow: too large to parse into usize
        "truncated_body.mtx",   // body ends mid-window
        "huge_shape.mtx",       // rows far beyond the MAX_DIM ceiling
        "huge_rows_in_u32.mtx", // rows fit u32 but exceed MAX_DIM: ~GB indptr lie
        "extra_entries.mtx",    // more entries than the size line promises
        "missing_value.mtx",    // real body with a pattern-style entry
        "missing_size.mtx",
    ] {
        let p = fixture(name);
        assert!(p.exists(), "fixture {name} missing");
        for transpose in [false, true] {
            let mem = loader::load_mtx(&p, transpose, 0);
            assert!(mem.is_err(), "{name} transpose={transpose}: in-memory must Err");
            for chunk in [1usize, 1 << 20] {
                let st = stream_load(&p, StreamOptions { chunk_nnz: chunk, transpose, limit: 0 });
                assert!(st.is_err(), "{name} transpose={transpose} chunk={chunk}: chunked must Err");
            }
        }
    }
}

/// A size line declaring more entries than the matrix has cells is legal
/// when the extras are duplicate coordinates (summed in file order) —
/// both readers must accept it and agree. Pre-PR-4 the in-memory loader
/// accepted such files; this pins that the shared grammar still does.
#[test]
fn duplicate_heavy_overfull_file_loads_in_both_readers() {
    let p = fixture("duplicate_overfull.mtx"); // 2x2, nnz=5, (1,1) twice
    let mem = loader::load_mtx(&p, false, 0).unwrap();
    let Points::Sparse(m) = &mem.points else { unreachable!() };
    assert_eq!(m.row(0), (&[0u32, 1][..], &[2.0f32, 1.0][..])); // dup summed
    assert_eq!(m.row(1), (&[0u32, 1][..], &[1.0f32, 1.0][..]));
    for chunk in [1usize, 1 << 20] {
        for transpose in [false, true] {
            let st = stream_load(&p, StreamOptions { chunk_nnz: chunk, transpose, limit: 0 })
                .unwrap();
            let mem_t = loader::load_mtx(&p, transpose, 0).unwrap();
            let Points::Sparse(e) = &mem_t.points else { unreachable!() };
            assert_eq!(&st, e, "chunk={chunk} transpose={transpose}");
        }
    }
}

/// The `--limit` row cap applies to **post-transpose** rows — cells, not
/// genes, on a 10x-layout file — identically in both readers. (Before the
/// streaming subsystem, `--limit` was silently ignored for `.mtx` input;
/// this fixture pins the repaired semantics.)
#[test]
fn limit_counts_post_transpose_rows_in_both_readers() {
    let p = fixture("limit_transpose.mtx"); // 3 genes x 4 cells
    // transpose: points = cells; limit 2 keeps cells 0 and 1 only
    let mem = loader::load_mtx(&p, true, 2).unwrap();
    assert_eq!(mem.len(), 2);
    assert_eq!(mem.points.dim(), Some(3));
    let Points::Sparse(m) = &mem.points else { unreachable!() };
    assert_eq!(m.row(0), (&[0u32, 1][..], &[1.0f32, 2.0][..])); // cell 1: genes 1, 2
    assert_eq!(m.row(1), (&[2u32][..], &[3.0f32][..])); // cell 2: gene 3
    for chunk in [1usize, 3, 1 << 20] {
        let st = stream_load(&p, StreamOptions { chunk_nnz: chunk, transpose: true, limit: 2 })
            .unwrap();
        assert_eq!(&st, m, "chunk={chunk}");
    }
    // no transpose: points = genes; limit 2 keeps genes 0 and 1
    let mem_g = loader::load_mtx(&p, false, 2).unwrap();
    assert_eq!(mem_g.len(), 2);
    assert_eq!(mem_g.points.dim(), Some(4));
    let Points::Sparse(g) = &mem_g.points else { unreachable!() };
    assert_eq!(g.row(0), (&[0u32, 2][..], &[1.0f32, 4.0][..])); // gene 1: cells 1, 3
    assert_eq!(g.row(1), (&[0u32, 3][..], &[2.0f32, 5.0][..])); // gene 2: cells 1, 4
    for chunk in [1usize, 1 << 20] {
        let st = stream_load(&p, StreamOptions { chunk_nnz: chunk, transpose: false, limit: 2 })
            .unwrap();
        assert_eq!(&st, g, "chunk={chunk}");
    }
    // limit 0 = all rows, and limit > rows saturates
    assert_eq!(loader::load_mtx(&p, true, 0).unwrap().len(), 4);
    assert_eq!(loader::load_mtx(&p, true, 99).unwrap().len(), 4);
}

/// write -> chunked-read -> write must reproduce the original file byte
/// for byte: the streamed matrix is bitwise the in-memory one, and the
/// writer's canonical row-major triplet order is a pure function of it.
#[test]
fn write_chunked_read_write_roundtrip_is_byte_identical() {
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(17), 40, 64, 0.10);
    let dir = std::env::temp_dir();
    let first = dir.join(format!("banditpam_rt_a_{}.mtx", std::process::id()));
    let second = dir.join(format!("banditpam_rt_b_{}.mtx", std::process::id()));
    loader::save_mtx(&ds, &first).unwrap();
    let (streamed, stats) = stream::load_mtx_streamed(
        &first,
        &StreamOptions { chunk_nnz: 37, ..Default::default() },
    )
    .unwrap();
    assert!(stats.windows > 1, "budget must actually window the file");
    loader::save_mtx(&streamed, &second).unwrap();
    let a = std::fs::read(&first).unwrap();
    let b = std::fs::read(&second).unwrap();
    assert_eq!(a, b, "roundtrip must be byte-identical");
    let _ = std::fs::remove_file(first);
    let _ = std::fs::remove_file(second);
}
