#!/usr/bin/env python3
"""Regenerate the serve-protocol golden fixtures.

The two valid_* files pin the wire format byte-exactly (the Rust side
asserts encode_request output equals them); the corrupt_* files are
hostile inputs the parser must reject with a clean error, never a panic.
Layout reference: rust/SERVE.md.
"""
import struct
from pathlib import Path

HERE = Path(__file__).parent
MAGIC = b"BQ"
VERSION = 1
KIND_PREDICT = 1
KIND_STATS_RESP = 0x84


def frame(kind: int, body: bytes, version: int = VERSION, magic: bytes = MAGIC,
          length: int | None = None) -> bytes:
    n = len(body) if length is None else length
    return magic + bytes([version, kind]) + struct.pack("<I", n) + body


def dense_predict(req_id: int, name: bytes, deadline_ms: int, n: int, dim: int,
                  values: list[float]) -> bytes:
    body = struct.pack("<Q", req_id)
    body += struct.pack("<H", len(name)) + name
    body += struct.pack("<I", deadline_ms)
    body += b"\x00"  # dense
    body += struct.pack("<II", n, dim)
    body += b"".join(struct.pack("<f", v) for v in values)
    return body


def sparse_predict(req_id: int, name: bytes, deadline_ms: int, n: int, dim: int,
                   indptr: list[int], indices: list[int], values: list[float],
                   nnz: int | None = None) -> bytes:
    body = struct.pack("<Q", req_id)
    body += struct.pack("<H", len(name)) + name
    body += struct.pack("<I", deadline_ms)
    body += b"\x01"  # sparse
    body += struct.pack("<II", n, dim)
    body += struct.pack("<Q", len(indices) if nnz is None else nnz)
    body += b"".join(struct.pack("<Q", p) for p in indptr)
    body += b"".join(struct.pack("<I", j) for j in indices)
    body += b"".join(struct.pack("<f", v) for v in values)
    return body


def write(name: str, data: bytes) -> None:
    (HERE / name).write_bytes(data)
    print(f"{name}: {len(data)} bytes")


valid_dense_body = dense_predict(7, b"gmm", 250, 2, 3, [1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
valid_dense = frame(KIND_PREDICT, valid_dense_body)
write("valid_dense_predict.bin", valid_dense)

valid_sparse_body = sparse_predict(42, b"cells", 0, 2, 4,
                                   [0, 2, 3], [0, 3, 1], [1.5, -2.0, 0.25])
write("valid_sparse_predict.bin", frame(KIND_PREDICT, valid_sparse_body))

# The stats response must be byte-deterministic for fixed counters:
# stable key order, per_model sorted by model id (BTreeMap iteration).
# The Rust side rebuilds this exact JSON from a populated ServeStats via
# snapshot_json_at(42, 7) and asserts the encoded frame equals this file.
stats_json = (
    '{"admitted":9,"shed":2,"deadline_expired":1,"batches":4,"panics":1,'
    '"served_ok":7,"bad_requests":3,"reloads":2,"quarantined":1,'
    '"uptime_secs":42,"queue_depth":7,"per_model":{"alpha":5,"zeta":2}}'
)
stats_body = struct.pack("<Q", 77)
stats_body += struct.pack("<I", len(stats_json)) + stats_json.encode()
write("valid_stats_response.bin", frame(KIND_STATS_RESP, stats_body))

# --- framing-fatal corruptions (read_frame must Err) ---
write("corrupt_bad_magic.bin", frame(KIND_PREDICT, valid_dense_body, magic=b"XQ"))
write("corrupt_bad_version.bin", frame(KIND_PREDICT, valid_dense_body, version=9))
write("corrupt_oversized_len.bin",
      frame(KIND_PREDICT, valid_dense_body, length=0xFFFFFFFF))
write("corrupt_truncated_header.bin", valid_dense[:5])
write("corrupt_truncated_body.bin", valid_dense[:-8])

# --- body-grammar corruptions (parse_request must Err, id echoed) ---
write("corrupt_unknown_kind.bin", frame(0x7F, struct.pack("<Q", 9)))
write("corrupt_trailing_bytes.bin", frame(KIND_PREDICT, valid_dense_body + b"\x00"))
write("corrupt_lying_nnz.bin",
      frame(KIND_PREDICT, sparse_predict(11, b"cells", 0, 2, 4,
                                         [0, 2, 3], [0, 3, 1], [1.5, -2.0, 0.25],
                                         nnz=1000)))
write("corrupt_bad_indptr.bin",
      frame(KIND_PREDICT, sparse_predict(12, b"cells", 0, 2, 4,
                                         [0, 3, 2], [0, 3, 1], [1.5, -2.0, 0.25])))
write("corrupt_nan_value.bin",
      frame(KIND_PREDICT, dense_predict(13, b"gmm", 0, 1, 2,
                                        [1.0, float("nan")])))
huge_name = struct.pack("<Q", 14) + struct.pack("<H", 0xFFFF) + b"x" * 16
write("corrupt_huge_name.bin", frame(KIND_PREDICT, huge_name))
dim_overflow = struct.pack("<Q", 15) + struct.pack("<H", 1) + b"m"
dim_overflow += struct.pack("<I", 0) + b"\x00"
dim_overflow += struct.pack("<II", 0xFFFFFFFF, 0xFFFFFFFF)
write("corrupt_dim_overflow.bin", frame(KIND_PREDICT, dim_overflow))
