#!/usr/bin/env python3
"""Regenerate the dist-protocol ("BD" dialect) golden fixtures.

The valid_* files pin the wire format byte-exactly in both directions
(the Rust side asserts encode_request / encode_response output equals
them, and that parsing recovers every field); the corrupt_* files are
hostile inputs the parser must reject with a clean error at the right
tier — framing (connection-fatal) or body (recoverable, id echoed) —
never a panic. Layout reference: rust/DIST.md.
"""
import struct
from pathlib import Path

HERE = Path(__file__).parent
MAGIC = b"BD"
VERSION = 1

REQ_LOAD = 1
REQ_LOAD_FILE = 2
REQ_BLOCK = 3
REQ_SCORE = 4
REQ_PING = 5
RESP_LOADED = 0x81
RESP_DISTANCES = 0x82
RESP_SCORE_PARTIAL = 0x83

METRIC_L2, METRIC_L1, METRIC_COSINE = 0, 1, 2


def frame(kind: int, body: bytes, version: int = VERSION, magic: bytes = MAGIC,
          length: int | None = None) -> bytes:
    n = len(body) if length is None else length
    return magic + bytes([version, kind]) + struct.pack("<I", n) + body


def dense_points(rows: int, cols: int, values: list[float]) -> bytes:
    out = b"\x00" + struct.pack("<II", rows, cols)
    return out + b"".join(struct.pack("<f", v) for v in values)


def sparse_points(rows: int, cols: int, indptr: list[int], indices: list[int],
                  values: list[float], nnz: int | None = None) -> bytes:
    out = b"\x01" + struct.pack("<II", rows, cols)
    out += struct.pack("<Q", len(indices) if nnz is None else nnz)
    out += b"".join(struct.pack("<Q", p) for p in indptr)
    out += b"".join(struct.pack("<I", j) for j in indices)
    out += b"".join(struct.pack("<f", v) for v in values)
    return out


def load(req_id: int, shard: int, metric: int, points: bytes) -> bytes:
    return struct.pack("<QI", req_id, shard) + bytes([metric]) + points


def load_file(req_id: int, shard: int, metric: int, start: int, end: int,
              chunk_nnz: int, path: bytes, path_len: int | None = None) -> bytes:
    body = struct.pack("<QI", req_id, shard) + bytes([metric])
    body += struct.pack("<QQQ", start, end, chunk_nnz)
    body += struct.pack("<I", len(path) if path_len is None else path_len) + path
    return body


def block(req_id: int, shard: int, targets: bytes, refs: list[int],
          ref_count: int | None = None) -> bytes:
    body = struct.pack("<QI", req_id, shard) + targets
    body += struct.pack("<I", len(refs) if ref_count is None else ref_count)
    return body + b"".join(struct.pack("<I", j) for j in refs)


def write(name: str, data: bytes) -> None:
    (HERE / name).write_bytes(data)
    print(f"{name}: {len(data)} bytes")


DENSE = dense_points(2, 3, [1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
SPARSE = sparse_points(2, 4, [0, 2, 3], [0, 3, 1], [1.5, -2.0, 0.25])

# --- valid fixtures: pinned byte-exactly in both directions ---
write("valid_load_dense.bin", frame(REQ_LOAD, load(3, 1, METRIC_COSINE, DENSE)))
write("valid_load_sparse.bin", frame(REQ_LOAD, load(4, 0, METRIC_L2, SPARSE)))
write("valid_load_file.bin",
      frame(REQ_LOAD_FILE,
            load_file(9, 2, METRIC_L1, 100, 250, 4096, b"data/cells.mtx")))
write("valid_block.bin", frame(REQ_BLOCK, block(7, 0, DENSE, [0, 2, 5])))
write("valid_score.bin", frame(REQ_SCORE, struct.pack("<QI", 5, 3) + SPARSE))

dists = [0.5, 1.25, 2.0, -0.25, 3.5, 0.125]
write("valid_distances_response.bin",
      frame(RESP_DISTANCES,
            struct.pack("<QIQI", 7, 0, 6, len(dists))
            + b"".join(struct.pack("<d", d) for d in dists)))
write("valid_score_partial_response.bin",
      frame(RESP_SCORE_PARTIAL,
            struct.pack("<QIQI", 5, 3, 8, 4)
            + b"".join(struct.pack("<I", a) for a in [0, 1, 1, 0])
            + b"".join(struct.pack("<d", d) for d in [0.1, 0.2, 0.3, 0.4])))

# --- framing-fatal corruptions (read_frame must Err, link dead) ---
valid_block_frame = frame(REQ_BLOCK, block(7, 0, DENSE, [0, 2, 5]))
write("corrupt_bad_magic.bin",
      frame(REQ_BLOCK, block(7, 0, DENSE, [0, 2, 5]), magic=b"XD"))
# The serve dialect against the dist parser: wrong magic, dead link.
write("corrupt_serve_magic.bin",
      frame(REQ_BLOCK, block(7, 0, DENSE, [0, 2, 5]), magic=b"BQ"))
write("corrupt_bad_version.bin",
      frame(REQ_BLOCK, block(7, 0, DENSE, [0, 2, 5]), version=9))
write("corrupt_oversized_len.bin",
      frame(REQ_BLOCK, block(7, 0, DENSE, [0, 2, 5]), length=0xFFFFFFFF))
write("corrupt_truncated_header.bin", valid_block_frame[:5])
write("corrupt_truncated_body.bin", valid_block_frame[:-4])

# --- body-grammar corruptions (parse must Err, id echoed, link lives) ---
write("corrupt_unknown_kind.bin", frame(0x7F, struct.pack("<Q", 21)))
write("corrupt_trailing_bytes.bin", frame(REQ_PING, struct.pack("<Q", 22) + b"\x00"))
write("corrupt_lying_ref_count.bin",
      frame(REQ_BLOCK, block(23, 0, DENSE, [0, 2, 5], ref_count=1000)))
write("corrupt_bad_metric_tag.bin", frame(REQ_LOAD, load(24, 0, 9, DENSE)))
write("corrupt_bad_storage_tag.bin",
      frame(REQ_LOAD, load(25, 0, METRIC_L2, b"\x07" + struct.pack("<II", 2, 3))))
write("corrupt_nan_value.bin",
      frame(REQ_LOAD,
            load(26, 0, METRIC_L2,
                 dense_points(1, 2, [1.0, float("nan")]))))
write("corrupt_bad_indptr.bin",
      frame(REQ_LOAD,
            load(27, 0, METRIC_L2,
                 sparse_points(2, 4, [0, 3, 2], [0, 3, 1], [1.5, -2.0, 0.25]))))
write("corrupt_huge_path.bin",
      frame(REQ_LOAD_FILE,
            load_file(28, 0, METRIC_L2, 0, 10, 64, b"x" * 16, path_len=0xFFFF)))
write("corrupt_empty_window.bin",
      frame(REQ_LOAD_FILE,
            load_file(29, 0, METRIC_L2, 50, 50, 64, b"data/cells.mtx")))
write("corrupt_dim_overflow.bin",
      frame(REQ_LOAD,
            load(30, 0, METRIC_L2,
                 b"\x00" + struct.pack("<II", 0xFFFFFFFF, 0xFFFFFFFF))))

# --- corrupt responses (the coordinator-side parser, same two tiers) ---
write("corrupt_resp_unknown_kind.bin", frame(0x7E, struct.pack("<Q", 31)))
write("corrupt_resp_lying_count.bin",
      frame(RESP_DISTANCES,
            struct.pack("<QIQI", 32, 0, 6, 1000)
            + b"".join(struct.pack("<d", d) for d in [0.5, 1.25, 2.0])))
