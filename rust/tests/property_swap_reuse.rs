//! Parity suite for the SWAP reuse subsystem (ISSUE 2): the session-backed
//! virtual arms must be *bitwise* interchangeable with the per-arm
//! `SwapArms` path — same g-values from `pull_many`, same exact means, and
//! a seeded end-to-end fit must return identical medoids with reuse on vs
//! off — across all four metrics, k, thread counts, and the pairwise cache.

use banditpam::algorithms::KMedoids;
use banditpam::bandits::adaptive::ArmSet;
use banditpam::coordinator::arms::{SwapArms, VirtualSwapArms};
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::coordinator::config::BanditPamConfig;
use banditpam::coordinator::session::SwapSession;
use banditpam::coordinator::state::MedoidState;
use banditpam::data::{synthetic, Dataset};
use banditpam::distance::Metric;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::util::rng::Rng;

/// All four metrics the repository supports.
const METRICS: &[Metric] = &[Metric::L2, Metric::L1, Metric::Cosine, Metric::TreeEdit];
const KS: &[usize] = &[1, 3, 10];
const THREADS: &[usize] = &[1, 8];

fn dataset_for(metric: Metric) -> Dataset {
    let mut rng = Rng::seed_from(0xDA7A);
    match metric {
        Metric::TreeEdit => synthetic::hoc4_like(&mut rng, 40),
        _ => synthetic::gmm(&mut rng, 40, 16, 4, 3.0),
    }
}

fn backend_for(ds: &Dataset, metric: Metric, threads: usize, cached: bool) -> NativeBackend<'_> {
    let mut b = NativeBackend::new(&ds.points, metric)
        .with_threads(threads)
        .with_pool_min_work(0); // force pooled execution even on tiny blocks
    if cached {
        b = b.with_cache(1 << 16);
    }
    b
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn virtual_arm_pulls_and_exact_match_swap_arms_bitwise() {
    for &metric in METRICS {
        let ds = dataset_for(metric);
        let n = ds.len();
        for &k in KS {
            for &threads in THREADS {
                for cached in [false, true] {
                    // Two identically-configured backends so evaluation
                    // counters and caches stay independent per path.
                    let b_virt = backend_for(&ds, metric, threads, cached);
                    let b_legacy = backend_for(&ds, metric, threads, cached);
                    let mut state = MedoidState::empty(n);
                    for m in 0..k {
                        state.add_medoid(&b_legacy, (m * 3) % n);
                    }
                    let cfg = BanditPamConfig::default();
                    let mut session =
                        SwapSession::new(n, k, &cfg, &mut Rng::seed_from(99));
                    // Reference batches: a shared-permutation prefix (the
                    // real Algorithm-1 access pattern) and an arbitrary
                    // subset (API generality).
                    let refs_prefix: Vec<usize> = session.shared_perm()[..17].to_vec();
                    let refs_arbitrary: Vec<usize> =
                        Rng::seed_from(5).sample_indices(n, 11);

                    let mut virt = VirtualSwapArms::new(&b_virt, &state, &mut session);
                    let mut legacy = SwapArms::new(&b_legacy, &state, true);
                    assert_eq!(virt.n_arms(), legacy.n_arms());
                    assert_eq!(virt.n_arms(), (n - k) * k);
                    let all_arms: Vec<usize> = (0..virt.n_arms()).collect();

                    for refs in [&refs_prefix, &refs_arbitrary] {
                        let mut out_v = vec![0.0; all_arms.len() * refs.len()];
                        let mut out_l = out_v.clone();
                        virt.pull_many(&all_arms, refs, &mut out_v);
                        legacy.pull_many(&all_arms, refs, &mut out_l);
                        assert_eq!(
                            bits(&out_v),
                            bits(&out_l),
                            "{metric} k={k} threads={threads} cached={cached}: \
                             pull_many diverged"
                        );
                    }

                    // Exact means, including consecutive same-candidate arms
                    // (the Algorithm-1 fallback pattern) and a far arm.
                    let probes = [0usize, 1.min(virt.n_arms() - 1), virt.n_arms() - 1];
                    for &arm in &probes {
                        let ev = virt.exact(arm);
                        let el = legacy.exact(arm);
                        assert_eq!(
                            ev.to_bits(),
                            el.to_bits(),
                            "{metric} k={k} threads={threads} cached={cached}: \
                             exact({arm}) {ev} vs {el}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn repeated_virtual_pull_costs_zero_extra_evals() {
    // The reuse claim itself, at the unit level: the second identical pull
    // round is served entirely from the session row cache.
    let ds = dataset_for(Metric::L2);
    let n = ds.len();
    let b = backend_for(&ds, Metric::L2, 1, false);
    let mut state = MedoidState::empty(n);
    for m in 0..3 {
        state.add_medoid(&b, m);
    }
    let cfg = BanditPamConfig::default();
    let mut session = SwapSession::new(n, 3, &cfg, &mut Rng::seed_from(1));
    let refs: Vec<usize> = session.shared_perm()[..20].to_vec();
    let mut virt = VirtualSwapArms::new(&b, &state, &mut session);
    let all_arms: Vec<usize> = (0..virt.n_arms()).collect();
    let mut out = vec![0.0; all_arms.len() * refs.len()];

    let before = b.counter().get();
    virt.pull_many(&all_arms, &refs, &mut out);
    let first_cost = b.counter().get() - before;
    assert_eq!(first_cost, ((n - 3) * 20) as u64, "one row per candidate");

    let out_first = out.clone();
    let before = b.counter().get();
    virt.pull_many(&all_arms, &refs, &mut out);
    assert_eq!(b.counter().get() - before, 0, "second pull must be free");
    assert_eq!(bits(&out), bits(&out_first));
}

#[test]
fn seeded_fit_identical_with_reuse_on_and_off() {
    // End-to-end parity: same seed, reuse on vs off -> identical medoids,
    // bitwise-identical loss, identical search trajectory (trace modulo
    // evaluation counts), and no extra evaluations with reuse.
    for (seed, metric, n, k) in [
        (1u64, Metric::L2, 400usize, 4usize),
        (2, Metric::Cosine, 300, 3),
        (3, Metric::L1, 250, 5),
    ] {
        let ds = synthetic::mnist_like(&mut Rng::seed_from(100 + seed), n);
        let run = |reuse: bool| {
            let backend = NativeBackend::new(&ds.points, metric);
            let mut algo = BanditPam::new(BanditPamConfig {
                swap_reuse: reuse,
                ..Default::default()
            });
            let fit = algo.fit(&backend, k, &mut Rng::seed_from(seed)).unwrap();
            (fit, algo.trace)
        };
        let (fit_on, trace_on) = run(true);
        let (fit_off, trace_off) = run(false);
        assert_eq!(fit_on.medoids, fit_off.medoids, "{metric} seed {seed}");
        assert_eq!(fit_on.loss.to_bits(), fit_off.loss.to_bits());
        assert_eq!(fit_on.stats.swaps_applied, fit_off.stats.swaps_applied);
        assert_eq!(fit_on.stats.swap_iters, fit_off.stats.swap_iters);
        assert_eq!(trace_on.len(), trace_off.len());
        for (a, b) in trace_on.iter().zip(&trace_off) {
            assert_eq!(
                (a.phase, a.arms, a.rounds, a.exact_fallbacks),
                (b.phase, b.arms, b.rounds, b.exact_fallbacks),
                "{metric} seed {seed}: trajectory diverged"
            );
        }
        assert!(
            fit_on.stats.swap_evals <= fit_off.stats.swap_evals,
            "{metric} seed {seed}: reuse cost extra evals ({} vs {})",
            fit_on.stats.swap_evals,
            fit_off.stats.swap_evals
        );
        assert_eq!(
            fit_off.stats.swap_evals_saved, 0,
            "reuse-off must not report savings"
        );
    }
}

#[test]
fn warm_start_preserves_quality() {
    // Estimator carry-over changes the trajectory (that is the point), so
    // the guarantee is statistical, not bitwise: same-quality clustering,
    // no eval blow-up.
    let ds = synthetic::mnist_like(&mut Rng::seed_from(55), 500);
    let run = |warm: bool| {
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = BanditPam::new(BanditPamConfig {
            swap_reuse: true,
            swap_warm_start: warm,
            ..Default::default()
        });
        algo.fit(&backend, 4, &mut Rng::seed_from(8)).unwrap()
    };
    let cold = run(false);
    let warm = run(true);
    assert!(
        warm.loss <= cold.loss * 1.02,
        "warm start degraded the clustering: {} vs {}",
        warm.loss,
        cold.loss
    );
    assert!(
        warm.stats.swap_evals <= cold.stats.swap_evals + cold.stats.swap_evals / 4,
        "warm start blew up the eval count: {} vs {}",
        warm.stats.swap_evals,
        cold.stats.swap_evals
    );
}
