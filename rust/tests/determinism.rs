//! Algorithm-level determinism (ISSUE 2): identical seeds must produce
//! byte-identical medoid sequences and `SearchTrace`s across thread counts
//! and across consecutive runs. PR 1 established counter/value determinism
//! for one `block`; with the SWAP session in the loop this suite locks the
//! same claim in at the full-fit level.

use banditpam::algorithms::KMedoids;
use banditpam::coordinator::banditpam::{BanditPam, SearchTrace};
use banditpam::coordinator::config::BanditPamConfig;
use banditpam::coordinator::session::SwapSession;
use banditpam::coordinator::state::MedoidState;
use banditpam::coordinator::swap::swap_step_session;
use banditpam::data::{synthetic, Dataset};
use banditpam::distance::Metric;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::util::rng::Rng;

const THREADS: &[usize] = &[1, 2, 8];

fn dataset() -> Dataset {
    synthetic::mnist_like(&mut Rng::seed_from(21), 350)
}

fn fit_once(
    ds: &Dataset,
    threads: usize,
    seed: u64,
) -> (Vec<usize>, u64, u64, Vec<SearchTrace>) {
    let backend = NativeBackend::new(&ds.points, Metric::L2)
        .with_threads(threads)
        .with_pool_min_work(0); // pooled even for tiny blocks
    let mut algo = BanditPam::default_paper();
    let fit = algo.fit(&backend, 4, &mut Rng::seed_from(seed)).unwrap();
    (
        fit.medoids,
        fit.loss.to_bits(),
        backend.counter().get(),
        algo.trace,
    )
}

/// ISSUE 9: the new arms consume the seeded rng (fasterpam shuffles its
/// candidate order every sweep, onebatchpam draws its batch through
/// `sample_indices`), so the determinism claim needs explicit coverage:
/// medoids, loss bits, backend counters, attributed eval counts and
/// assignments must be byte-identical across threads {1, 8} and reruns.
#[test]
fn new_arm_fits_are_byte_identical_across_thread_counts_and_runs() {
    let ds = dataset();
    for name in ["fasterpam", "onebatchpam"] {
        let mut results = Vec::new();
        for &threads in &[1usize, 8] {
            for _run in 0..2 {
                let backend = NativeBackend::new(&ds.points, Metric::L2)
                    .with_threads(threads)
                    .with_pool_min_work(0);
                let mut algo = banditpam::algorithms::make_algorithm(name).unwrap();
                let fit = algo.fit(&backend, 4, &mut Rng::seed_from(9)).unwrap();
                results.push((
                    fit.medoids,
                    fit.loss.to_bits(),
                    backend.counter().get(),
                    fit.stats.distance_evals,
                    fit.assignments,
                ));
            }
        }
        let first = &results[0];
        for r in &results[1..] {
            assert_eq!(first.0, r.0, "{name}: medoids must not depend on threads/reruns");
            assert_eq!(first.1, r.1, "{name}: loss bits must match");
            assert_eq!(first.2, r.2, "{name}: backend counters must match");
            assert_eq!(first.3, r.3, "{name}: attributed eval counts must match");
            assert_eq!(first.4, r.4, "{name}: assignments must match");
        }
    }
}

#[test]
fn fits_are_byte_identical_across_thread_counts_and_runs() {
    let ds = dataset();
    let mut results = Vec::new();
    for &threads in THREADS {
        for _run in 0..2 {
            results.push(fit_once(&ds, threads, 9));
        }
    }
    let first = &results[0];
    for r in &results[1..] {
        assert_eq!(first.0, r.0, "medoids must not depend on threads/reruns");
        assert_eq!(first.1, r.1, "loss bits must match");
        assert_eq!(first.2, r.2, "evaluation counts must match");
        assert_eq!(first.3, r.3, "SearchTraces must be byte-identical");
    }
}

/// The per-iteration medoid *sequence*, captured by driving the session
/// loop directly (the fit only exposes the final set). A deliberately bad
/// init (point 0 and its nearest neighbours, one tight clump) guarantees
/// the loop applies real swaps.
fn medoid_sequence(ds: &Dataset, threads: usize, seed: u64) -> Vec<Vec<usize>> {
    let backend = NativeBackend::new(&ds.points, Metric::L2)
        .with_threads(threads)
        .with_pool_min_work(0);
    let cfg = BanditPamConfig::default();
    let k = 4;
    let n = backend.n();
    let mut rng = Rng::seed_from(seed);
    let mut state = MedoidState::empty(n);
    let refs: Vec<usize> = (0..n).collect();
    let mut row = vec![0.0f64; n];
    backend.block(&[0], &refs, &mut row);
    let mut by_dist: Vec<usize> = (0..n).collect();
    by_dist.sort_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
    for &m in by_dist.iter().take(k) {
        state.add_medoid(&backend, m);
    }
    let mut session = SwapSession::new(n, k, &cfg, &mut rng);
    let mut seq = vec![state.medoids.clone()];
    for _ in 0..cfg.max_swap_iters {
        let step = swap_step_session(&backend, &mut state, &mut session, &cfg, &mut rng);
        if step.applied.is_none() {
            break;
        }
        seq.push(state.medoids.clone());
    }
    seq
}

#[test]
fn medoid_sequences_are_byte_identical_across_thread_counts_and_runs() {
    let ds = dataset();
    let reference = medoid_sequence(&ds, 1, 13);
    assert!(
        reference.len() >= 2,
        "fixture must exercise at least one applied swap"
    );
    for &threads in THREADS {
        for _run in 0..2 {
            let seq = medoid_sequence(&ds, threads, 13);
            assert_eq!(reference, seq, "threads={threads}");
        }
    }
}
