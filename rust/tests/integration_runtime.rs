//! Runtime integration: the AOT artifacts load through PJRT and the XLA
//! distance backend agrees with the native kernels.
//!
//! Requires `make artifacts` (skips gracefully when artifacts are absent,
//! e.g. on a fresh checkout, but the Makefile `test` target always builds
//! them first).

use banditpam::algorithms::KMedoids;
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::data::synthetic;
use banditpam::distance::Metric;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::runtime::executable::Client;
use banditpam::runtime::manifest::Manifest;
use banditpam::runtime::xla_backend::XlaBackend;
use banditpam::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    // Tests run from the crate root, so ./artifacts is the default.
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

#[test]
fn manifest_covers_all_three_metrics() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for metric in ["l2", "l1", "cosine"] {
        assert!(
            m.find_pairwise(metric, 16).is_some(),
            "missing {metric} artifact"
        );
        assert!(m.find_pairwise(metric, 784).is_some());
    }
}

#[test]
fn xla_backend_matches_native_for_all_metrics() {
    let Some(dir) = artifacts_dir() else { return };
    let client = Client::cpu().expect("PJRT CPU client");
    for (metric, tol) in [
        (Metric::L2, 2e-2),    // norm-trick cancellation at small distances
        (Metric::L1, 1e-3),
        (Metric::Cosine, 1e-3),
    ] {
        let ds = synthetic::gmm(&mut Rng::seed_from(3), 50, 24, 3, 3.0);
        let native = NativeBackend::new(&ds.points, metric);
        let xla = XlaBackend::new(&client, &dir, &ds.points, metric).unwrap();
        // block path (the hot path)
        let targets: Vec<usize> = (0..10).collect();
        let refs: Vec<usize> = (20..50).collect();
        let mut want = vec![0.0; targets.len() * refs.len()];
        let mut got = vec![0.0; targets.len() * refs.len()];
        native.block(&targets, &refs, &mut want);
        xla.block(&targets, &refs, &mut got);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= tol * (1.0 + w.abs()),
                "{metric} block[{i}]: {g} vs {w}"
            );
        }
        // counters agree on the number of evaluations
        assert_eq!(native.counter().get(), xla.counter().get());
        // single-distance path
        let g = xla.dist(1, 2);
        let w = native.dist(1, 2);
        assert!((g - w).abs() <= tol * (1.0 + w.abs()), "{metric} dist: {g} vs {w}");
    }
}

#[test]
fn xla_backend_pads_mnist_dimension() {
    let Some(dir) = artifacts_dir() else { return };
    let client = Client::cpu().expect("PJRT CPU client");
    // d = 300 forces padding up to the 784 artifact.
    let ds = synthetic::gmm(&mut Rng::seed_from(4), 20, 300, 2, 2.0);
    let xla = XlaBackend::new(&client, &dir, &ds.points, Metric::L2).unwrap();
    assert_eq!(xla.artifact().d, 784);
    let native = NativeBackend::new(&ds.points, Metric::L2);
    for (i, j) in [(0, 1), (3, 17), (19, 0)] {
        let g = xla.dist(i, j);
        let w = native.dist(i, j);
        assert!((g - w).abs() < 2e-2 * (1.0 + w), "d({i},{j}): {g} vs {w}");
    }
}

#[test]
fn xla_backend_rejects_unsupported_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let client = Client::cpu().expect("PJRT CPU client");
    // d larger than any artifact
    let ds = synthetic::gmm(&mut Rng::seed_from(5), 10, 2000, 2, 2.0);
    let err = XlaBackend::new(&client, &dir, &ds.points, Metric::L2).unwrap_err();
    assert!(err.to_string().contains("no pairwise artifact"), "{err}");
    // tree points
    let trees = synthetic::hoc4_like(&mut Rng::seed_from(6), 10);
    let err = XlaBackend::new(&client, &dir, &trees.points, Metric::TreeEdit).unwrap_err();
    assert!(err.to_string().contains("dense"), "{err}");
}

#[test]
fn banditpam_through_xla_backend_matches_native_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let client = Client::cpu().expect("PJRT CPU client");
    let ds = synthetic::gmm(&mut Rng::seed_from(7), 120, 16, 3, 4.0);

    let xla = XlaBackend::new(&client, &dir, &ds.points, Metric::L2).unwrap();
    let fit_xla = BanditPam::default_paper()
        .fit(&xla, 3, &mut Rng::seed_from(8))
        .unwrap();

    let native = NativeBackend::new(&ds.points, Metric::L2);
    let fit_native = BanditPam::default_paper()
        .fit(&native, 3, &mut Rng::seed_from(8))
        .unwrap();

    assert_eq!(
        fit_xla.medoids, fit_native.medoids,
        "the three-layer stack must reproduce the native result"
    );
    assert!((fit_xla.loss - fit_native.loss).abs() < 1e-2 * fit_native.loss);
    assert!(xla.executions() > 0, "PJRT was actually exercised");
}
