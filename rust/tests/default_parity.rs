//! `X::default()` must fit identically to `X::new()` for every registry
//! arm (ISSUE 9 satellite). The PAM-family structs used to
//! `#[derive(Default)]`, which zeroed their iteration caps — so
//! `FastPam::default()` (and struct-update `..Default::default()`) ran
//! zero swap sweeps and silently diverged from `new()`'s cap of 100. The
//! derives are now manual impls delegating to `new()`; this suite pins
//! the equivalence end to end, per arm, on a dataset where the swap phase
//! actually applies swaps.

use banditpam::algorithms::{
    clara::Clara, clarans::Clarans, fasterpam::FasterPam, fastpam::FastPam,
    fastpam1::FastPam1, meddit::Meddit, onebatchpam::OneBatchPam, pam::Pam,
    voronoi::VoronoiIteration, KMedoids, REGISTRY,
};
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::coordinator::config::BanditPamConfig;
use banditpam::data::synthetic;
use banditpam::distance::Metric;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::util::rng::Rng;

/// One `(new, default)` pair per registry arm, in registry order.
/// BanditPAM has no bare `default()`; its two default constructions
/// (`default_paper` and `new(BanditPamConfig::default())`) are pinned
/// against each other instead.
fn pairs() -> Vec<(&'static str, Box<dyn KMedoids>, Box<dyn KMedoids>)> {
    vec![
        (
            "banditpam",
            Box::new(BanditPam::default_paper()),
            Box::new(BanditPam::new(BanditPamConfig::default())),
        ),
        ("pam", Box::new(Pam::new()), Box::new(Pam::default())),
        ("fastpam1", Box::new(FastPam1::new()), Box::new(FastPam1::default())),
        ("fastpam", Box::new(FastPam::new()), Box::new(FastPam::default())),
        ("fasterpam", Box::new(FasterPam::new()), Box::new(FasterPam::default())),
        ("clara", Box::new(Clara::new()), Box::new(Clara::default())),
        ("onebatchpam", Box::new(OneBatchPam::new()), Box::new(OneBatchPam::default())),
        ("clarans", Box::new(Clarans::new()), Box::new(Clarans::default())),
        ("voronoi", Box::new(VoronoiIteration::new()), Box::new(VoronoiIteration::default())),
        ("meddit", Box::new(Meddit::new()), Box::new(Meddit::default())),
    ]
}

#[test]
fn default_fits_identically_to_new_for_every_registry_arm() {
    let ds = synthetic::gmm(&mut Rng::seed_from(90), 60, 4, 3, 3.0);
    let one = synthetic::gmm(&mut Rng::seed_from(91), 40, 4, 1, 3.0);
    let entries = pairs();
    assert_eq!(
        entries.len(),
        REGISTRY.len(),
        "every registry arm needs a (new, default) parity pair"
    );
    for (i, (name, mut via_new, mut via_default)) in entries.into_iter().enumerate() {
        assert_eq!(name, REGISTRY[i].name, "pairs() must follow registry order");
        assert_eq!(via_new.name(), name);
        assert_eq!(via_default.name(), name);
        // meddit solves k = 1 only
        let (data, k) = if name == "meddit" { (&one, 1) } else { (&ds, 3) };
        let b1 = NativeBackend::new(&data.points, Metric::L2);
        let a = via_new.fit(&b1, k, &mut Rng::seed_from(17)).unwrap();
        let b2 = NativeBackend::new(&data.points, Metric::L2);
        let b = via_default.fit(&b2, k, &mut Rng::seed_from(17)).unwrap();
        assert_eq!(a.medoids, b.medoids, "{name}: medoids diverge");
        assert_eq!(a.assignments, b.assignments, "{name}: assignments diverge");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{name}: loss bits diverge");
        assert_eq!(
            a.stats.distance_evals, b.stats.distance_evals,
            "{name}: eval counts diverge"
        );
        assert_eq!(
            a.stats.swaps_applied, b.stats.swaps_applied,
            "{name}: swap counts diverge"
        );
        assert_eq!(
            a.stats.swap_iters, b.stats.swap_iters,
            "{name}: swap iteration counts diverge"
        );
        assert_eq!(
            b1.counter().get(),
            b2.counter().get(),
            "{name}: backend counters diverge"
        );
        // Non-vacuity: the PAM-family swap loops increment swap_iters
        // before checking convergence, so a working cap always yields at
        // least one iteration — the zeroed cap of the old derives yielded
        // exactly zero, which the swap_iters equality above would catch.
        if matches!(name, "pam" | "fastpam1" | "fastpam" | "fasterpam") {
            assert!(a.stats.swap_iters >= 1, "{name}: swap phase never entered");
        }
    }
}

/// The regression the old derives caused: struct-update syntax with
/// `..Default::default()` must inherit the working caps, not zeros.
#[test]
fn struct_update_with_default_keeps_the_iteration_caps() {
    assert_eq!(Pam { ..Default::default() }.max_swap_iters, Pam::new().max_swap_iters);
    assert_eq!(FastPam { ..Default::default() }.max_sweeps, FastPam::new().max_sweeps);
    assert_eq!(
        FastPam1 { ..Default::default() }.max_swap_iters,
        FastPam1::new().max_swap_iters
    );
    assert_eq!(
        VoronoiIteration { ..Default::default() }.max_iters,
        VoronoiIteration::new().max_iters
    );
    assert_eq!(
        FasterPam { ..Default::default() }.max_sweeps,
        FasterPam::new().max_sweeps
    );
    let ob = OneBatchPam { batch_size: 64, ..Default::default() };
    assert_eq!(ob.max_swap_iters, OneBatchPam::new().max_swap_iters);
    assert!(FastPam::new().max_sweeps > 0, "the cap the derive zeroed");
}
