//! Parity property suite for the out-of-core CSR streaming subsystem
//! (`data/stream.rs`).
//!
//! The contract under test is **bitwise** equality with the in-memory
//! path — not tolerance: the chunked reader builds each row-window with
//! the same stable-sorted `CsrMatrix::from_triplets` the in-memory loader
//! uses on the whole file, window triplet subsequences preserve file
//! order (directly on the ordered path, per-bucket on the spill path),
//! and windows never split rows, so concatenated window parts must equal
//! the global build bit for bit. Any difference is a logic bug, never a
//! rounding excuse.
//!
//! Grid: body kind in {real, integer, pattern} (with shuffled entry
//! order, duplicate coordinates and an explicit zero) x transpose on/off
//! x chunk-nnz budget in {1, 17, 4096, >= nnz}. Plus the experimental
//! protocol end to end: a streamed subsample of a seeded scRNA n=2000
//! file draws the identical rng stream as `Dataset::subsample` and fits
//! to identical medoids, assignments and eval counters.

use banditpam::data::sparse::CsrMatrix;
use banditpam::data::stream::{self, CsrChunkReader, StreamOptions};
use banditpam::data::{loader, synthetic, Points};
use banditpam::prelude::*;
use std::path::PathBuf;

const CHUNKS: &[usize] = &[1, 17, 4096, 1 << 30];

fn tmpfile(name: &str, contents: &[u8]) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "banditpam_prop_stream_{}_{name}",
        std::process::id()
    ));
    std::fs::write(&p, contents).unwrap();
    p
}

/// Strict bitwise equality: shapes, indptr, indices, and value *bits*
/// (f32 `==` would conflate 0.0/-0.0 and choke on NaN).
fn assert_bitwise(a: &CsrMatrix, b: &CsrMatrix, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape");
    let (ap, ai, av) = a.parts();
    let (bp, bi, bv) = b.parts();
    assert_eq!(ap, bp, "{what}: indptr");
    assert_eq!(ai, bi, "{what}: indices");
    let abits: Vec<u32> = av.iter().map(|v| v.to_bits()).collect();
    let bbits: Vec<u32> = bv.iter().map(|v| v.to_bits()).collect();
    assert_eq!(abits, bbits, "{what}: value bits");
}

fn sparse(ds: &banditpam::data::Dataset) -> &CsrMatrix {
    let Points::Sparse(m) = &ds.points else {
        panic!("expected sparse points, got {}", ds.points.kind())
    };
    m
}

/// Shuffled rows, duplicate coordinates (summed in file order), an
/// explicit zero entry, negative and tiny values, empty rows and columns.
fn bodies() -> Vec<(&'static str, &'static [u8])> {
    vec![
        (
            "real",
            &b"%%MatrixMarket matrix coordinate real general\n\
               % shuffled order, duplicates, explicit zero\n\
               5 4 9\n\
               3 2 1.25\n1 1 0.5\n5 4 -2.75\n2 3 0\n3 2 0.75\n\
               1 4 3.5\n4 1 0.001\n1 1 0.25\n5 1 7\n"[..],
        ),
        (
            "integer",
            &b"%%MatrixMarket matrix coordinate integer general\n\
               4 5 6\n\
               4 5 9\n1 2 3\n2 1 -4\n4 5 1\n3 3 5\n1 1 2\n"[..],
        ),
        (
            "pattern",
            &b"%%MatrixMarket matrix coordinate pattern general\n\
               4 4 5\n\
               4 4\n1 3\n2 2\n1 1\n3 4\n"[..],
        ),
    ]
}

#[test]
fn streamed_load_matches_in_memory_bitwise_across_grid() {
    for (kind, body) in bodies() {
        let p = tmpfile(&format!("grid_{kind}.mtx"), body);
        for transpose in [false, true] {
            let mem = loader::load_mtx(&p, transpose, 0).unwrap();
            for &chunk in CHUNKS {
                let opts = StreamOptions { chunk_nnz: chunk, transpose, limit: 0 };
                let (st, stats) = stream::load_mtx_streamed(&p, &opts).unwrap();
                let what = format!("{kind} transpose={transpose} chunk={chunk}");
                assert_bitwise(sparse(&mem), sparse(&st), &what);
                assert_eq!(mem.name, st.name, "{what}: dataset name");
                assert!(stats.kept_nnz <= stats.total_nnz, "{what}: counters");
            }
        }
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn streamed_load_matches_on_row_major_writer_output() {
    // Our own writer emits row-major entries: the no-transpose read must
    // take the ordered (no-spill) path, the transposed read must spill,
    // and both must match the in-memory loader at every budget.
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(42), 200, 64, 0.10);
    let p = tmpfile("rowmajor.mtx", b"");
    loader::save_mtx(&ds, &p).unwrap();
    for transpose in [false, true] {
        let mem = loader::load_mtx(&p, transpose, 0).unwrap();
        for &chunk in CHUNKS {
            let opts = StreamOptions { chunk_nnz: chunk, transpose, limit: 0 };
            let (st, stats) = stream::load_mtx_streamed(&p, &opts).unwrap();
            assert_eq!(
                stats.spilled, transpose,
                "row-major input: spill iff transposed (chunk={chunk})"
            );
            assert_bitwise(
                sparse(&mem),
                sparse(&st),
                &format!("row-major transpose={transpose} chunk={chunk}"),
            );
        }
    }
    // no-transpose load is also bitwise the generator's own matrix
    let mem = loader::load_mtx(&p, false, 0).unwrap();
    assert_bitwise(sparse(&ds), sparse(&mem), "writer roundtrip");
    let _ = std::fs::remove_file(p);
}

#[test]
fn limit_matches_in_memory_at_every_budget() {
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(13), 90, 40, 0.10);
    let p = tmpfile("limit_grid.mtx", b"");
    loader::save_mtx(&ds, &p).unwrap();
    for transpose in [false, true] {
        for limit in [1usize, 7, 64, 10_000] {
            let mem = loader::load_mtx(&p, transpose, limit).unwrap();
            for &chunk in &[1usize, 17, 1 << 30] {
                let opts = StreamOptions { chunk_nnz: chunk, transpose, limit };
                let (st, _) = stream::load_mtx_streamed(&p, &opts).unwrap();
                assert_bitwise(
                    sparse(&mem),
                    sparse(&st),
                    &format!("limit={limit} transpose={transpose} chunk={chunk}"),
                );
                assert_eq!(mem.name, st.name);
            }
        }
    }
    let _ = std::fs::remove_file(p);
}

/// The experimental protocol end to end on a seeded scRNA n=2000 file:
/// the streamed subsample must (a) assemble the bitwise-identical matrix,
/// (b) leave the rng stream in the identical position, and (c) fit to
/// identical medoids, assignments, loss bits and eval counters.
#[test]
fn streamed_subsample_fit_matches_in_memory() {
    let n = 2000;
    let genes = 256;
    let sub_n = 600;
    let k = 5;
    let base = synthetic::scrna_sparse(&mut Rng::seed_from(11), n, genes, 0.10);
    let p = tmpfile("scrna_fit.mtx", b"");
    loader::save_mtx(&base, &p).unwrap();

    // in-memory protocol: full load, then Dataset::subsample
    let mem = loader::load_mtx(&p, false, 0).unwrap();
    let mut rng_mem = Rng::seed_from(5);
    let sub_mem = mem.subsample(sub_n, &mut rng_mem);

    // streamed protocol: bounded windows, same draw
    let mut rng_st = Rng::seed_from(5);
    let opts = StreamOptions { chunk_nnz: 2048, ..Default::default() };
    let (sub_st, stats) = stream::subsample_mtx_streamed(&p, &opts, sub_n, &mut rng_st).unwrap();

    assert_bitwise(sparse(&sub_mem), sparse(&sub_st), "subsample matrix");
    assert_eq!(sub_mem.name, sub_st.name, "subsample dataset name");
    assert!(stats.windows > 1, "budget must actually window the file");
    assert!(
        stats.peak_resident_nnz < sparse(&mem).nnz(),
        "subsample must not have materialized the full matrix \
         (resident {} vs total {})",
        stats.peak_resident_nnz,
        sparse(&mem).nnz()
    );
    // rng streams in lockstep after the draw
    assert_eq!(
        rng_mem.clone().next_u64(),
        rng_st.clone().next_u64(),
        "rng stream position"
    );

    // identical fits from the identical data + rng
    let fit_mem = BanditPam::new(BanditPamConfig::default())
        .fit(
            &NativeBackend::new(&sub_mem.points, Metric::L1).with_threads(4),
            k,
            &mut rng_mem,
        )
        .unwrap();
    let fit_st = BanditPam::new(BanditPamConfig::default())
        .fit(
            &NativeBackend::new(&sub_st.points, Metric::L1).with_threads(4),
            k,
            &mut rng_st,
        )
        .unwrap();
    assert_eq!(fit_mem.medoids, fit_st.medoids, "medoids");
    assert_eq!(fit_mem.assignments, fit_st.assignments, "assignments");
    assert_eq!(fit_mem.loss.to_bits(), fit_st.loss.to_bits(), "loss bits");
    assert_eq!(
        fit_mem.stats.distance_evals, fit_st.stats.distance_evals,
        "distance eval counter"
    );
    assert_eq!(fit_mem.stats.swap_iters, fit_st.stats.swap_iters, "swap iters");
    let _ = std::fs::remove_file(p);
}

/// Windows stay readable one at a time through the public iterator, and
/// partial consumption + `read_all` of the remainder still covers every
/// row exactly once.
#[test]
fn window_iterator_covers_rows_exactly_once() {
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(7), 64, 32, 0.10);
    let p = tmpfile("iter.mtx", b"");
    loader::save_mtx(&ds, &p).unwrap();
    let mut reader =
        CsrChunkReader::open(&p, StreamOptions { chunk_nnz: 40, ..Default::default() })
            .unwrap();
    let mut next_row = 0usize;
    let mut nnz = 0usize;
    while let Some(w) = reader.next_window().unwrap() {
        assert_eq!(w.start_row, next_row, "windows arrive in row order");
        assert!(w.matrix.rows() > 0, "windows are non-empty row ranges");
        assert_eq!(w.matrix.cols(), 32);
        next_row += w.matrix.rows();
        nnz += w.matrix.nnz();
    }
    assert_eq!(next_row, 64, "windows partition the row range");
    assert_eq!(nnz, sparse(&ds).nnz());
    // exhausted iterator keeps returning None
    assert!(reader.next_window().unwrap().is_none());
    let _ = std::fs::remove_file(p);
}
