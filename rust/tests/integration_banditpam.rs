//! BanditPAM-specific integration tests: the paper's complexity and
//! fidelity claims at test scale.

use banditpam::algorithms::{fastpam1::FastPam1, KMedoids};
use banditpam::bandits::adaptive::{SamplingMode, SigmaMode};
use banditpam::bandits::confidence::CiKind;
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::coordinator::config::{BanditPamConfig, DeltaMode};
use banditpam::coordinator::session::SwapSession;
use banditpam::coordinator::state::MedoidState;
use banditpam::coordinator::swap::swap_step_session;
use banditpam::data::synthetic;
use banditpam::distance::Metric;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::util::rng::Rng;

#[test]
fn evals_scale_subquadratically() {
    // Theorem 2 at test scale: per-iteration evals grow far slower than
    // quadratically. 4x the sample size must cost well under 16x; the
    // paper's almost-linear regime gives ~4-6x (constant-dominated at
    // these small n, so we allow margin).
    let base = synthetic::mnist_like(&mut Rng::seed_from(1), 4800);
    let mut per_iter = Vec::new();
    for &n in &[1200usize, 4800] {
        let sub = base.subsample(n, &mut Rng::seed_from(2));
        let backend = NativeBackend::new(&sub.points, Metric::L2);
        let fit = BanditPam::default_paper()
            .fit(&backend, 3, &mut Rng::seed_from(3))
            .unwrap();
        per_iter.push(fit.stats.evals_per_iter());
    }
    let growth = per_iter[1] / per_iter[0];
    assert!(
        growth < 12.0,
        "4x n gave {growth:.1}x evals/iter (quadratic would be 16x)"
    );
}

#[test]
fn banditpam_beats_pam_per_iteration_at_moderate_n() {
    // Paper accounting (§5.2): PAM needs exactly k*n^2 evaluations per
    // iteration; BanditPAM's measured per-iteration count must be well
    // below that already at n ~ 2000 (the paper's Fig 1b crossover region).
    let ds = synthetic::mnist_like(&mut Rng::seed_from(4), 2000);
    let k = 4;
    let b1 = NativeBackend::new(&ds.points, Metric::L2);
    let bp = BanditPam::default_paper().fit(&b1, k, &mut Rng::seed_from(5)).unwrap();
    let pam_per_iter = (k * 2000 * 2000) as f64;
    assert!(
        bp.stats.evals_per_iter() * 2.0 < pam_per_iter,
        "bandit {}/iter vs pam {}/iter",
        bp.stats.evals_per_iter(),
        pam_per_iter
    );
    // and the quality matches the exact reference
    let b2 = NativeBackend::new(&ds.points, Metric::L2);
    let fp = FastPam1::new().fit(&b2, k, &mut Rng::seed_from(0)).unwrap();
    assert!(bp.loss <= fp.loss * 1.01);
}

#[test]
fn all_config_variants_return_sane_results() {
    let ds = synthetic::gmm(&mut Rng::seed_from(6), 150, 6, 3, 3.0);
    let reference = {
        let b = NativeBackend::new(&ds.points, Metric::L2);
        FastPam1::new().fit(&b, 3, &mut Rng::seed_from(0)).unwrap()
    };
    let variants: Vec<BanditPamConfig> = vec![
        BanditPamConfig { ci: CiKind::EmpiricalBernstein, ..Default::default() },
        BanditPamConfig { sampling: SamplingMode::FixedPermutation, ..Default::default() },
        BanditPamConfig { sigma_mode: SigmaMode::PerArmRunning, ..Default::default() },
        BanditPamConfig { sigma_mode: SigmaMode::GlobalFirstBatch, ..Default::default() },
        BanditPamConfig { delta: DeltaMode::NCubed, ..Default::default() },
        BanditPamConfig { fastpam1_swap: false, ..Default::default() },
        BanditPamConfig { batch_size: 17, ..Default::default() },
    ];
    for (i, cfg) in variants.into_iter().enumerate() {
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let fit = BanditPam::new(cfg.clone())
            .fit(&b, 3, &mut Rng::seed_from(7))
            .unwrap_or_else(|e| panic!("variant {i} failed: {e}"));
        assert!(
            fit.loss <= reference.loss * 1.05,
            "variant {i} ({cfg:?}) loss {} vs {}",
            fit.loss,
            reference.loss
        );
    }
}

#[test]
fn approximate_mode_trades_loss_for_evals() {
    // Appendix 2.3: very loose delta must not use more evals than tight.
    let ds = synthetic::mnist_like(&mut Rng::seed_from(8), 300);
    let run = |delta: f64| {
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let fit = BanditPam::new(BanditPamConfig {
            delta: DeltaMode::Fixed(delta),
            ..Default::default()
        })
        .fit(&b, 4, &mut Rng::seed_from(9))
        .unwrap();
        (fit.stats.distance_evals, fit.loss)
    };
    let (tight_evals, tight_loss) = run(1e-8);
    let (loose_evals, loose_loss) = run(0.2);
    assert!(loose_evals <= tight_evals);
    assert!(loose_loss >= tight_loss * 0.999, "looser cannot be better than exact-tracking");
    assert!(loose_loss <= tight_loss * 1.5, "approximate mode collapsed");
}

#[test]
fn cache_reduces_counted_evals_with_fixed_permutation() {
    let ds = synthetic::gmm(&mut Rng::seed_from(10), 400, 8, 3, 3.0);
    let cfg = BanditPamConfig {
        sampling: SamplingMode::FixedPermutation,
        ..Default::default()
    };
    let plain = {
        let b = NativeBackend::new(&ds.points, Metric::L2);
        BanditPam::new(cfg.clone()).fit(&b, 3, &mut Rng::seed_from(11)).unwrap()
    };
    let cached = {
        let b = NativeBackend::new(&ds.points, Metric::L2).with_cache(4_000_000);
        BanditPam::new(cfg).fit(&b, 3, &mut Rng::seed_from(11)).unwrap()
    };
    assert_eq!(plain.medoids, cached.medoids, "cache must not change results");
    assert!(
        cached.stats.distance_evals < plain.stats.distance_evals,
        "cache: {} vs plain: {}",
        cached.stats.distance_evals,
        plain.stats.distance_evals
    );
}

#[test]
fn swap_reuse_halves_swap_phase_evals_at_mnist_scale() {
    // ISSUE 2 acceptance: mnist_like n=4800 k=5 — SWAP-phase distance
    // evaluations with reuse enabled are <= 0.5x the non-reuse path while
    // the final medoids and loss are identical. An adversarial init (point
    // 0 plus its 4 nearest neighbours: one tight clump) forces several
    // improving swaps, which is exactly the regime the cross-iteration
    // cache targets — with I SWAP iterations only the first pays full
    // price, so the expected reduction is ~I-fold.
    const N: usize = 4800;
    const K: usize = 5;
    let ds = synthetic::mnist_like(&mut Rng::seed_from(30), N);
    let run = |reuse: bool| {
        let backend = NativeBackend::new(&ds.points, Metric::L2).with_threads(8);
        let cfg = BanditPamConfig {
            swap_reuse: reuse,
            max_swap_iters: 10,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(31);
        let mut state = MedoidState::empty(N);
        let refs: Vec<usize> = (0..N).collect();
        let mut row = vec![0.0f64; N];
        backend.block(&[0], &refs, &mut row);
        let mut by_dist: Vec<usize> = (0..N).collect();
        by_dist.sort_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
        for &m in by_dist.iter().take(K) {
            state.add_medoid(&backend, m);
        }
        let mut session = SwapSession::new(N, K, &cfg, &mut rng);
        let swap_start = backend.counter().get();
        let mut swaps = 0usize;
        for _ in 0..cfg.max_swap_iters {
            let step = swap_step_session(&backend, &mut state, &mut session, &cfg, &mut rng);
            if step.applied.is_none() {
                break;
            }
            swaps += 1;
        }
        let swap_evals = backend.counter().get() - swap_start;
        (state.medoids.clone(), state.loss(), swap_evals, swaps)
    };
    let (med_on, loss_on, evals_on, swaps_on) = run(true);
    let (med_off, loss_off, evals_off, swaps_off) = run(false);
    assert_eq!(med_on, med_off, "reuse must not change the medoids");
    assert_eq!(loss_on.to_bits(), loss_off.to_bits(), "loss must be identical");
    assert_eq!(swaps_on, swaps_off, "identical swap sequences");
    assert!(swaps_on >= 2, "clumped init must force several swaps");
    assert!(
        2 * evals_on <= evals_off,
        "SWAP-phase evals with reuse must drop >= 2x: {evals_on} vs {evals_off}"
    );
}

#[test]
fn trace_telemetry_is_consistent() {
    let ds = synthetic::gmm(&mut Rng::seed_from(12), 200, 6, 3, 3.0);
    let b = NativeBackend::new(&ds.points, Metric::L2);
    let mut algo = BanditPam::default_paper();
    let fit = algo.fit(&b, 3, &mut Rng::seed_from(13)).unwrap();
    let traced: u64 = algo.trace.iter().map(|t| t.distance_evals).sum();
    // trace covers build + swap search evals; fit.stats additionally counts
    // state maintenance, so traced <= total.
    assert!(traced <= fit.stats.distance_evals + 1);
    assert_eq!(
        algo.trace.iter().filter(|t| t.phase == "swap").count(),
        fit.stats.swap_iters
    );
}
