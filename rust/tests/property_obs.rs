//! Observability must be bitwise-inert: attaching a trace sink (or not)
//! must never change what a fit computes — same medoids, same assignment
//! vector, same loss bits, same eval counters — across algorithms and
//! thread counts. Also pins the concurrency story for the atomic
//! histogram and the JSONL trace format (dense, strictly increasing
//! `seq`; every line valid JSON). No wall-clock assertions — CI-safe.

use banditpam::algorithms::KMedoids;
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::coordinator::config::BanditPamConfig;
use banditpam::data::synthetic;
use banditpam::distance::Metric;
use banditpam::model::Fit;
use banditpam::obs::{Histogram, SharedBuf, TraceSink};
use banditpam::runtime::backend::NativeBackend;
use banditpam::util::json::Json;
use banditpam::util::rng::Rng;
use std::sync::Arc;
use std::thread;

/// Parse a JSONL buffer, asserting every line is valid JSON with a
/// dense, strictly increasing `seq` starting at 0. Returns the events.
fn check_jsonl(text: &str) -> Vec<Json> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("line {i} not JSON ({e}): {line}"));
        assert_eq!(
            v.get("seq"),
            Some(&Json::Num(i as f64)),
            "seq must be dense and ascending in file order (line {i}): {line}"
        );
        assert!(v.get("event").is_some(), "line {i} has no event: {line}");
        events.push(v);
    }
    events
}

fn event_names(events: &[Json]) -> Vec<String> {
    events
        .iter()
        .filter_map(|e| match e.get("event") {
            Some(Json::Str(s)) => Some(s.clone()),
            _ => None,
        })
        .collect()
}

#[test]
fn traced_banditpam_fit_is_bitwise_identical() {
    let ds = synthetic::mnist_like(&mut Rng::seed_from(11), 240);
    for threads in [1usize, 8] {
        let backend = NativeBackend::new(&ds.points, Metric::L2).with_threads(threads);

        let mut plain = BanditPam::new(BanditPamConfig::default());
        let base = plain.fit(&backend, 4, &mut Rng::seed_from(5)).expect("untraced fit");

        let buf = SharedBuf::new();
        let sink = Arc::new(TraceSink::to_writer(Box::new(buf.clone())));
        // A fresh backend so the second fit sees the same cold cache /
        // counter state as the first.
        let backend2 = NativeBackend::new(&ds.points, Metric::L2).with_threads(threads);
        let mut traced =
            BanditPam::new(BanditPamConfig::default()).with_trace_sink(Arc::clone(&sink));
        let got = traced.fit(&backend2, 4, &mut Rng::seed_from(5)).expect("traced fit");

        assert_eq!(got.medoids, base.medoids, "threads={threads}");
        assert_eq!(got.assignments, base.assignments, "threads={threads}");
        assert_eq!(got.loss.to_bits(), base.loss.to_bits(), "threads={threads}");
        assert_eq!(
            got.stats.distance_evals, base.stats.distance_evals,
            "threads={threads}: tracing must not change the eval count"
        );
        assert_eq!(
            traced.trace, plain.trace,
            "threads={threads}: per-search telemetry must be identical"
        );

        sink.flush().expect("flush");
        let events = check_jsonl(&buf.text());
        let names = event_names(&events);
        assert!(
            names.iter().any(|n| n == "build_round"),
            "threads={threads}: expected build_round spans, got {names:?}"
        );
        assert!(
            names.iter().any(|n| n == "swap_iter"),
            "threads={threads}: expected swap_iter spans, got {names:?}"
        );
        assert_eq!(
            names.last().map(String::as_str),
            Some("fit_summary"),
            "threads={threads}: the last event is the fit summary"
        );
        // One span per BUILD round: k rounds for k medoids.
        assert_eq!(
            names.iter().filter(|n| *n == "build_round").count(),
            4,
            "threads={threads}"
        );
    }
}

#[test]
fn traced_bigfit_is_bitwise_identical() {
    let ds = synthetic::gmm(&mut Rng::seed_from(21), 300, 8, 4, 3.0);
    for threads in [1usize, 8] {
        let base_fit = Fit::banditpam().metric(Metric::L2).k(3).seed(13).threads(threads);
        let (base_model, base_stats) =
            base_fit.big().samples(3).fit_with_stats(&ds).expect("untraced bigfit");

        let buf = SharedBuf::new();
        let sink = Arc::new(TraceSink::to_writer(Box::new(buf.clone())));
        let traced_fit = Fit::banditpam()
            .metric(Metric::L2)
            .k(3)
            .seed(13)
            .threads(threads)
            .trace_sink(Arc::clone(&sink));
        let (model, stats) =
            traced_fit.big().samples(3).fit_with_stats(&ds).expect("traced bigfit");

        assert_eq!(
            model.clustering().medoids,
            base_model.clustering().medoids,
            "threads={threads}"
        );
        assert_eq!(
            model.clustering().assignments,
            base_model.clustering().assignments,
            "threads={threads}"
        );
        assert_eq!(
            model.loss().to_bits(),
            base_model.loss().to_bits(),
            "threads={threads}"
        );
        assert_eq!(
            model.clustering().stats.distance_evals,
            base_model.clustering().stats.distance_evals,
            "threads={threads}"
        );
        assert_eq!(stats.samples, base_stats.samples, "threads={threads}");

        sink.flush().expect("flush");
        let events = check_jsonl(&buf.text());
        let names = event_names(&events);
        assert_eq!(
            names.iter().filter(|n| *n == "bigfit_sample").count(),
            3,
            "threads={threads}: one span per outer-loop sample, got {names:?}"
        );
        assert!(
            names.iter().any(|n| n == "bigfit_summary"),
            "threads={threads}: expected a bigfit_summary span, got {names:?}"
        );
    }
}

#[test]
fn kernel_span_timers_are_bitwise_inert_and_recorded() {
    // The per-kernel scoped timers around the tiled block kernels
    // (`kernel_us{kernel="<metric>_<storage>"}`) only observe wall time:
    // two identical fits must agree bit for bit, and the labeled
    // histogram must have recorded the kernel invocations.
    let ds = synthetic::gmm(&mut Rng::seed_from(31), 200, 8, 4, 3.0);
    let backend = NativeBackend::new(&ds.points, Metric::L2).with_threads(4);
    let mut a = BanditPam::new(BanditPamConfig::default());
    let first = a.fit(&backend, 3, &mut Rng::seed_from(7)).expect("first fit");

    let backend2 = NativeBackend::new(&ds.points, Metric::L2).with_threads(4);
    let mut b = BanditPam::new(BanditPamConfig::default());
    let second = b.fit(&backend2, 3, &mut Rng::seed_from(7)).expect("second fit");

    assert_eq!(first.medoids, second.medoids);
    assert_eq!(first.assignments, second.assignments);
    assert_eq!(first.loss.to_bits(), second.loss.to_bits());
    assert_eq!(first.stats.distance_evals, second.stats.distance_evals);

    let snap = banditpam::obs::global()
        .histogram("kernel_us{kernel=\"l2_dense\"}")
        .snapshot();
    assert!(snap.count > 0, "kernel_us{{kernel=\"l2_dense\"}} recorded nothing");

    // The labeled family renders as Prometheus label syntax, not as a
    // mangled bare name.
    let text = banditpam::obs::global().render_prometheus();
    assert!(
        text.contains("# TYPE kernel_us histogram"),
        "expected one kernel_us TYPE line:\n{text}"
    );
    assert!(
        text.contains("kernel_us_bucket{kernel=\"l2_dense\",le="),
        "expected labeled bucket lines:\n{text}"
    );
}

#[test]
fn histogram_is_deterministic_under_concurrent_hammering() {
    // 8 threads record disjoint deterministic sequences into one shared
    // histogram; the result must equal the single-threaded recording of
    // the same multiset, run after run.
    let shared = Arc::new(Histogram::new());
    let serial = Histogram::new();
    let per_thread = 5_000u64;
    let handles: Vec<_> = (0..8u64)
        .map(|t| {
            let h = Arc::clone(&shared);
            thread::spawn(move || {
                for i in 0..per_thread {
                    h.record(t * 1_000_003 + i * 17);
                }
            })
        })
        .collect();
    for t in 0..8u64 {
        for i in 0..per_thread {
            serial.record(t * 1_000_003 + i * 17);
        }
    }
    for h in handles {
        h.join().expect("recorder thread");
    }
    assert_eq!(shared.snapshot(), serial.snapshot());

    // Merging per-thread histograms must give the same answer as the
    // shared recording.
    let parts: Vec<Histogram> = (0..8u64)
        .map(|t| {
            let h = Histogram::new();
            for i in 0..per_thread {
                h.record(t * 1_000_003 + i * 17);
            }
            h
        })
        .collect();
    let merged = Histogram::new();
    for p in &parts {
        merged.merge(p);
    }
    assert_eq!(merged.snapshot(), serial.snapshot());
}

#[test]
fn concurrent_trace_emitters_keep_seq_dense() {
    let buf = SharedBuf::new();
    let sink = Arc::new(TraceSink::to_writer(Box::new(buf.clone())));
    let handles: Vec<_> = (0..8u64)
        .map(|t| {
            let s = Arc::clone(&sink);
            thread::spawn(move || {
                for i in 0..200u64 {
                    s.emit("hammer", &[("thread", t.into()), ("i", i.into())]);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("emitter thread");
    }
    sink.flush().expect("flush");
    assert_eq!(sink.len(), 8 * 200);
    let events = check_jsonl(&buf.text());
    assert_eq!(events.len(), 8 * 200);
}
