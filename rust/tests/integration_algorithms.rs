//! Cross-algorithm integration tests: every solver on shared datasets,
//! checking the quality ordering the paper's Figure 1a establishes.

use banditpam::algorithms::{
    clara::Clara, clarans::Clarans, fasterpam::FasterPam, fastpam::FastPam,
    fastpam1::FastPam1, onebatchpam::OneBatchPam, pam::Pam,
    voronoi::VoronoiIteration, KMedoids,
};
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::data::synthetic;
use banditpam::distance::Metric;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::util::rng::Rng;

fn fit(
    algo: &mut dyn KMedoids,
    ds: &banditpam::data::Dataset,
    metric: Metric,
    k: usize,
    seed: u64,
) -> banditpam::algorithms::Clustering {
    let backend = NativeBackend::new(&ds.points, metric);
    algo.fit(&backend, k, &mut Rng::seed_from(seed)).unwrap()
}

#[test]
fn all_algorithms_produce_valid_clusterings() {
    let ds = synthetic::gmm(&mut Rng::seed_from(1), 120, 8, 4, 3.0);
    let algos: Vec<Box<dyn KMedoids>> = vec![
        Box::new(BanditPam::default_paper()),
        Box::new(Pam::new()),
        Box::new(FastPam1::new()),
        Box::new(FastPam::new()),
        Box::new(FasterPam::new()),
        Box::new(Clara::new()),
        Box::new(OneBatchPam::new()),
        Box::new(Clarans::new()),
        Box::new(VoronoiIteration::new()),
    ];
    for mut algo in algos {
        let c = fit(algo.as_mut(), &ds, Metric::L2, 4, 7);
        assert_eq!(c.medoids.len(), 4, "{}", algo.name());
        // medoids distinct, sorted, in range
        assert!(c.medoids.windows(2).all(|w| w[0] < w[1]), "{}", algo.name());
        assert!(c.medoids.iter().all(|&m| m < 120), "{}", algo.name());
        assert_eq!(c.assignments.len(), 120);
        assert!(c.loss.is_finite() && c.loss > 0.0);
        // every point assigned to its genuinely nearest medoid
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        for i in 0..120 {
            let d_assigned = backend.dist(c.medoids[c.assignments[i]], i);
            for &m in &c.medoids {
                assert!(
                    d_assigned <= backend.dist(m, i) + 1e-9,
                    "{}: point {i} misassigned",
                    algo.name()
                );
            }
        }
    }
}

#[test]
fn quality_ordering_matches_figure_1a() {
    // PAM (== FastPAM1 == BanditPAM whp) <= FastPAM <~ CLARANS/Voronoi.
    let mut pam_loss = 0.0;
    let mut bandit_loss = 0.0;
    let mut fastpam_loss = 0.0;
    let mut clarans_loss = 0.0;
    let mut voronoi_loss = 0.0;
    let reps = 4;
    for seed in 0..reps {
        let ds = synthetic::gmm(&mut Rng::seed_from(900 + seed), 150, 6, 4, 2.0);
        pam_loss += fit(&mut Pam::new(), &ds, Metric::L2, 4, seed).loss;
        bandit_loss += fit(&mut BanditPam::default_paper(), &ds, Metric::L2, 4, seed).loss;
        fastpam_loss += fit(&mut FastPam::new(), &ds, Metric::L2, 4, seed).loss;
        clarans_loss += fit(&mut Clarans::new(), &ds, Metric::L2, 4, seed).loss;
        voronoi_loss += fit(&mut VoronoiIteration::new(), &ds, Metric::L2, 4, seed).loss;
    }
    assert!(bandit_loss <= pam_loss * 1.01, "banditpam must match PAM quality");
    assert!(fastpam_loss <= pam_loss * 1.10, "fastpam comparable to PAM");
    assert!(clarans_loss >= pam_loss * 0.999, "PAM is the quality reference");
    assert!(voronoi_loss >= pam_loss * 0.999);
}

#[test]
fn banditpam_matches_pam_across_metrics() {
    for metric in [Metric::L2, Metric::L1, Metric::Cosine] {
        let ds = synthetic::gmm(&mut Rng::seed_from(77), 80, 6, 3, 3.0);
        let pam = fit(&mut Pam::new(), &ds, metric, 3, 0);
        let bp = fit(&mut BanditPam::default_paper(), &ds, metric, 3, 5);
        assert!(
            bp.medoids == pam.medoids || bp.loss <= pam.loss * 1.02,
            "{metric}: {:?} vs {:?} (loss {} vs {})",
            bp.medoids,
            pam.medoids,
            bp.loss,
            pam.loss
        );
    }
}

#[test]
fn banditpam_on_trees_matches_pam() {
    let ds = synthetic::hoc4_like(&mut Rng::seed_from(5), 70);
    let pam = fit(&mut Pam::new(), &ds, Metric::TreeEdit, 2, 0);
    let bp = fit(&mut BanditPam::default_paper(), &ds, Metric::TreeEdit, 2, 3);
    assert!(
        bp.medoids == pam.medoids || (bp.loss - pam.loss).abs() < 1e-9,
        "tree medoids {:?} vs {:?}",
        bp.medoids,
        pam.medoids
    );
}

#[test]
fn k_equals_one_agrees_with_meddit_and_pam() {
    use banditpam::algorithms::meddit::Meddit;
    let ds = synthetic::gmm(&mut Rng::seed_from(6), 90, 4, 1, 1.0);
    let pam = fit(&mut Pam::new(), &ds, Metric::L2, 1, 0);
    let meddit = fit(&mut Meddit::new(), &ds, Metric::L2, 1, 1);
    let bp = fit(&mut BanditPam::default_paper(), &ds, Metric::L2, 1, 2);
    assert_eq!(pam.medoids, meddit.medoids);
    assert_eq!(pam.medoids, bp.medoids);
}

#[test]
fn subsampled_fits_are_deterministic_given_seed() {
    let ds = synthetic::mnist_like(&mut Rng::seed_from(9), 150);
    let a = fit(&mut BanditPam::default_paper(), &ds, Metric::L2, 3, 42);
    let b = fit(&mut BanditPam::default_paper(), &ds, Metric::L2, 3, 42);
    assert_eq!(a.medoids, b.medoids);
    assert_eq!(a.stats.distance_evals, b.stats.distance_evals);
}
