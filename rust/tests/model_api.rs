//! Fitted-model API lockdown: out-of-sample predict parity, persistence
//! round trips, and hostile-input hardening of the binary model format.
//!
//! The parity contract is **bitwise**: `model.predict(training points)`
//! equals `Clustering::assignments` exactly, across metrics {l1, l2,
//! cosine} x storage {dense, sparse} x threads {1, 8} x cache on/off, and
//! a saved model reloads byte-identically and predicts identically with
//! the training dataset dropped. Malformed model files must Err — never
//! panic, never over-allocate — in the `tests/stream_fixtures.rs` golden
//! fixture style.

use banditpam::prelude::*;
use banditpam::util::matrix::Matrix;
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("models")
        .join(name)
}

fn dense_data(seed: u64) -> Dataset {
    synthetic::gmm(&mut Rng::seed_from(seed), 220, 24, 4, 3.0)
}

fn sparse_data(seed: u64) -> Dataset {
    synthetic::scrna_sparse(&mut Rng::seed_from(seed), 180, 256, 0.10)
}

/// The acceptance grid: predict-on-training-set is bitwise-equal to the
/// stored assignments for every metric x storage x thread-count x cache
/// combination, and the assignment distances are exact zeros on medoids.
#[test]
fn predict_parity_metrics_by_storage_by_threads() {
    for (ds, storage) in [(dense_data(11), "dense"), (sparse_data(12), "sparse")] {
        for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
            for threads in [1usize, 8] {
                for cache in [false, true] {
                    let mut fit =
                        Fit::banditpam().metric(metric).threads(threads).seed(31).k(5);
                    if cache {
                        fit = fit.cache(1 << 16);
                    }
                    let model = fit.fit(&ds).unwrap();
                    let ctx = format!("{storage}/{metric}/threads={threads}/cache={cache}");
                    let pred = model.predict(&ds.points).unwrap();
                    assert_eq!(pred, model.clustering().assignments, "{ctx}");
                    let (pred2, dists) = model.predict_with_dists(&ds.points).unwrap();
                    assert_eq!(pred2, pred, "{ctx}");
                    for (pos, &m) in model.clustering().medoids.iter().enumerate() {
                        assert_eq!(pred[m], pos, "{ctx}: medoid {m} self-assignment");
                        assert_eq!(dists[m], 0.0, "{ctx}: medoid {m} self-distance");
                    }
                }
            }
        }
    }
}

/// Thread count must never change predicted bits — same contract as the
/// training-side determinism suite.
#[test]
fn predict_is_thread_invariant_on_unseen_points() {
    for (train, queries) in [
        (dense_data(21), dense_data(22)),
        (sparse_data(23), sparse_data(24)),
    ] {
        let model = Fit::banditpam().metric(Metric::L2).seed(7).k(4).fit(&train).unwrap();
        let (a1, d1) = model
            .clone()
            .with_threads(1)
            .predict_with_dists(&queries.points)
            .unwrap();
        let (a8, d8) = model
            .with_threads(8)
            .predict_with_dists(&queries.points)
            .unwrap();
        assert_eq!(a1, a8);
        let bits1: Vec<u64> = d1.iter().map(|d| d.to_bits()).collect();
        let bits8: Vec<u64> = d8.iter().map(|d| d.to_bits()).collect();
        assert_eq!(bits1, bits8, "distances must be bitwise thread-invariant");
    }
}

/// save -> load -> re-save is byte-identical, and the reloaded model
/// serves predict with the training dataset dropped.
#[test]
fn save_load_roundtrip_is_byte_identical_and_serves_without_training_data() {
    for (ds, metric) in [(dense_data(41), Metric::Cosine), (sparse_data(42), Metric::L1)] {
        let queries = ds.select(&(0..40).collect::<Vec<_>>());
        let model = Fit::banditpam().metric(metric).seed(9).k(6).fit(&ds).unwrap();
        let want_train = model.clustering().assignments.clone();
        let want_queries = model.predict(&queries.points).unwrap();

        let bytes = model.to_bytes().unwrap();
        let reloaded = KMedoidsModel::from_bytes(&bytes).unwrap();
        assert_eq!(reloaded.to_bytes().unwrap(), bytes, "re-save must be byte-identical");

        // metadata survives exactly
        assert_eq!(reloaded.k(), model.k());
        assert_eq!(reloaded.metric(), model.metric());
        assert_eq!(reloaded.dim(), model.dim());
        assert_eq!(reloaded.n_train(), model.n_train());
        assert_eq!(reloaded.algorithm(), model.algorithm());
        assert_eq!(reloaded.config_fingerprint(), model.config_fingerprint());
        assert_eq!(reloaded.clustering().medoids, model.clustering().medoids);
        assert_eq!(reloaded.clustering().assignments, model.clustering().assignments);
        assert_eq!(
            reloaded.loss().to_bits(),
            model.loss().to_bits(),
            "loss must round-trip bitwise"
        );
        let (s, m) = (&reloaded.clustering().stats, &model.clustering().stats);
        assert_eq!(s.distance_evals, m.distance_evals);
        assert_eq!(s.swap_iters, m.swap_iters);

        // file round trip + serving with the training data dropped
        let path = std::env::temp_dir().join(format!(
            "banditpam_model_api_{}_{}.bpmodel",
            std::process::id(),
            metric
        ));
        model.save(&path).unwrap();
        drop(model);
        drop(ds);
        let served = KMedoidsModel::load(&path).unwrap();
        assert_eq!(served.predict(&queries.points).unwrap(), want_queries);
        // ... and the original training points, regenerated bit-identically
        let regen = if served.metric() == Metric::Cosine {
            dense_data(41)
        } else {
            sparse_data(42)
        };
        assert_eq!(served.predict(&regen.points).unwrap(), want_train);
        let _ = std::fs::remove_file(&path);
    }
}

/// `k == n` through the whole stack: facade -> degenerate fit -> model ->
/// predict -> persistence.
#[test]
fn degenerate_k_equals_n_end_to_end() {
    let ds = synthetic::gmm(&mut Rng::seed_from(51), 25, 6, 3, 3.0);
    let model = Fit::banditpam().metric(Metric::L2).seed(1).k(25).fit(&ds).unwrap();
    assert_eq!(model.k(), 25);
    assert_eq!(model.loss(), 0.0);
    assert_eq!(model.clustering().medoids, (0..25).collect::<Vec<_>>());
    let pred = model.predict(&ds.points).unwrap();
    assert_eq!(pred, model.clustering().assignments);
    let reloaded = KMedoidsModel::from_bytes(&model.to_bytes().unwrap()).unwrap();
    assert_eq!(reloaded.predict(&ds.points).unwrap(), pred);
}

/// Golden corrupted fixtures: every malformed model file must produce a
/// clean `Err` from `KMedoidsModel::load` — never a panic, never an
/// allocation blow-up (`lying_nnz` declares 2^40 entries).
#[test]
fn corrupted_model_fixtures_err_cleanly() {
    for name in [
        "bad_magic.bpmodel",
        "bad_version.bpmodel",
        "bad_metric.bpmodel",
        "bad_storage.bpmodel",
        "nonzero_reserved.bpmodel",
        "zero_k.bpmodel",
        "k_exceeds_n.bpmodel",
        "truncated_header.bpmodel",
        "truncated_payload.bpmodel",
        "trailing_bytes.bpmodel",
        "decreasing_medoids.bpmodel",
        "medoid_out_of_range.bpmodel",
        "bad_assignment.bpmodel",
        "huge_string.bpmodel",
        "lying_nnz.bpmodel",
        "explicit_zero_value.bpmodel",
        "decreasing_indptr.bpmodel",
        "column_out_of_range.bpmodel",
    ] {
        let p = fixture(name);
        assert!(p.exists(), "fixture {name} missing");
        let err = KMedoidsModel::load(&p).expect_err(&format!("{name} must Err"));
        assert_eq!(err.kind(), "model", "{name}: {err}");
    }
    // missing file is also a clean model error
    assert_eq!(
        KMedoidsModel::load(&fixture("does_not_exist.bpmodel"))
            .unwrap_err()
            .kind(),
        "model"
    );
}

/// Golden *valid* fixtures pin the byte format itself: files written by
/// this version (and checked in) must keep loading and predicting, so any
/// accidental format change breaks loudly here.
#[test]
fn golden_valid_fixtures_load_and_predict() {
    let dense = KMedoidsModel::load(&fixture("valid_dense.bpmodel")).unwrap();
    assert_eq!(dense.k(), 2);
    assert_eq!(dense.metric(), Metric::L2);
    assert_eq!(dense.dim(), Some(2));
    assert_eq!(dense.n_train(), 4);
    assert_eq!(dense.algorithm(), "pam");
    assert_eq!(dense.config_fingerprint(), "golden");
    assert_eq!(dense.loss(), 1.0);
    assert_eq!(dense.clustering().medoids, vec![0, 2]);
    let queries = Points::Dense(Matrix::from_vec(
        vec![0.1, -0.1, 2.9, 3.2, 0.0, 0.0],
        3,
        2,
    ));
    assert_eq!(dense.predict(&queries).unwrap(), vec![0, 1, 0]);

    let sparse = KMedoidsModel::load(&fixture("valid_sparse.bpmodel")).unwrap();
    assert_eq!(sparse.k(), 2);
    assert_eq!(sparse.dim(), Some(3));
    let Points::Sparse(m) = sparse.medoid_points() else { unreachable!() };
    assert_eq!(m.nnz(), 3);
    assert_eq!(m.row(0), (&[0u32][..], &[1.0f32][..]));
    assert_eq!(m.row(1), (&[0u32, 2][..], &[2.0f32, 3.0][..]));
    let sq = CsrMatrix::from_triplets(2, 3, &[(0, 0, 1.0), (1, 0, 2.0), (1, 2, 3.0)]);
    let pred = sparse.predict(&Points::Sparse(sq)).unwrap();
    assert_eq!(pred, vec![0, 1]);
}

/// Every strict prefix of a valid model must Err (truncation), and random
/// single-byte corruption must never panic.
#[test]
fn truncation_and_bitflip_sweep_never_panics() {
    let ds = sparse_data(61);
    let model = Fit::banditpam().metric(Metric::L1).seed(3).k(3).fit(&ds).unwrap();
    let bytes = model.to_bytes().unwrap();
    for cut in (0..bytes.len()).step_by(7) {
        assert!(
            KMedoidsModel::from_bytes(&bytes[..cut]).is_err(),
            "prefix of {cut} bytes must not load"
        );
    }
    for pos in (0..bytes.len()).step_by(11) {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0xA5;
        // any Result is acceptable; panicking or over-allocating is not
        let _ = KMedoidsModel::from_bytes(&corrupt);
    }
}

/// The `Fit` facade acceptance line from the issue, verbatim shape.
#[test]
fn acceptance_one_liner() {
    let data = dense_data(71);
    let model = Fit::banditpam().metric(Metric::L2).seed(7).fit(&data).unwrap();
    assert_eq!(model.k(), 5, "default k");
    let pred = model.predict(&data.points).unwrap();
    assert_eq!(pred, model.clustering().assignments);
}
