//! Property-based suite over the coordinator's invariants (DESIGN.md:
//! "proptest on coordinator invariants — routing, batching, state").
//! Uses the in-tree `testkit::prop` framework; failures report a replay
//! seed.

use banditpam::algorithms::matrix_cache::{exact_build, FullMatrix, MatState};
use banditpam::algorithms::{fastpam1::FastPam1, pam::Pam, KMedoids};
use banditpam::coordinator::banditpam::BanditPam;
use banditpam::coordinator::scheduler;
use banditpam::coordinator::state::MedoidState;
use banditpam::data::Points;
use banditpam::distance::{dense, tree_edit, Metric};
use banditpam::prop_assert;
use banditpam::runtime::backend::{DistanceBackend, NativeBackend};
use banditpam::testkit::prop::{check, gen, PropConfig};
use banditpam::util::rng::Rng;

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

#[test]
fn prop_dense_metrics_are_metrics() {
    check("dense-metric-axioms", &cfg(40), |rng| {
        let d = rng.range(1, 40);
        let a = gen::vector(rng, d);
        let b = gen::vector(rng, d);
        let c = gen::vector(rng, d);
        for (name, f) in [
            ("l2", dense::l2 as fn(&[f32], &[f32]) -> f64),
            ("l1", dense::l1),
        ] {
            let dab = f(&a, &b);
            prop_assert!(dab >= 0.0, "{name} negative");
            prop_assert!((dab - f(&b, &a)).abs() < 1e-12, "{name} asymmetric");
            prop_assert!(f(&a, &a) < 1e-12, "{name} identity");
            let (dac, dbc) = (f(&a, &c), f(&b, &c));
            // relative tolerance: the sqrt/sum rounding error scales with
            // the magnitudes involved
            let tol = 1e-6 * (1.0 + dab + dbc);
            prop_assert!(
                dac <= dab + dbc + tol,
                "{name} triangle violated: {dac} > {dab} + {dbc}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_tree_edit_is_a_metric() {
    check("tree-edit-axioms", &cfg(30), |rng| {
        let a = gen::small_tree(rng);
        let b = gen::small_tree(rng);
        let c = gen::small_tree(rng);
        let dab = tree_edit::ted(&a, &b);
        prop_assert!(dab >= 0.0, "negative");
        prop_assert!(tree_edit::ted(&a, &a) == 0.0, "identity");
        prop_assert!(
            (dab - tree_edit::ted(&b, &a)).abs() < 1e-12,
            "asymmetric: {dab}"
        );
        let dac = tree_edit::ted(&a, &c);
        let dbc = tree_edit::ted(&b, &c);
        prop_assert!(dac <= dab + dbc + 1e-9, "triangle: {dac} > {dab}+{dbc}");
        // edit distance bounded by total sizes
        prop_assert!(
            dab <= (a.size() + b.size()) as f64,
            "bound: {dab} > {} + {}",
            a.size(),
            b.size()
        );
        Ok(())
    });
}

#[test]
fn prop_medoid_state_invariants_under_random_ops() {
    check("state-invariants", &cfg(20), |rng| {
        let ds = gen::small_dataset(rng);
        let n = ds.len();
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(n);
        let k = rng.range(1, 4.min(n));
        for m in rng.sample_indices(n, k) {
            state.add_medoid(&backend, m);
        }
        for _ in 0..3 {
            let pos = rng.below(state.k());
            let x = rng.below(n);
            if state.medoids.contains(&x) {
                continue;
            }
            state.apply_swap(&backend, pos, x);
        }
        for j in 0..n {
            prop_assert!(state.d1[j] <= state.d2[j] + 1e-9, "d1 > d2 at {j}");
            let true_min = state
                .medoids
                .iter()
                .map(|&m| backend.dist(m, j))
                .fold(f64::INFINITY, f64::min);
            prop_assert!(
                (state.d1[j] - true_min).abs() < 1e-9,
                "stale d1 at {j}"
            );
            prop_assert!(state.a1[j] < state.k(), "bad a1 at {j}");
        }
        Ok(())
    });
}

#[test]
fn prop_swap_loop_monotone_loss() {
    check("pam-swap-monotone", &cfg(15), |rng| {
        let ds = gen::small_dataset(rng);
        let n = ds.len();
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let m = FullMatrix::compute(&backend);
        let mut st = MatState::empty(n);
        let k = rng.range(1, 4.min(n));
        exact_build(&m, k, &mut st);
        let mut prev = st.loss();
        for _ in 0..5 {
            let (delta, x, pos) =
                banditpam::algorithms::fastpam1::best_swap_eq12(&m, &st, &mut Vec::new());
            if !(delta < -1e-12) {
                break;
            }
            st.medoids[pos] = x;
            st.rebuild(&m);
            let now = st.loss();
            prop_assert!(now <= prev + 1e-9, "loss rose {prev} -> {now}");
            prop_assert!(
                (now - (prev + delta)).abs() < 1e-6,
                "delta prediction off: {} vs {}",
                now - prev,
                delta
            );
            prev = now;
        }
        Ok(())
    });
}

#[test]
fn prop_fastpam1_equals_pam() {
    check("fastpam1-eq-pam", &cfg(12), |rng| {
        let ds = gen::small_dataset(rng);
        let k = rng.range(1, 4.min(ds.len() - 1).max(2));
        let b1 = NativeBackend::new(&ds.points, Metric::L2);
        let pam = Pam::new().fit(&b1, k, &mut Rng::seed_from(0)).unwrap();
        let b2 = NativeBackend::new(&ds.points, Metric::L2);
        let fp1 = FastPam1::new().fit(&b2, k, &mut Rng::seed_from(0)).unwrap();
        prop_assert!(
            pam.medoids == fp1.medoids,
            "diverged: {:?} vs {:?}",
            pam.medoids,
            fp1.medoids
        );
        Ok(())
    });
}

#[test]
fn prop_banditpam_loss_matches_pam_loss() {
    check("banditpam-quality", &cfg(10), |rng| {
        let ds = gen::small_dataset(rng);
        if ds.len() < 15 {
            return Ok(());
        }
        let k = rng.range(1, 4);
        let b1 = NativeBackend::new(&ds.points, Metric::L2);
        let pam = Pam::new().fit(&b1, k, &mut Rng::seed_from(0)).unwrap();
        let b2 = NativeBackend::new(&ds.points, Metric::L2);
        let bp = BanditPam::default_paper().fit(&b2, k, rng).unwrap();
        prop_assert!(
            bp.loss <= pam.loss * 1.05,
            "loss {} vs PAM {}",
            bp.loss,
            pam.loss
        );
        Ok(())
    });
}

#[test]
fn prop_scheduler_dedup_is_lossless() {
    check("scheduler-dedup", &cfg(30), |rng| {
        let n = rng.range(2, 50);
        let reqs: Vec<usize> = (0..rng.range(1, 80)).map(|_| rng.below(n)).collect();
        let d = scheduler::dedup(&reqs);
        prop_assert!(d.row_of.len() == reqs.len(), "row map length");
        let unique_set: std::collections::HashSet<_> = d.unique.iter().collect();
        prop_assert!(unique_set.len() == d.unique.len(), "dup in unique");
        for (req, &row) in reqs.iter().zip(&d.row_of) {
            prop_assert!(d.unique[row] == *req, "row map wrong");
        }
        Ok(())
    });
}

#[test]
fn prop_assignments_are_nearest_medoid() {
    check("assignment-optimality", &cfg(10), |rng| {
        let ds = gen::small_dataset(rng);
        if ds.len() < 10 {
            return Ok(());
        }
        let backend = NativeBackend::new(&ds.points, Metric::L1);
        let k = rng.range(1, 4);
        let fit = BanditPam::default_paper().fit(&backend, k, rng).unwrap();
        for i in 0..ds.len() {
            let assigned = backend.dist(fit.medoids[fit.assignments[i]], i);
            for &m in &fit.medoids {
                prop_assert!(
                    assigned <= backend.dist(m, i) + 1e-9,
                    "point {i} not nearest-assigned"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_subsample_preserves_point_identity() {
    check("subsample-identity", &cfg(20), |rng| {
        let ds = gen::small_dataset(rng);
        let n = ds.len();
        let take = rng.range(1, n + 1);
        let sub = ds.subsample(take, rng);
        prop_assert!(sub.len() == take, "size");
        if let (Points::Dense(orig), Points::Dense(s)) = (&ds.points, &sub.points) {
            // every subsampled row must exist in the original
            for i in 0..s.rows() {
                let found = (0..orig.rows()).any(|j| orig.row(j) == s.row(i));
                prop_assert!(found, "row {i} not from original");
            }
        }
        Ok(())
    });
}
