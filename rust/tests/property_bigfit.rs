//! ISSUE 7 acceptance suite: the window-at-a-time evaluation primitive is
//! bitwise-equal to the in-memory `loss_and_assignments` across metrics,
//! storage kinds, thread counts and window budgets; the BigFit outer loop
//! over a streamed `.mtx` is bitwise-identical to the in-memory outer
//! loop; and CLARA's fixed evaluation path (one full-dataset pass per
//! candidate, honest stats) stays pinned.

use banditpam::data::stream::{CsrChunkReader, StreamOptions};
use banditpam::data::{loader, synthetic};
use banditpam::prelude::*;
use banditpam::runtime::backend::{loss_and_assignments, loss_and_assignments_streamed};
use std::path::PathBuf;

fn tmpfile(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "banditpam_property_bigfit_{}_{name}",
        std::process::id()
    ))
}

/// Evaluate `medoids` against `points` through the streamed primitive,
/// feeding fixed-size row-range windows — the in-memory window source
/// BigFit uses, parameterized so the grid can sweep window sizes and
/// thread counts.
fn eval_streamed_ranges(
    points: &Points,
    metric: Metric,
    medoids: &[usize],
    rows_per_window: usize,
    threads: usize,
) -> (f64, Vec<usize>) {
    let medoid_points = points.select(medoids);
    let mut backend = NativeBackend::new(&medoid_points, metric);
    if threads > 1 {
        // min_work 0 forces the pool onto these tiny tiles, so the
        // multi-thread path is genuinely exercised.
        backend = backend.with_threads(threads).with_pool_min_work(0);
    }
    let n = points.len();
    let mut start = 0usize;
    loss_and_assignments_streamed(&backend, n, || {
        if start == n {
            return Ok(None);
        }
        let end = (start + rows_per_window).min(n);
        let idx: Vec<usize> = (start..end).collect();
        let window = points.select(&idx);
        let s = start;
        start = end;
        Ok(Some((s, window)))
    })
    .unwrap()
}

/// The tentpole parity grid: {l1, l2, cosine} x {dense, sparse} x threads
/// {1, 8} x window sizes {1 row, tiny, everything} — every cell bitwise
/// equal to the one-shot in-memory evaluation.
#[test]
fn streamed_primitive_matches_in_memory_across_grid() {
    let n = 120usize;
    let dense = synthetic::gmm(&mut Rng::seed_from(5), n, 10, 4, 3.0);
    // density high enough that no row is all-zero (cosine needs norms)
    let sparse = synthetic::scrna_sparse(&mut Rng::seed_from(6), n, 48, 0.25);
    let medoids = [3usize, 37, 58, 119];
    for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
        for ds in [&dense, &sparse] {
            let backend = NativeBackend::new(&ds.points, metric);
            let (want_loss, want_assign) = loss_and_assignments(&backend, &medoids);
            for threads in [1usize, 8] {
                for rows in [1usize, 7, n] {
                    let (loss, assign) =
                        eval_streamed_ranges(&ds.points, metric, &medoids, rows, threads);
                    assert_eq!(
                        loss.to_bits(),
                        want_loss.to_bits(),
                        "loss bits: {metric} {} threads={threads} rows={rows}",
                        ds.points.kind()
                    );
                    assert_eq!(
                        assign,
                        want_assign,
                        "assignments: {metric} {} threads={threads} rows={rows}",
                        ds.points.kind()
                    );
                }
            }
        }
    }
}

/// Same parity through a real on-disk `.mtx` and the chunked reader's
/// windows (the streamed BigFit evaluation path), across window budgets
/// from one-entry-per-window to everything-in-one-window. Also pins the
/// reader's residency accounting for raw window iteration.
#[test]
fn streamed_primitive_matches_through_real_chunk_reader() {
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(9), 90, 40, 0.25);
    let path = tmpfile("reader.mtx");
    loader::save_mtx(&ds, &path).unwrap();
    let medoids = [0usize, 41, 89];
    let backend = NativeBackend::new(&ds.points, Metric::L2);
    let (want_loss, want_assign) = loss_and_assignments(&backend, &medoids);
    let medoid_points = ds.points.select(&medoids);
    for chunk in [1usize, 53, 1_000_000] {
        let mut reader = CsrChunkReader::open(
            &path,
            StreamOptions { chunk_nnz: chunk, ..Default::default() },
        )
        .unwrap();
        let mb = NativeBackend::new(&medoid_points, Metric::L2);
        let (loss, assign) = loss_and_assignments_streamed(&mb, ds.len(), || {
            Ok(reader
                .next_window()?
                .map(|w| (w.start_row, Points::Sparse(w.matrix))))
        })
        .unwrap();
        assert_eq!(loss.to_bits(), want_loss.to_bits(), "loss bits at chunk={chunk}");
        assert_eq!(assign, want_assign, "assignments at chunk={chunk}");
        // Raw window iteration records one-window residency: positive,
        // and never more than the largest planned window.
        let stats = reader.stats();
        assert!(stats.peak_resident_nnz > 0, "residency recorded at chunk={chunk}");
        assert!(
            stats.peak_resident_nnz <= stats.peak_window_nnz,
            "resident {} > window peak {} at chunk={chunk}",
            stats.peak_resident_nnz,
            stats.peak_window_nnz
        );
    }
    let _ = std::fs::remove_file(path);
}

/// The BigFit outer loop over a streamed `.mtx` is bitwise-identical —
/// medoids, assignments, loss bits, eval counts — to the in-memory outer
/// loop with the same seed, across window budgets; the streamed run's
/// resident working set stays far below the full matrix; and the
/// resulting extracted-row model predicts and persists like any other.
#[test]
fn bigfit_streamed_bitwise_matches_in_memory() {
    let ds = synthetic::scrna_sparse(&mut Rng::seed_from(11), 600, 64, 0.10);
    let path = tmpfile("bigfit.mtx");
    loader::save_mtx(&ds, &path).unwrap();
    let loaded = loader::load_mtx(&path, false, 0).unwrap();
    let Points::Sparse(csr) = &loaded.points else { unreachable!() };
    let total_nnz = csr.nnz();

    let big = Fit::banditpam().metric(Metric::L1).k(4).seed(3).big().samples(3);
    let (mem_model, mem_stats) = big.fit_with_stats(&loaded).unwrap();
    assert_eq!(mem_stats.n_rows, 600);
    assert_eq!(mem_stats.trajectory.len(), 3);

    for chunk in [97usize, 1_000_000] {
        let opts = StreamOptions { chunk_nnz: chunk, ..Default::default() };
        let (st_model, st_stats) = big.fit_streamed(&path, &opts).unwrap();
        assert_eq!(
            mem_model.clustering().medoids,
            st_model.clustering().medoids,
            "medoids at chunk={chunk}"
        );
        assert_eq!(
            mem_model.clustering().assignments,
            st_model.clustering().assignments,
            "assignments at chunk={chunk}"
        );
        assert_eq!(
            mem_model.loss().to_bits(),
            st_model.loss().to_bits(),
            "loss bits at chunk={chunk}"
        );
        assert_eq!(
            mem_model.clustering().stats.distance_evals,
            st_model.clustering().stats.distance_evals,
            "eval counts at chunk={chunk}"
        );
        assert_eq!(st_stats.total_nnz, total_nnz);
        if chunk == 97 {
            // Bounded memory at a small window budget: sample + window /
            // medoids + window stays well under the full matrix.
            assert!(
                st_stats.peak_resident_nnz * 4 < total_nnz,
                "peak resident {} nnz >= 25% of {total_nnz}",
                st_stats.peak_resident_nnz
            );
        }
    }

    // The extracted-row model behaves like any other: training-set
    // predict reproduces the stored assignments, and it round-trips
    // through the binary format.
    let pred = mem_model.predict(&loaded.points).unwrap();
    assert_eq!(&pred, &mem_model.clustering().assignments);
    let bytes = mem_model.to_bytes().unwrap();
    let reloaded = KMedoidsModel::from_bytes(&bytes).unwrap();
    assert_eq!(reloaded.algorithm(), "bigfit+banditpam");
    assert_eq!(reloaded.clustering().medoids, mem_model.clustering().medoids);
    assert_eq!(reloaded.loss().to_bits(), mem_model.loss().to_bits());
    assert_eq!(reloaded.n_train(), 600);

    let _ = std::fs::remove_file(path);
}

/// CLARA bugfix regression (integration level): the backend counter reads
/// exactly `samples * (ssize^2 + k*n)` — one subsample pair matrix plus
/// one full-dataset scoring pass per candidate, and **no** second
/// evaluation of the winner at finalize — with the work attributed to the
/// right stats fields.
#[test]
fn clara_scores_each_candidate_exactly_once_with_honest_stats() {
    let (n, k, samples) = (200usize, 3usize, 4usize);
    let ds = synthetic::gmm(&mut Rng::seed_from(13), n, 5, k, 4.0);
    let backend = NativeBackend::new(&ds.points, Metric::L2);
    let mut clara = Clara { samples, sample_size: 0 };
    let fit = clara.fit(&backend, k, &mut Rng::seed_from(2)).unwrap();
    let ssize = 40 + 2 * k;
    let expect = (samples * (ssize * ssize + k * n)) as u64;
    assert_eq!(backend.counter().get(), expect, "one full pass per candidate");
    assert_eq!(fit.stats.distance_evals, expect);
    assert_eq!(fit.stats.build_evals, (samples * ssize * ssize) as u64);
    assert_eq!(fit.stats.eval_evals, (samples * k * n) as u64);
    assert_eq!(fit.stats.samples, samples);
    assert_eq!(fit.stats.swap_evals, 0);
}
