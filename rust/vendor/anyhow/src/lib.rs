//! Offline stand-in for the `anyhow` crate: the subset of its API this
//! workspace uses, with compatible semantics.
//!
//! The real crate is not available in the offline build cache, so this
//! shim provides: [`Error`] (a context chain), [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the [`Context`]
//! extension trait for `Result` and `Option`. Error's `Display` shows the
//! outermost message; the alternate form (`{:#}`) joins the whole chain
//! with `": "`, matching anyhow's formatting contract that the test suite
//! and CLI rely on.

use std::fmt;

/// Error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, [])) => f.write_str(head),
            Some((head, rest)) => {
                writeln!(f, "{head}")?;
                writeln!(f)?;
                writeln!(f, "Caused by:")?;
                for (i, cause) in rest.iter().enumerate() {
                    writeln!(f, "    {i}: {cause}")?;
                }
                Ok(())
            }
        }
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension methods for attaching context, as in `anyhow::Context`.
pub trait Context<T, E> {
    /// Attach a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let some: Option<u32> = Some(7);
        assert_eq!(some.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("coord {},{}", 4, 5);
        assert_eq!(e.to_string(), "coord 4,5");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "mid", "inner"]);
        assert_eq!(e.root_cause(), "inner");
    }
}
