//! Figure 1(b): distance evaluations per iteration vs n on HOC4-like ASTs
//! with tree edit distance, k = 2, log–log.
//!
//! The paper reports a fitted slope of 1.046 for BanditPAM and draws
//! analytic reference lines for PAM (k·n²) and FastPAM1 (n²); we print all
//! three plus our fitted slope.

use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::data::synthetic;
use crate::distance::Metric;
use crate::experiments::harness::{aggregate, default_threads, run_setting, scaling_slope};
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (Vec<usize>, usize, usize) {
    match scale {
        Scale::Smoke => (vec![120, 240], 2, 2),
        Scale::Quick => (vec![100, 200, 400, 800], 3, 2),
        Scale::Paper => (vec![200, 400, 800, 1600, 3360], 5, 2),
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (sizes, repeats, k) = params(scale);
    let base = synthetic::hoc4_like(&mut Rng::seed_from(seed), *sizes.iter().max().unwrap());
    let threads = default_threads();

    let mut table = Table::new(
        format!("Fig 1b — distance evals/iter vs n (hoc4_like, tree edit, k={k})"),
        &["n", "banditpam evals/iter", "ci95", "PAM ref (kn^2)", "FastPAM1 ref (n^2)"],
    );
    let mut points = Vec::new();
    for &n in &sizes {
        let mut algo = BanditPam::default_paper();
        let ms = run_setting(&mut algo, &base, Metric::TreeEdit, n, k, repeats, threads, seed);
        let p = aggregate(n, &ms);
        table.row(vec![
            n.to_string(),
            fnum(p.evals_per_iter.0),
            fnum(p.evals_per_iter.1),
            fnum((k * n * n) as f64),
            fnum((n * n) as f64),
        ]);
        points.push(p);
    }
    let slope = scaling_slope(&points, false);
    let mut summary = Table::new("Fig 1b — fitted log-log slope", &["series", "slope", "paper"]);
    summary.row(vec!["banditpam evals/iter".into(), fnum(slope), "1.046".into()]);
    summary.row(vec!["pam ref".into(), "2.0".into(), "2".into()]);
    summary.row(vec!["fastpam1 ref".into(), "2.0".into(), "2".into()]);
    vec![table, summary]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_and_scaling_is_subquadratic() {
        let tables = run(Scale::Smoke, 13);
        assert_eq!(tables.len(), 2);
        // pre-asymptotic at smoke sizes; see fig2 smoke test comment
        let slope: f64 = tables[1].rows[0][1].parse().unwrap();
        assert!(slope.is_finite() && slope < 2.4, "slope {slope}");
    }
}
