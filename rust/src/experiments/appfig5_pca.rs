//! Appendix Figure 5: BanditPAM scaling on scRNA-PCA (the assumption-
//! violation dataset).
//!
//! Paper: slope of the line of best fit 1.204 — noticeably superlinear,
//! versus ~1.0 on the well-behaved datasets, because the arm means
//! concentrate near the minimum and the reward tails fatten.

use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::data::synthetic;
use crate::distance::Metric;
use crate::experiments::harness::{aggregate, default_threads, run_setting, scaling_slope};
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (Vec<usize>, usize, usize) {
    match scale {
        Scale::Smoke => (vec![150, 300], 2, 128),
        Scale::Quick => (vec![500, 1000, 2000], 3, 512),
        Scale::Paper => (vec![500, 1000, 2000, 4000, 8000], 5, 1024),
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (sizes, repeats, genes) = params(scale);
    let max = *sizes.iter().max().unwrap();
    let base = synthetic::scrna_pca(&mut Rng::seed_from(seed), max * 2, genes, 10);
    let threads = default_threads();
    let k = 5.min(sizes[0] / 10).max(2);

    let mut table = Table::new(
        format!("Appendix Fig 5 — evals/iter vs n (scrna_pca, l2, k={k})"),
        &["n", "evals/iter", "ci95", "PAM ref (kn^2)"],
    );
    let mut points = Vec::new();
    for &n in &sizes {
        let mut algo = BanditPam::default_paper();
        let ms = run_setting(&mut algo, &base, Metric::L2, n, k, repeats, threads, seed);
        let p = aggregate(n, &ms);
        table.row(vec![
            n.to_string(),
            fnum(p.evals_per_iter.0),
            fnum(p.evals_per_iter.1),
            fnum((k * n * n) as f64),
        ]);
        points.push(p);
    }
    let mut summary = Table::new("Appendix Fig 5 — slope", &["series", "slope", "paper"]);
    summary.row(vec![
        "evals/iter".into(),
        fnum(scaling_slope(&points, false)),
        "1.204 (superlinear)".into(),
    ]);
    vec![table, summary]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs() {
        let tables = run(Scale::Smoke, 37);
        assert_eq!(tables.len(), 2);
        let slope: f64 = tables[1].rows[0][1].parse().unwrap();
        assert!(slope.is_finite());
    }
}
