//! Appendix Figures 3–4: per-arm reward distributions (4 sample arms) in
//! the first BUILD step, MNIST-like vs scRNA-PCA.
//!
//! The paper's observation: MNIST rewards look Gaussian-ish; scRNA-PCA
//! rewards are much heavier-tailed (large sigma_x), violating the
//! effective sub-Gaussian assumption. We print per-arm summary stats plus
//! excess kurtosis as the tail-weight readout.

use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::data::{synthetic, Dataset};
use crate::distance::Metric;
use crate::runtime::backend::{DistanceBackend, NativeBackend};
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (usize, usize) {
    match scale {
        Scale::Smoke => (150, 128),
        Scale::Quick => (1000, 512),
        Scale::Paper => (3000, 1024),
    }
}

/// Excess kurtosis of a sample (0 for a Gaussian).
pub fn excess_kurtosis(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let m2 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    let m4 = xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n;
    if m2 <= 0.0 {
        return 0.0;
    }
    m4 / (m2 * m2) - 3.0
}

fn arm_rows(ds: &Dataset, metric: Metric, arms: &[usize]) -> Vec<Vec<f64>> {
    let backend = NativeBackend::new(&ds.points, metric);
    let n = backend.n();
    let refs: Vec<usize> = (0..n).collect();
    arms.iter()
        .map(|&a| {
            let mut row = vec![0.0f64; n];
            backend.block(&[a], &refs, &mut row);
            row
        })
        .collect()
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (n, genes) = params(scale);
    let mut rng = Rng::seed_from(seed);
    let mnist = synthetic::mnist_like(&mut rng, n);
    let pca = synthetic::scrna_pca(&mut rng, n, genes, 10);
    let mut arm_rng = Rng::seed_from(seed ^ 0xABCD);
    let arms = arm_rng.sample_indices(n, 4);

    let mut out = Vec::new();
    for (name, ds, metric) in [
        ("mnist_like / l2 (App Fig 3)", &mnist, Metric::L2),
        ("scrna_pca / l2 (App Fig 4)", &pca, Metric::L2),
    ] {
        let mut table = Table::new(
            format!("Reward distributions, first BUILD step — {name}"),
            &["arm", "mean", "std", "min", "max", "excess kurtosis"],
        );
        for (ai, rewards) in arm_rows(ds, metric, &arms).iter().enumerate() {
            let s = crate::stats::summary::Summary::of(rewards);
            let mut r = crate::stats::running::Running::new();
            r.extend(rewards.iter().copied());
            table.row(vec![
                format!("x{}", arms[ai]),
                fnum(s.mean),
                fnum(r.std_pop()),
                fnum(s.min),
                fnum(s.max),
                fnum(excess_kurtosis(rewards)),
            ]);
        }
        out.push(table);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kurtosis_of_gaussian_is_near_zero() {
        let mut rng = Rng::seed_from(5);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let k = excess_kurtosis(&xs);
        assert!(k.abs() < 0.15, "kurtosis {k}");
    }

    #[test]
    fn kurtosis_of_heavy_tail_is_positive() {
        let mut rng = Rng::seed_from(6);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.lognormal(0.0, 1.0)).collect();
        assert!(excess_kurtosis(&xs) > 1.0);
    }

    #[test]
    fn smoke_produces_two_tables_with_four_arms() {
        let tables = run(Scale::Smoke, 31);
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 4);
        assert_eq!(tables[1].rows.len(), 4);
    }
}
