//! The paper's headline claims (§1 / §5): BanditPAM returns **the same
//! medoids as PAM** while computing dramatically fewer distances ("up to
//! 200x fewer"), crossing over by n ≈ 1–2k.
//!
//! This experiment runs BanditPAM and FastPAM1 (PAM-identical) on the same
//! subsamples and reports the evaluation ratio, wall-clock ratio and
//! medoid agreement at each n, plus the extrapolated ratio at the paper's
//! full-MNIST n = 70,000 (the evaluation ratio grows like n / log n).

use crate::algorithms::fastpam1::FastPam1;
use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::data::synthetic;
use crate::distance::Metric;
use crate::experiments::harness::{default_threads, run_setting};
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (Vec<usize>, usize, usize) {
    match scale {
        Scale::Smoke => (vec![100, 200], 2, 3),
        Scale::Quick => (vec![500, 1000, 2000, 4000], 2, 5),
        Scale::Paper => (vec![1000, 2000, 4000, 8000], 3, 5),
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (sizes, repeats, k) = params(scale);
    let base = synthetic::mnist_like(&mut Rng::seed_from(seed), *sizes.iter().max().unwrap() * 2);
    let threads = default_threads();

    // Per-iteration accounting follows the paper (§5.2): BanditPAM's
    // measured evals are divided by (swap iterations + 1); PAM and
    // FastPAM1 are "expected to be exactly k n^2 and n^2 respectively in
    // each iteration" — the analytic reference lines of Figs 1b/2/3.
    let mut table = Table::new(
        format!("Headline — BanditPAM vs PAM/FastPAM1 per-iteration (mnist_like, l2, k={k})"),
        &[
            "n",
            "bp evals/iter",
            "vs fp1 (n^2)",
            "vs pam (kn^2)",
            "bp secs",
            "fp1 secs (measured)",
            "same medoids",
        ],
    );
    let mut last_ratio_pam = 0.0;
    let mut last_n = 1usize;
    for &n in &sizes {
        let mut bp = BanditPam::default_paper();
        let bp_runs = run_setting(&mut bp, &base, Metric::L2, n, k, repeats, threads, seed);
        let mut fp1 = FastPam1::new();
        let fp1_runs = run_setting(&mut fp1, &base, Metric::L2, n, k, repeats, threads, seed);

        let bp_iter: f64 =
            bp_runs.iter().map(|m| m.evals_per_iter).sum::<f64>() / repeats as f64;
        let bp_s: f64 =
            bp_runs.iter().map(|m| m.wall_secs).sum::<f64>() / repeats as f64;
        let fp_s: f64 =
            fp1_runs.iter().map(|m| m.wall_secs).sum::<f64>() / repeats as f64;
        let same = bp_runs
            .iter()
            .zip(&fp1_runs)
            .filter(|(a, b)| a.medoids == b.medoids)
            .count();
        let ratio_fp1 = (n * n) as f64 / bp_iter.max(1.0);
        let ratio_pam = (k * n * n) as f64 / bp_iter.max(1.0);
        table.row(vec![
            n.to_string(),
            fnum(bp_iter),
            format!("{}x fewer", fnum(ratio_fp1)),
            format!("{}x fewer", fnum(ratio_pam)),
            fnum(bp_s),
            fnum(fp_s),
            format!("{same}/{repeats}"),
        ]);
        last_ratio_pam = ratio_pam;
        last_n = n;
    }

    // Extrapolate the PAM ratio to n = 70,000: BanditPAM/iter ~ c n log n
    // vs PAM's k n^2, so the ratio grows ~ n / log n.
    let c = last_ratio_pam * (last_n as f64).ln() / last_n as f64;
    let extro = c * 70_000.0 / 70_000f64.ln();
    let mut summary = Table::new("Headline — extrapolation", &["quantity", "value", "paper"]);
    summary.row(vec![
        "evals/iter ratio vs PAM @ n=70k (extrapolated)".into(),
        format!("{}x", fnum(extro)),
        "up to 200x".into(),
    ]);
    vec![table, summary]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_grows_with_n_and_medoids_agree() {
        let tables = run(Scale::Smoke, 41);
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), 2);
        let parse_ratio = |s: &str| -> f64 {
            s.split('x').next().unwrap().parse().unwrap()
        };
        let r0 = parse_ratio(&rows[0][3]);
        let r1 = parse_ratio(&rows[1][3]);
        assert!(r1 > r0 * 0.8, "PAM ratio should trend upward: {r0} -> {r1}");
        // medoid agreement in most repeats
        for row in rows {
            let (a, b) = row[6].split_once('/').unwrap();
            let a: usize = a.parse().unwrap();
            let b: usize = b.parse().unwrap();
            assert!(a + 1 >= b, "medoid agreement too low: {}", row[6]);
        }
    }
}
