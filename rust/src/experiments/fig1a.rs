//! Figure 1(a): clustering loss relative to PAM.
//!
//! Protocol (paper §5.1): data subsampled from MNIST, n ∈ {500..3000},
//! k = 5, l2, 10 repeats, 95% CIs. BanditPAM returns the same solution as
//! PAM (ratio exactly 1, as does FastPAM1); FastPAM is comparable; CLARANS
//! and Voronoi Iteration are significantly worse.
//!
//! PAM's loss is obtained through FastPAM1 (guaranteed-identical result,
//! O(k) cheaper per iteration) — the paper itself plots FastPAM1 at ratio 1
//! "omitted for clarity".

use crate::algorithms::{
    clarans::Clarans, fastpam::FastPam, fastpam1::FastPam1,
    voronoi::VoronoiIteration, KMedoids,
};
use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::data::synthetic;
use crate::distance::Metric;
use crate::experiments::harness::{default_threads, run_setting};
use crate::stats::summary::mean_ci95;
use crate::util::rng::Rng;

/// Sweep sizes / repeats / k per scale.
pub fn params(scale: Scale) -> (Vec<usize>, usize, usize) {
    match scale {
        Scale::Smoke => (vec![80, 150], 2, 3),
        Scale::Quick => (vec![500, 1000, 2000], 3, 5),
        Scale::Paper => (vec![500, 1000, 1500, 2000, 2500, 3000], 10, 5),
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (sizes, repeats, k) = params(scale);
    let base_n = *sizes.iter().max().unwrap() * 2;
    let base = synthetic::mnist_like(&mut Rng::seed_from(seed), base_n);
    let threads = default_threads();

    let mut table = Table::new(
        format!("Fig 1a — loss relative to PAM (mnist_like, l2, k={k}, {repeats} repeats)"),
        &["n", "banditpam", "fastpam", "clarans", "voronoi", "banditpam==pam"],
    );

    for &n in &sizes {
        // Reference (PAM-equivalent) runs per repeat.
        let mut pam_ref = FastPam1::new();
        let pam_runs = run_setting(&mut pam_ref, &base, Metric::L2, n, k, repeats, threads, seed);

        let mut ratios: Vec<Vec<f64>> = Vec::new();
        let mut exact_matches = 0usize;
        let algos: Vec<Box<dyn KMedoids>> = vec![
            Box::new(BanditPam::default_paper()),
            Box::new(FastPam::new()),
            Box::new(Clarans::new()),
            Box::new(VoronoiIteration::new()),
        ];
        for mut algo in algos {
            let runs = run_setting(algo.as_mut(), &base, Metric::L2, n, k, repeats, threads, seed);
            let r: Vec<f64> = runs
                .iter()
                .zip(&pam_runs)
                .map(|(a, p)| a.loss / p.loss)
                .collect();
            if algo.name() == "banditpam" {
                exact_matches = runs
                    .iter()
                    .zip(&pam_runs)
                    .filter(|(a, p)| a.medoids == p.medoids)
                    .count();
            }
            ratios.push(r);
        }

        let cell = |rs: &[f64]| {
            let (m, ci) = mean_ci95(rs);
            format!("{}±{}", fnum(m), fnum(ci))
        };
        table.row(vec![
            n.to_string(),
            cell(&ratios[0]),
            cell(&ratios[1]),
            cell(&ratios[2]),
            cell(&ratios[3]),
            format!("{exact_matches}/{repeats}"),
        ]);
    }
    vec![table]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_scale_runs_and_banditpam_ratio_is_one() {
        let tables = run(Scale::Smoke, 11);
        assert_eq!(tables.len(), 1);
        let t = &tables[0];
        assert_eq!(t.rows.len(), 2);
        for row in &t.rows {
            // banditpam ratio column starts with "1" or very close to it
            let ratio: f64 = row[1].split('±').next().unwrap().parse().unwrap();
            assert!(
                (ratio - 1.0).abs() < 0.02,
                "banditpam loss ratio {ratio} too far from 1"
            );
        }
    }
}
