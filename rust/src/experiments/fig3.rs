//! Figure 3: runtime per iteration vs n for (a) MNIST-like with cosine
//! distance and (b) scRNA-like with l1, both k = 5, log–log.
//!
//! Paper slopes: 1.007 (MNIST/cosine) and 1.011 (scRNA/l1).

use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::data::{synthetic, Dataset};
use crate::distance::Metric;
use crate::experiments::harness::{aggregate, default_threads, run_setting, scaling_slope};
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (Vec<usize>, usize, usize) {
    match scale {
        Scale::Smoke => (vec![150, 300], 2, 128),
        Scale::Quick => (vec![500, 1000, 2000], 3, 1024),
        Scale::Paper => (vec![500, 1000, 2000, 4000], 5, 1024),
    }
}

fn sweep(
    name: &str,
    base: &Dataset,
    metric: Metric,
    sizes: &[usize],
    repeats: usize,
    seed: u64,
    paper_slope: &str,
) -> (Table, Table) {
    let threads = default_threads();
    let k = 5.min(sizes[0] / 10).max(2);
    let mut table = Table::new(
        format!("Fig 3 — runtime/iter vs n ({name}, {metric}, k={k})"),
        &["n", "secs/iter", "ci95", "evals/iter", "FastPAM1 ref (n^2)"],
    );
    let mut points = Vec::new();
    for &n in sizes {
        let mut algo = BanditPam::default_paper();
        let ms = run_setting(&mut algo, base, metric, n, k, repeats, threads, seed);
        let p = aggregate(n, &ms);
        table.row(vec![
            n.to_string(),
            fnum(p.secs_per_iter.0),
            fnum(p.secs_per_iter.1),
            fnum(p.evals_per_iter.0),
            fnum((n * n) as f64),
        ]);
        points.push(p);
    }
    let mut summary = Table::new(
        format!("Fig 3 — slopes ({name}, {metric})"),
        &["series", "slope", "paper"],
    );
    summary.row(vec![
        "evals/iter".into(),
        fnum(scaling_slope(&points, false)),
        paper_slope.into(),
    ]);
    (table, summary)
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (sizes, repeats, genes) = params(scale);
    let max = *sizes.iter().max().unwrap();
    let mnist = synthetic::mnist_like(&mut Rng::seed_from(seed), max * 2);
    let scrna = synthetic::scrna_like(&mut Rng::seed_from(seed ^ 2), max * 2, genes);
    let (t1, s1) = sweep("mnist_like", &mnist, Metric::Cosine, &sizes, repeats, seed, "1.007");
    let (t2, s2) = sweep("scrna_like", &scrna, Metric::L1, &sizes, repeats, seed, "1.011");
    vec![t1, s1, t2, s2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_runs_both_datasets() {
        let tables = run(Scale::Smoke, 19);
        assert_eq!(tables.len(), 4);
        assert!(tables[0].title.contains("cosine"));
        assert!(tables[2].title.contains("l1"));
        for summary in [&tables[1], &tables[3]] {
            // pre-asymptotic at smoke sizes; see fig2 smoke test comment
            let slope: f64 = summary.rows[0][1].parse().unwrap();
            assert!(slope.is_finite() && slope < 2.4, "slope {slope}");
        }
    }
}
