//! Ablations of BanditPAM's design choices (DESIGN.md: abl-sigma,
//! abl-delta, abl-cache, abl-fastpam1).
//!
//! * **sigma mode** (paper §3.2 / Appendix 1.2): per-arm first-batch
//!   (default) vs per-arm running vs one global sigma. Global sigma
//!   inflates CIs and wastes evaluations.
//! * **delta sweep** (paper Appendix 2.3): larger delta = approximate
//!   BanditPAM; fewer evaluations, possible loss concessions.
//! * **cache** (paper Appendix 2.2): fixed-permutation sampling + pairwise
//!   cache trades memory for recomputation.
//! * **FastPAM1 row sharing** (paper Appendix 1.1): disabling the Eq. 12
//!   sharing makes each SWAP arm pay its own distance row.
//! * **SWAP reuse** (BanditPAM++, `abl-swap-reuse`): cross-iteration
//!   candidate-row caching (bitwise-identical results, fewer evals) and
//!   opt-in estimator carry-over (same w.h.p. guarantee, fewer pulls).

use crate::algorithms::{fastpam1::FastPam1, make_algorithm, KMedoids};
use crate::bandits::adaptive::{SamplingMode, SigmaMode};
use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::coordinator::config::{BanditPamConfig, DeltaMode};
use crate::data::synthetic;
use crate::distance::Metric;
use crate::runtime::backend::NativeBackend;
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (usize, usize, usize) {
    // (n, k, repeats)
    match scale {
        Scale::Smoke => (120, 3, 2),
        Scale::Quick => (1000, 5, 3),
        Scale::Paper => (2000, 5, 5),
    }
}

struct RunResult {
    evals: f64,
    swap_evals: f64,
    swap_saved: f64,
    loss: f64,
    same_as_pam: usize,
}

fn run_config(
    cfg: BanditPamConfig,
    n: usize,
    k: usize,
    repeats: usize,
    seed: u64,
    use_cache: bool,
) -> RunResult {
    let base = synthetic::mnist_like(&mut Rng::seed_from(seed), n * 2);
    let mut evals = 0.0;
    let mut swap_evals = 0.0;
    let mut swap_saved = 0.0;
    let mut loss = 0.0;
    let mut same = 0;
    for rep in 0..repeats {
        let sub = base.subsample(n, &mut Rng::seed_from(seed ^ (0xD0D0 + rep as u64)));
        let backend = if use_cache {
            NativeBackend::new(&sub.points, Metric::L2)
                .with_cache(32 * n * ((n as f64).ln() as usize + 1))
        } else {
            NativeBackend::new(&sub.points, Metric::L2)
        };
        let mut algo = BanditPam::new(cfg.clone());
        let fit = algo
            .fit(&backend, k, &mut Rng::seed_from(seed ^ (0xA1A1 + rep as u64)))
            .unwrap();
        let pam_backend = NativeBackend::new(&sub.points, Metric::L2);
        let pam = FastPam1::new()
            .fit(&pam_backend, k, &mut Rng::seed_from(0))
            .unwrap();
        evals += fit.stats.distance_evals as f64 / repeats as f64;
        swap_evals += fit.stats.swap_evals as f64 / repeats as f64;
        swap_saved += fit.stats.swap_evals_saved as f64 / repeats as f64;
        loss += fit.loss / pam.loss / repeats as f64;
        if fit.medoids == pam.medoids {
            same += 1;
        }
    }
    RunResult { evals, swap_evals, swap_saved, loss, same_as_pam: same }
}

/// The baseline lineup of the arms head-to-head table: the paper's
/// algorithm, the exact reference, and the strongest PAM-family/sampling
/// baselines (including the post-paper FasterPAM and OneBatchPAM arms).
pub const ARM_LINEUP: &[&str] =
    &["banditpam", "pam", "fastpam1", "fastpam", "fasterpam", "onebatchpam"];

/// Head-to-head result for one registry arm over the shared subsample
/// protocol, against the exact-PAM reference (FastPAM1 — identical
/// trajectory, O(k) cheaper to run).
pub struct ArmResult {
    pub evals: f64,
    pub loss: f64,
    pub same_as_pam: usize,
}

pub fn run_arm(name: &str, n: usize, k: usize, repeats: usize, seed: u64) -> ArmResult {
    let base = synthetic::mnist_like(&mut Rng::seed_from(seed), n * 2);
    let mut evals = 0.0;
    let mut loss = 0.0;
    let mut same = 0;
    for rep in 0..repeats {
        let sub = base.subsample(n, &mut Rng::seed_from(seed ^ (0xD0D0 + rep as u64)));
        let backend = NativeBackend::new(&sub.points, Metric::L2);
        let fit = make_algorithm(name)
            .unwrap()
            .fit(&backend, k, &mut Rng::seed_from(seed ^ (0xA1A1 + rep as u64)))
            .unwrap();
        let pam_backend = NativeBackend::new(&sub.points, Metric::L2);
        let pam = FastPam1::new()
            .fit(&pam_backend, k, &mut Rng::seed_from(0))
            .unwrap();
        evals += fit.stats.distance_evals as f64 / repeats as f64;
        loss += fit.loss / pam.loss / repeats as f64;
        if fit.medoids == pam.medoids {
            same += 1;
        }
    }
    ArmResult { evals, loss, same_as_pam: same }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (n, k, repeats) = params(scale);
    let mut out = Vec::new();

    // --- abl-sigma -------------------------------------------------------
    let mut t = Table::new(
        format!("Ablation: sigma estimation (n={n}, k={k}, {repeats} repeats)"),
        &["sigma mode", "mean evals", "loss ratio vs PAM", "same medoids"],
    );
    for (name, mode) in [
        ("per-arm first batch (paper)", SigmaMode::PerArmFirstBatch),
        ("per-arm running", SigmaMode::PerArmRunning),
        ("global first batch", SigmaMode::GlobalFirstBatch),
    ] {
        let cfg = BanditPamConfig { sigma_mode: mode, ..Default::default() };
        let r = run_config(cfg, n, k, repeats, seed, false);
        t.row(vec![
            name.into(),
            fnum(r.evals),
            fnum(r.loss),
            format!("{}/{repeats}", r.same_as_pam),
        ]);
    }
    out.push(t);

    // --- abl-delta (approximate BanditPAM) -------------------------------
    let mut t = Table::new(
        "Ablation: delta sweep (Appendix 2.3 approximate BanditPAM)",
        &["delta", "mean evals", "loss ratio vs PAM", "same medoids"],
    );
    for &delta in &[1e-8, 1e-5, 1e-3, 1e-1] {
        let cfg = BanditPamConfig { delta: DeltaMode::Fixed(delta), ..Default::default() };
        let r = run_config(cfg, n, k, repeats, seed, false);
        t.row(vec![
            format!("{delta:.0e}"),
            fnum(r.evals),
            fnum(r.loss),
            format!("{}/{repeats}", r.same_as_pam),
        ]);
    }
    out.push(t);

    // --- abl-cache --------------------------------------------------------
    let mut t = Table::new(
        "Ablation: fixed-permutation sampling + pairwise cache (Appendix 2.2)",
        &["config", "counted evals (cache misses)", "loss ratio", "same medoids"],
    );
    for (name, sampling, cache) in [
        ("with-replacement, no cache (paper)", SamplingMode::WithReplacement, false),
        ("fixed permutation, no cache", SamplingMode::FixedPermutation, false),
        ("fixed permutation + cache", SamplingMode::FixedPermutation, true),
    ] {
        let cfg = BanditPamConfig { sampling, ..Default::default() };
        let r = run_config(cfg, n, k, repeats, seed, cache);
        t.row(vec![
            name.into(),
            fnum(r.evals),
            fnum(r.loss),
            format!("{}/{repeats}", r.same_as_pam),
        ]);
    }
    out.push(t);

    // --- abl-fastpam1 ------------------------------------------------------
    let mut t = Table::new(
        "Ablation: FastPAM1 SWAP row sharing (Appendix 1.1)",
        &["config", "mean evals", "loss ratio", "same medoids"],
    );
    for (name, share) in [("shared rows (paper)", true), ("per-arm rows", false)] {
        let cfg = BanditPamConfig { fastpam1_swap: share, ..Default::default() };
        let r = run_config(cfg, n, k, repeats, seed, false);
        t.row(vec![
            name.into(),
            fnum(r.evals),
            fnum(r.loss),
            format!("{}/{repeats}", r.same_as_pam),
        ]);
    }
    out.push(t);

    // --- abl-swap-reuse ----------------------------------------------------
    let mut t = Table::new(
        "Ablation: SWAP reuse (BanditPAM++ virtual arms + carry-over)",
        &[
            "config",
            "mean evals",
            "mean swap evals",
            "swap evals saved",
            "loss ratio",
            "same medoids",
        ],
    );
    for (name, reuse, warm) in [
        ("no reuse (BanditPAM)", false, false),
        ("row reuse (virtual arms)", true, false),
        ("row reuse + warm estimators", true, true),
    ] {
        let cfg = BanditPamConfig {
            swap_reuse: reuse,
            swap_warm_start: warm,
            ..Default::default()
        };
        let r = run_config(cfg, n, k, repeats, seed, false);
        t.row(vec![
            name.into(),
            fnum(r.evals),
            fnum(r.swap_evals),
            fnum(r.swap_saved),
            fnum(r.loss),
            format!("{}/{repeats}", r.same_as_pam),
        ]);
    }
    out.push(t);

    // --- abl-arms: algorithm arms head-to-head -----------------------------
    // Every baseline the registry offers on one protocol: mean distance
    // evaluations and loss ratio against the exact-PAM reference. This is
    // the honest version of the paper's Figure 1a lineup, extended with
    // the post-paper FasterPAM and OneBatchPAM arms.
    let mut t = Table::new(
        format!("Ablation: algorithm arms head-to-head (n={n}, k={k}, {repeats} repeats)"),
        &["arm", "mean evals", "loss ratio vs PAM", "same medoids"],
    );
    for &arm in ARM_LINEUP {
        let r = run_arm(arm, n, k, repeats, seed);
        t.row(vec![
            arm.into(),
            fnum(r.evals),
            fnum(r.loss),
            format!("{}/{repeats}", r.same_as_pam),
        ]);
    }
    out.push(t);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_ablations_run_and_delta_monotonicity_holds() {
        let tables = run(Scale::Smoke, 43);
        assert_eq!(tables.len(), 6);
        // delta sweep: evals at delta=1e-1 <= evals at delta=1e-8
        let d = &tables[1].rows;
        let tight: f64 = d[0][1].parse().unwrap();
        let loose: f64 = d[3][1].parse().unwrap();
        assert!(
            loose <= tight * 1.05,
            "looser delta should not cost more evals: {tight} -> {loose}"
        );
        // abl-swap-reuse: row reuse must not add swap evals and must not
        // change the clustering (identical loss ratio by bitwise parity).
        let r = &tables[4].rows;
        let off_swap: f64 = r[0][2].parse().unwrap();
        let on_swap: f64 = r[1][2].parse().unwrap();
        assert!(
            on_swap <= off_swap + 1e-9,
            "row reuse added swap evals: {off_swap} -> {on_swap}"
        );
        assert_eq!(r[0][4], r[1][4], "row reuse changed the loss ratio");
        assert_eq!(r[0][5], r[1][5], "row reuse changed the medoid agreement");
        // the arms head-to-head covers the whole lineup, one row per arm
        let arms = &tables[5];
        assert_eq!(arms.rows.len(), ARM_LINEUP.len());
        for (row, &arm) in arms.rows.iter().zip(ARM_LINEUP) {
            assert_eq!(row[0], arm);
            let evals: f64 = row[1].parse().unwrap();
            assert!(evals > 0.0, "{arm} recorded no evaluations");
        }
    }

    /// Seeded quality pins for the two post-paper arms (ISSUE 9): the
    /// eager randomized FasterPAM must not lose quality relative to
    /// FastPAM's eager per-medoid sweeps (both converge to single-swap
    /// local optima, so the ratios agree up to local-optimum noise — a 1%
    /// slack keeps the pin meaningful without asserting a dominance the
    /// algorithms do not guarantee), and both stay in the Figure-1a band
    /// just above the exact-PAM reference.
    #[test]
    fn fasterpam_loss_ratio_is_no_worse_than_fastpam() {
        let (n, k, repeats) = params(Scale::Smoke);
        let fastpam = run_arm("fastpam", n, k, repeats, 43);
        let fasterpam = run_arm("fasterpam", n, k, repeats, 43);
        assert!(
            fasterpam.loss <= fastpam.loss + 0.01,
            "fasterpam mean loss ratio {} must track fastpam's {}",
            fasterpam.loss,
            fastpam.loss
        );
        assert!(fasterpam.loss < 1.05, "Figure-1a band: {}", fasterpam.loss);
    }

    /// OneBatchPAM's frugality pin at the paper scale n = 2000: one batch
    /// fit plus one scoring pass is a small fraction of PAM's analytic n²
    /// matrix precompute (pinned exactly in `algorithms::pam`), so the
    /// comparison needs no slow exact fit.
    #[test]
    fn onebatchpam_eval_count_is_far_below_pam_at_n_2000() {
        let (n, k) = (2000usize, 5usize);
        let ds = synthetic::mnist_like(&mut Rng::seed_from(7), n);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = make_algorithm("onebatchpam")
            .unwrap()
            .fit(&backend, k, &mut Rng::seed_from(1))
            .unwrap();
        let pam_evals = (n * n) as u64;
        assert!(
            fit.stats.distance_evals * 50 <= pam_evals,
            "onebatchpam spent {} evals, PAM would spend {}",
            fit.stats.distance_evals,
            pam_evals
        );
        assert!(fit.loss.is_finite() && fit.loss > 0.0);
    }
}
