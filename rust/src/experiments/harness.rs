//! Shared experiment harness: sweep runner following the paper's protocol
//! ("each parameter setting was repeated 10 times with data subsampled
//! from the original dataset and 95% confidence intervals are provided").

use crate::algorithms::{Clustering, KMedoids};
use crate::data::Dataset;
use crate::distance::Metric;
use crate::runtime::backend::NativeBackend;
use crate::stats::regression::loglog_slope;
use crate::stats::summary::mean_ci95;
use crate::util::rng::Rng;

/// One measurement (a single fit).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub n: usize,
    pub loss: f64,
    pub distance_evals: u64,
    pub evals_per_iter: f64,
    pub secs_per_iter: f64,
    pub wall_secs: f64,
    pub swap_iters: usize,
    pub medoids: Vec<usize>,
}

impl Measurement {
    pub fn from_fit(n: usize, fit: &Clustering) -> Measurement {
        Measurement {
            n,
            loss: fit.loss,
            distance_evals: fit.stats.distance_evals,
            evals_per_iter: fit.stats.evals_per_iter(),
            secs_per_iter: fit.stats.secs_per_iter(),
            wall_secs: fit.stats.wall_secs,
            swap_iters: fit.stats.swap_iters,
            medoids: fit.medoids.clone(),
        }
    }
}

/// Aggregated point of a sweep (mean ± 95% CI over repeats).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub n: usize,
    pub evals_per_iter: (f64, f64),
    pub secs_per_iter: (f64, f64),
    pub loss: (f64, f64),
}

/// Run `algo` on `repeats` subsamples of size `n` from `base` and collect
/// measurements. The backend uses `threads` for block sharding.
#[allow(clippy::too_many_arguments)]
pub fn run_setting(
    algo: &mut dyn KMedoids,
    base: &Dataset,
    metric: Metric,
    n: usize,
    k: usize,
    repeats: usize,
    threads: usize,
    seed: u64,
) -> Vec<Measurement> {
    let mut out = Vec::with_capacity(repeats);
    for rep in 0..repeats {
        let mut data_rng = Rng::seed_from(seed ^ (0xD0D0 + rep as u64));
        let sub = if n < base.len() {
            base.subsample(n, &mut data_rng)
        } else {
            base.clone()
        };
        let backend = NativeBackend::new(&sub.points, metric).with_threads(threads);
        let mut algo_rng = Rng::seed_from(seed ^ (0xA1A1 + rep as u64));
        let fit = algo
            .fit(&backend, k, &mut algo_rng)
            .expect("fit failed in sweep");
        out.push(Measurement::from_fit(sub.len(), &fit));
    }
    out
}

/// Aggregate measurements at one n.
pub fn aggregate(n: usize, ms: &[Measurement]) -> SweepPoint {
    let e: Vec<f64> = ms.iter().map(|m| m.evals_per_iter).collect();
    let s: Vec<f64> = ms.iter().map(|m| m.secs_per_iter).collect();
    let l: Vec<f64> = ms.iter().map(|m| m.loss).collect();
    SweepPoint {
        n,
        evals_per_iter: mean_ci95(&e),
        secs_per_iter: mean_ci95(&s),
        loss: mean_ci95(&l),
    }
}

/// Fitted log–log scaling exponent of evals/iter (or secs/iter) vs n —
/// the readout the paper reports for Figures 1b, 2, 3 and Appendix Fig 5.
pub fn scaling_slope(points: &[SweepPoint], use_time: bool) -> f64 {
    let xs: Vec<f64> = points.iter().map(|p| p.n as f64).collect();
    let ys: Vec<f64> = points
        .iter()
        .map(|p| if use_time { p.secs_per_iter.0 } else { p.evals_per_iter.0 })
        .map(|y| y.max(1e-12))
        .collect();
    loglog_slope(&xs, &ys).slope
}

/// Default thread count for sweeps (leave two cores for the system).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get().saturating_sub(2).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::banditpam::BanditPam;
    use crate::data::synthetic;

    #[test]
    fn sweep_and_slope_on_tiny_sizes() {
        let base = synthetic::gmm(&mut Rng::seed_from(1), 200, 6, 3, 3.0);
        let mut points = Vec::new();
        for &n in &[60usize, 120] {
            let mut algo = BanditPam::default_paper();
            let ms = run_setting(&mut algo, &base, Metric::L2, n, 2, 2, 1, 7);
            assert_eq!(ms.len(), 2);
            assert!(ms.iter().all(|m| m.n == n && m.distance_evals > 0));
            points.push(aggregate(n, &ms));
        }
        let slope = scaling_slope(&points, false);
        assert!(slope.is_finite());
    }

    #[test]
    fn aggregate_computes_ci() {
        let ms = vec![
            Measurement {
                n: 10, loss: 1.0, distance_evals: 100, evals_per_iter: 50.0,
                secs_per_iter: 0.1, wall_secs: 0.2, swap_iters: 1, medoids: vec![0],
            },
            Measurement {
                n: 10, loss: 3.0, distance_evals: 200, evals_per_iter: 70.0,
                secs_per_iter: 0.3, wall_secs: 0.6, swap_iters: 1, medoids: vec![1],
            },
        ];
        let p = aggregate(10, &ms);
        assert!((p.loss.0 - 2.0).abs() < 1e-12);
        assert!(p.loss.1 > 0.0);
        assert!((p.evals_per_iter.0 - 60.0).abs() < 1e-12);
    }
}
