//! Appendix Figure 2: histogram of the true arm parameters mu_x in the
//! first BUILD step for each (dataset, metric) pair.
//!
//! The paper's observation: MNIST (l2, cosine) and scRNA (l1) have broad
//! unimodal arm-mean distributions, while scRNA-PCA (l2) is sharply peaked
//! near the minimum — the pathology behind its degraded n^1.2 scaling.
//! We report the histogram plus a concentration statistic (the fraction of
//! arms within 5% of the minimum) that makes the comparison quantitative.

use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::data::{synthetic, Dataset};
use crate::distance::Metric;
use crate::runtime::backend::{DistanceBackend, NativeBackend};
use crate::stats::histogram::Histogram;
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (usize, usize, usize) {
    // (dataset n, sampled arms, genes)
    match scale {
        Scale::Smoke => (150, 60, 128),
        Scale::Quick => (1000, 300, 512),
        Scale::Paper => (3000, 1000, 1024),
    }
}

/// True first-step arm means: mean distance from each sampled arm to all
/// points.
fn arm_means(ds: &Dataset, metric: Metric, arms: usize, rng: &mut Rng) -> Vec<f64> {
    let backend = NativeBackend::new(&ds.points, metric)
        .with_threads(crate::experiments::harness::default_threads());
    let n = backend.n();
    let picks = rng.sample_indices(n, arms.min(n));
    let refs: Vec<usize> = (0..n).collect();
    let mut row = vec![0.0f64; n];
    picks
        .iter()
        .map(|&a| {
            backend.block(&[a], &refs, &mut row);
            row.iter().sum::<f64>() / n as f64
        })
        .collect()
}

fn concentration(mus: &[f64]) -> f64 {
    let lo = mus.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = mus.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if hi <= lo {
        return 1.0;
    }
    let thr = lo + 0.05 * (hi - lo);
    mus.iter().filter(|&&m| m <= thr).count() as f64 / mus.len() as f64
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (n, arms, genes) = params(scale);
    let mut rng = Rng::seed_from(seed);
    let mnist = synthetic::mnist_like(&mut rng, n);
    let scrna = synthetic::scrna_like(&mut rng, n, genes);
    let pca = synthetic::scrna_pca(&mut rng, n, genes, 10);

    let cases: Vec<(&str, &Dataset, Metric)> = vec![
        ("mnist_like / l2", &mnist, Metric::L2),
        ("mnist_like / cosine", &mnist, Metric::Cosine),
        ("scrna_like / l1", &scrna, Metric::L1),
        ("scrna_pca / l2", &pca, Metric::L2),
    ];

    let mut table = Table::new(
        format!("Appendix Fig 2 — first-BUILD arm means mu_x ({arms} arms, n={n})"),
        &["dataset/metric", "min", "median", "max", "frac within 5% of min"],
    );
    let mut out = vec![];
    for (name, ds, metric) in cases {
        let mut arng = Rng::seed_from(seed ^ 0xF00D);
        let mus = arm_means(ds, metric, arms, &mut arng);
        let s = crate::stats::summary::Summary::of(&mus);
        table.row(vec![
            name.into(),
            fnum(s.min),
            fnum(s.median),
            fnum(s.max),
            fnum(concentration(&mus)),
        ]);
        let mut hist_table = Table::new(
            format!("Appendix Fig 2 — histogram ({name})"),
            &["bin center", "count"],
        );
        let h = Histogram::fit(&mus, 12);
        for (i, &c) in h.counts().iter().enumerate() {
            hist_table.row(vec![fnum(h.bin_center(i)), c.to_string()]);
        }
        out.push(hist_table);
    }
    let mut all = vec![table];
    all.extend(out);
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_four_dataset_metric_pairs_report() {
        // The concentration ordering itself (scRNA-PCA >> MNIST) is a
        // Quick/Paper-scale observation recorded in EXPERIMENTS.md — at
        // smoke scale (60 arms, 128 genes) the statistic is too noisy to
        // assert. Here we verify structure and sanity.
        let tables = run(Scale::Smoke, 29);
        assert_eq!(tables.len(), 5); // summary + 4 histograms
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), 4);
        for row in rows {
            let min: f64 = row[1].parse().unwrap();
            let med: f64 = row[2].parse().unwrap();
            let max: f64 = row[3].parse().unwrap();
            let frac: f64 = row[4].parse().unwrap();
            assert!(min <= med && med <= max, "{row:?}");
            assert!((0.0..=1.0).contains(&frac), "{row:?}");
        }
        // each histogram sums to the number of sampled arms
        for h in &tables[1..] {
            let total: u64 = h.rows.iter().map(|r| r[1].parse::<u64>().unwrap()).sum();
            assert!(total >= 55, "histogram lost arms: {total}");
        }
    }
}
