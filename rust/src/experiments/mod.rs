//! Experiment registry: one module per paper table/figure.
//!
//! Every experiment implements a `run(scale) -> Table(s)` entry point used
//! both by the `banditpam experiment <id>` CLI subcommand and by the
//! corresponding `cargo bench` target. See DESIGN.md §Experiment-index for
//! the mapping (figure → module → bench) and EXPERIMENTS.md for recorded
//! paper-vs-measured results.

pub mod ablations;
pub mod appfig1_sigma;
pub mod appfig2_mu;
pub mod appfig34_rewards;
pub mod appfig5_pca;
pub mod fig1a;
pub mod fig1b;
pub mod fig2;
pub mod fig3;
pub mod harness;
pub mod headline;

use crate::bench::Scale;
use crate::bench::table::Table;

/// Run an experiment by id; returns its printed tables.
pub fn run(id: &str, scale: Scale, seed: u64) -> anyhow::Result<Vec<Table>> {
    match id {
        "fig1a" => Ok(fig1a::run(scale, seed)),
        "fig1b" => Ok(fig1b::run(scale, seed)),
        "fig2" => Ok(fig2::run(scale, seed)),
        "fig3" => Ok(fig3::run(scale, seed)),
        "appfig1" => Ok(appfig1_sigma::run(scale, seed)),
        "appfig2" => Ok(appfig2_mu::run(scale, seed)),
        "appfig34" => Ok(appfig34_rewards::run(scale, seed)),
        "appfig5" => Ok(appfig5_pca::run(scale, seed)),
        "headline" => Ok(headline::run(scale, seed)),
        "ablations" => Ok(ablations::run(scale, seed)),
        other => anyhow::bail!(
            "unknown experiment {other:?}; available: fig1a fig1b fig2 fig3 \
             appfig1 appfig2 appfig34 appfig5 headline ablations"
        ),
    }
}

/// All experiment ids (for `banditpam experiment all`).
pub const ALL: &[&str] = &[
    "fig1a", "fig1b", "fig2", "fig3", "appfig1", "appfig2", "appfig34",
    "appfig5", "headline", "ablations",
];
