//! Appendix Figure 1: boxplot of the per-arm sigma estimates at each BUILD
//! assignment step (MNIST-like, l2).
//!
//! The paper's observation: the median sigma drops dramatically after the
//! first medoid is assigned and keeps decreasing, while the spread across
//! arms stays wide — justifying both per-arm sigma and re-estimation at
//! every step (§3.2 / Appendix 1.2).

use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::coordinator::config::BanditPamConfig;
use crate::data::synthetic;
use crate::distance::Metric;
use crate::runtime::backend::NativeBackend;
use crate::stats::summary::Summary;
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (usize, usize) {
    match scale {
        Scale::Smoke => (120, 3),
        Scale::Quick => (1000, 5),
        Scale::Paper => (3000, 10),
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (n, k) = params(scale);
    let ds = synthetic::mnist_like(&mut Rng::seed_from(seed), n);
    let backend = NativeBackend::new(&ds.points, Metric::L2);
    let mut algo = BanditPam::new(BanditPamConfig {
        record_sigmas: true,
        ..Default::default()
    });
    algo.build_only(&backend, k, &mut Rng::seed_from(seed ^ 3))
        .expect("build failed");

    let mut table = Table::new(
        format!("Appendix Fig 1 — sigma_x distribution per BUILD step (mnist_like n={n})"),
        &["build step", "min", "q1", "median", "q3", "max"],
    );
    for (step, sigmas) in algo.build_sigmas.iter().enumerate() {
        let nonzero: Vec<f64> = sigmas.iter().copied().filter(|s| *s > 0.0).collect();
        let s = Summary::of(if nonzero.is_empty() { sigmas } else { &nonzero });
        table.row(vec![
            format!("{}", step + 1),
            fnum(s.min),
            fnum(s.q1),
            fnum(s.median),
            fnum(s.q3),
            fnum(s.max),
        ]);
    }
    vec![table]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_sigma_drops_after_first_medoid() {
        let tables = run(Scale::Smoke, 23);
        let rows = &tables[0].rows;
        assert_eq!(rows.len(), 3);
        let med0: f64 = rows[0][3].parse().unwrap();
        let med1: f64 = rows[1][3].parse().unwrap();
        assert!(
            med1 < med0,
            "paper App Fig 1: median sigma should drop ({med0} -> {med1})"
        );
    }
}
