//! Figure 2: runtime (and evals) per iteration vs n on MNIST-like data
//! with l2, for (a) k = 5 and (b) k = 10, log–log.
//!
//! Paper slopes of the lines of best fit: 0.984 (k=5) and 0.922 (k=10) —
//! i.e. almost exactly linear in n, versus the quadratic reference lines.

use crate::bench::table::{fnum, Table};
use crate::bench::Scale;
use crate::coordinator::banditpam::BanditPam;
use crate::data::synthetic;
use crate::distance::Metric;
use crate::experiments::harness::{aggregate, default_threads, run_setting, scaling_slope};
use crate::util::rng::Rng;

pub fn params(scale: Scale) -> (Vec<usize>, usize) {
    match scale {
        Scale::Smoke => (vec![150, 300], 2),
        Scale::Quick => (vec![500, 1000, 2000], 3),
        Scale::Paper => (vec![500, 1000, 2000, 4000, 8000], 5),
    }
}

fn sweep(k: usize, scale: Scale, seed: u64, paper_slope: &str) -> (Table, Table) {
    let (sizes, repeats) = params(scale);
    let base = synthetic::mnist_like(&mut Rng::seed_from(seed), *sizes.iter().max().unwrap() * 2);
    let threads = default_threads();
    let mut table = Table::new(
        format!("Fig 2 — runtime/iter vs n (mnist_like, l2, k={k})"),
        &["n", "secs/iter", "ci95", "evals/iter", "evals ci95", "PAM ref (kn^2)"],
    );
    let mut points = Vec::new();
    for &n in &sizes {
        let mut algo = BanditPam::default_paper();
        let ms = run_setting(&mut algo, &base, Metric::L2, n, k, repeats, threads, seed);
        let p = aggregate(n, &ms);
        table.row(vec![
            n.to_string(),
            fnum(p.secs_per_iter.0),
            fnum(p.secs_per_iter.1),
            fnum(p.evals_per_iter.0),
            fnum(p.evals_per_iter.1),
            fnum((k * n * n) as f64),
        ]);
        points.push(p);
    }
    let mut summary = Table::new(
        format!("Fig 2 — slopes (k={k})"),
        &["series", "slope", "paper"],
    );
    summary.row(vec![
        "secs/iter".into(),
        fnum(scaling_slope(&points, true)),
        paper_slope.into(),
    ]);
    summary.row(vec![
        "evals/iter".into(),
        fnum(scaling_slope(&points, false)),
        "~1".into(),
    ]);
    (table, summary)
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table> {
    let (t1, s1) = sweep(5, scale, seed, "0.984");
    let (t2, s2) = sweep(10.min(20), scale, seed ^ 1, "0.922");
    vec![t1, s1, t2, s2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_produces_four_tables() {
        let tables = run(Scale::Smoke, 17);
        assert_eq!(tables.len(), 4);
        assert_eq!(tables[0].rows.len(), 2);
        // Smoke sizes (150/300 with B=100) are pre-asymptotic: only 2-3
        // batches fit in n_ref, so elimination barely engages and the
        // fitted slope can brush 2. The real sub-quadratic assertion lives
        // at bench scale (EXPERIMENTS.md fig2). Structural sanity only:
        let slope: f64 = tables[1].rows[1][1].parse().unwrap();
        assert!(slope.is_finite() && slope < 2.4, "evals slope {slope}");
    }
}
