//! Seeded randomized property checking.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses `Rng::seed_from(base_seed + i)`.
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Honors BANDITPAM_PROP_CASES for heavier local runs.
        let cases = std::env::var("BANDITPAM_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(25);
        PropConfig { cases, base_seed: 0xBAD5EED }
    }
}

/// Run `property` over `cfg.cases` seeded RNGs; panic with the replayable
/// seed on the first failure. The property returns `Err(reason)` to fail.
pub fn check<F>(name: &str, cfg: &PropConfig, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.base_seed + case as u64;
        let mut rng = Rng::seed_from(seed);
        if let Err(reason) = property(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{} (replay with \
                 Rng::seed_from({seed})): {reason}",
                cfg.cases
            );
        }
    }
}

/// Convenience assertion macro for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Random generators for common test inputs.
pub mod gen {
    use crate::data::ast::{self, Tree};
    use crate::data::Dataset;
    use crate::util::rng::Rng;

    /// A small GMM dataset with randomized (n, d, k, separation).
    pub fn small_dataset(rng: &mut Rng) -> Dataset {
        let n = rng.range(10, 60);
        let d = rng.range(2, 12);
        let k = rng.range(1, 5);
        let sep = 0.5 + rng.f64() * 5.0;
        crate::data::synthetic::gmm(rng, n, d, k, sep)
    }

    /// A random AST of bounded size.
    pub fn small_tree(rng: &mut Rng) -> Tree {
        let mut t = ast::prototypes()[rng.below(4)].clone();
        for _ in 0..rng.below(8) {
            ast::mutate(&mut t, rng);
        }
        t
    }

    /// A random f32 vector.
    pub fn vector(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-ok", &PropConfig { cases: 7, base_seed: 1 }, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 7);
    }

    #[test]
    #[should_panic(expected = "replay with")]
    fn failing_property_reports_seed() {
        check("always-bad", &PropConfig { cases: 3, base_seed: 2 }, |_rng| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn generators_produce_valid_shapes() {
        let mut rng = Rng::seed_from(5);
        for _ in 0..10 {
            let ds = gen::small_dataset(&mut rng);
            assert!(ds.len() >= 10 && ds.len() < 60);
            let t = gen::small_tree(&mut rng);
            assert!(t.size() >= 1);
        }
    }
}
