//! In-tree property-testing framework (no `proptest` in the offline cache).
//!
//! [`prop::check`] runs a predicate over many seeded random cases and, on
//! failure, reports the seed and case index so the exact failing input can
//! be replayed deterministically (`Rng::seed_from(reported_seed)`).

pub mod prop;
