//! # BanditPAM — almost linear time k-medoids via multi-armed bandits
//!
//! Production-quality reproduction of *BanditPAM: Almost Linear Time
//! k-Medoids Clustering via Multi-Armed Bandits* (Tiwari et al., NeurIPS
//! 2020) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: the BanditPAM adaptive
//!   search ([`bandits::adaptive`], Algorithm 1 of the paper), BUILD/SWAP
//!   orchestration and state management ([`coordinator`]), every baseline
//!   the paper evaluates against ([`algorithms`]), dataset generators
//!   ([`data`]), distance substrates ([`distance`]) and the experiment /
//!   benchmark harness ([`experiments`], [`bench`]).
//! * **Layer 2/1 (build time)** — `python/compile/` lowers JAX graphs that
//!   call Pallas pairwise-distance kernels to HLO-text artifacts.
//! * **Runtime** — [`runtime`] loads those artifacts through the PJRT C API
//!   (`xla` crate) so the Rust hot path can execute the AOT-compiled
//!   kernels; Python is never on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! # // no_run: rustdoc test binaries miss the cargo rpath to
//! # // /opt/xla_extension/lib (libstdc++); compile-checked only.
//! use banditpam::prelude::*;
//!
//! let mut rng = Rng::seed_from(7);
//! let data = synthetic::gmm(&mut rng, 200, 16, 5, 3.0);
//! let backend = NativeBackend::new(&data.points, Metric::L2);
//! let fit = BanditPam::new(BanditPamConfig::default())
//!     .fit(&backend, 5, &mut rng)
//!     .unwrap();
//! println!("loss = {}, medoids = {:?}", fit.loss, fit.medoids);
//! assert_eq!(fit.medoids.len(), 5);
//! ```
//!
//! See `examples/` for end-to-end drivers (including one that routes all
//! distance computation through the AOT XLA artifacts) and `DESIGN.md` for
//! the experiment index.

pub mod algorithms;
pub mod bandits;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod distance;
pub mod experiments;
pub mod runtime;
pub mod stats;
pub mod testkit;
pub mod util;

/// Convenient single-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::{
        clara::Clara, clarans::Clarans, fastpam::FastPam, fastpam1::FastPam1,
        meddit::Meddit, pam::Pam, voronoi::VoronoiIteration, Clustering, FitStats,
        KMedoids,
    };
    pub use crate::coordinator::{banditpam::BanditPam, config::BanditPamConfig};
    pub use crate::data::sparse::CsrMatrix;
    pub use crate::data::{synthetic, Dataset, Points};
    pub use crate::distance::{counter::DistanceCounter, Metric};
    pub use crate::runtime::backend::{DistanceBackend, NativeBackend};
    pub use crate::util::rng::Rng;
}
