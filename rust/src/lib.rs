//! # BanditPAM — almost linear time k-medoids via multi-armed bandits
//!
//! Production-quality reproduction of *BanditPAM: Almost Linear Time
//! k-Medoids Clustering via Multi-Armed Bandits* (Tiwari et al., NeurIPS
//! 2020) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: the BanditPAM adaptive
//!   search ([`bandits::adaptive`], Algorithm 1 of the paper), BUILD/SWAP
//!   orchestration and state management ([`coordinator`]), every baseline
//!   the paper evaluates against ([`algorithms`]), dataset generators
//!   ([`data`]), distance substrates ([`distance`]) and the experiment /
//!   benchmark harness ([`experiments`], [`bench`]).
//! * **Layer 2/1 (build time)** — `python/compile/` lowers JAX graphs that
//!   call Pallas pairwise-distance kernels to HLO-text artifacts.
//! * **Runtime** — [`runtime`] loads those artifacts through the PJRT C API
//!   (`xla` crate) so the Rust hot path can execute the AOT-compiled
//!   kernels; Python is never on the request path.
//!
//! ## Quickstart
//!
//! The front door is the [`model::Fit`] builder: pick an algorithm, chain
//! the knobs, fit a [`data::Dataset`]. The result is a fitted
//! [`model::KMedoidsModel`] that **owns** its medoid points — it assigns
//! unseen points, saves to a versioned binary file (`rust/MODEL.md`), and
//! outlives the training data.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries miss the cargo rpath to
//! # // /opt/xla_extension/lib (libstdc++); compile-checked only.
//! use banditpam::prelude::*;
//!
//! let data = synthetic::gmm(&mut Rng::seed_from(7), 200, 16, 5, 3.0);
//! let model = Fit::banditpam().metric(Metric::L2).seed(7).k(5).fit(&data)?;
//! println!("loss = {}, medoid rows = {:?}", model.loss(), model.clustering().medoids);
//!
//! // Out-of-sample assignment: the medoids are owned by the model, so
//! // the training dataset can be dropped.
//! let queries = synthetic::gmm(&mut Rng::seed_from(8), 50, 16, 5, 3.0);
//! drop(data);
//! let assignments = model.predict(&queries.points)?;
//! assert_eq!(assignments.len(), 50);
//!
//! // Persistence: save, reload, serve.
//! model.save(std::path::Path::new("gmm.bpmodel"))?;
//! let served = KMedoidsModel::load(std::path::Path::new("gmm.bpmodel"))?;
//! assert_eq!(served.predict(&queries.points)?, assignments);
//! # Ok::<(), banditpam::Error>(())
//! ```
//!
//! The lower layers stay public for full control — build a
//! [`runtime::backend::NativeBackend`] and run any
//! [`algorithms::KMedoids`] implementation by hand:
//!
//! ```no_run
//! use banditpam::prelude::*;
//!
//! let mut rng = Rng::seed_from(7);
//! let data = synthetic::gmm(&mut rng, 200, 16, 5, 3.0);
//! let backend = NativeBackend::new(&data.points, Metric::L2).with_threads(8);
//! let fit = BanditPam::new(BanditPamConfig::default())
//!     .fit(&backend, 5, &mut rng)?;
//! println!("evals = {}", fit.stats.distance_evals);
//! # Ok::<(), banditpam::Error>(())
//! ```
//!
//! See `examples/` for end-to-end drivers (including one that routes all
//! distance computation through the AOT XLA artifacts) and `DESIGN.md` for
//! the experiment index.

pub mod algorithms;
pub mod bandits;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod distance;
pub mod error;
pub mod experiments;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod testkit;
pub mod util;

pub use error::{Error, Result};

/// Convenient single-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::{
        clara::Clara, clarans::Clarans, fasterpam::FasterPam, fastpam::FastPam,
        fastpam1::FastPam1, meddit::Meddit, onebatchpam::OneBatchPam, pam::Pam,
        voronoi::VoronoiIteration, Clustering, FitStats, KMedoids,
    };
    pub use crate::coordinator::{banditpam::BanditPam, config::BanditPamConfig};
    pub use crate::data::sparse::CsrMatrix;
    pub use crate::data::{synthetic, Dataset, Points};
    pub use crate::distance::{counter::DistanceCounter, Metric};
    pub use crate::error::{Error, Result};
    pub use crate::model::{BigFit, BigFitStats, Fit, KMedoidsModel};
    pub use crate::obs::TraceSink;
    pub use crate::runtime::backend::{DistanceBackend, NativeBackend};
    pub use crate::util::rng::Rng;
}
