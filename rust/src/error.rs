//! Crate-wide error type for the public API boundary.
//!
//! Historically every fallible public function returned `anyhow::Result`
//! (via the vendored shim), which made failure modes stringly-typed: a
//! caller could not tell a bad `k` from a corrupt model file without
//! parsing messages. [`Error`] classifies the crate's failure surface into
//! a small closed set of variants; the [`crate::algorithms::KMedoids`]
//! trait, the [`crate::data::loader`] functions and the whole
//! [`crate::model`] layer return it.
//!
//! The `anyhow` shim remains in use at *internal* call sites (streaming
//! reader, manifest/XLA plumbing, `main.rs` glue) — interop is seamless in
//! both directions:
//!
//! * `Error` implements [`std::error::Error`], so `?` lifts it into
//!   `anyhow::Result` through the shim's blanket `From` impl (and the real
//!   crate's, if it were substituted).
//! * `From<anyhow::Error> for Error` folds an internal context chain into
//!   [`Error::Internal`], preserving the full `{:#}` rendering.

use std::fmt;

/// Classified error for the public API boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// A caller-supplied argument is out of range or inconsistent
    /// (`k == 0`, dimension mismatch between a model and its queries, ...).
    InvalidArgument(String),
    /// A configuration value (or combination) is invalid —
    /// [`crate::coordinator::config::BanditPamConfig::validate`].
    Config(String),
    /// A dataset could not be read or parsed (CSV/MTX/IDX grammar, I/O).
    Data(String),
    /// A model file could not be written, read or parsed
    /// ([`crate::model::KMedoidsModel::save`] / `load`).
    Model(String),
    /// The requested metric/storage/algorithm combination is unsupported
    /// (tree edit distance on dense points, saving a tree-medoid model).
    Unsupported(String),
    /// An internal subsystem failed; carries the flattened `anyhow`
    /// context chain.
    Internal(String),
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an [`Error::InvalidArgument`].
    pub fn invalid_argument(msg: impl fmt::Display) -> Error {
        Error::InvalidArgument(msg.to_string())
    }

    /// Build an [`Error::Config`].
    pub fn config(msg: impl fmt::Display) -> Error {
        Error::Config(msg.to_string())
    }

    /// Build an [`Error::Data`].
    pub fn data(msg: impl fmt::Display) -> Error {
        Error::Data(msg.to_string())
    }

    /// Build an [`Error::Model`].
    pub fn model(msg: impl fmt::Display) -> Error {
        Error::Model(msg.to_string())
    }

    /// Build an [`Error::Unsupported`].
    pub fn unsupported(msg: impl fmt::Display) -> Error {
        Error::Unsupported(msg.to_string())
    }

    /// Short machine-checkable category name.
    pub fn kind(&self) -> &'static str {
        match self {
            Error::InvalidArgument(_) => "invalid_argument",
            Error::Config(_) => "config",
            Error::Data(_) => "data",
            Error::Model(_) => "model",
            Error::Unsupported(_) => "unsupported",
            Error::Internal(_) => "internal",
        }
    }

    /// Process exit code for CLI reporting: `2` for caller mistakes
    /// (invalid argument/config, unsupported combination — "fix your
    /// invocation"), `1` for everything else (bad data/model files,
    /// internal failures).
    pub fn exit_code(&self) -> i32 {
        match self {
            Error::InvalidArgument(_) | Error::Config(_) | Error::Unsupported(_) => 2,
            _ => 1,
        }
    }

    /// The human-readable message (without the category prefix).
    pub fn message(&self) -> &str {
        match self {
            Error::InvalidArgument(m)
            | Error::Config(m)
            | Error::Data(m)
            | Error::Model(m)
            | Error::Unsupported(m)
            | Error::Internal(m) => m,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::Config(m) => write!(f, "invalid config: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Model(m) => write!(f, "model error: {m}"),
            Error::Unsupported(m) => write!(f, "unsupported: {m}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

/// Fold an internal `anyhow` chain into [`Error::Internal`], keeping the
/// whole context chain (the `{:#}` rendering: "outer: mid: root").
impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Error {
        Error::Internal(format!("{e:#}"))
    }
}

/// I/O failures surface as [`Error::Data`] — in practice they come from
/// reading datasets/models or writing CLI outputs.
impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Data(e.to_string())
    }
}

/// A CLI option that fails to parse is the caller's mistake.
impl From<crate::util::cli::ParseError> for Error {
    fn from(e: crate::util::cli::ParseError) -> Error {
        Error::InvalidArgument(e.to_string())
    }
}

/// So is an option a subcommand does not accept (misspelled flag).
impl From<crate::util::cli::UnknownOptionError> for Error {
    fn from(e: crate::util::cli::UnknownOptionError) -> Error {
        Error::InvalidArgument(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        let e = Error::invalid_argument("k must be >= 1 (got 0)");
        assert_eq!(e.to_string(), "invalid argument: k must be >= 1 (got 0)");
        assert_eq!(e.kind(), "invalid_argument");
        assert_eq!(e.message(), "k must be >= 1 (got 0)");
        assert_eq!(Error::model("bad magic").kind(), "model");
    }

    #[test]
    fn lifts_into_anyhow_via_question_mark() {
        fn inner() -> anyhow::Result<()> {
            Err(Error::config("batch_size must be >= 1"))?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("batch_size"));
    }

    #[test]
    fn folds_anyhow_chains_into_internal() {
        use anyhow::Context;
        let chained: anyhow::Result<()> =
            std::result::Result::<(), _>::Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                "gone",
            ))
            .context("reading manifest");
        let e = Error::from(chained.unwrap_err());
        assert_eq!(e.kind(), "internal");
        assert!(e.message().contains("reading manifest"));
        assert!(e.message().contains("gone"));
    }

    #[test]
    fn equality_by_variant_and_message() {
        assert_eq!(Error::data("x"), Error::data("x"));
        assert_ne!(Error::data("x"), Error::model("x"));
    }

    #[test]
    fn exit_codes_distinguish_usage_errors() {
        assert_eq!(Error::invalid_argument("x").exit_code(), 2);
        assert_eq!(Error::config("x").exit_code(), 2);
        assert_eq!(Error::unsupported("x").exit_code(), 2);
        assert_eq!(Error::data("x").exit_code(), 1);
        assert_eq!(Error::model("x").exit_code(), 1);
        assert_eq!(Error::Internal("x".into()).exit_code(), 1);
    }

    #[test]
    fn io_and_cli_errors_convert() {
        let e: Error =
            std::io::Error::new(std::io::ErrorKind::NotFound, "missing.csv").into();
        assert_eq!(e.kind(), "data");
        assert!(e.message().contains("missing.csv"));
        let p = crate::util::cli::ParseError {
            key: "k".to_string(),
            value: "abc".to_string(),
            expected: "usize",
        };
        let e: Error = p.into();
        assert_eq!(e.kind(), "invalid_argument");
        assert_eq!(e.exit_code(), 2);
        let u = crate::util::cli::UnknownOptionError {
            subcommand: "cluster".to_string(),
            option: "chunk-nzz".to_string(),
            accepted: "--chunk-nnz V".to_string(),
        };
        let e: Error = u.into();
        assert_eq!(e.kind(), "invalid_argument");
        assert_eq!(e.exit_code(), 2);
        assert!(e.message().contains("chunk-nzz"));
    }
}
