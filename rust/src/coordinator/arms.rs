//! Concrete arm sets for the two PAM search problems (paper Eqs. 9–10),
//! plus the session-backed virtual SWAP arms (BanditPAM++ reuse).

use crate::bandits::adaptive::ArmSet;
use crate::bandits::estimator::ArmEstimator;
use crate::coordinator::scheduler;
use crate::coordinator::session::SwapSession;
use crate::coordinator::state::MedoidState;
use crate::runtime::backend::DistanceBackend;

/// The FastPAM1 swap objective (Eq. 12): loss delta contributed by
/// reference `j` when candidate `x` (whose distance to `j` is `d`)
/// replaces the medoid at position `m_pos`. Shared by [`SwapArms`] and
/// [`VirtualSwapArms`] so the two paths are bitwise-identical by
/// construction.
#[inline]
fn swap_g(d1: &[f64], d2: &[f64], a1: &[usize], m_pos: usize, d: f64, j: usize) -> f64 {
    let base = if a1[j] == m_pos {
        // j's nearest medoid is being removed: falls back to d2 or d(x,j)
        d2[j].min(d)
    } else {
        d1[j].min(d)
    };
    base - d1[j]
}

/// BUILD-step arms (Eq. 9): one arm per candidate point x, with
/// `g_x(j) = min(d(x, x_j) - d1_j, 0)` — or plain `d(x, x_j)` for the very
/// first medoid (empty medoid set).
///
/// All working buffers (`scratch`, the arm-to-point remap, the full
/// reference list for `exact`) are owned by the arm set and reused, so
/// repeated `pull_many` calls allocate nothing in steady state.
pub struct BuildArms<'a> {
    backend: &'a dyn DistanceBackend,
    /// Candidate point ids (non-medoids).
    pub candidates: Vec<usize>,
    d1: &'a [f64],
    scratch: Vec<f64>,
    /// Reused arm-index -> point-id remap for `pull_many`.
    targets: Vec<usize>,
    /// Reused full reference list (0..n) for `exact`.
    all_refs: Vec<usize>,
}

impl<'a> BuildArms<'a> {
    /// Candidates are all non-medoid points of `state`.
    pub fn new(backend: &'a dyn DistanceBackend, state: &'a MedoidState) -> Self {
        let medoids: std::collections::HashSet<usize> =
            state.medoids.iter().copied().collect();
        let candidates: Vec<usize> =
            (0..backend.n()).filter(|i| !medoids.contains(i)).collect();
        BuildArms {
            backend,
            candidates,
            d1: &state.d1,
            scratch: Vec::new(),
            targets: Vec::new(),
            all_refs: (0..backend.n()).collect(),
        }
    }

    #[inline]
    fn g(&self, d: f64, j: usize) -> f64 {
        let d1 = self.d1[j];
        if d1.is_infinite() {
            d // first medoid: plain mean distance (Eq. 4 with empty M)
        } else {
            (d - d1).min(0.0)
        }
    }
}

impl<'a> ArmSet for BuildArms<'a> {
    fn n_arms(&self) -> usize {
        self.candidates.len()
    }

    fn n_ref(&self) -> usize {
        self.backend.n()
    }

    fn pull_many(&mut self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        self.targets.clear();
        self.targets.extend(arms.iter().map(|&a| self.candidates[a]));
        let need = arms.len() * refs.len();
        if self.scratch.len() < need {
            self.scratch.resize(need, 0.0);
        }
        self.backend.block(&self.targets, refs, &mut self.scratch[..need]);
        let rn = refs.len();
        for ai in 0..arms.len() {
            for (ri, &j) in refs.iter().enumerate() {
                out[ai * rn + ri] = self.g(self.scratch[ai * rn + ri], j);
            }
        }
    }

    fn exact(&mut self, arm: usize) -> f64 {
        let x = self.candidates[arm];
        let n = self.backend.n();
        if self.scratch.len() < n {
            self.scratch.resize(n, 0.0);
        }
        self.backend.block(&[x], &self.all_refs, &mut self.scratch[..n]);
        let mut acc = 0.0;
        for j in 0..n {
            acc += self.g(self.scratch[j], j);
        }
        acc / n as f64
    }
}

/// SWAP-step arms (Eq. 10): one arm per (medoid position m, candidate x)
/// pair, using the FastPAM1 decomposition (Eq. 12):
///
/// `g_{m,x}(j) = -d1_j + [a1_j != m] min(d1_j, d(x,j)) + [a1_j == m] min(d2_j, d(x,j))`
///
/// Arms with the same candidate share one distance row: `pull_many`
/// deduplicates candidates through the scheduler, so a round over all
/// k·(n−k) arms costs only (n−k)·B distance evaluations.
pub struct SwapArms<'a> {
    backend: &'a dyn DistanceBackend,
    pub candidates: Vec<usize>,
    pub k: usize,
    d1: &'a [f64],
    d2: &'a [f64],
    a1: &'a [usize],
    /// When false (`abl-fastpam1` ablation) deduplication is disabled and
    /// every arm evaluates its own row — PAM-style O(k n^2) counting.
    share_rows: bool,
    scratch: Vec<f64>,
    /// Reused arm-index -> candidate-point remap for `pull_many`.
    cand_pts: Vec<usize>,
    /// Reused dedup state (unique candidates + row map).
    dd: scheduler::Dedup,
    /// Reused full reference list (0..n) for `exact`.
    all_refs: Vec<usize>,
    /// Last full distance row computed by `exact` (candidate, row):
    /// Algorithm 1's exact fallback visits arms in id order, so arms of
    /// the same candidate are consecutive and share this row.
    exact_row: Option<(usize, Vec<f64>)>,
    /// Cross-iteration reference permutation supplied by a [`SwapSession`]
    /// (see `ArmSet::shared_permutation`); `None` outside a session.
    shared_perm: Option<&'a [usize]>,
}

impl<'a> SwapArms<'a> {
    /// Arms over all (medoid, non-medoid) pairs of `state`.
    pub fn new(
        backend: &'a dyn DistanceBackend,
        state: &'a MedoidState,
        share_rows: bool,
    ) -> Self {
        let medoids: std::collections::HashSet<usize> =
            state.medoids.iter().copied().collect();
        let candidates: Vec<usize> =
            (0..backend.n()).filter(|i| !medoids.contains(i)).collect();
        SwapArms {
            backend,
            candidates,
            k: state.medoids.len(),
            d1: &state.d1,
            d2: &state.d2,
            a1: &state.a1,
            share_rows,
            scratch: Vec::new(),
            cand_pts: Vec::new(),
            dd: scheduler::Dedup::new(),
            all_refs: (0..backend.n()).collect(),
            exact_row: None,
            shared_perm: None,
        }
    }

    /// Attach a cross-iteration reference permutation (the non-reuse leg of
    /// a [`SwapSession`]-driven SWAP phase: same permutation as the reuse
    /// leg, so the two trajectories are identical by construction).
    pub fn with_shared_perm(mut self, perm: &'a [usize]) -> Self {
        self.shared_perm = Some(perm);
        self
    }

    /// Arm id encoding: `arm = cand_idx * k + medoid_pos`.
    #[inline]
    pub fn decode(&self, arm: usize) -> (usize, usize) {
        (self.candidates[arm / self.k], arm % self.k)
    }

    #[inline]
    fn g(&self, m_pos: usize, d: f64, j: usize) -> f64 {
        swap_g(self.d1, self.d2, self.a1, m_pos, d, j)
    }
}

impl<'a> ArmSet for SwapArms<'a> {
    fn n_arms(&self) -> usize {
        self.candidates.len() * self.k
    }

    fn n_ref(&self) -> usize {
        self.backend.n()
    }

    fn pull_many(&mut self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        let rn = refs.len();
        if self.share_rows {
            self.cand_pts.clear();
            self.cand_pts
                .extend(arms.iter().map(|&a| self.candidates[a / self.k]));
            scheduler::block_dedup_into(
                self.backend,
                &self.cand_pts,
                refs,
                &mut self.scratch,
                &mut self.dd,
            );
            for (ai, &arm) in arms.iter().enumerate() {
                let m_pos = arm % self.k;
                let row = self.dd.row_of[ai];
                for (ri, &j) in refs.iter().enumerate() {
                    out[ai * rn + ri] = self.g(m_pos, self.scratch[row * rn + ri], j);
                }
            }
        } else {
            // Ablation: each arm computes its own row (PAM-style counting).
            if self.scratch.len() < rn {
                self.scratch.resize(rn, 0.0);
            }
            for (ai, &arm) in arms.iter().enumerate() {
                let (x, m_pos) = self.decode(arm);
                self.backend.block(&[x], refs, &mut self.scratch[..rn]);
                for (ri, &j) in refs.iter().enumerate() {
                    out[ai * rn + ri] = self.g(m_pos, self.scratch[ri], j);
                }
            }
        }
    }

    fn exact(&mut self, arm: usize) -> f64 {
        let (x, m_pos) = self.decode(arm);
        let n = self.backend.n();
        let reuse = matches!(&self.exact_row, Some((c, _)) if *c == x && self.share_rows);
        if !reuse {
            // Reuse the previous row buffer when present (the exact
            // fallback visits many arms in sequence).
            let mut row = match self.exact_row.take() {
                Some((_, row)) => row,
                None => vec![0.0f64; n],
            };
            self.backend.block(&[x], &self.all_refs, &mut row);
            self.exact_row = Some((x, row));
        }
        let row = &self.exact_row.as_ref().unwrap().1;
        let mut acc = 0.0;
        for (j, &d) in row.iter().enumerate() {
            acc += self.g(m_pos, d, j);
        }
        acc / n as f64
    }

    fn shared_permutation(&self) -> Option<&[usize]> {
        self.shared_perm
    }
}

/// Session-backed SWAP arms ("virtual arms", BanditPAM++ §3): the same
/// k·(n−k) arm space and the same g-values as [`SwapArms`], but every pull
/// is served from the [`SwapSession`] row cache — one candidate row feeds
/// all k `(candidate, medoid-slot)` arms *and* stays valid across SWAP
/// iterations, so a re-pulled batch costs zero distance evaluations. With
/// `swap_warm_start` the session additionally carries each arm's estimator
/// between iterations (`ArmSet::warm_estimator` / `finish`).
pub struct VirtualSwapArms<'a> {
    backend: &'a dyn DistanceBackend,
    session: &'a mut SwapSession,
    pub candidates: Vec<usize>,
    pub k: usize,
    d1: &'a [f64],
    d2: &'a [f64],
    a1: &'a [usize],
    /// Distinct candidate points of the current pull (run-collapsed: the
    /// live set is ascending, so arms of one candidate are adjacent).
    group: Vec<usize>,
    /// Last candidate served by `exact` (consecutive exact calls on the
    /// same candidate charge the non-reuse baseline only once, mirroring
    /// `SwapArms`' row reuse).
    last_exact: Option<usize>,
}

impl<'a> VirtualSwapArms<'a> {
    /// Arms over all (medoid, non-medoid) pairs of `state`, pulling
    /// through `session`'s cross-iteration row cache.
    pub fn new(
        backend: &'a dyn DistanceBackend,
        state: &'a MedoidState,
        session: &'a mut SwapSession,
    ) -> Self {
        let medoids: std::collections::HashSet<usize> =
            state.medoids.iter().copied().collect();
        let candidates: Vec<usize> =
            (0..backend.n()).filter(|i| !medoids.contains(i)).collect();
        VirtualSwapArms {
            backend,
            session,
            candidates,
            k: state.medoids.len(),
            d1: &state.d1,
            d2: &state.d2,
            a1: &state.a1,
            group: Vec::new(),
            last_exact: None,
        }
    }

    /// Arm id encoding: `arm = cand_idx * k + medoid_pos` (same as
    /// [`SwapArms::decode`]).
    #[inline]
    pub fn decode(&self, arm: usize) -> (usize, usize) {
        (self.candidates[arm / self.k], arm % self.k)
    }
}

impl<'a> ArmSet for VirtualSwapArms<'a> {
    fn n_arms(&self) -> usize {
        self.candidates.len() * self.k
    }

    fn n_ref(&self) -> usize {
        self.backend.n()
    }

    fn pull_many(&mut self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
        let rn = refs.len();
        // One row per distinct candidate. Algorithm 1 passes live arms in
        // ascending id order, so a run-collapse deduplicates; out-of-order
        // repeats would only cost a redundant (idempotent) fill request.
        self.group.clear();
        for &arm in arms {
            let c = self.candidates[arm / self.k];
            if self.group.last() != Some(&c) {
                self.group.push(c);
            }
        }
        self.session.pull_rows(self.backend, &self.group, refs);
        for (ai, &arm) in arms.iter().enumerate() {
            let c = self.candidates[arm / self.k];
            let m_pos = arm % self.k;
            let row = self.session.row(c);
            for (ri, &j) in refs.iter().enumerate() {
                let d = row[self.session.pos(j)];
                out[ai * rn + ri] = swap_g(self.d1, self.d2, self.a1, m_pos, d, j);
            }
        }
    }

    fn exact(&mut self, arm: usize) -> f64 {
        let (x, m_pos) = self.decode(arm);
        let n = self.backend.n();
        let fresh_candidate = self.last_exact != Some(x);
        self.session.ensure_full_row(self.backend, x, fresh_candidate);
        self.last_exact = Some(x);
        let row = self.session.row(x);
        let mut acc = 0.0;
        // Natural point order, exactly like `SwapArms::exact`, so the
        // floating-point sum is bitwise-identical.
        for j in 0..n {
            let d = row[self.session.pos(j)];
            acc += swap_g(self.d1, self.d2, self.a1, m_pos, d, j);
        }
        acc / n as f64
    }

    fn shared_permutation(&self) -> Option<&[usize]> {
        Some(self.session.shared_perm())
    }

    fn warm_estimator(&mut self, arm: usize) -> Option<ArmEstimator> {
        let (x, m_pos) = self.decode(arm);
        self.session.warm(x, m_pos)
    }

    fn finish(&mut self, est: &[ArmEstimator]) {
        if !self.session.warm_enabled() {
            return;
        }
        debug_assert_eq!(est.len(), self.n_arms());
        for (arm, e) in est.iter().enumerate() {
            let (x, m_pos) = self.decode(arm);
            self.session.store_carry(x, m_pos, e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;
    use crate::util::rng::Rng;

    fn fixture() -> (crate::data::Dataset, MedoidState) {
        let ds = synthetic::gmm(&mut Rng::seed_from(7), 25, 4, 3, 3.0);
        (ds, MedoidState::empty(25))
    }

    #[test]
    fn build_arms_first_step_is_mean_distance() {
        let (ds, state) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut arms = BuildArms::new(&b, &state);
        assert_eq!(arms.n_arms(), 25);
        let mu = arms.exact(3);
        // first BUILD step: mu == mean distance to all points
        let manual: f64 = (0..25).map(|j| b.dist(arms.candidates[3], j)).sum::<f64>() / 25.0;
        assert!((mu - manual).abs() < 1e-12);
    }

    #[test]
    fn build_arms_g_is_nonpositive_after_first_medoid() {
        let (ds, mut state) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        state.add_medoid(&b, 0);
        let mut arms = BuildArms::new(&b, &state);
        assert_eq!(arms.n_arms(), 24); // medoid excluded
        let refs: Vec<usize> = (0..25).collect();
        let mut out = vec![0.0; arms.n_arms() * 25];
        let all: Vec<usize> = (0..arms.n_arms()).collect();
        arms.pull_many(&all, &refs, &mut out);
        assert!(out.iter().all(|&g| g <= 1e-12));
    }

    #[test]
    fn build_pull_mean_converges_to_exact() {
        let (ds, mut state) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        state.add_medoid(&b, 2);
        let mut arms = BuildArms::new(&b, &state);
        // pulling over ALL refs once == exact
        let refs: Vec<usize> = (0..25).collect();
        let mut out = vec![0.0; 25];
        arms.pull_many(&[5], &refs, &mut out);
        let mean: f64 = out.iter().sum::<f64>() / 25.0;
        assert!((mean - arms.exact(5)).abs() < 1e-12);
    }

    #[test]
    fn swap_arms_decode_roundtrip() {
        let (ds, mut state) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        state.add_medoid(&b, 0);
        state.add_medoid(&b, 1);
        let arms = SwapArms::new(&b, &state, true);
        assert_eq!(arms.n_arms(), 23 * 2);
        let (x, m) = arms.decode(2 * 2 + 1);
        assert_eq!(x, arms.candidates[2]);
        assert_eq!(m, 1);
    }

    #[test]
    fn swap_exact_equals_bruteforce_delta() {
        let (ds, mut state) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        state.add_medoid(&b, 0);
        state.add_medoid(&b, 10);
        let mut arms = SwapArms::new(&b, &state, true);
        for arm in [0usize, 5, 11, arms.n_arms() - 1] {
            let (x, m_pos) = arms.decode(arm);
            let got = arms.exact(arm);
            // brute force: loss delta of swapping medoids[m_pos] -> x
            let mut med = state.medoids.clone();
            med[m_pos] = x;
            let before: f64 = state.loss();
            let after: f64 = (0..25)
                .map(|j| med.iter().map(|&m| b.dist(m, j)).fold(f64::INFINITY, f64::min))
                .sum();
            let want = (after - before) / 25.0;
            assert!((got - want).abs() < 1e-9, "arm {arm}: {got} vs {want}");
        }
    }

    #[test]
    fn swap_row_sharing_saves_distance_evals() {
        let (ds, mut state) = fixture();
        let b_shared = NativeBackend::new(&ds.points, Metric::L2);
        state.add_medoid(&b_shared, 0);
        state.add_medoid(&b_shared, 1);

        let refs: Vec<usize> = (0..10).collect();
        let all_arms: Vec<usize> = (0..(23 * 2)).collect();
        let mut out = vec![0.0; all_arms.len() * refs.len()];

        let before = b_shared.counter().get();
        let mut arms = SwapArms::new(&b_shared, &state, true);
        arms.pull_many(&all_arms, &refs, &mut out);
        let shared_cost = b_shared.counter().get() - before;
        assert_eq!(shared_cost, 23 * 10, "k rows shared per candidate");

        let out_shared = out.clone();
        let before = b_shared.counter().get();
        let mut arms_naive = SwapArms::new(&b_shared, &state, false);
        arms_naive.pull_many(&all_arms, &refs, &mut out);
        let naive_cost = b_shared.counter().get() - before;
        assert_eq!(naive_cost, 23 * 2 * 10, "naive recomputes per medoid");
        assert_eq!(out, out_shared, "ablation must not change values");
    }
}
