//! One BUILD assignment (paper Eq. 6) as a bandit search.

use crate::bandits::adaptive::{adaptive_search, AdaptiveOutcome};
use crate::coordinator::arms::BuildArms;
use crate::coordinator::config::BanditPamConfig;
use crate::coordinator::state::MedoidState;
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;

/// Select and append the next BUILD medoid. Returns the chosen point and
/// the search telemetry.
pub fn build_step(
    backend: &dyn DistanceBackend,
    state: &mut MedoidState,
    cfg: &BanditPamConfig,
    rng: &mut Rng,
) -> (usize, AdaptiveOutcome) {
    let (chosen, outcome) = {
        let mut arms = BuildArms::new(backend, state);
        let acfg = cfg.adaptive(arms.candidates.len(), backend.n(), None);
        let outcome = adaptive_search(&mut arms, &acfg, rng);
        (arms.candidates[outcome.best], outcome)
    };
    state.add_medoid(backend, chosen);
    (chosen, outcome)
}

/// Run the full BUILD phase: k sequential assignments.
/// Returns chosen medoids and per-step telemetry.
pub fn build_phase(
    backend: &dyn DistanceBackend,
    state: &mut MedoidState,
    k: usize,
    cfg: &BanditPamConfig,
    rng: &mut Rng,
) -> Vec<(usize, AdaptiveOutcome)> {
    assert!(k >= 1 && k < backend.n(), "need 1 <= k < n");
    (0..k).map(|_| build_step(backend, state, cfg, rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    /// Exact BUILD reference: Eq. 4 by brute force.
    fn exact_build_choice(
        backend: &dyn DistanceBackend,
        state: &MedoidState,
    ) -> usize {
        let n = backend.n();
        let mut best = (f64::INFINITY, usize::MAX);
        for x in 0..n {
            if state.medoids.contains(&x) {
                continue;
            }
            let mut acc = 0.0;
            for j in 0..n {
                let d = backend.dist(x, j);
                acc += if state.d1[j].is_infinite() { d } else { d.min(state.d1[j]) };
            }
            if acc < best.0 {
                best = (acc, x);
            }
        }
        best.1
    }

    #[test]
    fn build_matches_exact_pam_choice() {
        for seed in 0..5 {
            let ds = synthetic::gmm(&mut Rng::seed_from(100 + seed), 60, 6, 4, 4.0);
            let backend = NativeBackend::new(&ds.points, Metric::L2);
            let mut state = MedoidState::empty(60);
            let mut rng = Rng::seed_from(seed);
            let cfg = BanditPamConfig::default();
            for step in 0..3 {
                let want = exact_build_choice(&backend, &state);
                let mut probe = state.clone();
                let (got, _) = build_step(&backend, &mut probe, &cfg, &mut rng);
                assert_eq!(got, want, "seed {seed} step {step}");
                state = probe;
            }
        }
    }

    #[test]
    fn build_phase_returns_k_distinct_medoids() {
        let ds = synthetic::gmm(&mut Rng::seed_from(9), 50, 4, 5, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(50);
        let mut rng = Rng::seed_from(1);
        let steps = build_phase(&backend, &mut state, 5, &BanditPamConfig::default(), &mut rng);
        assert_eq!(steps.len(), 5);
        let set: std::collections::HashSet<_> = state.medoids.iter().collect();
        assert_eq!(set.len(), 5, "medoids must be distinct");
        state.check_invariants(&backend);
    }

    #[test]
    #[should_panic(expected = "need 1 <= k < n")]
    fn build_k_zero_panics() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 10, 2, 2, 1.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(10);
        build_phase(&backend, &mut state, 0, &BanditPamConfig::default(), &mut Rng::seed_from(0));
    }

    use crate::util::rng::Rng;
}
