//! Batching scheduler: turns arm-pull requests into deduplicated dense
//! distance blocks.
//!
//! Algorithm 1 evaluates every live arm against one shared reference batch.
//! In the SWAP step each arm is a (medoid, candidate) *pair* but — per the
//! FastPAM1 decomposition — its g-values depend on the backend only through
//! the candidate's distance row. The scheduler therefore deduplicates
//! candidates before dispatching one `[unique_candidates x batch]` block to
//! the backend (native: threaded kernels; XLA: padded PJRT tiles). This is
//! the step that realizes the paper's O(k) SWAP saving and the MXU-shaped
//! workload described in DESIGN.md §Hardware-Adaptation.

use crate::runtime::backend::DistanceBackend;
use std::collections::HashMap;

/// A deduplicated block request: unique point ids and, for each original
/// request, the row of the block it maps to. Reusable: the internal index
/// map and both vectors keep their capacity across [`dedup_into`] calls,
/// so the steady state of an Algorithm-1 run is allocation-free.
#[derive(Debug, Default)]
pub struct Dedup {
    pub unique: Vec<usize>,
    pub row_of: Vec<usize>,
    index: HashMap<usize, usize>,
}

impl Dedup {
    /// Empty, reusable dedup state.
    pub fn new() -> Dedup {
        Dedup::default()
    }
}

/// Deduplicate `requested` point ids into `out`, preserving first-seen
/// order. Clears previous contents but keeps allocated capacity.
pub fn dedup_into(requested: &[usize], out: &mut Dedup) {
    out.unique.clear();
    out.row_of.clear();
    out.index.clear();
    for &p in requested {
        let unique = &mut out.unique;
        let row = *out.index.entry(p).or_insert_with(|| {
            unique.push(p);
            unique.len() - 1
        });
        out.row_of.push(row);
    }
}

/// Deduplicate `requested` point ids, preserving first-seen order.
pub fn dedup(requested: &[usize]) -> Dedup {
    let mut out = Dedup::new();
    dedup_into(requested, &mut out);
    out
}

/// Evaluate the distance block for (possibly duplicated) `targets` over
/// `refs` into `out`/`scratch`, computing each unique target row once.
/// `scratch` receives the *unique* block (row-major `[unique x refs]`);
/// both buffers are reused across calls without reallocating.
pub fn block_dedup_into(
    backend: &dyn DistanceBackend,
    targets: &[usize],
    refs: &[usize],
    scratch: &mut Vec<f64>,
    out: &mut Dedup,
) {
    dedup_into(targets, out);
    let need = out.unique.len() * refs.len();
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    backend.block(&out.unique, refs, &mut scratch[..need]);
}

/// Evaluate the distance block for (possibly duplicated) `targets` over
/// `refs`, computing each unique target row once. Returns the *unique*
/// block (row-major `[unique x refs]`) plus the row map.
pub fn block_dedup(
    backend: &dyn DistanceBackend,
    targets: &[usize],
    refs: &[usize],
    scratch: &mut Vec<f64>,
) -> Dedup {
    let mut d = Dedup::new();
    block_dedup_into(backend, targets, refs, scratch, &mut d);
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;
    use crate::util::rng::Rng;

    #[test]
    fn dedup_preserves_order_and_maps_rows() {
        let d = dedup(&[5, 3, 5, 7, 3]);
        assert_eq!(d.unique, vec![5, 3, 7]);
        assert_eq!(d.row_of, vec![0, 1, 0, 2, 1]);
    }

    #[test]
    fn dedup_of_unique_input_is_identity() {
        let d = dedup(&[1, 2, 3]);
        assert_eq!(d.unique, vec![1, 2, 3]);
        assert_eq!(d.row_of, vec![0, 1, 2]);
    }

    #[test]
    fn dedup_into_reuses_state_across_calls() {
        let mut d = Dedup::new();
        dedup_into(&[1, 1, 2], &mut d);
        assert_eq!(d.unique, vec![1, 2]);
        assert_eq!(d.row_of, vec![0, 0, 1]);
        dedup_into(&[9, 8, 9], &mut d);
        assert_eq!(d.unique, vec![9, 8]);
        assert_eq!(d.row_of, vec![0, 1, 0]);
    }

    #[test]
    fn block_dedup_counts_unique_rows_only() {
        let ds = synthetic::gmm(&mut Rng::seed_from(3), 20, 4, 2, 2.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let targets = [4usize, 4, 4, 9, 9]; // 2 unique
        let refs: Vec<usize> = (0..10).collect();
        let mut scratch = Vec::new();
        let d = block_dedup(&b, &targets, &refs, &mut scratch);
        assert_eq!(d.unique.len(), 2);
        assert_eq!(b.counter().get(), 2 * 10, "only unique rows evaluated");
        // mapped rows reproduce the duplicated view
        for (req, &row) in targets.iter().zip(&d.row_of) {
            for (ri, &r) in refs.iter().enumerate() {
                assert_eq!(scratch[row * refs.len() + ri], b.dist(*req, r));
            }
        }
    }
}
