//! Batching scheduler: turns arm-pull requests into deduplicated dense
//! distance blocks.
//!
//! Algorithm 1 evaluates every live arm against one shared reference batch.
//! In the SWAP step each arm is a (medoid, candidate) *pair* but — per the
//! FastPAM1 decomposition — its g-values depend on the backend only through
//! the candidate's distance row. The scheduler therefore deduplicates
//! candidates before dispatching one `[unique_candidates x batch]` block to
//! the backend (native: threaded kernels; XLA: padded PJRT tiles). This is
//! the step that realizes the paper's O(k) SWAP saving and the MXU-shaped
//! workload described in DESIGN.md §Hardware-Adaptation.

use crate::runtime::backend::DistanceBackend;
use std::collections::HashMap;

/// A deduplicated block request: unique point ids and, for each original
/// request, the row of the block it maps to.
#[derive(Debug)]
pub struct Dedup {
    pub unique: Vec<usize>,
    pub row_of: Vec<usize>,
}

/// Deduplicate `requested` point ids, preserving first-seen order.
pub fn dedup(requested: &[usize]) -> Dedup {
    let mut index: HashMap<usize, usize> = HashMap::with_capacity(requested.len());
    let mut unique = Vec::new();
    let mut row_of = Vec::with_capacity(requested.len());
    for &p in requested {
        let row = *index.entry(p).or_insert_with(|| {
            unique.push(p);
            unique.len() - 1
        });
        row_of.push(row);
    }
    Dedup { unique, row_of }
}

/// Evaluate the distance block for (possibly duplicated) `targets` over
/// `refs`, computing each unique target row once. Returns the *unique*
/// block (row-major `[unique x refs]`) plus the row map.
pub fn block_dedup(
    backend: &dyn DistanceBackend,
    targets: &[usize],
    refs: &[usize],
    scratch: &mut Vec<f64>,
) -> Dedup {
    let d = dedup(targets);
    scratch.resize(d.unique.len() * refs.len(), 0.0);
    backend.block(&d.unique, refs, scratch);
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;
    use crate::util::rng::Rng;

    #[test]
    fn dedup_preserves_order_and_maps_rows() {
        let d = dedup(&[5, 3, 5, 7, 3]);
        assert_eq!(d.unique, vec![5, 3, 7]);
        assert_eq!(d.row_of, vec![0, 1, 0, 2, 1]);
    }

    #[test]
    fn dedup_of_unique_input_is_identity() {
        let d = dedup(&[1, 2, 3]);
        assert_eq!(d.unique, vec![1, 2, 3]);
        assert_eq!(d.row_of, vec![0, 1, 2]);
    }

    #[test]
    fn block_dedup_counts_unique_rows_only() {
        let ds = synthetic::gmm(&mut Rng::seed_from(3), 20, 4, 2, 2.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let targets = [4usize, 4, 4, 9, 9]; // 2 unique
        let refs: Vec<usize> = (0..10).collect();
        let mut scratch = Vec::new();
        let d = block_dedup(&b, &targets, &refs, &mut scratch);
        assert_eq!(d.unique.len(), 2);
        assert_eq!(b.counter().get(), 2 * 10, "only unique rows evaluated");
        // mapped rows reproduce the duplicated view
        for (req, &row) in targets.iter().zip(&d.row_of) {
            for (ri, &r) in refs.iter().enumerate() {
                assert_eq!(scratch[row * refs.len() + ri], b.dist(*req, r));
            }
        }
    }
}
