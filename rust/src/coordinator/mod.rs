//! The BanditPAM coordinator: the paper's system contribution.
//!
//! PAM's trajectory is a sequence of argmin searches — k BUILD assignments
//! (Eq. 6) followed by SWAP iterations (Eq. 7) until convergence. The
//! coordinator runs each of those searches through the bandit engine
//! ([`crate::bandits::adaptive`], Algorithm 1):
//!
//! * [`state`]   — the d₁/d₂/assignment cache PAM's recurrences rely on;
//! * [`arms`]    — the two arm sets: BUILD candidates, and SWAP
//!   (medoid, candidate) pairs with the FastPAM1 row-sharing (Eq. 12);
//! * [`scheduler`] — batches arm pulls into deduplicated dense distance
//!   blocks for the backend (this is where the XLA tile shape comes from);
//! * [`session`]  — cross-iteration SWAP state (BanditPAM++-style reuse):
//!   the fixed reference permutation, the candidate-row cache that makes
//!   repeated pulls free, and the per-arm estimator carry-over;
//! * [`build`] / [`swap`] — one PAM step each, as a bandit search;
//! * [`banditpam`] — the public driver implementing
//!   [`crate::algorithms::KMedoids`];
//! * [`config`]  — all tunables (B, delta, sigma mode, CI kind, sampling
//!   mode, swap cap T, instrumentation).

pub mod arms;
pub mod banditpam;
pub mod build;
pub mod config;
pub mod scheduler;
pub mod session;
pub mod state;
pub mod swap;
