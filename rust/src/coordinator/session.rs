//! Cross-iteration SWAP state: the BanditPAM++-style reuse subsystem.
//!
//! BanditPAM re-runs Algorithm 1 from scratch for every SWAP iteration, so
//! consecutive iterations re-evaluate the same candidate distance rows
//! against the same reference points. BanditPAM++ (Tiwari et al., 2023)
//! observes that almost all of that work is redundant and removes it with
//! two mechanisms, both implemented here:
//!
//! * **Virtual arms / shared rows** — all k `(candidate, medoid-slot)` arms
//!   of one candidate read the same distance row `d(candidate, ·)`
//!   (FastPAM1, Eq. 12), and the row itself is *medoid-independent*, so
//!   once computed it stays valid for the whole SWAP phase. The session
//!   caches each point's row as a prefix in the order of one **fixed
//!   reference permutation** shared by every iteration; re-pulling a
//!   previously seen batch therefore costs zero distance evaluations.
//!   Medoid rows computed by the post-swap rebuild land in the same cache,
//!   so a swapped-out medoid re-enters candidacy fully cached.
//! * **Estimator carry-over** (opt-in, `swap_warm_start`) — per-arm bandit
//!   state survives the iteration boundary. After a swap, only arms whose
//!   g-values the swap could have changed (some reference inside their
//!   consumed permutation prefix had `d1`/`d2`/`a1` change) are re-admitted
//!   cold; every other arm resumes its estimator, and Algorithm 1 skips the
//!   batches that estimator already covers (`ArmSet::warm_estimator`).
//!
//! **Parity.** The permutation is drawn exactly once per session, whether
//! row reuse is enabled or not, and a cached distance is bitwise equal to a
//! recomputed one (the block kernels are per-pair deterministic — see
//! `rust/PERF.md`). A fit with row reuse on therefore follows the
//! *identical* search trajectory as one with it off and returns identical
//! medoids; only the distance-evaluation count changes. This is asserted by
//! `tests/property_swap_reuse.rs`. Warm starts intentionally change the
//! trajectory (fewer pulls) and preserve the result only with Algorithm 1's
//! usual high-probability guarantee, which is why they are off by default.

use crate::bandits::adaptive::SamplingMode;
use crate::bandits::estimator::ArmEstimator;
use crate::coordinator::config::BanditPamConfig;
use crate::coordinator::state::MedoidState;
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;

/// State shared by every SWAP iteration of one fit.
pub struct SwapSession {
    n: usize,
    k: usize,
    /// Row caching active (requires `swap_reuse`, fixed-permutation
    /// sampling and the FastPAM1 decomposition).
    reuse_rows: bool,
    /// Estimator carry-over active (requires `reuse_rows`).
    warm_start: bool,
    /// The fixed reference permutation shared by every iteration.
    perm: Vec<usize>,
    /// Inverse permutation: `pos_of[j]` = position of point `j` in `perm`.
    pos_of: Vec<usize>,
    /// Per-point cached distance-row prefix in *permutation order*:
    /// `rows[p][t] = d(p, perm[t])`. Grows monotonically; empty until the
    /// point is first pulled. Medoid-independent, hence iteration-stable.
    /// A prefix's length is the number of *references consumed*, never the
    /// feature dimension, so the cache is storage-agnostic — dense, sparse
    /// (CSR) and tree points all go through it unchanged
    /// (`tests/property_sparse.rs` pins the sparse case).
    rows: Vec<Vec<f64>>,
    /// Carried per-arm estimators, keyed `point * k + slot`, stamped with
    /// the iteration that stored them.
    carried: Vec<Option<(u64, ArmEstimator)>>,
    /// Current SWAP iteration (1-based once `begin_iteration` runs).
    iteration: u64,
    /// Longest permutation prefix whose references all kept their
    /// `d1`/`d2`/`a1` through the last applied swap; carried estimators
    /// with a longer consumed prefix are re-admitted cold.
    valid_prefix: usize,
    /// Distance evaluations the non-reuse path would have performed.
    requested: u64,
    /// Distance evaluations actually issued to the backend.
    issued: u64,
    // Reused scratch (allocation-free steady state, like the arm sets).
    fill_plan: Vec<(usize, usize)>,
    fill_targets: Vec<usize>,
    fill_scratch: Vec<f64>,
    nat_buf: Vec<f64>,
    prev_d1: Vec<f64>,
    prev_d2: Vec<f64>,
    prev_a1: Vec<usize>,
}

impl SwapSession {
    /// Create the session for a SWAP phase over `n` points and `k` medoids.
    /// Under fixed-permutation sampling this draws the shared reference
    /// permutation (one shuffle — the only rng consumption, performed
    /// identically whether reuse is enabled or not, so enabling/disabling
    /// reuse cannot shift the rng stream). `WithReplacement` sampling never
    /// reads the permutation, so nothing is drawn and the rng stream stays
    /// byte-compatible with the session-less code path.
    pub fn new(n: usize, k: usize, cfg: &BanditPamConfig, rng: &mut Rng) -> SwapSession {
        assert!(k >= 1 && k < n, "need 1 <= k < n (k={k}, n={n})");
        let fixed = cfg.sampling == SamplingMode::FixedPermutation;
        let mut perm: Vec<usize> = (0..n).collect();
        if fixed {
            rng.shuffle(&mut perm);
        }
        let mut pos_of = vec![0usize; n];
        for (p, &j) in perm.iter().enumerate() {
            pos_of[j] = p;
        }
        let reuse_rows = cfg.swap_reuse && fixed && cfg.fastpam1_swap;
        let warm_start = cfg.swap_warm_start && reuse_rows;
        SwapSession {
            n,
            k,
            reuse_rows,
            warm_start,
            perm,
            pos_of,
            rows: if reuse_rows { vec![Vec::new(); n] } else { Vec::new() },
            carried: if warm_start { vec![None; n * k] } else { Vec::new() },
            iteration: 0,
            valid_prefix: 0,
            requested: 0,
            issued: 0,
            fill_plan: Vec::new(),
            fill_targets: Vec::new(),
            fill_scratch: Vec::new(),
            nat_buf: Vec::new(),
            prev_d1: Vec::new(),
            prev_d2: Vec::new(),
            prev_a1: Vec::new(),
        }
    }

    /// Row caching active for this session?
    pub fn rows_enabled(&self) -> bool {
        self.reuse_rows
    }

    /// Estimator carry-over active for this session?
    pub fn warm_enabled(&self) -> bool {
        self.warm_start
    }

    /// The fixed reference permutation (length n).
    pub fn shared_perm(&self) -> &[usize] {
        &self.perm
    }

    /// Position of point `j` inside the shared permutation.
    #[inline]
    pub fn pos(&self, j: usize) -> usize {
        self.pos_of[j]
    }

    /// Cached row prefix of point `p`, in permutation order.
    #[inline]
    pub fn row(&self, p: usize) -> &[f64] {
        &self.rows[p]
    }

    /// Distance evaluations avoided so far relative to the non-reuse path.
    pub fn evals_saved(&self) -> u64 {
        self.requested.saturating_sub(self.issued)
    }

    /// Mark the start of the next SWAP iteration (bumps the carry stamp).
    pub fn begin_iteration(&mut self) {
        self.iteration += 1;
    }

    /// Serve the distance rows of `points` over the reference batch `refs`
    /// (typically a slice of the shared permutation), filling only the
    /// permutation prefix not yet cached. Counts what the non-reuse path
    /// would have paid for telemetry.
    pub fn pull_rows(&mut self, backend: &dyn DistanceBackend, points: &[usize], refs: &[usize]) {
        debug_assert!(self.reuse_rows);
        let end = refs.iter().map(|&j| self.pos_of[j] + 1).max().unwrap_or(0);
        self.requested += (points.len() * refs.len()) as u64;
        self.fill_rows_to(backend, points, end);
    }

    /// Ensure point `p`'s row covers the whole permutation (the exact-mean
    /// path). `count_request` charges the telemetry with the n evaluations
    /// the non-reuse path would pay for a fresh candidate.
    pub fn ensure_full_row(
        &mut self,
        backend: &dyn DistanceBackend,
        p: usize,
        count_request: bool,
    ) {
        debug_assert!(self.reuse_rows);
        if count_request {
            self.requested += self.n as u64;
        }
        let n = self.n;
        self.fill_rows_to(backend, &[p], n);
    }

    /// Extend the cached rows of `points` through permutation position
    /// `end`, batching points with equal fill fronts into single dense
    /// blocks so the backend sees the same multi-target shapes as the
    /// non-reuse path (pooled row kernels apply).
    fn fill_rows_to(&mut self, backend: &dyn DistanceBackend, points: &[usize], end: usize) {
        let end = end.min(self.n);
        self.fill_plan.clear();
        for &p in points {
            let cur = self.rows[p].len();
            if cur < end {
                self.fill_plan.push((cur, p));
            }
        }
        if self.fill_plan.is_empty() {
            return;
        }
        self.fill_plan.sort_unstable();
        self.fill_plan.dedup();
        let mut i = 0;
        while i < self.fill_plan.len() {
            let start = self.fill_plan[i].0;
            let mut stop = i;
            while stop < self.fill_plan.len() && self.fill_plan[stop].0 == start {
                stop += 1;
            }
            self.fill_targets.clear();
            self.fill_targets.extend(self.fill_plan[i..stop].iter().map(|&(_, p)| p));
            let rn = end - start;
            let need = self.fill_targets.len() * rn;
            if self.fill_scratch.len() < need {
                self.fill_scratch.resize(need, 0.0);
            }
            backend.block(
                &self.fill_targets,
                &self.perm[start..end],
                &mut self.fill_scratch[..need],
            );
            for (ti, &p) in self.fill_targets.iter().enumerate() {
                self.rows[p].extend_from_slice(&self.fill_scratch[ti * rn..(ti + 1) * rn]);
                debug_assert_eq!(self.rows[p].len(), end);
            }
            self.issued += need as u64;
            i = stop;
        }
    }

    /// Carried estimator for arm `(point, slot)` if it is still valid:
    /// stored by the immediately preceding iteration, and its consumed
    /// permutation prefix untouched by the last swap. The returned copy has
    /// its (stale) exact mean cleared.
    pub fn warm(&self, point: usize, slot: usize) -> Option<ArmEstimator> {
        if !self.warm_start {
            return None;
        }
        let (stamp, est) = self.carried[point * self.k + slot].as_ref()?;
        if *stamp + 1 != self.iteration {
            return None;
        }
        let prefix = est.count() as usize;
        if prefix == 0 || prefix > self.valid_prefix {
            return None;
        }
        Some(est.carry())
    }

    /// Persist arm `(point, slot)`'s final estimator for the next iteration.
    pub fn store_carry(&mut self, point: usize, slot: usize, est: &ArmEstimator) {
        if !self.warm_start {
            return;
        }
        self.carried[point * self.k + slot] = Some((self.iteration, est.clone()));
    }

    /// Apply the swap `medoids[pos] <- x` and rebuild `state`'s d1/d2/a1
    /// from session-cached medoid rows — bitwise-identical to
    /// [`MedoidState::apply_swap`], which recomputes every row — then
    /// record which permutation prefix survived unchanged (for carry-over).
    pub fn apply_swap(
        &mut self,
        backend: &dyn DistanceBackend,
        state: &mut MedoidState,
        pos: usize,
        x: usize,
    ) {
        debug_assert!(self.reuse_rows);
        assert_eq!(state.medoids.len(), self.k);
        assert!(pos < self.k);
        let n = self.n;
        if self.warm_start {
            self.prev_d1.clone_from(&state.d1);
            self.prev_d2.clone_from(&state.d2);
            self.prev_a1.clone_from(&state.a1);
        }
        state.medoids[pos] = x;
        self.requested += (self.k * n) as u64;
        let meds = state.medoids.clone();
        self.fill_rows_to(backend, &meds, n);
        // Re-emit the cached (permutation-order) rows in natural point
        // order so the cache update folds them in exactly like a fresh
        // `rebuild` block would.
        self.nat_buf.clear();
        self.nat_buf.resize(self.k * n, 0.0);
        for (mi, &m) in meds.iter().enumerate() {
            let row = &self.rows[m];
            let dst = &mut self.nat_buf[mi * n..(mi + 1) * n];
            for (j, d) in dst.iter_mut().enumerate() {
                *d = row[self.pos_of[j]];
            }
        }
        state.ingest_rows(&self.nat_buf, n);
        if self.warm_start {
            let mut valid = n;
            for j in 0..n {
                if self.prev_d1[j].to_bits() != state.d1[j].to_bits()
                    || self.prev_a1[j] != state.a1[j]
                    || self.prev_d2[j].to_bits() != state.d2[j].to_bits()
                {
                    valid = valid.min(self.pos_of[j]);
                }
            }
            self.valid_prefix = valid;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    fn fixture() -> (crate::data::Dataset, MedoidState) {
        let ds = synthetic::gmm(&mut Rng::seed_from(31), 40, 6, 3, 3.0);
        (ds, MedoidState::empty(40))
    }

    fn default_session(n: usize, k: usize, seed: u64) -> SwapSession {
        SwapSession::new(n, k, &BanditPamConfig::default(), &mut Rng::seed_from(seed))
    }

    #[test]
    fn permutation_is_a_permutation_and_inverse_is_consistent() {
        let s = default_session(40, 3, 1);
        let mut sorted = s.shared_perm().to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..40).collect::<Vec<_>>());
        for j in 0..40 {
            assert_eq!(s.shared_perm()[s.pos(j)], j);
        }
    }

    #[test]
    fn pull_rows_caches_and_saves_on_repeat() {
        let (ds, _) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut s = default_session(40, 3, 2);
        let refs: Vec<usize> = s.shared_perm()[..10].to_vec();
        s.pull_rows(&b, &[5, 7], &refs);
        assert_eq!(b.counter().get(), 2 * 10);
        assert_eq!(s.evals_saved(), 0);
        // identical repeat: fully served from cache
        s.pull_rows(&b, &[5, 7], &refs);
        assert_eq!(b.counter().get(), 2 * 10);
        assert_eq!(s.evals_saved(), 2 * 10);
        // cached values match direct evaluation
        for &p in &[5usize, 7] {
            for (t, &j) in refs.iter().enumerate() {
                assert_eq!(s.row(p)[t], b.dist(p, j));
            }
        }
    }

    #[test]
    fn fill_extends_prefix_without_recomputation() {
        let (ds, _) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut s = default_session(40, 3, 3);
        let first: Vec<usize> = s.shared_perm()[..8].to_vec();
        let wider: Vec<usize> = s.shared_perm()[..20].to_vec();
        s.pull_rows(&b, &[4], &first);
        s.pull_rows(&b, &[4], &wider);
        // only the 12 new positions were evaluated
        assert_eq!(b.counter().get(), 20);
        assert_eq!(s.row(4).len(), 20);
    }

    #[test]
    fn ensure_full_row_completes_the_prefix() {
        let (ds, _) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut s = default_session(40, 3, 4);
        let first: Vec<usize> = s.shared_perm()[..15].to_vec();
        s.pull_rows(&b, &[9], &first);
        s.ensure_full_row(&b, 9, true);
        assert_eq!(s.row(9).len(), 40);
        assert_eq!(b.counter().get(), 40);
        for j in 0..40 {
            assert_eq!(s.row(9)[s.pos(j)], b.dist(9, j));
        }
    }

    #[test]
    fn session_apply_swap_matches_legacy_rebuild_bitwise() {
        let (ds, mut state) = fixture();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        for m in [0usize, 11, 22] {
            state.add_medoid(&b, m);
        }
        let mut legacy = state.clone();
        let mut s = default_session(40, 3, 5);
        s.begin_iteration();
        s.apply_swap(&b, &mut state, 1, 33);
        legacy.apply_swap(&b, 1, 33);
        assert_eq!(state.medoids, legacy.medoids);
        for j in 0..40 {
            assert_eq!(state.d1[j].to_bits(), legacy.d1[j].to_bits(), "d1[{j}]");
            assert_eq!(state.d2[j].to_bits(), legacy.d2[j].to_bits(), "d2[{j}]");
            assert_eq!(state.a1[j], legacy.a1[j], "a1[{j}]");
        }
        state.check_invariants(&b);
    }

    #[test]
    fn warm_carry_respects_stamp_and_valid_prefix() {
        let cfg = BanditPamConfig {
            swap_warm_start: true,
            ..Default::default()
        };
        let mut s = SwapSession::new(30, 2, &cfg, &mut Rng::seed_from(6));
        assert!(s.warm_enabled());
        s.begin_iteration(); // iteration 1
        let mut est = ArmEstimator::default();
        est.update(&[1.0, 2.0, 3.0]);
        s.store_carry(7, 1, &est);
        // same iteration: not yet offered
        assert!(s.warm(7, 1).is_none());
        s.begin_iteration(); // iteration 2
        // valid_prefix defaults to 0 until a swap computes it
        assert!(s.warm(7, 1).is_none());
        s.valid_prefix = 3;
        let w = s.warm(7, 1).expect("valid carry");
        assert_eq!(w.count(), 3);
        assert!(w.exact.is_none());
        // prefix longer than the surviving one: re-admitted cold
        s.valid_prefix = 2;
        assert!(s.warm(7, 1).is_none());
        // two iterations later: stale stamp
        s.valid_prefix = 3;
        s.begin_iteration(); // iteration 3
        assert!(s.warm(7, 1).is_none());
    }

    #[test]
    fn reuse_disabled_under_with_replacement_sampling() {
        let cfg = BanditPamConfig {
            sampling: SamplingMode::WithReplacement,
            ..Default::default()
        };
        let s = SwapSession::new(20, 2, &cfg, &mut Rng::seed_from(7));
        assert!(!s.rows_enabled());
        assert!(!s.warm_enabled());
    }
}
