//! Medoid state cache: nearest / second-nearest medoid distances.
//!
//! PAM's recurrences (paper Eqs. 4–5) and the FastPAM1 decomposition
//! (Eq. 12) need, for every point j, the distance to its nearest medoid
//! (`d1`), which medoid that is (`a1`), and the distance to the second
//! nearest (`d2`). This cache is maintained incrementally: adding a medoid
//! costs n evaluations; a swap triggers a full rebuild (n·k evaluations,
//! the O(n) bookkeeping Theorem 1's `4n` term accounts for).

use crate::runtime::backend::DistanceBackend;

/// d₁/a₁/d₂ cache for a (possibly growing) medoid set.
#[derive(Debug, Clone)]
pub struct MedoidState {
    pub medoids: Vec<usize>,
    /// Distance from each point to its nearest medoid (`+inf` when none).
    pub d1: Vec<f64>,
    /// Index *into `medoids`* of each point's nearest medoid.
    pub a1: Vec<usize>,
    /// Distance to the second-nearest medoid (`+inf` with < 2 medoids).
    pub d2: Vec<f64>,
}

impl MedoidState {
    /// Empty state over `n` points.
    pub fn empty(n: usize) -> MedoidState {
        MedoidState {
            medoids: Vec::new(),
            d1: vec![f64::INFINITY; n],
            a1: vec![usize::MAX; n],
            d2: vec![f64::INFINITY; n],
        }
    }

    /// Number of medoids.
    pub fn k(&self) -> usize {
        self.medoids.len()
    }

    /// Current loss (Eq. 1): sum of nearest-medoid distances.
    pub fn loss(&self) -> f64 {
        self.d1.iter().sum()
    }

    /// Append a new medoid, updating the cache with n evaluations.
    pub fn add_medoid(&mut self, backend: &dyn DistanceBackend, m: usize) {
        let pos = self.medoids.len();
        self.medoids.push(m);
        let n = backend.n();
        let refs: Vec<usize> = (0..n).collect();
        let mut row = vec![0.0f64; n];
        backend.block(&[m], &refs, &mut row);
        for (j, &d) in row.iter().enumerate() {
            if d < self.d1[j] {
                self.d2[j] = self.d1[j];
                self.d1[j] = d;
                self.a1[j] = pos;
            } else if d < self.d2[j] {
                self.d2[j] = d;
            }
        }
    }

    /// Replace `medoids[pos]` with point `x` and rebuild the cache
    /// (n·k evaluations).
    pub fn apply_swap(&mut self, backend: &dyn DistanceBackend, pos: usize, x: usize) {
        assert!(pos < self.medoids.len());
        self.medoids[pos] = x;
        self.rebuild(backend);
    }

    /// Recompute d₁/a₁/d₂ from scratch for the current medoid set.
    pub fn rebuild(&mut self, backend: &dyn DistanceBackend) {
        let n = backend.n();
        let k = self.medoids.len();
        let mut rows = vec![0.0f64; k * n];
        if k > 0 {
            let refs: Vec<usize> = (0..n).collect();
            backend.block(&self.medoids, &refs, &mut rows);
        }
        self.ingest_rows(&rows, n);
    }

    /// Reset d₁/a₁/d₂ and fold in per-medoid distance rows — row-major
    /// `[k x n]`, natural point order, `rows[pos * n + j] = d(medoids[pos], j)`.
    /// The shared second half of [`MedoidState::rebuild`]; the SWAP session
    /// calls it with cached rows instead of a fresh block
    /// ([`crate::coordinator::session::SwapSession::apply_swap`]).
    pub fn ingest_rows(&mut self, rows: &[f64], n: usize) {
        assert_eq!(rows.len(), self.medoids.len() * n);
        self.d1.iter_mut().for_each(|v| *v = f64::INFINITY);
        self.d2.iter_mut().for_each(|v| *v = f64::INFINITY);
        self.a1.iter_mut().for_each(|v| *v = usize::MAX);
        for (pos, row) in rows.chunks(n).enumerate() {
            for (j, &d) in row.iter().enumerate() {
                if d < self.d1[j] {
                    self.d2[j] = self.d1[j];
                    self.d1[j] = d;
                    self.a1[j] = pos;
                } else if d < self.d2[j] {
                    self.d2[j] = d;
                }
            }
        }
    }

    /// Debug invariant: d1 <= d2, a1 valid, d1 is the true minimum.
    #[cfg(any(test, feature = "strict"))]
    pub fn check_invariants(&self, backend: &dyn DistanceBackend) {
        for j in 0..backend.n() {
            assert!(self.d1[j] <= self.d2[j] + 1e-9, "d1 > d2 at {j}");
            if self.k() > 0 {
                assert!(self.a1[j] < self.k());
                let true_min = self
                    .medoids
                    .iter()
                    .map(|&m| backend.dist(m, j))
                    .fold(f64::INFINITY, f64::min);
                assert!(
                    (self.d1[j] - true_min).abs() < 1e-9,
                    "stale d1 at {j}: {} vs {}",
                    self.d1[j],
                    true_min
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;
    use crate::util::rng::Rng;

    fn setup() -> (crate::data::Dataset, ()) {
        (synthetic::gmm(&mut Rng::seed_from(5), 30, 4, 3, 3.0), ())
    }

    #[test]
    fn add_medoid_maintains_invariants() {
        let (ds, _) = setup();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut st = MedoidState::empty(30);
        for &m in &[3, 17, 9] {
            st.add_medoid(&b, m);
            st.check_invariants(&b);
        }
        assert_eq!(st.k(), 3);
        // medoid points have d1 == 0 and are assigned to themselves
        assert_eq!(st.d1[3], 0.0);
        assert_eq!(st.medoids[st.a1[17]], 17);
    }

    #[test]
    fn loss_decreases_as_medoids_are_added() {
        let (ds, _) = setup();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut st = MedoidState::empty(30);
        st.add_medoid(&b, 0);
        let l1 = st.loss();
        st.add_medoid(&b, 15);
        let l2 = st.loss();
        assert!(l2 <= l1);
    }

    #[test]
    fn swap_rebuild_matches_fresh_state() {
        let (ds, _) = setup();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut st = MedoidState::empty(30);
        st.add_medoid(&b, 0);
        st.add_medoid(&b, 1);
        st.apply_swap(&b, 0, 20);
        st.check_invariants(&b);
        let mut fresh = MedoidState::empty(30);
        fresh.add_medoid(&b, 20);
        fresh.add_medoid(&b, 1);
        for j in 0..30 {
            assert!((st.d1[j] - fresh.d1[j]).abs() < 1e-12);
            assert!(
                (st.d2[j] - fresh.d2[j]).abs() < 1e-12
                    || (st.d2[j].is_infinite() && fresh.d2[j].is_infinite())
            );
        }
        assert!((st.loss() - fresh.loss()).abs() < 1e-12);
    }

    #[test]
    fn empty_state_has_infinite_loss_components() {
        let st = MedoidState::empty(5);
        assert_eq!(st.k(), 0);
        assert!(st.loss().is_infinite());
    }
}
