//! The BanditPAM driver: k BUILD searches + SWAP-until-converged, each via
//! Algorithm 1. Implements [`crate::algorithms::KMedoids`].

use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::coordinator::build::build_step;
use crate::coordinator::config::BanditPamConfig;
use crate::coordinator::session::SwapSession;
use crate::coordinator::state::MedoidState;
use crate::coordinator::swap::swap_step_session;
use crate::obs::{TraceSink, TraceValue};
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use std::sync::Arc;

/// BanditPAM (paper §3). Tracks PAM's optimization trajectory with high
/// probability in O(n log n) distance evaluations per iteration.
pub struct BanditPam {
    pub config: BanditPamConfig,
    /// Telemetry from the last fit (populated when
    /// `config.record_sigmas` is set): per BUILD step, all sigma_x.
    pub build_sigmas: Vec<Vec<f64>>,
    /// Per-call adaptive-search telemetry from the last fit.
    pub trace: Vec<SearchTrace>,
    /// Opt-in JSONL span sink (`--trace-out`). Emission happens *after*
    /// each search from values the fit already computed, so attaching a
    /// sink never changes the trajectory, the rng stream or the eval
    /// counters (pinned by `tests/property_obs.rs`).
    sink: Option<Arc<TraceSink>>,
}

/// One Algorithm-1 invocation's telemetry. `PartialEq` so determinism
/// tests can compare whole traces byte-for-byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchTrace {
    /// "build" or "swap".
    pub phase: &'static str,
    pub arms: usize,
    pub rounds: usize,
    pub exact_fallbacks: usize,
    pub distance_evals: u64,
    /// Distance evaluations the SWAP session served from its
    /// cross-iteration row cache (0 for BUILD and for reuse-off runs).
    pub evals_saved: u64,
}

impl BanditPam {
    /// With explicit configuration.
    pub fn new(config: BanditPamConfig) -> Self {
        BanditPam { config, build_sigmas: Vec::new(), trace: Vec::new(), sink: None }
    }

    /// Paper-default configuration.
    pub fn default_paper() -> Self {
        Self::new(BanditPamConfig::default())
    }

    /// Attach a JSONL trace sink: each BUILD round and SWAP iteration
    /// emits one span event (see `rust/OBS.md` for the schema).
    pub fn with_trace_sink(mut self, sink: Arc<TraceSink>) -> Self {
        self.sink = Some(sink);
        self
    }

    /// Set or clear the trace sink on an existing instance.
    pub fn set_trace_sink(&mut self, sink: Option<Arc<TraceSink>>) {
        self.sink = sink;
    }

    /// `DistanceCache` effectiveness as trace fields (empty when the
    /// backend runs without a cache).
    fn cache_fields(backend: &dyn DistanceBackend, fields: &mut Vec<(&'static str, TraceValue)>) {
        if let Some((hits, misses)) = backend.cache_stats() {
            fields.push(("cache_hits", hits.into()));
            fields.push(("cache_misses", misses.into()));
            let total = hits + misses;
            if total > 0 {
                fields.push(("cache_hit_rate", (hits as f64 / total as f64).into()));
            }
        }
    }

    /// Run only the BUILD phase (used by the Appendix-Figure-1 experiment).
    pub fn build_only(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<MedoidState> {
        self.config.validate()?;
        check_fit_args(backend, k)?;
        self.build_sigmas.clear();
        self.trace.clear();
        let mut state = MedoidState::empty(backend.n());
        if k == backend.n() {
            // Degenerate k == n: every point is a medoid; no search.
            for i in 0..k {
                state.add_medoid(backend, i);
            }
            return Ok(state);
        }
        for step in 0..k {
            let before = backend.counter().get();
            let (chosen, outcome) = build_step(backend, &mut state, &self.config, rng);
            if self.config.record_sigmas {
                self.build_sigmas.push(outcome.sigmas.clone());
            }
            let evals = backend.counter().get() - before;
            self.trace.push(SearchTrace {
                phase: "build",
                arms: outcome.sigmas.len(),
                rounds: outcome.rounds,
                exact_fallbacks: outcome.exact_fallbacks,
                distance_evals: evals,
                evals_saved: 0,
            });
            if let Some(sink) = &self.sink {
                let mut fields: Vec<(&'static str, TraceValue)> = vec![
                    ("round", step.into()),
                    ("arms", outcome.sigmas.len().into()),
                    ("batches", outcome.rounds.into()),
                    ("exact_fallbacks", outcome.exact_fallbacks.into()),
                    ("evals", evals.into()),
                    ("ci_half_width", outcome.best_half_width.into()),
                    ("chosen", chosen.into()),
                ];
                Self::cache_fields(backend, &mut fields);
                sink.emit("build_round", &fields);
            }
        }
        Ok(state)
    }
}

impl KMedoids for BanditPam {
    fn name(&self) -> &'static str {
        "banditpam"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        // validate/check repeat inside build_only (both are public entry
        // points and the checks are O(1)); they must run here first so the
        // degenerate shortcut below cannot bypass them. Unlike build_only's
        // k == n branch (which must materialize a MedoidState and therefore
        // evaluates distances), this shortcut is evaluation-free.
        self.config.validate()?;
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            // No search ran: leave no stale telemetry from a prior fit.
            self.build_sigmas.clear();
            self.trace.clear();
            return Ok(c);
        }
        let timer = Timer::start();
        let start_evals = backend.counter().get();
        let mut state = self.build_only(backend, k, rng)?;
        let build_evals = backend.counter().get() - start_evals;

        let mut stats = FitStats { build_evals, ..Default::default() };
        // One session per SWAP phase: it pins the reference permutation
        // (drawn here, identically whether reuse is on or off) and carries
        // the row cache / bandit state across iterations.
        let mut session = SwapSession::new(backend.n(), k, &self.config, rng);
        for _ in 0..self.config.max_swap_iters {
            let before = backend.counter().get();
            let saved_before = session.evals_saved();
            let step = swap_step_session(backend, &mut state, &mut session, &self.config, rng);
            stats.swap_iters += 1;
            let evals = backend.counter().get() - before;
            let saved = session.evals_saved().saturating_sub(saved_before);
            self.trace.push(SearchTrace {
                phase: "swap",
                arms: state.medoids.len() * (backend.n() - state.medoids.len()),
                rounds: step.outcome.rounds,
                exact_fallbacks: step.outcome.exact_fallbacks,
                distance_evals: evals,
                evals_saved: saved,
            });
            if let Some(sink) = &self.sink {
                let mut fields: Vec<(&'static str, TraceValue)> = vec![
                    ("iter", stats.swap_iters.into()),
                    ("arms", (state.medoids.len() * (backend.n() - state.medoids.len())).into()),
                    ("batches", step.outcome.rounds.into()),
                    ("exact_fallbacks", step.outcome.exact_fallbacks.into()),
                    ("evals", evals.into()),
                    ("evals_saved", saved.into()),
                    ("ci_half_width", step.outcome.best_half_width.into()),
                    ("best_delta", step.best_delta.into()),
                    ("applied", step.applied.is_some().into()),
                ];
                Self::cache_fields(backend, &mut fields);
                sink.emit("swap_iter", &fields);
            }
            match step.applied {
                Some(_) => stats.swaps_applied += 1,
                None => break,
            }
        }
        stats.swap_evals_saved = session.evals_saved();
        stats.swap_evals = backend.counter().get() - start_evals - build_evals;
        stats.iters_plus_one = stats.swap_iters + 1;
        stats.wall_secs = timer.secs();
        let clustering = Clustering::finalize(backend, state.medoids, stats);
        if let Some(sink) = &self.sink {
            let mut fields: Vec<(&'static str, TraceValue)> = vec![
                ("algo", "banditpam".into()),
                ("n", backend.n().into()),
                ("k", k.into()),
                ("loss", clustering.loss.into()),
                ("distance_evals", clustering.stats.distance_evals.into()),
                ("swap_iters", clustering.stats.swap_iters.into()),
                ("swaps_applied", clustering.stats.swaps_applied.into()),
                ("swap_evals_saved", clustering.stats.swap_evals_saved.into()),
                ("wall_secs", clustering.stats.wall_secs.into()),
            ];
            Self::cache_fields(backend, &mut fields);
            sink.emit("fit_summary", &fields);
            let _ = sink.flush();
        }
        Ok(clustering)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn banditpam_matches_pam_on_small_data() {
        // The paper's core claim (Theorem 2): same medoids as PAM w.h.p.
        let mut agree = 0;
        let total = 8;
        for seed in 0..total {
            let ds = synthetic::gmm(&mut Rng::seed_from(200 + seed), 70, 5, 3, 3.0);
            let backend = NativeBackend::new(&ds.points, Metric::L2);
            let pam_fit = Pam::new()
                .fit(&backend, 3, &mut Rng::seed_from(0))
                .unwrap();
            let bp_fit = BanditPam::default_paper()
                .fit(&backend, 3, &mut Rng::seed_from(seed))
                .unwrap();
            if bp_fit.same_medoids(&pam_fit) {
                agree += 1;
            } else {
                // when the sets differ, the loss must still match closely
                assert!(
                    bp_fit.loss <= pam_fit.loss * 1.05,
                    "seed {seed}: {} vs {}",
                    bp_fit.loss,
                    pam_fit.loss
                );
            }
        }
        assert!(agree >= total - 1, "only {agree}/{total} exact agreements");
    }

    #[test]
    fn stats_are_populated() {
        let ds = synthetic::gmm(&mut Rng::seed_from(3), 60, 4, 3, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = BanditPam::default_paper();
        let fit = algo.fit(&backend, 3, &mut Rng::seed_from(1)).unwrap();
        assert_eq!(fit.medoids.len(), 3);
        assert!(fit.stats.build_evals > 0);
        assert!(fit.stats.swap_iters >= 1);
        assert_eq!(fit.stats.iters_plus_one, fit.stats.swap_iters + 1);
        assert!(fit.stats.distance_evals >= fit.stats.build_evals);
        assert!(!algo.trace.is_empty());
        assert_eq!(
            algo.trace.iter().filter(|t| t.phase == "build").count(),
            3
        );
    }

    #[test]
    fn record_sigmas_captures_build_steps() {
        let ds = synthetic::gmm(&mut Rng::seed_from(4), 50, 4, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = BanditPam::new(BanditPamConfig {
            record_sigmas: true,
            ..Default::default()
        });
        algo.fit(&backend, 2, &mut Rng::seed_from(2)).unwrap();
        assert_eq!(algo.build_sigmas.len(), 2);
        assert_eq!(algo.build_sigmas[0].len(), 50);
        // paper Appendix Fig 1: sigma drops once the first medoid exists
        let med0: f64 = crate::stats::quantile(&algo.build_sigmas[0], 0.5);
        let med1: f64 = crate::stats::quantile(&algo.build_sigmas[1], 0.5);
        assert!(med1 <= med0, "median sigma should not grow: {med0} -> {med1}");
    }

    #[test]
    fn swap_cap_is_respected() {
        let ds = synthetic::gmm(&mut Rng::seed_from(5), 80, 4, 4, 1.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = BanditPam::new(BanditPamConfig {
            max_swap_iters: 1,
            ..Default::default()
        });
        let fit = algo.fit(&backend, 4, &mut Rng::seed_from(3)).unwrap();
        assert!(fit.stats.swap_iters <= 1);
    }

    #[test]
    fn rejects_bad_k() {
        let ds = synthetic::gmm(&mut Rng::seed_from(6), 10, 2, 2, 1.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        assert!(BanditPam::default_paper().fit(&backend, 0, &mut Rng::seed_from(0)).is_err());
        assert!(BanditPam::default_paper().fit(&backend, 11, &mut Rng::seed_from(0)).is_err());
        // k == n is the degenerate identity solution, not an error
        let fit = BanditPam::default_paper()
            .fit(&backend, 10, &mut Rng::seed_from(0))
            .unwrap();
        assert_eq!(fit.medoids, (0..10).collect::<Vec<_>>());
        assert_eq!(fit.loss, 0.0);
    }

    #[test]
    fn fit_rejects_invalid_config() {
        let ds = synthetic::gmm(&mut Rng::seed_from(6), 10, 2, 2, 1.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = BanditPam::new(BanditPamConfig {
            swap_reuse: false,
            swap_warm_start: true,
            ..Default::default()
        });
        let err = algo.fit(&backend, 3, &mut Rng::seed_from(0)).unwrap_err();
        assert_eq!(err.kind(), "config");
        assert!(algo.build_only(&backend, 3, &mut Rng::seed_from(0)).is_err());
    }
}
