//! BanditPAM configuration.

use crate::bandits::adaptive::{SamplingMode, SigmaMode};
use crate::bandits::confidence::CiKind;

/// How the per-call error probability `delta` is chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeltaMode {
    /// The paper's experimental setting: `delta = 1 / (1000 * |S_tar|)`.
    PaperDefault,
    /// The theoretical setting of Theorems 1–2: `delta = n^-3`.
    NCubed,
    /// Explicit value (the Appendix-2.3 approximate-BanditPAM knob:
    /// larger `delta` trades clustering fidelity for fewer evaluations).
    Fixed(f64),
}

impl DeltaMode {
    /// Resolve to a concrete probability for a call with `n_targets` arms
    /// over a dataset of `n` points.
    pub fn resolve(&self, n_targets: usize, n: usize) -> f64 {
        match self {
            DeltaMode::PaperDefault => 1.0 / (1000.0 * n_targets.max(1) as f64),
            DeltaMode::NCubed => (n.max(2) as f64).powi(-3),
            DeltaMode::Fixed(d) => *d,
        }
    }
}

/// Full configuration for a BanditPAM run.
#[derive(Debug, Clone)]
pub struct BanditPamConfig {
    /// Reference batch size `B` (paper: 100).
    pub batch_size: usize,
    pub delta: DeltaMode,
    /// Hard cap `T` on SWAP iterations (paper Remark 1; empirically O(k)).
    pub max_swap_iters: usize,
    pub sigma_mode: SigmaMode,
    pub ci: CiKind,
    pub sampling: SamplingMode,
    /// Use the FastPAM1 decomposition in SWAP (paper §3.2 / Appendix 1.1).
    /// Disabling it makes each (m, x) arm compute its own distance row —
    /// the `abl-fastpam1` ablation.
    pub fastpam1_swap: bool,
    /// Record per-arm sigma estimates of every BUILD step (Appendix Fig 1).
    pub record_sigmas: bool,
    /// Minimum exact loss improvement required to accept a swap.
    pub swap_tolerance: f64,
    /// Reuse candidate distance rows across SWAP iterations through a
    /// [`crate::coordinator::session::SwapSession`] (BanditPAM++ "virtual
    /// arms"): distance rows are medoid-independent, so one fixed reference
    /// permutation lets every iteration after the first serve most pulls
    /// from cache. Requires `SamplingMode::FixedPermutation` and
    /// `fastpam1_swap` (silently inactive otherwise). The clustering is
    /// bitwise-identical with this on or off — only the evaluation count
    /// changes (`tests/property_swap_reuse.rs` asserts it).
    pub swap_reuse: bool,
    /// Carry per-arm bandit estimators across SWAP iterations, re-admitting
    /// cold only the arms whose g-values the applied swap could have
    /// changed (BanditPAM++ "PI"). Skips re-pulling, so it changes the
    /// search trajectory; the result keeps Algorithm 1's usual
    /// high-probability guarantee rather than bitwise parity. Off by
    /// default; requires `swap_reuse` — [`BanditPamConfig::validate`]
    /// rejects `swap_warm_start` without it (it used to be silently
    /// inactive). The `abl-swap-reuse` ablation measures it.
    pub swap_warm_start: bool,
}

impl Default for BanditPamConfig {
    fn default() -> Self {
        BanditPamConfig {
            batch_size: 100,
            delta: DeltaMode::PaperDefault,
            max_swap_iters: 100,
            sigma_mode: SigmaMode::PerArmFirstBatch,
            ci: CiKind::Hoeffding,
            // Fixed-permutation reference sampling (the paper's Appendix
            // 2.2 "fixed ordering" idea): statistically equivalent batches,
            // but when the permutation is exhausted the surviving arms'
            // running means are *exact*, so Algorithm 1's line-14 exact
            // recomputation is free. `SamplingMode::WithReplacement` is the
            // paper-literal variant (abl-cache ablation compares them).
            sampling: SamplingMode::FixedPermutation,
            fastpam1_swap: true,
            record_sigmas: false,
            swap_tolerance: 1e-12,
            swap_reuse: true,
            swap_warm_start: false,
        }
    }
}

impl BanditPamConfig {
    /// Reject configurations that cannot run or would silently misbehave:
    ///
    /// * `batch_size == 0` — Algorithm 1 would never pull an arm;
    /// * `DeltaMode::Fixed` outside the open interval `(0, 1)` — not a
    ///   probability (and 0/1 degenerate the confidence intervals);
    /// * `swap_warm_start` without `swap_reuse` — the estimator carry-over
    ///   rides on the session row cache, so this combination used to be
    ///   *silently inactive*; it is now a hard error.
    ///
    /// Called by the [`crate::model::Fit`] builder before construction and
    /// by [`crate::coordinator::banditpam::BanditPam`] at the top of every
    /// fit (the config field is public and mutable, so construction-time
    /// validation alone could be bypassed).
    pub fn validate(&self) -> crate::error::Result<()> {
        use crate::error::Error;
        if self.batch_size == 0 {
            return Err(Error::config("batch_size must be >= 1 (got 0)"));
        }
        if let DeltaMode::Fixed(d) = self.delta {
            if !(d > 0.0 && d < 1.0) {
                return Err(Error::config(format!(
                    "DeltaMode::Fixed must lie in (0, 1) (got {d})"
                )));
            }
        }
        if self.swap_warm_start && !self.swap_reuse {
            return Err(Error::config(
                "swap_warm_start requires swap_reuse (estimator carry-over rides on \
                 the session row cache; enabling it alone would silently do nothing)",
            ));
        }
        Ok(())
    }

    /// Adaptive-search knobs for a call with `n_targets` arms over `n`
    /// points. BUILD searches always have a strictly-improving winner;
    /// SWAP searches pass `early_stop` so a converged iteration terminates
    /// after a few batches instead of exhausting all k(n-k) tied arms.
    pub fn adaptive(
        &self,
        n_targets: usize,
        n: usize,
        early_stop: Option<f64>,
    ) -> crate::bandits::adaptive::AdaptiveConfig {
        crate::bandits::adaptive::AdaptiveConfig {
            batch_size: self.batch_size,
            delta: self.delta.resolve(n_targets, n),
            sigma_mode: self.sigma_mode,
            ci: self.ci,
            sampling: self.sampling,
            early_stop_above: early_stop,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_modes_resolve() {
        assert!((DeltaMode::PaperDefault.resolve(500, 1000) - 1.0 / 500_000.0).abs() < 1e-15);
        assert!((DeltaMode::NCubed.resolve(10, 100) - 1e-6).abs() < 1e-12);
        assert_eq!(DeltaMode::Fixed(0.05).resolve(10, 100), 0.05);
    }

    #[test]
    fn delta_degenerate_inputs() {
        assert!(DeltaMode::PaperDefault.resolve(0, 0) > 0.0);
        assert!(DeltaMode::NCubed.resolve(0, 0) > 0.0);
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(BanditPamConfig::default().validate().is_ok());
        let zero_batch = BanditPamConfig { batch_size: 0, ..Default::default() };
        assert_eq!(zero_batch.validate().unwrap_err().kind(), "config");
        for d in [0.0, 1.0, -0.5, 1.5, f64::NAN] {
            let c = BanditPamConfig { delta: DeltaMode::Fixed(d), ..Default::default() };
            assert!(c.validate().is_err(), "Fixed({d}) must be rejected");
        }
        let ok_fixed =
            BanditPamConfig { delta: DeltaMode::Fixed(0.01), ..Default::default() };
        assert!(ok_fixed.validate().is_ok());
        // warm start without reuse: previously silently inactive, now hard
        let warm_only = BanditPamConfig {
            swap_reuse: false,
            swap_warm_start: true,
            ..Default::default()
        };
        let err = warm_only.validate().unwrap_err();
        assert!(err.to_string().contains("swap_reuse"), "{err}");
        let warm_with_reuse =
            BanditPamConfig { swap_warm_start: true, ..Default::default() };
        assert!(warm_with_reuse.validate().is_ok());
    }

    #[test]
    fn default_config_matches_paper() {
        let c = BanditPamConfig::default();
        assert_eq!(c.batch_size, 100);
        assert_eq!(c.delta, DeltaMode::PaperDefault);
        assert!(c.fastpam1_swap);
        assert!(c.swap_reuse, "SWAP row reuse is the default (BanditPAM++)");
        assert!(!c.swap_warm_start, "estimator carry-over is opt-in");
        let a = c.adaptive(200, 1000, None);
        assert_eq!(a.batch_size, 100);
        assert!((a.delta - 1.0 / 200_000.0).abs() < 1e-15);
    }
}
