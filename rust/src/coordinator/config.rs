//! BanditPAM configuration.

use crate::bandits::adaptive::{SamplingMode, SigmaMode};
use crate::bandits::confidence::CiKind;

/// How the per-call error probability `delta` is chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeltaMode {
    /// The paper's experimental setting: `delta = 1 / (1000 * |S_tar|)`.
    PaperDefault,
    /// The theoretical setting of Theorems 1–2: `delta = n^-3`.
    NCubed,
    /// Explicit value (the Appendix-2.3 approximate-BanditPAM knob:
    /// larger `delta` trades clustering fidelity for fewer evaluations).
    Fixed(f64),
}

impl DeltaMode {
    /// Resolve to a concrete probability for a call with `n_targets` arms
    /// over a dataset of `n` points.
    pub fn resolve(&self, n_targets: usize, n: usize) -> f64 {
        match self {
            DeltaMode::PaperDefault => 1.0 / (1000.0 * n_targets.max(1) as f64),
            DeltaMode::NCubed => (n.max(2) as f64).powi(-3),
            DeltaMode::Fixed(d) => *d,
        }
    }
}

/// Full configuration for a BanditPAM run.
#[derive(Debug, Clone)]
pub struct BanditPamConfig {
    /// Reference batch size `B` (paper: 100).
    pub batch_size: usize,
    pub delta: DeltaMode,
    /// Hard cap `T` on SWAP iterations (paper Remark 1; empirically O(k)).
    pub max_swap_iters: usize,
    pub sigma_mode: SigmaMode,
    pub ci: CiKind,
    pub sampling: SamplingMode,
    /// Use the FastPAM1 decomposition in SWAP (paper §3.2 / Appendix 1.1).
    /// Disabling it makes each (m, x) arm compute its own distance row —
    /// the `abl-fastpam1` ablation.
    pub fastpam1_swap: bool,
    /// Record per-arm sigma estimates of every BUILD step (Appendix Fig 1).
    pub record_sigmas: bool,
    /// Minimum exact loss improvement required to accept a swap.
    pub swap_tolerance: f64,
    /// Reuse candidate distance rows across SWAP iterations through a
    /// [`crate::coordinator::session::SwapSession`] (BanditPAM++ "virtual
    /// arms"): distance rows are medoid-independent, so one fixed reference
    /// permutation lets every iteration after the first serve most pulls
    /// from cache. Requires `SamplingMode::FixedPermutation` and
    /// `fastpam1_swap` (silently inactive otherwise). The clustering is
    /// bitwise-identical with this on or off — only the evaluation count
    /// changes (`tests/property_swap_reuse.rs` asserts it).
    pub swap_reuse: bool,
    /// Carry per-arm bandit estimators across SWAP iterations, re-admitting
    /// cold only the arms whose g-values the applied swap could have
    /// changed (BanditPAM++ "PI"). Skips re-pulling, so it changes the
    /// search trajectory; the result keeps Algorithm 1's usual
    /// high-probability guarantee rather than bitwise parity. Off by
    /// default; requires `swap_reuse`. The `abl-swap-reuse` ablation
    /// measures it.
    pub swap_warm_start: bool,
}

impl Default for BanditPamConfig {
    fn default() -> Self {
        BanditPamConfig {
            batch_size: 100,
            delta: DeltaMode::PaperDefault,
            max_swap_iters: 100,
            sigma_mode: SigmaMode::PerArmFirstBatch,
            ci: CiKind::Hoeffding,
            // Fixed-permutation reference sampling (the paper's Appendix
            // 2.2 "fixed ordering" idea): statistically equivalent batches,
            // but when the permutation is exhausted the surviving arms'
            // running means are *exact*, so Algorithm 1's line-14 exact
            // recomputation is free. `SamplingMode::WithReplacement` is the
            // paper-literal variant (abl-cache ablation compares them).
            sampling: SamplingMode::FixedPermutation,
            fastpam1_swap: true,
            record_sigmas: false,
            swap_tolerance: 1e-12,
            swap_reuse: true,
            swap_warm_start: false,
        }
    }
}

impl BanditPamConfig {
    /// Adaptive-search knobs for a call with `n_targets` arms over `n`
    /// points. BUILD searches always have a strictly-improving winner;
    /// SWAP searches pass `early_stop` so a converged iteration terminates
    /// after a few batches instead of exhausting all k(n-k) tied arms.
    pub fn adaptive(
        &self,
        n_targets: usize,
        n: usize,
        early_stop: Option<f64>,
    ) -> crate::bandits::adaptive::AdaptiveConfig {
        crate::bandits::adaptive::AdaptiveConfig {
            batch_size: self.batch_size,
            delta: self.delta.resolve(n_targets, n),
            sigma_mode: self.sigma_mode,
            ci: self.ci,
            sampling: self.sampling,
            early_stop_above: early_stop,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_modes_resolve() {
        assert!((DeltaMode::PaperDefault.resolve(500, 1000) - 1.0 / 500_000.0).abs() < 1e-15);
        assert!((DeltaMode::NCubed.resolve(10, 100) - 1e-6).abs() < 1e-12);
        assert_eq!(DeltaMode::Fixed(0.05).resolve(10, 100), 0.05);
    }

    #[test]
    fn delta_degenerate_inputs() {
        assert!(DeltaMode::PaperDefault.resolve(0, 0) > 0.0);
        assert!(DeltaMode::NCubed.resolve(0, 0) > 0.0);
    }

    #[test]
    fn default_config_matches_paper() {
        let c = BanditPamConfig::default();
        assert_eq!(c.batch_size, 100);
        assert_eq!(c.delta, DeltaMode::PaperDefault);
        assert!(c.fastpam1_swap);
        assert!(c.swap_reuse, "SWAP row reuse is the default (BanditPAM++)");
        assert!(!c.swap_warm_start, "estimator carry-over is opt-in");
        let a = c.adaptive(200, 1000, None);
        assert_eq!(a.batch_size, 100);
        assert!((a.delta - 1.0 / 200_000.0).abs() < 1e-15);
    }
}
