//! One SWAP iteration (paper Eq. 7) as a bandit search.
//!
//! Two entry points: [`swap_step`] is the standalone (seed-compatible)
//! iteration that draws a fresh reference permutation per call;
//! [`swap_step_session`] runs the same search through a [`SwapSession`],
//! which pins one permutation for the whole SWAP phase and — when reuse is
//! enabled — serves repeated pulls from its cross-iteration row cache
//! (BanditPAM++-style; see `coordinator::session`).

use crate::bandits::adaptive::{adaptive_search, AdaptiveOutcome, ArmSet};
use crate::coordinator::arms::{SwapArms, VirtualSwapArms};
use crate::coordinator::config::BanditPamConfig;
use crate::coordinator::session::SwapSession;
use crate::coordinator::state::MedoidState;
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;

/// Outcome of one SWAP iteration.
#[derive(Debug)]
pub struct SwapStep {
    /// `Some((medoid_position, new_point))` when an improving swap was
    /// found and applied; `None` when PAM has converged.
    pub applied: Option<(usize, usize)>,
    /// Exact mean loss delta of the best arm (negative = improvement).
    pub best_delta: f64,
    pub outcome: AdaptiveOutcome,
}

/// Shared search tail of both entry points: run Algorithm 1 over `arms`,
/// verify the winner exactly (the sampled estimate can be noisy near
/// convergence, and PAM's termination rule — "swap while it improves" —
/// needs the true sign), and decode it. One implementation so the reuse
/// and non-reuse legs cannot silently diverge.
fn search_winner<A: ArmSet>(
    arms: &mut A,
    decode: fn(&A, usize) -> (usize, usize),
    n: usize,
    cfg: &BanditPamConfig,
    rng: &mut Rng,
) -> (usize, usize, f64, AdaptiveOutcome) {
    let acfg = cfg.adaptive(arms.n_arms(), n, Some(-cfg.swap_tolerance));
    let outcome = adaptive_search(arms, &acfg, rng);
    let best_delta = arms.exact(outcome.best);
    let (x, m_pos) = decode(arms, outcome.best);
    (m_pos, x, best_delta, outcome)
}

/// Find the best (medoid, candidate) swap with Algorithm 1; verify the
/// winner's exact loss delta; apply it when it improves by more than
/// `cfg.swap_tolerance`.
pub fn swap_step(
    backend: &dyn DistanceBackend,
    state: &mut MedoidState,
    cfg: &BanditPamConfig,
    rng: &mut Rng,
) -> SwapStep {
    let (m_pos, x, best_delta, outcome) = {
        let mut arms = SwapArms::new(backend, state, cfg.fastpam1_swap);
        search_winner(&mut arms, SwapArms::decode, backend.n(), cfg, rng)
    };
    if best_delta < -cfg.swap_tolerance {
        state.apply_swap(backend, m_pos, x);
        SwapStep { applied: Some((m_pos, x)), best_delta, outcome }
    } else {
        SwapStep { applied: None, best_delta, outcome }
    }
}

/// One SWAP iteration through a [`SwapSession`]: the same Algorithm-1
/// search and exact winner verification as [`swap_step`], but the
/// reference permutation is the session's (fixed for the whole SWAP
/// phase), and with reuse enabled the pulls, the exact means and the
/// post-swap rebuild are all served from the session's cross-iteration
/// row cache. Enabling/disabling reuse changes only the evaluation
/// count, never the trajectory (see `coordinator::session`).
pub fn swap_step_session(
    backend: &dyn DistanceBackend,
    state: &mut MedoidState,
    session: &mut SwapSession,
    cfg: &BanditPamConfig,
    rng: &mut Rng,
) -> SwapStep {
    session.begin_iteration();
    let reuse = session.rows_enabled();
    let (m_pos, x, best_delta, outcome) = if reuse {
        let mut arms = VirtualSwapArms::new(backend, state, session);
        search_winner(&mut arms, VirtualSwapArms::decode, backend.n(), cfg, rng)
    } else {
        let mut arms = SwapArms::new(backend, state, cfg.fastpam1_swap)
            .with_shared_perm(session.shared_perm());
        search_winner(&mut arms, SwapArms::decode, backend.n(), cfg, rng)
    };
    if best_delta < -cfg.swap_tolerance {
        if reuse {
            session.apply_swap(backend, state, m_pos, x);
        } else {
            state.apply_swap(backend, m_pos, x);
        }
        SwapStep { applied: Some((m_pos, x)), best_delta, outcome }
    } else {
        SwapStep { applied: None, best_delta, outcome }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::build::build_phase;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn swap_never_increases_loss() {
        let ds = synthetic::gmm(&mut Rng::seed_from(11), 50, 4, 3, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(50);
        let mut rng = Rng::seed_from(2);
        let cfg = BanditPamConfig::default();
        // deliberately bad init: first 3 points
        for m in 0..3 {
            state.add_medoid(&backend, m);
        }
        let mut prev = state.loss();
        for _ in 0..10 {
            let step = swap_step(&backend, &mut state, &cfg, &mut rng);
            let now = state.loss();
            assert!(now <= prev + 1e-9, "loss increased: {prev} -> {now}");
            prev = now;
            if step.applied.is_none() {
                break;
            }
        }
    }

    #[test]
    fn session_swap_matches_non_reuse_session_swap_exactly() {
        // The tentpole parity claim at unit scale: the same SwapSession
        // permutation with row reuse on vs off yields bitwise-identical
        // trajectories; reuse only reduces the evaluation count.
        let ds = synthetic::gmm(&mut Rng::seed_from(14), 60, 5, 3, 2.0);
        let run = |reuse: bool| {
            let backend = NativeBackend::new(&ds.points, Metric::L2);
            let cfg = BanditPamConfig { swap_reuse: reuse, ..BanditPamConfig::default() };
            let mut state = MedoidState::empty(60);
            for m in 0..3 {
                state.add_medoid(&backend, m);
            }
            let mut rng = Rng::seed_from(4);
            let mut session = SwapSession::new(60, 3, &cfg, &mut rng);
            let mut applied = Vec::new();
            for _ in 0..12 {
                let step = swap_step_session(&backend, &mut state, &mut session, &cfg, &mut rng);
                match step.applied {
                    Some(s) => applied.push(s),
                    None => break,
                }
            }
            (applied, state.medoids.clone(), state.loss(), backend.counter().get())
        };
        let (applied_on, meds_on, loss_on, evals_on) = run(true);
        let (applied_off, meds_off, loss_off, evals_off) = run(false);
        assert_eq!(applied_on, applied_off, "identical swap sequences");
        assert_eq!(meds_on, meds_off);
        assert_eq!(loss_on.to_bits(), loss_off.to_bits());
        assert!(
            evals_on <= evals_off,
            "reuse must not cost extra evals: {evals_on} vs {evals_off}"
        );
    }

    #[test]
    fn session_swap_never_increases_loss() {
        let ds = synthetic::gmm(&mut Rng::seed_from(15), 50, 4, 3, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let cfg = BanditPamConfig::default();
        let mut state = MedoidState::empty(50);
        for m in 0..3 {
            state.add_medoid(&backend, m);
        }
        let mut rng = Rng::seed_from(5);
        let mut session = SwapSession::new(50, 3, &cfg, &mut rng);
        let mut prev = state.loss();
        for _ in 0..10 {
            let step = swap_step_session(&backend, &mut state, &mut session, &cfg, &mut rng);
            let now = state.loss();
            assert!(now <= prev + 1e-9, "loss increased: {prev} -> {now}");
            state.check_invariants(&backend);
            prev = now;
            if step.applied.is_none() {
                break;
            }
        }
    }

    #[test]
    fn converged_state_reports_no_swap() {
        let ds = synthetic::gmm(&mut Rng::seed_from(12), 40, 4, 2, 5.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(40);
        let mut rng = Rng::seed_from(3);
        let cfg = BanditPamConfig::default();
        build_phase(&backend, &mut state, 2, &cfg, &mut rng);
        // run to convergence
        let mut converged = false;
        for _ in 0..20 {
            if swap_step(&backend, &mut state, &cfg, &mut rng).applied.is_none() {
                converged = true;
                break;
            }
        }
        assert!(converged);
        // a converged state must again report no swap
        let again = swap_step(&backend, &mut state, &cfg, &mut rng);
        assert!(again.applied.is_none());
        assert!(again.best_delta >= -1e-9);
    }
}
