//! One SWAP iteration (paper Eq. 7) as a bandit search.

use crate::bandits::adaptive::{adaptive_search, AdaptiveOutcome, ArmSet};
use crate::coordinator::arms::SwapArms;
use crate::coordinator::config::BanditPamConfig;
use crate::coordinator::state::MedoidState;
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;

/// Outcome of one SWAP iteration.
#[derive(Debug)]
pub struct SwapStep {
    /// `Some((medoid_position, new_point))` when an improving swap was
    /// found and applied; `None` when PAM has converged.
    pub applied: Option<(usize, usize)>,
    /// Exact mean loss delta of the best arm (negative = improvement).
    pub best_delta: f64,
    pub outcome: AdaptiveOutcome,
}

/// Find the best (medoid, candidate) swap with Algorithm 1; verify the
/// winner's exact loss delta; apply it when it improves by more than
/// `cfg.swap_tolerance`.
pub fn swap_step(
    backend: &dyn DistanceBackend,
    state: &mut MedoidState,
    cfg: &BanditPamConfig,
    rng: &mut Rng,
) -> SwapStep {
    let (m_pos, x, best_delta, outcome) = {
        let mut arms = SwapArms::new(backend, state, cfg.fastpam1_swap);
        let acfg = cfg.adaptive(arms.n_arms(), backend.n(), Some(-cfg.swap_tolerance));
        let outcome = adaptive_search(&mut arms, &acfg, rng);
        // Verify exactly before committing (n evaluations) — the sampled
        // estimate can be noisy near convergence, and PAM's termination
        // rule ("swap while it improves") needs the true sign.
        let best_delta = arms.exact(outcome.best);
        let (x, m_pos) = arms.decode(outcome.best);
        (m_pos, x, best_delta, outcome)
    };
    if best_delta < -cfg.swap_tolerance {
        state.apply_swap(backend, m_pos, x);
        SwapStep { applied: Some((m_pos, x)), best_delta, outcome }
    } else {
        SwapStep { applied: None, best_delta, outcome }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::build::build_phase;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn swap_never_increases_loss() {
        let ds = synthetic::gmm(&mut Rng::seed_from(11), 50, 4, 3, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(50);
        let mut rng = Rng::seed_from(2);
        let cfg = BanditPamConfig::default();
        // deliberately bad init: first 3 points
        for m in 0..3 {
            state.add_medoid(&backend, m);
        }
        let mut prev = state.loss();
        for _ in 0..10 {
            let step = swap_step(&backend, &mut state, &cfg, &mut rng);
            let now = state.loss();
            assert!(now <= prev + 1e-9, "loss increased: {prev} -> {now}");
            prev = now;
            if step.applied.is_none() {
                break;
            }
        }
    }

    #[test]
    fn converged_state_reports_no_swap() {
        let ds = synthetic::gmm(&mut Rng::seed_from(12), 40, 4, 2, 5.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut state = MedoidState::empty(40);
        let mut rng = Rng::seed_from(3);
        let cfg = BanditPamConfig::default();
        build_phase(&backend, &mut state, 2, &cfg, &mut rng);
        // run to convergence
        let mut converged = false;
        for _ in 0..20 {
            if swap_step(&backend, &mut state, &cfg, &mut rng).applied.is_none() {
                converged = true;
                break;
            }
        }
        assert!(converged);
        // a converged state must again report no swap
        let again = swap_step(&backend, &mut state, &cfg, &mut rng);
        assert!(again.applied.is_none());
        assert!(again.best_delta >= -1e-9);
    }
}
