//! Aligned-text experiment tables (what the bench binaries print).

use std::fmt::Write as _;

/// A simple right-aligned table with a title and column headers.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1).max(0);
        writeln!(out, "\n== {} ==", self.title).unwrap();
        for (i, h) in self.headers.iter().enumerate() {
            write!(out, "{:>w$}{}", h, if i + 1 == ncol { "\n" } else { "  " }, w = widths[i]).unwrap();
        }
        writeln!(out, "{}", "-".repeat(total)).unwrap();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                write!(out, "{:>w$}{}", c, if i + 1 == ncol { "\n" } else { "  " }, w = widths[i]).unwrap();
            }
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV rendering (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        writeln!(out, "{}", self.headers.join(",")).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", row.join(",")).unwrap();
        }
        out
    }
}

/// Format a float compactly for table cells.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1e6 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_alignment_and_csv() {
        let mut t = Table::new("demo", &["n", "value"]);
        t.row(vec!["10".into(), "1.5".into()]);
        t.row(vec!["1000".into(), "2.25".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("1000"));
        let csv = t.to_csv();
        assert!(csv.starts_with("n,value\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(2.0e7), "2.000e7");
        assert_eq!(fnum(123.456), "123.5");
        assert_eq!(fnum(1.23456), "1.2346");
    }
}
