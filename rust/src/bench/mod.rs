//! Mini-criterion: the in-tree bench harness (`criterion` is not in the
//! offline cache).
//!
//! Used by the `harness = false` targets in `rust/benches/`. Provides
//! timed repetition with warmup ([`bench_fn`]) and, more importantly for
//! this paper, *experiment tables*: each paper figure's bench prints the
//! same rows the figure plots (sample size, evals/iteration, runtime/
//! iteration, fitted log–log slope) via [`table::Table`].

pub mod report;
pub mod table;

use crate::stats::summary::mean_ci95;
use crate::util::timer::Timer;

/// Result of a micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub ci95_secs: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>12}/iter ± {:<10} ({} iters)",
            self.name,
            crate::util::timer::fmt_duration(self.mean_secs),
            crate::util::timer::fmt_duration(self.ci95_secs),
            self.iters
        )
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `iters` measured
/// runs; returns mean ± 95% CI.
pub fn bench_fn<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        std::hint::black_box(f());
        times.push(t.secs());
    }
    let (mean, ci) = mean_ci95(&times);
    BenchResult { name: name.to_string(), iters, mean_secs: mean, ci95_secs: ci }
}

/// Scale knob shared by all bench binaries: `BANDITPAM_BENCH_SCALE` may be
/// `smoke` (tiny; used by `cargo test --benches` sanity runs), `quick`
/// (default for `cargo bench`; minutes) or `paper` (the full sweep sizes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Quick,
    Paper,
}

impl Scale {
    /// Read from the environment (default `Quick`).
    pub fn from_env() -> Scale {
        match std::env::var("BANDITPAM_BENCH_SCALE").as_deref() {
            Ok("smoke") => Scale::Smoke,
            Ok("paper") => Scale::Paper,
            _ => Scale::Quick,
        }
    }

    /// Pick one of three values by scale.
    pub fn pick<T: Copy>(&self, smoke: T, quick: T, paper: T) -> T {
        match self {
            Scale::Smoke => smoke,
            Scale::Quick => quick,
            Scale::Paper => paper,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_reports_positive_mean() {
        let r = bench_fn("spin", 1, 5, || (0..10_000u64).sum::<u64>());
        assert!(r.mean_secs >= 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.line().contains("spin"));
    }

    #[test]
    fn scale_pick() {
        assert_eq!(Scale::Smoke.pick(1, 2, 3), 1);
        assert_eq!(Scale::Quick.pick(1, 2, 3), 2);
        assert_eq!(Scale::Paper.pick(1, 2, 3), 3);
    }
}
