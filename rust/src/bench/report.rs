//! Unified `BENCH_*.json` envelope: one emitter for every bench binary.
//!
//! Before this module each bench hand-rolled its own JSON (or printed
//! tables only), so the cross-PR bench trajectory could not be compared
//! mechanically. Every artifact now shares one envelope:
//!
//! ```json
//! {
//!   "schema": 1,
//!   "bench": "serve",
//!   "scale": "smoke",
//!   "params": { ... },
//!   "metrics": { <obs::global() snapshot at write time> },
//!   "data": [ <the bench's own rows, fields unchanged> ]
//! }
//! ```
//!
//! The pre-envelope payload rows live unchanged under `data`, so existing
//! consumers only need to unwrap one level. `metrics` embeds the process
//! metrics snapshot ([`crate::obs::MetricsRegistry::snapshot_json`]) —
//! benches are one process per run, so the snapshot is the run's own
//! telemetry (kernel block counts, serve latency histograms, ...).

use std::fmt::Write as _;
use std::path::PathBuf;

use crate::bench::{table::Table, Scale};
use crate::util::json::escape;

/// Envelope schema version; bump on breaking shape changes.
pub const SCHEMA_VERSION: u32 = 1;

/// Ordered JSON object builder (insertion order preserved — unlike
/// `util::json::Json::Obj`, which sorts keys — so rows read in the order
/// the bench wrote them).
#[derive(Debug, Clone, Default)]
pub struct JsonObj {
    fields: Vec<(String, String)>,
}

impl JsonObj {
    /// Empty object.
    pub fn new() -> JsonObj {
        JsonObj::default()
    }

    /// String field (escaped).
    pub fn str(mut self, key: &str, value: &str) -> JsonObj {
        self.fields.push((key.to_string(), format!("\"{}\"", escape(value))));
        self
    }

    /// Unsigned integer field.
    pub fn u64(mut self, key: &str, value: u64) -> JsonObj {
        self.fields.push((key.to_string(), value.to_string()));
        self
    }

    /// Float field (Rust's shortest-roundtrip rendering; non-finite
    /// values become `null`).
    pub fn f64(mut self, key: &str, value: f64) -> JsonObj {
        let rendered = if value.is_finite() { format!("{value}") } else { "null".to_string() };
        self.fields.push((key.to_string(), rendered));
        self
    }

    /// Boolean field.
    pub fn bool(mut self, key: &str, value: bool) -> JsonObj {
        self.fields.push((key.to_string(), value.to_string()));
        self
    }

    /// Pre-rendered JSON fragment (caller guarantees validity).
    pub fn raw(mut self, key: &str, rendered_json: String) -> JsonObj {
        self.fields.push((key.to_string(), rendered_json));
        self
    }

    /// Render as a JSON object.
    pub fn render(&self) -> String {
        let mut out = String::from("{");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "\"{}\": {v}", escape(k));
        }
        out.push('}');
        out
    }
}

/// Builder for one `BENCH_<name>.json` artifact.
#[derive(Debug, Clone)]
pub struct Report {
    name: String,
    scale: Option<Scale>,
    params: JsonObj,
    rows: Vec<String>,
}

impl Report {
    /// Report writing to `BENCH_<name>.json` in the working directory.
    pub fn new(name: &str) -> Report {
        Report { name: name.to_string(), scale: None, params: JsonObj::new(), rows: Vec::new() }
    }

    /// Record the bench scale in the envelope.
    pub fn scale(mut self, scale: Scale) -> Report {
        self.scale = Some(scale);
        self
    }

    /// Set the `params` object (dataset sizes, thread counts, ...).
    pub fn params(mut self, params: JsonObj) -> Report {
        self.params = params;
        self
    }

    /// Append one payload row under `data`.
    pub fn row(&mut self, row: JsonObj) {
        self.rows.push(row.render());
    }

    /// Append an experiment table: one row per table row, cells keyed by
    /// header, plus a `"table"` field carrying the title. All cells are
    /// strings (tables are already formatted for humans); consumers that
    /// need numbers parse them.
    pub fn table(&mut self, table: &Table) {
        for row in &table.rows {
            let mut obj = JsonObj::new().str("table", &table.title);
            for (header, cell) in table.headers.iter().zip(row) {
                obj = obj.str(header, cell);
            }
            self.rows.push(obj.render());
        }
    }

    /// Rows appended so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// No rows yet?
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the full envelope (metrics snapshot taken now).
    pub fn render(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"schema\": {SCHEMA_VERSION},");
        let _ = writeln!(out, "  \"bench\": \"{}\",", escape(&self.name));
        if let Some(scale) = self.scale {
            let scale_name = match scale {
                Scale::Smoke => "smoke",
                Scale::Quick => "quick",
                Scale::Paper => "paper",
            };
            let _ = writeln!(out, "  \"scale\": \"{scale_name}\",");
        }
        let _ = writeln!(out, "  \"params\": {},", self.params.render());
        let _ = writeln!(out, "  \"metrics\": {},", crate::obs::global().snapshot_json());
        if self.rows.is_empty() {
            out.push_str("  \"data\": []\n");
        } else {
            let _ = writeln!(out, "  \"data\": [\n    {}\n  ]", self.rows.join(",\n    "));
        }
        out.push_str("}\n");
        out
    }

    /// Write `BENCH_<name>.json`; returns the path. Prints a one-line
    /// confirmation (or the error) like the hand-rolled writers did.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = PathBuf::from(format!("BENCH_{}.json", self.name));
        let body = self.render();
        match std::fs::write(&path, &body) {
            Ok(()) => {
                println!("wrote {}", path.display());
                Ok(path)
            }
            Err(e) => {
                println!("{}: write failed ({e})", path.display());
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn json_obj_preserves_order_and_escapes() {
        let obj = JsonObj::new()
            .str("name", "a \"b\"")
            .u64("n", 5)
            .f64("x", 1.5)
            .f64("bad", f64::INFINITY)
            .bool("ok", true)
            .raw("inner", "[1, 2]".to_string());
        let rendered = obj.render();
        assert!(
            rendered.starts_with("{\"name\": \"a \\\"b\\\"\", \"n\": 5"),
            "{rendered}"
        );
        let v = Json::parse(&rendered).expect("valid json");
        assert_eq!(v.get("n"), Some(&Json::Num(5.0)));
        assert_eq!(v.get("bad"), Some(&Json::Null));
        assert_eq!(v.get("inner"), Some(&Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])));
    }

    #[test]
    fn envelope_has_schema_bench_params_metrics_data() {
        let mut r = Report::new("unit_test")
            .scale(Scale::Smoke)
            .params(JsonObj::new().u64("n", 100));
        r.row(JsonObj::new().str("kind", "fit").f64("loss", 3.25));
        r.row(JsonObj::new().str("kind", "fit").f64("loss", 1.0));
        assert_eq!(r.len(), 2);
        let v = Json::parse(&r.render()).expect("envelope is valid JSON");
        assert_eq!(v.get("schema"), Some(&Json::Num(SCHEMA_VERSION as f64)));
        assert_eq!(v.get("bench"), Some(&Json::Str("unit_test".into())));
        assert_eq!(v.get("scale"), Some(&Json::Str("smoke".into())));
        assert_eq!(v.get("params").and_then(|p| p.get("n")), Some(&Json::Num(100.0)));
        assert!(v.get("metrics").is_some(), "metrics snapshot embedded");
        let data = v.get("data").and_then(|d| d.as_arr()).expect("data array");
        assert_eq!(data.len(), 2);
        assert_eq!(data[0].get("loss"), Some(&Json::Num(3.25)));
    }

    #[test]
    fn table_rows_are_keyed_by_header() {
        let mut t = Table::new("demo", &["algo", "loss"]);
        t.row(vec!["pam".into(), "1.5".into()]);
        let mut r = Report::new("unit_test_table");
        r.table(&t);
        let v = Json::parse(&r.render()).unwrap();
        let data = v.get("data").and_then(|d| d.as_arr()).unwrap();
        assert_eq!(data[0].get("table"), Some(&Json::Str("demo".into())));
        assert_eq!(data[0].get("algo"), Some(&Json::Str("pam".into())));
        assert_eq!(data[0].get("loss"), Some(&Json::Str("1.5".into())));
    }
}
