//! Partitioning Around Medoids (Kaufman & Rousseeuw [19, 20]) — the
//! clustering-quality reference of the paper.
//!
//! BUILD: greedy exact assignment (Eq. 4), k passes.
//! SWAP: exhaustive best-pair search over all k(n−k) swaps (Eq. 5),
//! repeated until no swap improves the loss.
//!
//! Like the reference implementations the paper compares against, PAM here
//! precomputes the full n² distance matrix (counted); each SWAP iteration
//! then touches k·n² cached summands. The per-pair loop recomputes the
//! delta for every medoid `m` separately — FastPAM1 (same trajectory)
//! removes exactly that factor-k redundancy.

use crate::algorithms::matrix_cache::{
    exact_build, finalize_from_state, swap_delta, FullMatrix, MatState,
};
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Exact PAM.
#[derive(Debug)]
pub struct Pam {
    /// Cap on SWAP iterations (the paper's T; usize::MAX = until converged).
    pub max_swap_iters: usize,
}

impl Pam {
    pub fn new() -> Pam {
        Pam { max_swap_iters: 100 }
    }
}

/// `derive(Default)` would zero `max_swap_iters` and silently skip the
/// SWAP phase; delegate to [`Pam::new`] instead.
impl Default for Pam {
    fn default() -> Pam {
        Pam::new()
    }
}

/// Shared PAM/FastPAM1 swap loop. `per_medoid` selects the iteration
/// order: PAM loops pairs (m, x) recomputing per m; FastPAM1 loops x once
/// computing all m simultaneously. Both choose the identical best pair
/// (ties broken toward the lexicographically smallest (x, m_pos)).
pub(crate) fn swap_until_converged(
    m: &FullMatrix,
    state: &mut MatState,
    max_iters: usize,
) -> (usize, usize) {
    let n = m.n();
    let mut iters = 0;
    let mut applied = 0;
    while iters < max_iters {
        iters += 1;
        let mut best = (f64::NEG_INFINITY, usize::MAX, usize::MAX); // (-delta, x, m)
        let mut found = false;
        for x in 0..n {
            if state.medoids.contains(&x) {
                continue;
            }
            for m_pos in 0..state.medoids.len() {
                let delta = swap_delta(m, state, m_pos, x);
                if -delta > best.0 + 1e-15 {
                    best = (-delta, x, m_pos);
                    found = true;
                }
            }
        }
        if !found || best.0 <= 1e-12 {
            break;
        }
        state.medoids[best.2] = best.1;
        state.rebuild(m);
        applied += 1;
    }
    (iters, applied)
}

impl KMedoids for Pam {
    fn name(&self) -> &'static str {
        "pam"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        _rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let m = FullMatrix::compute(backend);
        let mut state = MatState::empty(backend.n());
        exact_build(&m, k, &mut state);
        let build_evals = backend.counter().get() - start;
        let (iters, applied) = swap_until_converged(&m, &mut state, self.max_swap_iters);
        let stats = FitStats {
            build_evals,
            swap_evals: backend.counter().get() - start - build_evals,
            swap_iters: iters,
            swaps_applied: applied,
            iters_plus_one: iters + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(finalize_from_state(backend, &m, state, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn pam_finds_obvious_clusters() {
        let ds = synthetic::gmm(&mut Rng::seed_from(20), 60, 4, 3, 10.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = Pam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
        assert_eq!(fit.medoids.len(), 3);
        // with separation 10 the three medoids should come from 3 components
        let labels = ds.labels.unwrap();
        let medoid_labels: std::collections::HashSet<_> =
            fit.medoids.iter().map(|&m| labels[m]).collect();
        assert_eq!(medoid_labels.len(), 3);
    }

    #[test]
    fn pam_loss_is_optimal_under_single_swaps() {
        // After convergence no single swap can improve (local optimality).
        let ds = synthetic::gmm(&mut Rng::seed_from(21), 40, 3, 2, 2.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = Pam::new().fit(&backend, 2, &mut Rng::seed_from(0)).unwrap();
        let m = FullMatrix::compute(&backend);
        let mut st = MatState::empty(40);
        for &med in &fit.medoids {
            st.add_medoid(&m, med);
        }
        for x in 0..40 {
            if fit.medoids.contains(&x) {
                continue;
            }
            for pos in 0..2 {
                assert!(
                    swap_delta(&m, &st, pos, x) >= -1e-9,
                    "improving swap exists: pos {pos} x {x}"
                );
            }
        }
    }

    #[test]
    fn deterministic_across_rng() {
        let ds = synthetic::gmm(&mut Rng::seed_from(22), 30, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let a = Pam::new().fit(&backend, 2, &mut Rng::seed_from(1)).unwrap();
        let b = Pam::new().fit(&backend, 2, &mut Rng::seed_from(999)).unwrap();
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.loss, b.loss);
    }

    #[test]
    fn build_evals_are_n_squared() {
        let ds = synthetic::gmm(&mut Rng::seed_from(23), 25, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = Pam::new().fit(&backend, 2, &mut Rng::seed_from(0)).unwrap();
        assert_eq!(fit.stats.build_evals, 25 * 25, "matrix precompute");
    }

    #[test]
    fn total_evals_are_exactly_n_squared() {
        // The matrix precompute is the only evaluation source: SWAP reads
        // cached entries, and the finalize path reuses the MatState d1/a1
        // instead of re-scoring with an uncounted k x n pass.
        let ds = synthetic::gmm(&mut Rng::seed_from(24), 25, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = Pam::new().fit(&backend, 2, &mut Rng::seed_from(0)).unwrap();
        assert_eq!(fit.stats.distance_evals, 25 * 25);
        assert_eq!(backend.counter().get(), 25 * 25);
    }
}
