//! FastPAM1 (Schubert & Rousseeuw [42]): PAM with the factor-k redundancy
//! removed from each SWAP iteration — **guaranteed to return the same
//! result as PAM**.
//!
//! For a candidate x, the loss deltas of all k possible swaps share the
//! distance row d(x, ·); Eq. 12 computes them in one pass using the cached
//! d1/d2/assignment arrays, so a SWAP iteration costs n² summands instead
//! of PAM's k·n². The chosen swap (and therefore the whole trajectory) is
//! identical to PAM's.

use crate::algorithms::matrix_cache::{
    exact_build, finalize_from_state, FullMatrix, MatState,
};
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// FastPAM1: exact-PAM trajectory, O(k) faster SWAP iterations.
#[derive(Debug)]
pub struct FastPam1 {
    pub max_swap_iters: usize,
}

impl FastPam1 {
    pub fn new() -> FastPam1 {
        FastPam1 { max_swap_iters: 100 }
    }
}

/// `derive(Default)` would zero `max_swap_iters` and silently skip the
/// SWAP phase; delegate to [`FastPam1::new`] instead.
impl Default for FastPam1 {
    fn default() -> FastPam1 {
        FastPam1::new()
    }
}

/// One FastPAM1 sweep: best (x, m_pos) over all candidates, computing all
/// k deltas per candidate in a single pass over its distance row (Eq. 12).
pub fn best_swap_eq12(
    m: &FullMatrix,
    state: &MatState,
    deltas: &mut Vec<f64>,
) -> (f64, usize, usize) {
    let n = m.n();
    let k = state.medoids.len();
    let mut best = (f64::INFINITY, usize::MAX, usize::MAX); // (delta, x, m_pos)
    for x in 0..n {
        if state.medoids.contains(&x) {
            continue;
        }
        deltas.clear();
        deltas.resize(k, 0.0);
        let row = m.row(x);
        // Eq. 12: delta_m = sum_j -d1_j + [j notin C_m] min(d1_j, d) +
        //                                [j    in C_m] min(d2_j, d)
        // computed as: shared = sum_j (min(d1_j, d) - d1_j);
        // delta_m += sum_{j in C_m} (min(d2_j, d) - min(d1_j, d)).
        let mut shared = 0.0;
        for j in 0..n {
            let d = row[j];
            let m1 = state.d1[j].min(d);
            shared += m1 - state.d1[j];
            let a = state.a1[j];
            if a < k {
                deltas[a] += state.d2[j].min(d) - m1;
            }
        }
        for (m_pos, extra) in deltas.iter().enumerate() {
            let delta = shared + extra;
            if delta < best.0 - 1e-15 {
                best = (delta, x, m_pos);
            }
        }
    }
    best
}

impl KMedoids for FastPam1 {
    fn name(&self) -> &'static str {
        "fastpam1"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        _rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let m = FullMatrix::compute(backend);
        let mut state = MatState::empty(backend.n());
        exact_build(&m, k, &mut state);
        let build_evals = backend.counter().get() - start;

        let mut iters = 0;
        let mut applied = 0;
        let mut deltas = Vec::new();
        while iters < self.max_swap_iters {
            iters += 1;
            let (delta, x, m_pos) = best_swap_eq12(&m, &state, &mut deltas);
            if !(delta < -1e-12) {
                break;
            }
            state.medoids[m_pos] = x;
            state.rebuild(&m);
            applied += 1;
        }
        let stats = FitStats {
            build_evals,
            swap_evals: backend.counter().get() - start - build_evals,
            swap_iters: iters,
            swaps_applied: applied,
            iters_plus_one: iters + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(finalize_from_state(backend, &m, state, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn fastpam1_identical_to_pam() {
        // The defining property: same final medoids as PAM, always.
        for seed in 0..6 {
            let ds = synthetic::gmm(&mut Rng::seed_from(300 + seed), 50, 4, 3, 2.0);
            let backend = NativeBackend::new(&ds.points, Metric::L2);
            let pam = Pam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
            let fp1 = FastPam1::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
            assert_eq!(pam.medoids, fp1.medoids, "seed {seed}");
            assert!((pam.loss - fp1.loss).abs() < 1e-9);
        }
    }

    #[test]
    fn fastpam1_also_identical_on_l1_and_cosine() {
        for metric in [Metric::L1, Metric::Cosine] {
            let ds = synthetic::gmm(&mut Rng::seed_from(42), 40, 6, 2, 2.0);
            let backend = NativeBackend::new(&ds.points, metric);
            let pam = Pam::new().fit(&backend, 2, &mut Rng::seed_from(0)).unwrap();
            let fp1 = FastPam1::new().fit(&backend, 2, &mut Rng::seed_from(0)).unwrap();
            assert_eq!(pam.medoids, fp1.medoids, "{metric}");
        }
    }

    #[test]
    fn total_evals_are_exactly_n_squared() {
        // Matrix precompute only; the finalize path reuses the cached
        // d1/a1 instead of re-running loss_and_assignments uncounted.
        let ds = synthetic::gmm(&mut Rng::seed_from(44), 30, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = FastPam1::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
        assert_eq!(fit.stats.distance_evals, 30 * 30);
        assert_eq!(backend.counter().get(), 30 * 30);
    }

    #[test]
    fn eq12_matches_direct_delta() {
        use crate::algorithms::matrix_cache::swap_delta;
        let ds = synthetic::gmm(&mut Rng::seed_from(43), 30, 4, 2, 2.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let m = FullMatrix::compute(&backend);
        let mut st = MatState::empty(30);
        exact_build(&m, 2, &mut st);
        let mut deltas = Vec::new();
        let (best_delta, x, m_pos) = best_swap_eq12(&m, &st, &mut deltas);
        if x != usize::MAX {
            let direct = swap_delta(&m, &st, m_pos, x);
            assert!((best_delta - direct).abs() < 1e-9);
        }
    }
}
