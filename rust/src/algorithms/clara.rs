//! CLARA (Kaufman & Rousseeuw [20]): PAM on random subsamples.
//!
//! Draws `samples` subsets of size `40 + 2k` (the classical default), runs
//! exact PAM on each, evaluates each candidate medoid set on the *full*
//! dataset, and keeps the best. Fast but sacrifices quality — in the
//! paper's taxonomy it belongs to the "trade quality for runtime" family
//! CLARANS also lives in.
//!
//! The evaluation path is the tiled [`loss_and_assignments_with`]
//! primitive (one reused `k x REF_TILE` scratch across samples, not a
//! fresh `k x n` block per sample), and the winning sample's loss and
//! assignments are threaded through [`Clustering::finalize_with`], so the
//! full-dataset `n x k` pass runs exactly once per candidate — never a
//! second time for the winner.

use crate::algorithms::matrix_cache::{exact_build, FullMatrix, MatState};
use crate::algorithms::pam::swap_until_converged;
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::runtime::backend::{loss_and_assignments_with, DistanceBackend, EvalBuffers};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// CLARA with the classical sampling defaults.
#[derive(Debug)]
pub struct Clara {
    /// Number of subsamples (classic: 5).
    pub samples: usize,
    /// Sample size override; 0 = classic `40 + 2k`.
    pub sample_size: usize,
}

impl Default for Clara {
    fn default() -> Self {
        Clara { samples: 5, sample_size: 0 }
    }
}

impl Clara {
    pub fn new() -> Clara {
        Clara::default()
    }
}

/// The effective subsample size: the classical `40 + 2k` default (or the
/// explicit override), clamped to `n`. Shared with the BigFit outer loop
/// so both spellings of "CLARA-style sampling" agree.
pub(crate) fn effective_sample_size(sample_size: usize, k: usize, n: usize) -> usize {
    if sample_size == 0 {
        (40 + 2 * k).min(n)
    } else {
        sample_size.min(n)
    }
}

impl KMedoids for Clara {
    fn name(&self) -> &'static str {
        "clara"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let n = backend.n();
        let ssize = effective_sample_size(self.sample_size, k, n);
        if ssize <= k {
            return Err(crate::error::Error::invalid_argument(format!(
                "sample size {ssize} must exceed k {k}"
            )));
        }

        let counter = backend.counter();
        let mut bufs = EvalBuffers::new();
        let mut best: Option<(f64, Vec<usize>, Vec<usize>)> = None;
        let mut build_evals = 0u64;
        let mut eval_evals = 0u64;
        let mut swap_iters = 0usize;
        let mut swaps_applied = 0usize;
        for _ in 0..self.samples {
            let subset = rng.sample_indices(n, ssize);
            // Fit the subsample (exact PAM over its cached pair matrix).
            let fit_start = counter.get();
            let m = FullMatrix::compute_subset(backend, &subset);
            let mut st = MatState::empty(ssize);
            exact_build(&m, k, &mut st);
            let (iters, applied) = swap_until_converged(&m, &mut st, 100);
            build_evals += counter.get() - fit_start;
            swap_iters += iters;
            swaps_applied += applied;
            // Map to global indices, sorted ascending — the order the
            // final assignments must index.
            let mut medoids: Vec<usize> = st.medoids.iter().map(|&i| subset[i]).collect();
            medoids.sort_unstable();
            // Score on the full dataset (k*n evaluations) through the
            // reused tile; memory is bounded by the tile, not by n.
            let eval_start = counter.get();
            let (loss, assignments) = loss_and_assignments_with(backend, &medoids, &mut bufs);
            eval_evals += counter.get() - eval_start;
            if best.as_ref().map(|(l, _, _)| loss < *l).unwrap_or(true) {
                best = Some((loss, medoids, assignments));
            }
        }

        let (loss, medoids, assignments) = best.unwrap();
        let stats = FitStats {
            build_evals,
            eval_evals,
            samples: self.samples,
            swap_iters,
            swaps_applied,
            iters_plus_one: swap_iters + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        // The winner's loss/assignments were already computed above —
        // finalize without repeating the n x k pass.
        Ok(Clustering::finalize_with(backend, medoids, loss, assignments, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn clara_returns_valid_clustering() {
        let ds = synthetic::gmm(&mut Rng::seed_from(50), 200, 4, 3, 4.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = Clara::new().fit(&backend, 3, &mut Rng::seed_from(1)).unwrap();
        assert_eq!(fit.medoids.len(), 3);
        let set: std::collections::HashSet<_> = fit.medoids.iter().collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn clara_uses_far_fewer_evals_than_pam() {
        let ds = synthetic::gmm(&mut Rng::seed_from(51), 300, 4, 3, 4.0);
        let b1 = NativeBackend::new(&ds.points, Metric::L2);
        let pam = Pam::new().fit(&b1, 3, &mut Rng::seed_from(0)).unwrap();
        let b2 = NativeBackend::new(&ds.points, Metric::L2);
        let clara = Clara::new().fit(&b2, 3, &mut Rng::seed_from(1)).unwrap();
        assert!(clara.stats.distance_evals < pam.stats.distance_evals / 4);
        // quality is worse-or-equal but not catastrophic on easy data
        assert!(clara.loss >= pam.loss * 0.999);
        assert!(clara.loss <= pam.loss * 1.5, "{} vs {}", clara.loss, pam.loss);
    }

    #[test]
    fn sample_size_larger_than_n_is_clamped() {
        let ds = synthetic::gmm(&mut Rng::seed_from(52), 30, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut clara = Clara { samples: 2, sample_size: 500 };
        let fit = clara.fit(&backend, 2, &mut Rng::seed_from(2)).unwrap();
        assert_eq!(fit.medoids.len(), 2);
    }

    /// The winner is evaluated on the full dataset exactly once: the
    /// backend counter must read samples * (ssize^2 + k*n) on the nose —
    /// the subsample pair matrices plus one scoring pass per candidate,
    /// with no extra pass for the winning sample at finalize.
    #[test]
    fn clara_evaluates_each_candidate_exactly_once() {
        let (n, k, samples) = (150usize, 3usize, 4usize);
        let ds = synthetic::gmm(&mut Rng::seed_from(53), n, 4, k, 4.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut clara = Clara { samples, sample_size: 0 };
        let fit = clara.fit(&backend, k, &mut Rng::seed_from(3)).unwrap();
        let ssize = 40 + 2 * k;
        let expect = (samples * (ssize * ssize + k * n)) as u64;
        assert_eq!(backend.counter().get(), expect, "one full-dataset pass per candidate");
        assert_eq!(fit.stats.distance_evals, expect);
    }

    /// Stats land in the right fields: subsample fits in `build_evals`,
    /// full-dataset scoring in `eval_evals`, the sample count in
    /// `samples` (not `swap_iters`, which now counts inner PAM SWAP
    /// iterations honestly).
    #[test]
    fn clara_stats_attribute_work_honestly() {
        let (n, k, samples) = (120usize, 2usize, 5usize);
        let ds = synthetic::gmm(&mut Rng::seed_from(54), n, 4, k, 4.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = Clara::new().fit(&backend, k, &mut Rng::seed_from(4)).unwrap();
        let ssize = 40 + 2 * k;
        assert_eq!(fit.stats.build_evals, (samples * ssize * ssize) as u64);
        assert_eq!(fit.stats.eval_evals, (samples * k * n) as u64);
        assert_eq!(fit.stats.samples, samples);
        assert_eq!(fit.stats.swap_evals, 0);
        assert_eq!(
            fit.stats.distance_evals,
            fit.stats.build_evals + fit.stats.eval_evals
        );
        // inner SWAP iterations, not the sample count: every sample runs
        // at least one (possibly convergence-only) iteration
        assert!(fit.stats.swap_iters >= samples);
        assert_eq!(fit.stats.iters_plus_one, fit.stats.swap_iters + 1);
        assert!(fit.stats.swaps_applied <= fit.stats.swap_iters);
    }
}
