//! CLARA (Kaufman & Rousseeuw [20]): PAM on random subsamples.
//!
//! Draws `samples` subsets of size `40 + 2k` (the classical default), runs
//! exact PAM on each, evaluates each candidate medoid set on the *full*
//! dataset, and keeps the best. Fast but sacrifices quality — in the
//! paper's taxonomy it belongs to the "trade quality for runtime" family
//! CLARANS also lives in.

use crate::algorithms::matrix_cache::{exact_build, FullMatrix, MatState};
use crate::algorithms::pam::swap_until_converged;
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// CLARA with the classical sampling defaults.
#[derive(Debug)]
pub struct Clara {
    /// Number of subsamples (classic: 5).
    pub samples: usize,
    /// Sample size override; 0 = classic `40 + 2k`.
    pub sample_size: usize,
}

impl Default for Clara {
    fn default() -> Self {
        Clara { samples: 5, sample_size: 0 }
    }
}

impl Clara {
    pub fn new() -> Clara {
        Clara::default()
    }
}

impl KMedoids for Clara {
    fn name(&self) -> &'static str {
        "clara"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let n = backend.n();
        let ssize = if self.sample_size == 0 { (40 + 2 * k).min(n) } else { self.sample_size.min(n) };
        if ssize <= k {
            return Err(crate::error::Error::invalid_argument(format!(
                "sample size {ssize} must exceed k {k}"
            )));
        }

        let mut best: Option<(f64, Vec<usize>)> = None;
        for _ in 0..self.samples {
            let subset = rng.sample_indices(n, ssize);
            let m = FullMatrix::compute_subset(backend, &subset);
            let mut st = MatState::empty(ssize);
            exact_build(&m, k, &mut st);
            swap_until_converged(&m, &mut st, 100);
            let medoids: Vec<usize> = st.medoids.iter().map(|&i| subset[i]).collect();
            // Evaluate on the full dataset (n*k evaluations).
            let mut loss = 0.0;
            let refs: Vec<usize> = (0..n).collect();
            let mut rows = vec![0.0f64; k * n];
            backend.block(&medoids, &refs, &mut rows);
            for j in 0..n {
                let mut m1 = f64::INFINITY;
                for r in 0..k {
                    m1 = m1.min(rows[r * n + j]);
                }
                loss += m1;
            }
            if best.as_ref().map(|(l, _)| loss < *l).unwrap_or(true) {
                best = Some((loss, medoids));
            }
        }

        let (_, medoids) = best.unwrap();
        let evals = backend.counter().get() - start;
        let stats = FitStats {
            build_evals: evals,
            swap_iters: self.samples,
            iters_plus_one: self.samples + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(Clustering::finalize(backend, medoids, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn clara_returns_valid_clustering() {
        let ds = synthetic::gmm(&mut Rng::seed_from(50), 200, 4, 3, 4.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = Clara::new().fit(&backend, 3, &mut Rng::seed_from(1)).unwrap();
        assert_eq!(fit.medoids.len(), 3);
        let set: std::collections::HashSet<_> = fit.medoids.iter().collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn clara_uses_far_fewer_evals_than_pam() {
        let ds = synthetic::gmm(&mut Rng::seed_from(51), 300, 4, 3, 4.0);
        let b1 = NativeBackend::new(&ds.points, Metric::L2);
        let pam = Pam::new().fit(&b1, 3, &mut Rng::seed_from(0)).unwrap();
        let b2 = NativeBackend::new(&ds.points, Metric::L2);
        let clara = Clara::new().fit(&b2, 3, &mut Rng::seed_from(1)).unwrap();
        assert!(clara.stats.distance_evals < pam.stats.distance_evals / 4);
        // quality is worse-or-equal but not catastrophic on easy data
        assert!(clara.loss >= pam.loss * 0.999);
        assert!(clara.loss <= pam.loss * 1.5, "{} vs {}", clara.loss, pam.loss);
    }

    #[test]
    fn sample_size_larger_than_n_is_clamped() {
        let ds = synthetic::gmm(&mut Rng::seed_from(52), 30, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut clara = Clara { samples: 2, sample_size: 500 };
        let fit = clara.fit(&backend, 2, &mut Rng::seed_from(2)).unwrap();
        assert_eq!(fit.medoids.len(), 2);
    }
}
