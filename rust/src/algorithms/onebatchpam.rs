//! OneBatchPAM ("OneBatchPAM: A Fast and Frugal K-Medoids Algorithm",
//! arXiv:2501.19285): PAM on a single random batch, scored once.
//!
//! CLARA re-runs PAM on several subsamples and keeps the best; OneBatchPAM
//! observes that one batch already yields a near-optimal medoid set when
//! the swap phase optimizes the *batch* objective, so it pays for exactly
//! one batch fit (batch² evaluations) plus one full-dataset scoring pass
//! (k·n through [`loss_and_assignments_with`]) — frugal in the same sense
//! as BanditPAM's sub-quadratic budget, but with a fixed, data-independent
//! eval count. The batch is drawn through the rng-lockstep
//! [`Rng::sample_indices`], so fits are byte-deterministic across thread
//! counts and reruns, and the arm composes with the BigFit outer loop
//! (`bigfit+onebatchpam`) like any other registry algorithm.

use crate::algorithms::fastpam1::best_swap_eq12;
use crate::algorithms::matrix_cache::{exact_build, FullMatrix, MatState};
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::error::Error;
use crate::runtime::backend::{loss_and_assignments_with, DistanceBackend, EvalBuffers};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// OneBatchPAM: fit on one random batch, score the full dataset once.
#[derive(Debug)]
pub struct OneBatchPam {
    /// Batch size (0 = the frugal default, [`effective_batch_size`]).
    pub batch_size: usize,
    /// Cap on FastPAM1-style swap iterations over the batch.
    pub max_swap_iters: usize,
}

impl OneBatchPam {
    pub fn new() -> OneBatchPam {
        OneBatchPam { batch_size: 0, max_swap_iters: 100 }
    }
}

/// `derive(Default)` would zero `max_swap_iters` and skip the swap phase;
/// delegate to [`OneBatchPam::new`] instead.
impl Default for OneBatchPam {
    fn default() -> OneBatchPam {
        OneBatchPam::new()
    }
}

/// The default batch size: `min(n, 100 + 5k)`. The paper argues a batch
/// size independent of `n` suffices for the batch optimum to concentrate
/// around the full-data optimum; the floor of 100 keeps small-k batches
/// from starving, and the `5k` term scales the per-cluster sample with k
/// (a denser default than CLARA's `40 + 2k` since there is only one draw).
pub fn effective_batch_size(batch_size: usize, k: usize, n: usize) -> usize {
    if batch_size == 0 {
        (100 + 5 * k).min(n)
    } else {
        batch_size.min(n)
    }
}

impl KMedoids for OneBatchPam {
    fn name(&self) -> &'static str {
        "onebatchpam"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let n = backend.n();
        let b = effective_batch_size(self.batch_size, k, n);
        if b <= k {
            return Err(Error::invalid_argument(format!(
                "onebatchpam batch size {b} must exceed k = {k}"
            )));
        }
        let timer = Timer::start();
        let start = backend.counter().get();

        // One rng-lockstep batch draw, then exact BUILD + FastPAM1 swaps
        // against the batch² distance matrix (all counted evaluations).
        let batch = rng.sample_indices(n, b);
        let m = FullMatrix::compute_subset(backend, &batch);
        let mut state = MatState::empty(b);
        exact_build(&m, k, &mut state);
        let build_evals = backend.counter().get() - start;
        let mut iters = 0;
        let mut applied = 0;
        let mut deltas = Vec::new();
        while iters < self.max_swap_iters {
            iters += 1;
            let (delta, x, m_pos) = best_swap_eq12(&m, &state, &mut deltas);
            if !(delta < -1e-12) {
                break;
            }
            state.medoids[m_pos] = x;
            state.rebuild(&m);
            applied += 1;
        }

        // Map batch-local medoids to global point indices and score the
        // full dataset exactly once (k·n evaluations; the finalize path
        // trusts this pass instead of re-running it).
        let mut medoids: Vec<usize> = state.medoids.iter().map(|&loc| batch[loc]).collect();
        medoids.sort_unstable();
        let before_eval = backend.counter().get();
        let mut buffers = EvalBuffers::new();
        let (loss, assignments) = loss_and_assignments_with(backend, &medoids, &mut buffers);
        let stats = FitStats {
            build_evals,
            eval_evals: backend.counter().get() - before_eval,
            swap_iters: iters,
            swaps_applied: applied,
            samples: 1,
            iters_plus_one: iters + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(Clustering::finalize_with(backend, medoids, loss, assignments, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn eval_count_is_exactly_batch_squared_plus_kn() {
        let n = 500;
        let (k, b) = (4, 120);
        let ds = synthetic::gmm(&mut Rng::seed_from(60), n, k, 3, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = OneBatchPam { batch_size: b, ..OneBatchPam::new() };
        let fit = algo.fit(&backend, k, &mut Rng::seed_from(1)).unwrap();
        let want = (b * b + k * n) as u64;
        assert_eq!(fit.stats.distance_evals, want);
        assert_eq!(backend.counter().get(), want, "finalize adds no evals");
        assert_eq!(fit.stats.build_evals, (b * b) as u64);
        assert_eq!(fit.stats.eval_evals, (k * n) as u64);
        assert_eq!(fit.stats.samples, 1);
    }

    #[test]
    fn default_batch_covers_small_datasets_entirely() {
        // n below the frugal default: the batch is all of the data, so the
        // result matches a full FastPAM1-style fit in quality terms.
        let ds = synthetic::gmm(&mut Rng::seed_from(61), 80, 3, 2, 5.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = OneBatchPam::new().fit(&backend, 3, &mut Rng::seed_from(2)).unwrap();
        assert_eq!(fit.medoids.len(), 3);
        assert_eq!(fit.stats.build_evals, 80 * 80);
        let pam = Pam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
        assert!(fit.loss <= pam.loss * 1.2, "{} vs {}", fit.loss, pam.loss);
    }

    #[test]
    fn quality_is_bounded_on_separated_clusters() {
        let ds = synthetic::gmm(&mut Rng::seed_from(62), 600, 4, 3, 8.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = OneBatchPam::new().fit(&backend, 4, &mut Rng::seed_from(3)).unwrap();
        let pam = Pam::new().fit(&backend, 4, &mut Rng::seed_from(0)).unwrap();
        assert!(
            fit.loss <= pam.loss * 1.25,
            "one batch should land near the PAM optimum on well-separated data: {} vs {}",
            fit.loss,
            pam.loss
        );
    }

    #[test]
    fn batch_not_larger_than_k_is_rejected() {
        let ds = synthetic::gmm(&mut Rng::seed_from(63), 50, 3, 2, 2.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = OneBatchPam { batch_size: 3, ..OneBatchPam::new() };
        let err = algo.fit(&backend, 3, &mut Rng::seed_from(4)).unwrap_err();
        assert_eq!(err.kind(), "invalid_argument");
    }

    #[test]
    fn seeded_batch_draw_makes_fits_reproducible() {
        let ds = synthetic::gmm(&mut Rng::seed_from(64), 400, 4, 3, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let a = OneBatchPam::new().fit(&backend, 4, &mut Rng::seed_from(7)).unwrap();
        let b = OneBatchPam::new().fit(&backend, 4, &mut Rng::seed_from(7)).unwrap();
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        let c = OneBatchPam::new().fit(&backend, 4, &mut Rng::seed_from(8)).unwrap();
        // a different seed draws a different batch (not a hard guarantee,
        // but with 400 choose 120 batches a collision would be a bug)
        assert!(c.loss.is_finite());
    }
}
