//! Precomputed full distance matrix — the substrate of the *reference*
//! implementations.
//!
//! The paper notes (Appendix 2.2) that state-of-the-art PAM / FastPAM1
//! implementations "precompute and cache the entire n² distance matrix
//! before any medoid assignments are made"; BanditPAM's headline wall-clock
//! win is achieved *without* that cache. Our PAM-family baselines follow
//! the reference implementations and precompute, paying the n² evaluations
//! up front (counted); the analytic per-iteration reference lines
//! (k·n², n²) used in Figures 1b/2/3 are drawn by the bench harness exactly
//! as the paper draws them.

use crate::algorithms::{Clustering, FitStats};
use crate::runtime::backend::DistanceBackend;

/// Dense symmetric n x n distance table.
pub struct FullMatrix {
    n: usize,
    d: Vec<f64>,
}

impl FullMatrix {
    /// Evaluate all pairs (n² counted evaluations, computed as row blocks).
    pub fn compute(backend: &dyn DistanceBackend) -> FullMatrix {
        let n = backend.n();
        let refs: Vec<usize> = (0..n).collect();
        let mut d = vec![0.0f64; n * n];
        // Chunk target rows to bound scratch size and let the backend
        // thread-shard each block.
        let chunk = 256.max(1);
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            let targets: Vec<usize> = (start..end).collect();
            let rows = end - start;
            backend.block(&targets, &refs, &mut d[start * n..start * n + rows * n]);
            start = end;
        }
        FullMatrix { n, d }
    }

    /// Matrix over a subset of points: entry (i, j) is the distance between
    /// `subset[i]` and `subset[j]` (|subset|² counted evaluations).
    pub fn compute_subset(backend: &dyn DistanceBackend, subset: &[usize]) -> FullMatrix {
        let n = subset.len();
        let mut d = vec![0.0f64; n * n];
        backend.block(subset, subset, &mut d);
        FullMatrix { n, d }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.d[i * self.n + j]
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.d[i * self.n..(i + 1) * self.n]
    }
}

/// d1/a1/d2 arrays over a [`FullMatrix`] (PAM-internal bookkeeping).
pub struct MatState {
    pub medoids: Vec<usize>,
    pub d1: Vec<f64>,
    pub a1: Vec<usize>,
    pub d2: Vec<f64>,
}

impl MatState {
    pub fn empty(n: usize) -> MatState {
        MatState {
            medoids: Vec::new(),
            d1: vec![f64::INFINITY; n],
            a1: vec![usize::MAX; n],
            d2: vec![f64::INFINITY; n],
        }
    }

    pub fn loss(&self) -> f64 {
        self.d1.iter().sum()
    }

    pub fn add_medoid(&mut self, m: &FullMatrix, x: usize) {
        let pos = self.medoids.len();
        self.medoids.push(x);
        let row = m.row(x);
        for (j, &d) in row.iter().enumerate() {
            if d < self.d1[j] {
                self.d2[j] = self.d1[j];
                self.d1[j] = d;
                self.a1[j] = pos;
            } else if d < self.d2[j] {
                self.d2[j] = d;
            }
        }
    }

    pub fn rebuild(&mut self, m: &FullMatrix) {
        self.d1.iter_mut().for_each(|v| *v = f64::INFINITY);
        self.d2.iter_mut().for_each(|v| *v = f64::INFINITY);
        self.a1.iter_mut().for_each(|v| *v = usize::MAX);
        for pos in 0..self.medoids.len() {
            let row = m.row(self.medoids[pos]);
            for (j, &d) in row.iter().enumerate() {
                if d < self.d1[j] {
                    self.d2[j] = self.d1[j];
                    self.d1[j] = d;
                    self.a1[j] = pos;
                } else if d < self.d2[j] {
                    self.d2[j] = d;
                }
            }
        }
    }
}

/// Finish a matrix-based fit without re-running the k×n evaluation pass
/// [`Clustering::finalize`] would pay (uncounted — the `MatState` already
/// holds the loss and assignments). Sorts the medoids ascending and
/// rebuilds d1/a1 over the sorted order — matrix reads only, no counted
/// evaluations — which reproduces `loss_and_assignments` bitwise: the
/// matrix entries are bit-copies of `backend.dist`, both paths sum minima
/// in strict point order, and both break distance ties toward the lowest
/// medoid position (strict `<` update). Debug builds verify the claim
/// through `finalize_with`'s assertion.
pub(crate) fn finalize_from_state(
    backend: &dyn DistanceBackend,
    m: &FullMatrix,
    mut state: MatState,
    stats: FitStats,
) -> Clustering {
    state.medoids.sort_unstable();
    state.rebuild(m);
    let loss = state.loss();
    let assignments = std::mem::take(&mut state.a1);
    Clustering::finalize_with(backend, state.medoids, loss, assignments, stats)
}

/// Exact greedy BUILD (Eq. 4) over a matrix. Returns the chosen medoids.
pub fn exact_build(m: &FullMatrix, k: usize, state: &mut MatState) {
    let n = m.n();
    for _ in 0..k {
        let mut best = (f64::INFINITY, usize::MAX);
        for x in 0..n {
            if state.medoids.contains(&x) {
                continue;
            }
            let row = m.row(x);
            let mut acc = 0.0;
            for j in 0..n {
                let d = row[j];
                acc += if state.d1[j].is_infinite() { d } else { d.min(state.d1[j]) };
            }
            if acc < best.0 {
                best = (acc, x);
            }
        }
        state.add_medoid(m, best.1);
    }
}

/// Loss delta (un-normalized) of swapping `medoids[m_pos]` for `x`
/// (the shared inner expression of PAM's Eq. 5 and FastPAM1's Eq. 12).
#[inline]
pub fn swap_delta(m: &FullMatrix, state: &MatState, m_pos: usize, x: usize) -> f64 {
    let row = m.row(x);
    let mut acc = 0.0;
    for j in 0..m.n() {
        let d = row[j];
        let base = if state.a1[j] == m_pos {
            state.d2[j].min(d)
        } else {
            state.d1[j].min(d)
        };
        acc += base - state.d1[j];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;
    use crate::util::rng::Rng;

    #[test]
    fn matrix_matches_backend() {
        let ds = synthetic::gmm(&mut Rng::seed_from(1), 15, 3, 2, 2.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let m = FullMatrix::compute(&b);
        assert_eq!(b.counter().get(), 15 * 15);
        for i in 0..15 {
            for j in 0..15 {
                assert_eq!(m.get(i, j), b.dist(i, j));
            }
        }
    }

    #[test]
    fn subset_matrix() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 20, 3, 2, 2.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let subset = [3usize, 7, 11];
        let m = FullMatrix::compute_subset(&b, &subset);
        assert_eq!(m.n(), 3);
        assert_eq!(m.get(0, 2), b.dist(3, 11));
    }

    #[test]
    fn exact_build_monotone_loss() {
        let ds = synthetic::gmm(&mut Rng::seed_from(3), 30, 4, 3, 3.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let m = FullMatrix::compute(&b);
        let mut st = MatState::empty(30);
        exact_build(&m, 1, &mut st);
        let l1 = st.loss();
        exact_build(&m, 1, &mut st);
        assert!(st.loss() <= l1);
        assert_eq!(st.medoids.len(), 2);
    }

    #[test]
    fn swap_delta_matches_recompute() {
        let ds = synthetic::gmm(&mut Rng::seed_from(4), 25, 4, 2, 3.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let m = FullMatrix::compute(&b);
        let mut st = MatState::empty(25);
        exact_build(&m, 2, &mut st);
        let before = st.loss();
        for x in 0..25 {
            if st.medoids.contains(&x) {
                continue;
            }
            for pos in 0..2 {
                let delta = swap_delta(&m, &st, pos, x);
                let mut med = st.medoids.clone();
                med[pos] = x;
                let after: f64 = (0..25)
                    .map(|j| med.iter().map(|&mm| m.get(mm, j)).fold(f64::INFINITY, f64::min))
                    .sum();
                assert!((delta - (after - before)).abs() < 1e-9);
            }
        }
    }
}
