//! k-medoids algorithms: BanditPAM's baselines and the shared interface.
//!
//! The paper's evaluation (Figure 1a) compares against: PAM [20] (the
//! quality reference), FastPAM1 [42] (exact-PAM-equivalent, O(k) faster),
//! FastPAM [42] (near-PAM quality, not exact), CLARA [20] and CLARANS [36]
//! (sampling/randomized, lower quality) and Voronoi Iteration [40]
//! (k-means-style alternation). [`meddit`] is the 1-medoid bandit of
//! Bagaria et al. [4] that BanditPAM generalizes. Two post-paper baselines
//! round out the head-to-head: [`fasterpam`] (Schubert–Rousseeuw's eager
//! first-improvement swap, arXiv:1810.05691) and [`onebatchpam`] (the
//! single-batch frugal variant of arXiv:2501.19285).

pub mod clara;
pub mod clarans;
pub mod fasterpam;
pub mod fastpam;
pub mod fastpam1;
pub mod matrix_cache;
pub mod meddit;
pub mod onebatchpam;
pub mod pam;
pub mod voronoi;

use crate::error::{Error, Result};
use crate::runtime::backend::{loss_and_assignments, DistanceBackend};
use crate::util::rng::Rng;

/// Bookkeeping common to every fit.
#[derive(Debug, Clone, Default)]
pub struct FitStats {
    /// Total distance evaluations consumed by the algorithm itself
    /// (excludes the final loss/assignment computation).
    pub distance_evals: u64,
    /// Evaluations spent in the BUILD / initialization phase (for
    /// sampling outer loops: fitting the subsamples).
    pub build_evals: u64,
    /// Evaluations spent in SWAP / refinement.
    pub swap_evals: u64,
    /// Evaluations spent scoring candidate medoid sets against the full
    /// dataset (CLARA/BigFit outer loops; 0 for single-candidate
    /// algorithms).
    pub eval_evals: u64,
    /// Evaluations the SWAP session served from its cross-iteration row
    /// cache instead of recomputing (0 for algorithms without one).
    pub swap_evals_saved: u64,
    /// SWAP (or refinement) iterations executed.
    pub swap_iters: usize,
    /// Swaps actually applied.
    pub swaps_applied: usize,
    /// Subsamples drawn and fitted (CLARA/BigFit; 0 otherwise).
    pub samples: usize,
    /// Wall-clock seconds for the whole fit.
    pub wall_secs: f64,
    /// Per-iteration normalizer the paper uses for Figures 1b/2/3:
    /// swap iterations + 1 (the +1 folds in all k BUILD steps).
    pub iters_plus_one: usize,
    /// Pairwise-cache hits over the whole fit (0 when no cache is
    /// enabled — see [`FitStats::cache_hit_rate`] to disambiguate).
    pub cache_hits: u64,
    /// Pairwise-cache misses over the whole fit.
    pub cache_misses: u64,
}

impl FitStats {
    /// Distance evaluations per iteration (the paper's Fig 1b/2/3 y-axis).
    pub fn evals_per_iter(&self) -> f64 {
        self.distance_evals as f64 / self.iters_plus_one.max(1) as f64
    }

    /// Wall-clock per iteration (the paper's Fig 2/3 y-axis).
    pub fn secs_per_iter(&self) -> f64 {
        self.wall_secs / self.iters_plus_one.max(1) as f64
    }

    /// Pairwise-cache hit rate in `[0, 1]`, or `None` when the backend
    /// had no cache (hits and misses both zero).
    pub fn cache_hit_rate(&self) -> Option<f64> {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            None
        } else {
            Some(self.cache_hits as f64 / total as f64)
        }
    }
}

/// Result of a k-medoids fit.
#[derive(Debug, Clone)]
pub struct Clustering {
    /// Medoid point indices, sorted ascending (so set equality is `==`).
    pub medoids: Vec<usize>,
    /// For each point, the index into `medoids` of its nearest medoid.
    pub assignments: Vec<usize>,
    /// Final loss (Eq. 1).
    pub loss: f64,
    pub stats: FitStats,
}

impl Clustering {
    /// Assemble from an unsorted medoid set; computes loss + assignments
    /// (not counted into `stats.distance_evals`).
    pub fn finalize(
        backend: &dyn DistanceBackend,
        mut medoids: Vec<usize>,
        mut stats: FitStats,
    ) -> Clustering {
        medoids.sort_unstable();
        stats.distance_evals = stats.build_evals + stats.swap_evals + stats.eval_evals;
        if let Some((hits, misses)) = backend.cache_stats() {
            stats.cache_hits = hits;
            stats.cache_misses = misses;
        }
        let (loss, assignments) = loss_and_assignments(backend, &medoids);
        Clustering { medoids, assignments, loss, stats }
    }

    /// Like [`Clustering::finalize`], but trusts a `(loss, assignments)`
    /// pair the caller already computed over exactly this medoid set —
    /// sampling outer loops (CLARA, BigFit) score every candidate on the
    /// full dataset anyway, so re-running the `n x k` pass on the winner
    /// would double its cost. `medoids` must already be sorted ascending
    /// (the order `assignments` indexes).
    ///
    /// Debug builds verify the claim bitwise against a fresh evaluation,
    /// then un-count the verification's distance evaluations so debug and
    /// release builds report identical counter totals.
    pub fn finalize_with(
        backend: &dyn DistanceBackend,
        medoids: Vec<usize>,
        loss: f64,
        assignments: Vec<usize>,
        mut stats: FitStats,
    ) -> Clustering {
        debug_assert!(
            medoids.windows(2).all(|w| w[0] < w[1]),
            "finalize_with requires strictly increasing medoids"
        );
        stats.distance_evals = stats.build_evals + stats.swap_evals + stats.eval_evals;
        if let Some((hits, misses)) = backend.cache_stats() {
            stats.cache_hits = hits;
            stats.cache_misses = misses;
        }
        #[cfg(debug_assertions)]
        {
            let before = backend.counter().get();
            let (want_loss, want_assign) = loss_and_assignments(backend, &medoids);
            assert_eq!(
                loss.to_bits(),
                want_loss.to_bits(),
                "finalize_with: caller loss diverges from a fresh evaluation"
            );
            assert_eq!(
                assignments, want_assign,
                "finalize_with: caller assignments diverge from a fresh evaluation"
            );
            backend.counter().sub(backend.counter().get() - before);
        }
        Clustering { medoids, assignments, loss, stats }
    }

    /// Same medoid set as another clustering?
    pub fn same_medoids(&self, other: &Clustering) -> bool {
        self.medoids == other.medoids
    }

    /// The `k == n` degenerate solution: every point is its own medoid at
    /// loss 0. Assignments are the identity (point `i` → medoid position
    /// `i`), which is *a* — and, absent duplicate points, *the* — optimal
    /// assignment; no distances are evaluated.
    ///
    /// Caveat: because no distances are computed, the identity assignment
    /// is **not** re-derived through the first-minimum tie-break that
    /// `loss_and_assignments` (and model predict) use. If the data holds
    /// two points at distance zero from each other (duplicates; or
    /// cosine-parallel vectors), a later one is assigned to itself here
    /// but would tie-break to the *earlier* zero-distance medoid under
    /// predict. All distances involved are exactly zero either way, so
    /// the loss is unaffected — only the label choice among equals.
    pub fn each_point_its_own_medoid(n: usize) -> Clustering {
        Clustering {
            medoids: (0..n).collect(),
            assignments: (0..n).collect(),
            loss: 0.0,
            stats: FitStats { iters_plus_one: 1, ..Default::default() },
        }
    }
}

/// Common interface for all k-medoids solvers in this crate.
pub trait KMedoids {
    /// Short display name ("pam", "banditpam", ...).
    fn name(&self) -> &'static str;

    /// Cluster the backend's point set into `k` medoids.
    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> Result<Clustering>;
}

/// Validate common preconditions; shared by every implementation.
/// `k == n` is allowed — it has the trivial exact solution every
/// implementation returns through [`degenerate_fit`].
pub(crate) fn check_fit_args(backend: &dyn DistanceBackend, k: usize) -> Result<()> {
    if k < 1 {
        return Err(Error::invalid_argument(format!("k must be >= 1 (got {k})")));
    }
    if k > backend.n() {
        return Err(Error::invalid_argument(format!(
            "k = {k} must not exceed the dataset size n = {}",
            backend.n()
        )));
    }
    Ok(())
}

/// The shared `k == n` fast path: the unique zero-loss solution is every
/// point as its own medoid, so no search (and no distance evaluation) is
/// needed. Every implementation calls this right after [`check_fit_args`].
pub(crate) fn degenerate_fit(backend: &dyn DistanceBackend, k: usize) -> Option<Clustering> {
    (k == backend.n()).then(|| Clustering::each_point_its_own_medoid(k))
}

/// One constructible `KMedoids` implementation, as the CLI and the
/// [`crate::model::Fit`] facade see it.
pub struct AlgorithmSpec {
    /// The accepted `--algo` spelling (also [`KMedoids::name`]).
    pub name: &'static str,
    /// One-line description for `help` output.
    pub note: &'static str,
    /// Construct a fresh instance with its default configuration.
    pub make: fn() -> Box<dyn KMedoids>,
}

/// Registry of every `KMedoids` implementation. `main.rs` dispatch, its
/// `help` text and the [`crate::model::Fit`] facade all read this one
/// table, so the accepted names can never drift from the documented ones.
pub const REGISTRY: &[AlgorithmSpec] = &[
    AlgorithmSpec {
        name: "banditpam",
        note: "adaptive multi-armed bandit PAM (the paper; default)",
        make: || Box::new(crate::coordinator::banditpam::BanditPam::default_paper()),
    },
    AlgorithmSpec {
        name: "pam",
        note: "exact PAM (quality reference)",
        make: || Box::new(pam::Pam::new()),
    },
    AlgorithmSpec {
        name: "fastpam1",
        note: "exact-PAM-equivalent SWAP, O(k) faster",
        make: || Box::new(fastpam1::FastPam1::new()),
    },
    AlgorithmSpec {
        name: "fastpam",
        note: "near-PAM quality, eager sweeps",
        make: || Box::new(fastpam::FastPam::new()),
    },
    AlgorithmSpec {
        name: "fasterpam",
        note: "eager randomized-order swaps (Schubert-Rousseeuw)",
        make: || Box::new(fasterpam::FasterPam::new()),
    },
    AlgorithmSpec {
        name: "clara",
        note: "PAM on random subsamples",
        make: || Box::new(clara::Clara::new()),
    },
    AlgorithmSpec {
        name: "onebatchpam",
        note: "frugal PAM on one batch, scored once",
        make: || Box::new(onebatchpam::OneBatchPam::new()),
    },
    AlgorithmSpec {
        name: "clarans",
        note: "randomized neighbor search",
        make: || Box::new(clarans::Clarans::new()),
    },
    AlgorithmSpec {
        name: "voronoi",
        note: "k-means-style alternation",
        make: || Box::new(voronoi::VoronoiIteration::new()),
    },
    AlgorithmSpec {
        name: "meddit",
        note: "1-medoid bandit of Bagaria et al. (k=1 only)",
        make: || Box::new(meddit::Meddit::new()),
    },
];

/// Look up a registry entry by name. Shared by [`make_algorithm`] and the
/// [`crate::model::Fit`] facade so the lookup and its error message exist
/// exactly once.
pub fn find_algorithm(name: &str) -> Result<&'static AlgorithmSpec> {
    REGISTRY.iter().find(|spec| spec.name == name).ok_or_else(|| {
        Error::invalid_argument(format!(
            "unknown algorithm {name:?} (expected one of: {})",
            algorithm_names()
        ))
    })
}

/// Construct an algorithm by registry name.
pub fn make_algorithm(name: &str) -> Result<Box<dyn KMedoids>> {
    find_algorithm(name).map(|spec| (spec.make)())
}

/// The accepted algorithm names, comma-separated, in registry order.
pub fn algorithm_names() -> String {
    REGISTRY
        .iter()
        .map(|s| s.name)
        .collect::<Vec<_>>()
        .join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn finalize_sorts_and_assigns() {
        let ds = synthetic::gmm(&mut Rng::seed_from(1), 20, 3, 2, 3.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let c = Clustering::finalize(&b, vec![9, 2], FitStats::default());
        assert_eq!(c.medoids, vec![2, 9]);
        assert_eq!(c.assignments.len(), 20);
        assert!(c.loss > 0.0);
        assert_eq!(c.assignments[2], 0);
        assert_eq!(c.assignments[9], 1);
    }

    /// `finalize_with` must reproduce `finalize`'s result exactly while
    /// leaving the evaluation counter where the caller's own evaluation
    /// left it (the debug verification un-counts itself).
    #[test]
    fn finalize_with_trusts_precomputed_results_without_recounting() {
        let ds = synthetic::gmm(&mut Rng::seed_from(11), 30, 4, 2, 3.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let via_finalize = Clustering::finalize(&b, vec![9, 2], FitStats::default());
        b.counter().reset();
        let (loss, assignments) =
            crate::runtime::backend::loss_and_assignments(&b, &[2, 9]);
        let after_eval = b.counter().get();
        assert_eq!(after_eval, 2 * 30);
        let stats = FitStats { eval_evals: after_eval, ..Default::default() };
        let c = Clustering::finalize_with(&b, vec![2, 9], loss, assignments, stats);
        assert_eq!(
            b.counter().get(),
            after_eval,
            "finalize_with must not add evaluations (debug verification un-counts)"
        );
        assert_eq!(c.medoids, via_finalize.medoids);
        assert_eq!(c.assignments, via_finalize.assignments);
        assert_eq!(c.loss.to_bits(), via_finalize.loss.to_bits());
        assert_eq!(c.stats.distance_evals, 2 * 30);
    }

    #[test]
    fn stats_per_iter_normalization() {
        let stats = FitStats {
            distance_evals: 1000,
            swap_iters: 4,
            iters_plus_one: 5,
            wall_secs: 10.0,
            ..Default::default()
        };
        assert!((stats.evals_per_iter() - 200.0).abs() < 1e-12);
        assert!((stats.secs_per_iter() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn check_fit_args_bounds() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 10, 2, 2, 1.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        assert!(check_fit_args(&b, 0).is_err());
        assert!(check_fit_args(&b, 11).is_err());
        assert!(check_fit_args(&b, 3).is_ok());
        // k == n is legal: it has the trivial exact solution
        assert!(check_fit_args(&b, 10).is_ok());
    }

    /// `k == n` short-circuits to the zero-loss identity solution in every
    /// implementation, with no distance evaluations.
    #[test]
    fn degenerate_k_equals_n_fast_path() {
        let ds = synthetic::gmm(&mut Rng::seed_from(7), 12, 3, 2, 2.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        assert!(degenerate_fit(&b, 11).is_none());
        let c = degenerate_fit(&b, 12).expect("k == n is degenerate");
        assert_eq!(c.medoids, (0..12).collect::<Vec<_>>());
        assert_eq!(c.assignments, (0..12).collect::<Vec<_>>());
        assert_eq!(c.loss, 0.0);
        assert_eq!(b.counter().get(), 0, "no distances evaluated");
        // end to end through every registered algorithm (meddit is k=1
        // only, so it only hits the degenerate path at n = 1)
        for spec in REGISTRY {
            let mut rng = Rng::seed_from(5);
            if spec.name == "meddit" {
                let one = synthetic::gmm(&mut Rng::seed_from(8), 1, 3, 1, 1.0);
                let b1 = NativeBackend::new(&one.points, Metric::L2);
                let fit = (spec.make)().fit(&b1, 1, &mut rng).unwrap();
                assert_eq!(fit.medoids, vec![0], "{}", spec.name);
                continue;
            }
            let fit = (spec.make)().fit(&b, 12, &mut rng).unwrap();
            assert_eq!(fit.medoids, c.medoids, "{}", spec.name);
            assert_eq!(fit.assignments, c.assignments, "{}", spec.name);
            assert_eq!(fit.loss, 0.0, "{}", spec.name);
        }
    }

    #[test]
    fn registry_names_resolve_and_match_impl_names() {
        for spec in REGISTRY {
            let algo = make_algorithm(spec.name).unwrap();
            assert_eq!(algo.name(), spec.name);
        }
        let err = make_algorithm("kmeans").unwrap_err();
        assert!(err.to_string().contains("banditpam"), "{err}");
        assert!(algorithm_names().starts_with("banditpam, pam"));
    }
}
