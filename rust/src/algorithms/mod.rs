//! k-medoids algorithms: BanditPAM's baselines and the shared interface.
//!
//! The paper's evaluation (Figure 1a) compares against: PAM [20] (the
//! quality reference), FastPAM1 [42] (exact-PAM-equivalent, O(k) faster),
//! FastPAM [42] (near-PAM quality, not exact), CLARA [20] and CLARANS [36]
//! (sampling/randomized, lower quality) and Voronoi Iteration [40]
//! (k-means-style alternation). [`meddit`] is the 1-medoid bandit of
//! Bagaria et al. [4] that BanditPAM generalizes.

pub mod clara;
pub mod clarans;
pub mod fastpam;
pub mod fastpam1;
pub mod matrix_cache;
pub mod meddit;
pub mod pam;
pub mod voronoi;

use crate::runtime::backend::{loss_and_assignments, DistanceBackend};
use crate::util::rng::Rng;

/// Bookkeeping common to every fit.
#[derive(Debug, Clone, Default)]
pub struct FitStats {
    /// Total distance evaluations consumed by the algorithm itself
    /// (excludes the final loss/assignment computation).
    pub distance_evals: u64,
    /// Evaluations spent in the BUILD / initialization phase.
    pub build_evals: u64,
    /// Evaluations spent in SWAP / refinement.
    pub swap_evals: u64,
    /// Evaluations the SWAP session served from its cross-iteration row
    /// cache instead of recomputing (0 for algorithms without one).
    pub swap_evals_saved: u64,
    /// SWAP (or refinement) iterations executed.
    pub swap_iters: usize,
    /// Swaps actually applied.
    pub swaps_applied: usize,
    /// Wall-clock seconds for the whole fit.
    pub wall_secs: f64,
    /// Per-iteration normalizer the paper uses for Figures 1b/2/3:
    /// swap iterations + 1 (the +1 folds in all k BUILD steps).
    pub iters_plus_one: usize,
}

impl FitStats {
    /// Distance evaluations per iteration (the paper's Fig 1b/2/3 y-axis).
    pub fn evals_per_iter(&self) -> f64 {
        self.distance_evals as f64 / self.iters_plus_one.max(1) as f64
    }

    /// Wall-clock per iteration (the paper's Fig 2/3 y-axis).
    pub fn secs_per_iter(&self) -> f64 {
        self.wall_secs / self.iters_plus_one.max(1) as f64
    }
}

/// Result of a k-medoids fit.
#[derive(Debug, Clone)]
pub struct Clustering {
    /// Medoid point indices, sorted ascending (so set equality is `==`).
    pub medoids: Vec<usize>,
    /// For each point, the index into `medoids` of its nearest medoid.
    pub assignments: Vec<usize>,
    /// Final loss (Eq. 1).
    pub loss: f64,
    pub stats: FitStats,
}

impl Clustering {
    /// Assemble from an unsorted medoid set; computes loss + assignments
    /// (not counted into `stats.distance_evals`).
    pub fn finalize(
        backend: &dyn DistanceBackend,
        mut medoids: Vec<usize>,
        mut stats: FitStats,
    ) -> Clustering {
        medoids.sort_unstable();
        stats.distance_evals = stats.build_evals + stats.swap_evals;
        let (loss, assignments) = loss_and_assignments(backend, &medoids);
        Clustering { medoids, assignments, loss, stats }
    }

    /// Same medoid set as another clustering?
    pub fn same_medoids(&self, other: &Clustering) -> bool {
        self.medoids == other.medoids
    }
}

/// Common interface for all k-medoids solvers in this crate.
pub trait KMedoids {
    /// Short display name ("pam", "banditpam", ...).
    fn name(&self) -> &'static str;

    /// Cluster the backend's point set into `k` medoids.
    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<Clustering>;
}

/// Validate common preconditions; shared by every implementation.
pub(crate) fn check_fit_args(backend: &dyn DistanceBackend, k: usize) -> anyhow::Result<()> {
    anyhow::ensure!(k >= 1, "k must be >= 1 (got {k})");
    anyhow::ensure!(
        k < backend.n(),
        "k = {k} must be smaller than the dataset size n = {}",
        backend.n()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn finalize_sorts_and_assigns() {
        let ds = synthetic::gmm(&mut Rng::seed_from(1), 20, 3, 2, 3.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let c = Clustering::finalize(&b, vec![9, 2], FitStats::default());
        assert_eq!(c.medoids, vec![2, 9]);
        assert_eq!(c.assignments.len(), 20);
        assert!(c.loss > 0.0);
        assert_eq!(c.assignments[2], 0);
        assert_eq!(c.assignments[9], 1);
    }

    #[test]
    fn stats_per_iter_normalization() {
        let stats = FitStats {
            distance_evals: 1000,
            swap_iters: 4,
            iters_plus_one: 5,
            wall_secs: 10.0,
            ..Default::default()
        };
        assert!((stats.evals_per_iter() - 200.0).abs() < 1e-12);
        assert!((stats.secs_per_iter() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn check_fit_args_bounds() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 10, 2, 2, 1.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        assert!(check_fit_args(&b, 0).is_err());
        assert!(check_fit_args(&b, 10).is_err());
        assert!(check_fit_args(&b, 3).is_ok());
    }
}
