//! Meddit (Bagaria et al. [4]): the 1-medoid bandit BanditPAM generalizes.
//!
//! Finds the single medoid of a point set — `argmin_x mean_j d(x, x_j)` —
//! as a best-arm identification problem, exactly the first BUILD step of
//! BanditPAM. Included both as the historical baseline and as a
//! correctness cross-check (for k = 1, BanditPAM's BUILD must agree).

use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::bandits::adaptive::{adaptive_search, AdaptiveConfig};
use crate::coordinator::arms::BuildArms;
use crate::coordinator::state::MedoidState;
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// 1-medoid bandit solver.
#[derive(Debug, Default)]
pub struct Meddit {
    /// Error probability per CI (default 1e-3 / n as in BanditPAM).
    pub delta: Option<f64>,
}

impl Meddit {
    pub fn new() -> Meddit {
        Meddit::default()
    }
}

impl KMedoids for Meddit {
    fn name(&self) -> &'static str {
        "meddit"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if k != 1 {
            return Err(crate::error::Error::invalid_argument(format!(
                "meddit solves the 1-medoid problem (got k = {k})"
            )));
        }
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let n = backend.n();
        let state = MedoidState::empty(n);
        let mut arms = BuildArms::new(backend, &state);
        let cfg = AdaptiveConfig {
            delta: self.delta.unwrap_or(1.0 / (1000.0 * n as f64)),
            ..Default::default()
        };
        let outcome = adaptive_search(&mut arms, &cfg, rng);
        let medoid = arms.candidates[outcome.best];
        let stats = FitStats {
            build_evals: backend.counter().get() - start,
            iters_plus_one: 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(Clustering::finalize(backend, vec![medoid], stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    fn exact_medoid(backend: &dyn DistanceBackend) -> usize {
        let n = backend.n();
        (0..n)
            .min_by(|&a, &b| {
                let sa: f64 = (0..n).map(|j| backend.dist(a, j)).sum();
                let sb: f64 = (0..n).map(|j| backend.dist(b, j)).sum();
                sa.partial_cmp(&sb).unwrap()
            })
            .unwrap()
    }

    #[test]
    fn meddit_finds_the_true_medoid() {
        for seed in 0..5 {
            let ds = synthetic::gmm(&mut Rng::seed_from(500 + seed), 80, 4, 1, 1.0);
            let backend = NativeBackend::new(&ds.points, Metric::L2);
            let want = exact_medoid(&backend);
            let fit = Meddit::new().fit(&backend, 1, &mut Rng::seed_from(seed)).unwrap();
            assert_eq!(fit.medoids, vec![want], "seed {seed}");
        }
    }

    #[test]
    fn meddit_rejects_k_above_one() {
        let ds = synthetic::gmm(&mut Rng::seed_from(80), 20, 2, 1, 1.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        assert!(Meddit::new().fit(&backend, 2, &mut Rng::seed_from(0)).is_err());
    }
}
