//! FasterPAM (Schubert & Rousseeuw, "Fast and Eager k-Medoids Clustering",
//! arXiv:1810.05691): eager first-improvement SWAP.
//!
//! FastPAM1 computes all k swap deltas for a candidate in one pass over its
//! distance row (Eq. 12) but still restarts the whole sweep after applying
//! the single best swap. FasterPAM drops the best-swap requirement: it
//! visits candidates in a randomized order and, whenever a candidate's best
//! medoid-replacement improves the loss, applies that swap *immediately*
//! and keeps sweeping. Each candidate still costs one O(n) row pass (with
//! the O(k) delta accumulation folded in), so a full sweep is n² summands —
//! but convergence takes far fewer sweeps because every improvement is
//! banked as soon as it is found. The trajectory depends on the visit
//! order; the order is drawn from the seeded [`Rng`], so fits are
//! byte-deterministic across thread counts and reruns, and quality stays in
//! the FastPAM band (just above PAM's).

use crate::algorithms::matrix_cache::{
    exact_build, finalize_from_state, FullMatrix, MatState,
};
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// FasterPAM: eager randomized-order swaps, FastPAM-comparable quality.
#[derive(Debug)]
pub struct FasterPam {
    /// Cap on full candidate sweeps (a sweep with no applied swap ends the
    /// search earlier).
    pub max_sweeps: usize,
}

impl FasterPam {
    pub fn new() -> FasterPam {
        FasterPam { max_sweeps: 100 }
    }
}

/// `derive(Default)` would zero `max_sweeps` and silently skip the SWAP
/// phase entirely; delegate to [`FasterPam::new`] instead.
impl Default for FasterPam {
    fn default() -> FasterPam {
        FasterPam::new()
    }
}

impl KMedoids for FasterPam {
    fn name(&self) -> &'static str {
        "fasterpam"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let n = backend.n();
        let m = FullMatrix::compute(backend);
        let mut state = MatState::empty(n);
        exact_build(&m, k, &mut state);
        let build_evals = backend.counter().get() - start;

        let mut sweeps = 0;
        let mut applied = 0;
        let mut order: Vec<usize> = (0..n).collect();
        let mut deltas = vec![0.0f64; k];
        while sweeps < self.max_sweeps {
            sweeps += 1;
            rng.shuffle(&mut order);
            let mut improved = false;
            for &x in &order {
                if state.medoids.contains(&x) {
                    continue;
                }
                // Eq. 12 in one pass over d(x, ·): shared removal gain plus
                // the per-medoid correction for that medoid's own cluster.
                deltas.iter_mut().for_each(|d| *d = 0.0);
                let row = m.row(x);
                let mut shared = 0.0;
                for j in 0..n {
                    let d = row[j];
                    let m1 = state.d1[j].min(d);
                    shared += m1 - state.d1[j];
                    let a = state.a1[j];
                    if a < k {
                        deltas[a] += state.d2[j].min(d) - m1;
                    }
                }
                let mut best = (f64::INFINITY, usize::MAX);
                for (m_pos, extra) in deltas.iter().enumerate() {
                    let delta = shared + extra;
                    if delta < best.0 - 1e-15 {
                        best = (delta, m_pos);
                    }
                }
                // Eager: bank the improvement now and keep sweeping under
                // the updated state (FastPAM1 would restart the sweep).
                if best.0 < -1e-12 {
                    state.medoids[best.1] = x;
                    state.rebuild(&m);
                    applied += 1;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }

        let stats = FitStats {
            build_evals,
            swap_evals: backend.counter().get() - start - build_evals,
            swap_iters: sweeps,
            swaps_applied: applied,
            iters_plus_one: sweeps + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(finalize_from_state(backend, &m, state, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::matrix_cache::swap_delta;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn fasterpam_loss_close_to_pam() {
        // Same Figure-1a band as FastPAM: loss ratio within a few percent.
        let mut worst_ratio = 0.0f64;
        for seed in 0..5 {
            let ds = synthetic::gmm(&mut Rng::seed_from(500 + seed), 60, 4, 3, 2.0);
            let backend = NativeBackend::new(&ds.points, Metric::L2);
            let pam = Pam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
            let fp = FasterPam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
            worst_ratio = worst_ratio.max(fp.loss / pam.loss);
        }
        assert!(worst_ratio < 1.05, "loss ratio {worst_ratio}");
    }

    #[test]
    fn converged_fit_is_single_swap_optimal() {
        // A terminated sweep means no candidate improves: local optimality
        // under single swaps, same as PAM's convergence criterion.
        let ds = synthetic::gmm(&mut Rng::seed_from(46), 40, 3, 2, 2.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = FasterPam::new().fit(&backend, 2, &mut Rng::seed_from(3)).unwrap();
        assert!(fit.stats.swap_iters < 100, "must converge before the cap");
        let m = FullMatrix::compute(&backend);
        let mut st = MatState::empty(40);
        for &med in &fit.medoids {
            st.add_medoid(&m, med);
        }
        for x in 0..40 {
            if fit.medoids.contains(&x) {
                continue;
            }
            for pos in 0..2 {
                assert!(
                    swap_delta(&m, &st, pos, x) >= -1e-9,
                    "improving swap exists: pos {pos} x {x}"
                );
            }
        }
    }

    #[test]
    fn seeded_candidate_order_makes_fits_reproducible() {
        let ds = synthetic::gmm(&mut Rng::seed_from(47), 50, 4, 3, 2.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let a = FasterPam::new().fit(&backend, 3, &mut Rng::seed_from(11)).unwrap();
        let b = FasterPam::new().fit(&backend, 3, &mut Rng::seed_from(11)).unwrap();
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.stats.swaps_applied, b.stats.swaps_applied);
    }

    #[test]
    fn total_evals_are_exactly_n_squared() {
        // The matrix precompute is the only counted evaluation source: the
        // sweeps read cached entries and the finalize path reuses the
        // MatState d1/a1 instead of re-scoring (satellite: finalize_with).
        let ds = synthetic::gmm(&mut Rng::seed_from(48), 30, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = FasterPam::new().fit(&backend, 3, &mut Rng::seed_from(5)).unwrap();
        assert_eq!(fit.stats.distance_evals, 30 * 30);
        assert_eq!(backend.counter().get(), 30 * 30);
    }
}
