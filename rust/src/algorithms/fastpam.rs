//! FastPAM (Schubert & Rousseeuw [42]): the eager-swapping variant.
//!
//! Unlike FastPAM1 (which applies only the single best swap per iteration
//! and therefore reproduces PAM exactly), FastPAM applies, for **each
//! medoid**, its best improving candidate within one sweep — executing up
//! to k swaps per iteration. It converges in fewer iterations but may take
//! a different trajectory and end in a different (comparable-quality) local
//! optimum; the paper's Figure 1a shows its loss ratio hovering just above
//! 1.

use crate::algorithms::fastpam1::best_swap_eq12;
use crate::algorithms::matrix_cache::{
    exact_build, finalize_from_state, FullMatrix, MatState,
};
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// FastPAM: near-PAM quality, multiple eager swaps per sweep.
#[derive(Debug)]
pub struct FastPam {
    pub max_sweeps: usize,
}

impl FastPam {
    pub fn new() -> FastPam {
        FastPam { max_sweeps: 100 }
    }
}

/// `derive(Default)` would zero `max_sweeps` and silently skip the SWAP
/// phase; delegate to [`FastPam::new`] instead.
impl Default for FastPam {
    fn default() -> FastPam {
        FastPam::new()
    }
}

impl KMedoids for FastPam {
    fn name(&self) -> &'static str {
        "fastpam"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        _rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let m = FullMatrix::compute(backend);
        let n = backend.n();
        let mut state = MatState::empty(n);
        exact_build(&m, k, &mut state);
        let build_evals = backend.counter().get() - start;

        let mut sweeps = 0;
        let mut applied = 0;
        let mut deltas = Vec::new();
        while sweeps < self.max_sweeps {
            sweeps += 1;
            // Per-medoid best candidate this sweep (eager application).
            let mut improved = false;
            // For each medoid, find its best improving swap under the
            // *current* state, applying each improvement immediately.
            for m_pos in 0..k {
                let mut best = (f64::INFINITY, usize::MAX);
                for x in 0..n {
                    if state.medoids.contains(&x) {
                        continue;
                    }
                    let row = m.row(x);
                    let mut delta = 0.0;
                    for j in 0..n {
                        let d = row[j];
                        let base = if state.a1[j] == m_pos {
                            state.d2[j].min(d)
                        } else {
                            state.d1[j].min(d)
                        };
                        delta += base - state.d1[j];
                    }
                    if delta < best.0 - 1e-15 {
                        best = (delta, x);
                    }
                }
                if best.0 < -1e-12 {
                    state.medoids[m_pos] = best.1;
                    state.rebuild(&m);
                    applied += 1;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
        // One final FastPAM1-style sweep to harvest any remaining single
        // best swap (cheap polish; keeps quality close to PAM).
        let (delta, x, m_pos) = best_swap_eq12(&m, &state, &mut deltas);
        if delta < -1e-12 {
            state.medoids[m_pos] = x;
            state.rebuild(&m);
            applied += 1;
            sweeps += 1;
        }

        let stats = FitStats {
            build_evals,
            swap_evals: backend.counter().get() - start - build_evals,
            swap_iters: sweeps,
            swaps_applied: applied,
            iters_plus_one: sweeps + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(finalize_from_state(backend, &m, state, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn fastpam_loss_close_to_pam() {
        // Figure 1a behaviour: loss ratio ~1 (within a few percent).
        let mut worst_ratio = 0.0f64;
        for seed in 0..5 {
            let ds = synthetic::gmm(&mut Rng::seed_from(400 + seed), 60, 4, 3, 2.0);
            let backend = NativeBackend::new(&ds.points, Metric::L2);
            let pam = Pam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
            let fp = FastPam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
            worst_ratio = worst_ratio.max(fp.loss / pam.loss);
        }
        assert!(worst_ratio < 1.05, "loss ratio {worst_ratio}");
    }

    #[test]
    fn fastpam_loss_never_below_pam_minus_epsilon_is_allowed() {
        // FastPAM may occasionally *beat* PAM (different local optimum);
        // just verify it returns a sane clustering.
        let ds = synthetic::gmm(&mut Rng::seed_from(44), 40, 3, 2, 5.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = FastPam::new().fit(&backend, 2, &mut Rng::seed_from(0)).unwrap();
        assert_eq!(fit.medoids.len(), 2);
        assert!(fit.loss.is_finite() && fit.loss > 0.0);
    }

    #[test]
    fn converges_within_sweep_cap() {
        let ds = synthetic::gmm(&mut Rng::seed_from(45), 50, 4, 4, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = FastPam::new().fit(&backend, 4, &mut Rng::seed_from(0)).unwrap();
        assert!(fit.stats.swap_iters < 100);
    }

    #[test]
    fn total_evals_are_exactly_n_squared() {
        // Matrix precompute only; the finalize path reuses the cached
        // d1/a1 instead of re-running loss_and_assignments uncounted.
        let ds = synthetic::gmm(&mut Rng::seed_from(46), 30, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = FastPam::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
        assert_eq!(fit.stats.distance_evals, 30 * 30);
        assert_eq!(backend.counter().get(), 30 * 30);
    }
}
