//! CLARANS (Ng & Han [36]): randomized search on the swap graph.
//!
//! Treats medoid sets as nodes of a graph whose edges are single swaps.
//! From a random start it examines up to `max_neighbor` random neighbours,
//! moving greedily on any improvement; after `max_neighbor` consecutive
//! failures the node is declared a local optimum. The process restarts
//! `num_local` times and the best local optimum wins. Quality is
//! distinctly below PAM (paper Figure 1a) but each neighbour check is only
//! n evaluations.

use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::coordinator::state::MedoidState;
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// CLARANS with the classical parameter defaults.
#[derive(Debug)]
pub struct Clarans {
    /// Restarts (classic: 2).
    pub num_local: usize,
    /// Neighbour cap; 0 = classic `max(250, 1.25% of k(n-k))`.
    pub max_neighbor: usize,
}

impl Default for Clarans {
    fn default() -> Self {
        Clarans { num_local: 2, max_neighbor: 0 }
    }
}

impl Clarans {
    pub fn new() -> Clarans {
        Clarans::default()
    }

    fn neighbor_budget(&self, n: usize, k: usize) -> usize {
        if self.max_neighbor > 0 {
            self.max_neighbor
        } else {
            (((k * (n - k)) as f64 * 0.0125) as usize).max(250)
        }
    }
}

/// Exact loss delta of swapping `state.medoids[m_pos]` for `x`
/// (n distance evaluations, using the d1/d2 cache).
fn swap_delta(
    backend: &dyn DistanceBackend,
    state: &MedoidState,
    m_pos: usize,
    x: usize,
    row: &mut Vec<f64>,
) -> f64 {
    let n = backend.n();
    let refs: Vec<usize> = (0..n).collect();
    row.resize(n, 0.0);
    backend.block(&[x], &refs, row);
    let mut acc = 0.0;
    for j in 0..n {
        let d = row[j];
        let base = if state.a1[j] == m_pos {
            state.d2[j].min(d)
        } else {
            state.d1[j].min(d)
        };
        acc += base - state.d1[j];
    }
    acc
}

impl KMedoids for Clarans {
    fn name(&self) -> &'static str {
        "clarans"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let n = backend.n();
        let budget = self.neighbor_budget(n, k);

        let mut best: Option<(f64, Vec<usize>)> = None;
        let mut row = Vec::new();
        let mut moves_total = 0usize;
        for _ in 0..self.num_local {
            let mut state = MedoidState::empty(n);
            for m in rng.sample_indices(n, k) {
                state.add_medoid(backend, m);
            }
            let mut failures = 0;
            while failures < budget {
                let m_pos = rng.below(k);
                let x = loop {
                    let c = rng.below(n);
                    if !state.medoids.contains(&c) {
                        break c;
                    }
                };
                let delta = swap_delta(backend, &state, m_pos, x, &mut row);
                if delta < -1e-12 {
                    state.apply_swap(backend, m_pos, x);
                    moves_total += 1;
                    failures = 0;
                } else {
                    failures += 1;
                }
            }
            let loss = state.loss();
            if best.as_ref().map(|(l, _)| loss < *l).unwrap_or(true) {
                best = Some((loss, state.medoids.clone()));
            }
        }

        let (_, medoids) = best.unwrap();
        let stats = FitStats {
            swap_evals: backend.counter().get() - start,
            swap_iters: self.num_local,
            swaps_applied: moves_total,
            iters_plus_one: self.num_local + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(Clustering::finalize(backend, medoids, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn clarans_valid_and_distinct_medoids() {
        let ds = synthetic::gmm(&mut Rng::seed_from(60), 100, 4, 3, 4.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = Clarans { num_local: 2, max_neighbor: 100 };
        let fit = algo.fit(&backend, 3, &mut Rng::seed_from(1)).unwrap();
        let set: std::collections::HashSet<_> = fit.medoids.iter().collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn clarans_quality_within_reason_on_easy_data() {
        let ds = synthetic::gmm(&mut Rng::seed_from(61), 120, 4, 3, 8.0);
        let b1 = NativeBackend::new(&ds.points, Metric::L2);
        let pam = Pam::new().fit(&b1, 3, &mut Rng::seed_from(0)).unwrap();
        let b2 = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = Clarans { num_local: 2, max_neighbor: 200 };
        let cl = algo.fit(&b2, 3, &mut Rng::seed_from(1)).unwrap();
        assert!(cl.loss <= pam.loss * 2.0, "{} vs {}", cl.loss, pam.loss);
        assert!(cl.loss >= pam.loss - 1e-9);
    }

    #[test]
    fn seeded_runs_are_reproducible() {
        let ds = synthetic::gmm(&mut Rng::seed_from(62), 80, 3, 2, 3.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let mut algo = Clarans { num_local: 1, max_neighbor: 60 };
        let a = algo.fit(&backend, 2, &mut Rng::seed_from(7)).unwrap();
        let b = algo.fit(&backend, 2, &mut Rng::seed_from(7)).unwrap();
        assert_eq!(a.medoids, b.medoids);
    }
}
