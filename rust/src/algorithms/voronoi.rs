//! Voronoi Iteration (Park & Jun [40]): k-means-style alternation.
//!
//! Initializes with the k most "central" points (smallest weighted total
//! distance — Park & Jun's density heuristic), then alternates between
//! (a) assigning every point to its nearest medoid and (b) recomputing
//! each cluster's medoid as the point minimizing within-cluster total
//! distance, until assignments stabilize. Fast, but converges to weaker
//! local optima than PAM (paper Figure 1a, the worst of the four).

use crate::algorithms::matrix_cache::FullMatrix;
use crate::algorithms::{check_fit_args, degenerate_fit, Clustering, FitStats, KMedoids};
use crate::runtime::backend::DistanceBackend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Park–Jun Voronoi iteration.
#[derive(Debug)]
pub struct VoronoiIteration {
    pub max_iters: usize,
}

impl VoronoiIteration {
    pub fn new() -> Self {
        VoronoiIteration { max_iters: 100 }
    }
}

/// `derive(Default)` would zero `max_iters` and silently skip refinement;
/// delegate to [`VoronoiIteration::new`] instead.
impl Default for VoronoiIteration {
    fn default() -> VoronoiIteration {
        VoronoiIteration::new()
    }
}

impl KMedoids for VoronoiIteration {
    fn name(&self) -> &'static str {
        "voronoi"
    }

    fn fit(
        &mut self,
        backend: &dyn DistanceBackend,
        k: usize,
        _rng: &mut Rng,
    ) -> crate::error::Result<Clustering> {
        check_fit_args(backend, k)?;
        if let Some(c) = degenerate_fit(backend, k) {
            return Ok(c);
        }
        let timer = Timer::start();
        let start = backend.counter().get();
        let n = backend.n();
        let m = FullMatrix::compute(backend);

        // Park–Jun init: v_j = sum_i d(i,j) / sum_l d(i,l); pick k smallest.
        let row_sums: Vec<f64> = (0..n).map(|i| m.row(i).iter().sum()).collect();
        let mut v = vec![0.0f64; n];
        for i in 0..n {
            let inv = 1.0 / row_sums[i].max(1e-300);
            let row = m.row(i);
            for j in 0..n {
                v[j] += row[j] * inv;
            }
        }
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
        let mut medoids: Vec<usize> = order[..k].to_vec();

        let mut assign = vec![0usize; n];
        let mut iters = 0;
        loop {
            iters += 1;
            // (a) assignment
            let mut changed = false;
            for j in 0..n {
                let mut best = (f64::INFINITY, 0usize);
                for (pos, &med) in medoids.iter().enumerate() {
                    let d = m.get(med, j);
                    if d < best.0 {
                        best = (d, pos);
                    }
                }
                if assign[j] != best.1 {
                    assign[j] = best.1;
                    changed = true;
                }
            }
            if !changed && iters > 1 {
                break;
            }
            // (b) medoid update per cluster
            for pos in 0..k {
                let members: Vec<usize> =
                    (0..n).filter(|&j| assign[j] == pos).collect();
                if members.is_empty() {
                    continue;
                }
                let mut best = (f64::INFINITY, medoids[pos]);
                for &cand in &members {
                    let cost: f64 = members.iter().map(|&j| m.get(cand, j)).sum();
                    if cost < best.0 {
                        best = (cost, cand);
                    }
                }
                medoids[pos] = best.1;
            }
            if iters >= self.max_iters {
                break;
            }
        }

        let stats = FitStats {
            build_evals: backend.counter().get() - start,
            swap_iters: iters,
            iters_plus_one: iters + 1,
            wall_secs: timer.secs(),
            ..Default::default()
        };
        Ok(Clustering::finalize(backend, medoids, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::pam::Pam;
    use crate::data::synthetic;
    use crate::distance::Metric;
    use crate::runtime::backend::NativeBackend;

    #[test]
    fn voronoi_converges_and_is_deterministic() {
        let ds = synthetic::gmm(&mut Rng::seed_from(70), 80, 4, 3, 5.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let a = VoronoiIteration::new().fit(&backend, 3, &mut Rng::seed_from(0)).unwrap();
        let b = VoronoiIteration::new().fit(&backend, 3, &mut Rng::seed_from(42)).unwrap();
        assert_eq!(a.medoids, b.medoids);
        assert!(a.stats.swap_iters < 100);
    }

    #[test]
    fn voronoi_quality_is_bounded_vs_pam() {
        let ds = synthetic::gmm(&mut Rng::seed_from(71), 100, 4, 3, 6.0);
        let b1 = NativeBackend::new(&ds.points, Metric::L2);
        let pam = Pam::new().fit(&b1, 3, &mut Rng::seed_from(0)).unwrap();
        let b2 = NativeBackend::new(&ds.points, Metric::L2);
        let vor = VoronoiIteration::new().fit(&b2, 3, &mut Rng::seed_from(0)).unwrap();
        assert!(vor.loss >= pam.loss - 1e-9, "PAM is the quality reference");
        assert!(vor.loss <= pam.loss * 2.0, "{} vs {}", vor.loss, pam.loss);
    }

    #[test]
    fn medoids_lie_in_their_own_clusters() {
        let ds = synthetic::gmm(&mut Rng::seed_from(72), 60, 3, 2, 4.0);
        let backend = NativeBackend::new(&ds.points, Metric::L2);
        let fit = VoronoiIteration::new().fit(&backend, 2, &mut Rng::seed_from(0)).unwrap();
        for (pos, &m) in fit.medoids.iter().enumerate() {
            assert_eq!(fit.assignments[m], pos);
        }
    }
}
