//! Confidence-interval constructions for Algorithm 1.
//!
//! The paper uses the sub-Gaussian Hoeffding interval
//! `C_x = sigma_x * sqrt(log(1/delta) / n_used)` (Algorithm 1, line 8) with
//! `sigma_x` estimated from the first batch. Appendix 2.1 suggests the
//! empirical Bernstein inequality as a way to avoid the sub-Gaussian
//! assumption when a range bound is available; we implement both (the
//! ablation bench compares them).

use crate::bandits::estimator::ArmEstimator;

/// Which confidence interval to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CiKind {
    /// `sigma * sqrt(log(1/delta) / n)` — the paper's interval.
    Hoeffding,
    /// Empirical Bernstein (Maurer & Pontil):
    /// `sqrt(2 * Var * log(3/delta) / n) + 3 * R * log(3/delta) / n`
    /// with `R` the observed range. No sigma estimate required.
    EmpiricalBernstein,
}

/// Confidence half-width for an arm after `n` pulls.
///
/// Returns `f64::INFINITY` before any information is available; returns 0
/// for arms whose mean is known exactly.
pub fn half_width(kind: CiKind, arm: &ArmEstimator, delta: f64) -> f64 {
    if arm.exact.is_some() {
        return 0.0;
    }
    let n = arm.count();
    if n == 0 {
        return f64::INFINITY;
    }
    match kind {
        CiKind::Hoeffding => match arm.sigma {
            None => f64::INFINITY,
            Some(sigma) => {
                if sigma == 0.0 {
                    // Degenerate arm (all g values identical so far): keep a
                    // tiny floor so ties do not collapse CIs to exactly 0.
                    return 0.0;
                }
                sigma * ((1.0 / delta).ln() / n as f64).sqrt()
            }
        },
        CiKind::EmpiricalBernstein => {
            if n < 2 {
                return f64::INFINITY;
            }
            let log_term = (3.0 / delta).ln();
            let var = arm.var();
            (2.0 * var * log_term / n as f64).sqrt()
                + 3.0 * arm.range() * log_term / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arm_with(values: &[f64], sigma: Option<f64>) -> ArmEstimator {
        let mut a = ArmEstimator::default();
        a.update(values);
        a.sigma = sigma;
        a
    }

    #[test]
    fn hoeffding_formula() {
        let a = arm_with(&[0.0; 100], Some(2.0));
        let delta = 1e-3;
        let w = half_width(CiKind::Hoeffding, &a, delta);
        let expect = 2.0 * ((1.0f64 / delta).ln() / 100.0).sqrt();
        assert!((w - expect).abs() < 1e-12);
    }

    #[test]
    fn hoeffding_without_sigma_is_infinite() {
        let a = arm_with(&[1.0, 2.0], None);
        assert!(half_width(CiKind::Hoeffding, &a, 0.01).is_infinite());
    }

    #[test]
    fn widths_shrink_with_n() {
        for kind in [CiKind::Hoeffding, CiKind::EmpiricalBernstein] {
            let small = arm_with(&vec![1.0, 3.0, 2.0, 4.0], Some(1.0));
            let big_vals: Vec<f64> = (0..400).map(|i| 1.0 + (i % 4) as f64).collect();
            let big = arm_with(&big_vals, Some(1.0));
            let ws = half_width(kind, &small, 0.01);
            let wb = half_width(kind, &big, 0.01);
            assert!(wb < ws, "{kind:?}: {wb} !< {ws}");
        }
    }

    #[test]
    fn exact_arm_has_zero_width() {
        let mut a = arm_with(&[5.0, 6.0], Some(3.0));
        a.exact = Some(5.5);
        assert_eq!(half_width(CiKind::Hoeffding, &a, 0.01), 0.0);
        assert_eq!(half_width(CiKind::EmpiricalBernstein, &a, 0.01), 0.0);
    }

    #[test]
    fn bernstein_zero_variance_small_width() {
        let a = arm_with(&[2.0; 50], None);
        let w = half_width(CiKind::EmpiricalBernstein, &a, 0.01);
        assert!(w >= 0.0 && w < 0.1, "w = {w}");
    }

    #[test]
    fn no_pulls_is_infinite() {
        let a = ArmEstimator::default();
        assert!(half_width(CiKind::Hoeffding, &a, 0.01).is_infinite());
        assert!(half_width(CiKind::EmpiricalBernstein, &a, 0.01).is_infinite());
    }
}
