//! Generic best-arm identification: the statistical machinery behind
//! BanditPAM (paper §3.1, Algorithm 1).
//!
//! The BUILD step and every SWAP iteration of PAM share one structure
//! (paper Eq. 8): `argmin_{x in S_tar} (1/|S_ref|) sum_j g_x(x_j)`.
//! [`adaptive::adaptive_search`] solves it as a best-arm problem — batched
//! UCB + successive elimination with per-arm sub-Gaussian confidence
//! intervals — against any [`adaptive::ArmSet`].
//!
//! The coordinator supplies the two concrete arm sets (BUILD candidates,
//! FastPAM1-decomposed SWAP pairs); [`crate::algorithms::meddit`] reuses the
//! same search for the 1-medoid problem of Bagaria et al. [4].

pub mod adaptive;
pub mod confidence;
pub mod estimator;

pub use adaptive::{adaptive_search, AdaptiveConfig, AdaptiveOutcome, ArmSet};
pub use confidence::CiKind;
pub use estimator::ArmEstimator;
