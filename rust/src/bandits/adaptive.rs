//! Algorithm 1 of the paper: Adaptive-Search.
//!
//! A batched UCB / successive-elimination best-arm search over an abstract
//! [`ArmSet`]. Each round draws a reference batch of size `B`, evaluates
//! `g_x` for every *live* arm on that common batch (one `pull_many` — this
//! is what makes the XLA distance backend a dense-block computation),
//! updates per-arm means and confidence intervals, and eliminates arms
//! whose lower confidence bound exceeds the best upper bound. When the
//! sample budget reaches `|S_ref|` the survivors are computed exactly
//! (Algorithm 1, lines 11–15). Both the batched pulls and the exact
//! fallback are dense `block` requests, so on the native engine they run
//! through the pooled tiled row kernels (each exact survivor is a `1 x n`
//! block sharded along the reference axis — see `rust/PERF.md`).

use crate::bandits::confidence::{half_width, CiKind};
use crate::bandits::estimator::ArmEstimator;
use crate::util::rng::Rng;

/// The problem interface Algorithm 1 searches over.
///
/// Implementations: `coordinator::arms::BuildArms`,
/// `coordinator::arms::SwapArms`, and the test doubles in this module.
pub trait ArmSet {
    /// Number of target points (arms), `|S_tar|`.
    fn n_arms(&self) -> usize;

    /// Number of reference points, `|S_ref|`.
    fn n_ref(&self) -> usize;

    /// Evaluate `g_x(ref)` for every arm in `arms` over the common
    /// reference batch `refs`. `out` is row-major `[arms.len() * refs.len()]`.
    fn pull_many(&mut self, arms: &[usize], refs: &[usize], out: &mut [f64]);

    /// Exact mean `mu_x` over the whole reference set (line 14).
    fn exact(&mut self, arm: usize) -> f64;

    /// Cross-search reference permutation (BanditPAM++-style SWAP reuse).
    /// When `Some`, [`SamplingMode::FixedPermutation`] uses this
    /// permutation instead of drawing a fresh one — and consumes no rng —
    /// so consecutive searches see the same reference order and cached
    /// distance rows stay aligned. Must have length `n_ref()`.
    /// Default: `None` (a fresh permutation per search, the seed behavior).
    fn shared_permutation(&self) -> Option<&[usize]> {
        None
    }

    /// Estimator carried over from an earlier search on the same shared
    /// permutation (BanditPAM++ "PI" carry-over). The contract: the
    /// returned estimator must equal what re-pulling the arm on the first
    /// `count()` references of the shared permutation *under the current
    /// arm values* would produce. Algorithm 1 then skips the batches that
    /// prefix already covers. Default: start every arm cold.
    fn warm_estimator(&mut self, _arm: usize) -> Option<ArmEstimator> {
        None
    }

    /// Called once at the end of `adaptive_search` with every arm's final
    /// estimator, so stateful arm sets can persist them for the next
    /// search. Default: drop them.
    fn finish(&mut self, _est: &[ArmEstimator]) {}
}

/// How each arm's sub-Gaussianity parameter `sigma_x` is obtained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SigmaMode {
    /// Per-arm estimate from the first batch (paper §3.2, Eq. 11).
    PerArmFirstBatch,
    /// Per-arm, re-estimated after every batch (running population std).
    PerArmRunning,
    /// One global sigma: max over the per-arm first-batch estimates.
    /// (Ablation `abl-sigma`: the paper argues this inflates CIs.)
    GlobalFirstBatch,
    /// Externally supplied constant.
    Fixed(f64),
}

/// How reference batches are drawn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplingMode {
    /// Uniform with replacement (Algorithm 1, line 5).
    WithReplacement,
    /// Successive slices of one fixed random permutation — every arm sees
    /// the same reference sequence, enabling the Appendix 2.2 cache and
    /// exact-by-exhaustion semantics when the permutation is consumed.
    FixedPermutation,
}

/// Tuning for one Adaptive-Search call.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Batch size `B` (paper: 100).
    pub batch_size: usize,
    /// Error probability `delta` for each CI.
    pub delta: f64,
    pub sigma_mode: SigmaMode,
    pub ci: CiKind,
    pub sampling: SamplingMode,
    /// Early convergence cutoff: when every live arm's *lower* confidence
    /// bound exceeds this threshold, the search stops immediately — with
    /// high probability no arm has mean below it, so the caller (the SWAP
    /// step, with threshold ~0) already knows no improving swap exists.
    /// Without this, a converged SWAP search has all k(n-k) arms tied at
    /// mean 0, nothing is ever eliminated, and Algorithm 1's exact
    /// fallback (line 14) degenerates to k·n² evaluations.
    pub early_stop_above: Option<f64>,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            batch_size: 100,
            delta: 1e-3,
            sigma_mode: SigmaMode::PerArmFirstBatch,
            ci: CiKind::Hoeffding,
            sampling: SamplingMode::WithReplacement,
            early_stop_above: None,
        }
    }
}

/// Result of one Adaptive-Search call.
#[derive(Debug, Clone)]
pub struct AdaptiveOutcome {
    /// Index of the winning arm.
    pub best: usize,
    /// Its estimated (or exact) mean.
    pub best_mean: f64,
    /// Rounds of batched sampling performed.
    pub rounds: usize,
    /// Number of arms that fell through to exact computation (line 14).
    pub exact_fallbacks: usize,
    /// Total g-evaluations (pull count, excluding exact fallbacks).
    pub pulls: u64,
    /// Final per-arm sigma estimates (for the Appendix-Fig-1 experiment).
    pub sigmas: Vec<f64>,
    /// True when the convergence cutoff (`early_stop_above`) fired.
    pub early_stopped: bool,
    /// Confidence-interval half-width of the winning arm at termination
    /// (how decided the search was; telemetry only — computed after the
    /// winner is chosen, so it never influences the search).
    pub best_half_width: f64,
}

/// Run Algorithm 1. Panics if the arm set is empty.
pub fn adaptive_search(
    arms: &mut impl ArmSet,
    cfg: &AdaptiveConfig,
    rng: &mut Rng,
) -> AdaptiveOutcome {
    let n_arms = arms.n_arms();
    assert!(n_arms > 0, "adaptive_search over empty arm set");
    let n_ref = arms.n_ref();
    assert!(n_ref > 0, "adaptive_search with empty reference set");

    // Warm-started estimators (BanditPAM++ carry-over) resume where the
    // previous search on the same shared permutation left off; stateless
    // arm sets return None everywhere and start cold exactly as before.
    let mut est: Vec<ArmEstimator> = Vec::with_capacity(n_arms);
    for a in 0..n_arms {
        est.push(arms.warm_estimator(a).unwrap_or_default());
    }
    let mut live: Vec<usize> = (0..n_arms).collect();
    let mut n_used: usize = 0;
    let mut rounds = 0usize;
    let mut pulls: u64 = 0;
    let mut early_stopped = false;

    // Fixed permutation for SamplingMode::FixedPermutation: the arm set's
    // shared (cross-search) permutation when it offers one, else a fresh
    // draw. Copied locally because `arms` is mutably borrowed by the pulls.
    let mut perm: Vec<usize> = Vec::new();
    if cfg.sampling == SamplingMode::FixedPermutation {
        match arms.shared_permutation() {
            Some(p) => {
                debug_assert_eq!(p.len(), n_ref, "shared permutation length");
                perm.extend_from_slice(p);
            }
            None => {
                perm = (0..n_ref).collect();
                rng.shuffle(&mut perm);
            }
        }
    }

    let mut batch: Vec<usize> = Vec::with_capacity(cfg.batch_size);
    let mut pull_arms: Vec<usize> = Vec::new();
    let mut values: Vec<f64> = Vec::new();

    while n_used < n_ref && live.len() > 1 {
        // --- Line 5: draw the reference batch.
        let b = cfg.batch_size.min(n_ref - n_used).max(1);
        batch.clear();
        match cfg.sampling {
            SamplingMode::WithReplacement => {
                batch.extend((0..b).map(|_| rng.below(n_ref)));
            }
            SamplingMode::FixedPermutation => {
                batch.extend_from_slice(&perm[n_used..n_used + b]);
            }
        }

        // --- Lines 6-7: evaluate on the common batch every live arm whose
        // estimator does not already cover this prefix (warm-started arms
        // skip the batches they absorbed last search; batch boundaries are
        // deterministic in B and n_ref, so carried counts always align).
        pull_arms.clear();
        pull_arms.extend(live.iter().copied().filter(|&a| est[a].count() < (n_used + b) as u64));
        if !pull_arms.is_empty() {
            values.resize(pull_arms.len() * b, 0.0);
            arms.pull_many(&pull_arms, &batch, &mut values);
            pulls += (pull_arms.len() * b) as u64;
            for (row, &a) in pull_arms.iter().enumerate() {
                est[a].update(&values[row * b..(row + 1) * b]);
            }
        }
        n_used += b;
        rounds += 1;

        // --- Sigma estimation (paper §3.2; modes for the ablation).
        match cfg.sigma_mode {
            SigmaMode::PerArmFirstBatch => {
                if rounds == 1 {
                    for &a in &live {
                        // Warm arms keep their carried first-batch sigma.
                        if est[a].sigma.is_none() {
                            est[a].sigma = Some(est[a].std_pop());
                        }
                    }
                }
            }
            SigmaMode::PerArmRunning => {
                for &a in &live {
                    est[a].sigma = Some(est[a].std_pop());
                }
            }
            SigmaMode::GlobalFirstBatch => {
                if rounds == 1 {
                    let g = live
                        .iter()
                        .map(|&a| est[a].std_pop())
                        .fold(0.0f64, f64::max);
                    for &a in &live {
                        est[a].sigma = Some(g);
                    }
                }
            }
            SigmaMode::Fixed(s) => {
                if rounds == 1 {
                    for &a in &live {
                        est[a].sigma = Some(s);
                    }
                }
            }
        }

        // --- Lines 8-9: successive elimination.
        let mut best_ucb = f64::INFINITY;
        let mut best_lcb = f64::INFINITY;
        for &a in &live {
            let w = half_width(cfg.ci, &est[a], cfg.delta);
            best_ucb = best_ucb.min(est[a].mean() + w);
            best_lcb = best_lcb.min(est[a].mean() - w);
        }
        live.retain(|&a| {
            let w = half_width(cfg.ci, &est[a], cfg.delta);
            est[a].mean() - w <= best_ucb
        });
        debug_assert!(!live.is_empty(), "eliminated every arm");

        // --- Convergence cutoff (see AdaptiveConfig::early_stop_above).
        if let Some(thr) = cfg.early_stop_above {
            if best_lcb > thr {
                early_stopped = true;
                break;
            }
        }
    }

    // --- Lines 11-15: single survivor, or exact fallback. Two cases skip
    // the exact pass entirely:
    //   * the convergence cutoff fired (the estimate is already decisive);
    //   * FixedPermutation sampling exhausted the whole reference set — a
    //     surviving arm has then seen every reference exactly once, so its
    //     running mean *is* mu_x (the Appendix-2.2 "fixed ordering"
    //     optimization; with-replacement cannot make this claim).
    let exhausted_exactly =
        cfg.sampling == SamplingMode::FixedPermutation && n_used >= n_ref;
    let skip_exact = early_stopped || exhausted_exactly;
    let exact_fallbacks = if live.len() > 1 && !skip_exact { live.len() } else { 0 };
    if live.len() > 1 && !skip_exact {
        for &a in &live {
            let mu = arms.exact(a);
            est[a].exact = Some(mu);
        }
    }
    let best = *live
        .iter()
        .min_by(|&&a, &&b| est[a].mean().partial_cmp(&est[b].mean()).unwrap())
        .unwrap();

    // Hand the final estimators back to stateful arm sets (the SWAP
    // session persists them for the next iteration's warm start).
    arms.finish(&est);

    AdaptiveOutcome {
        best,
        best_mean: est[best].mean(),
        rounds,
        exact_fallbacks,
        pulls,
        best_half_width: half_width(cfg.ci, &est[best], cfg.delta),
        sigmas: est
            .iter()
            .map(|e| e.sigma.unwrap_or(0.0))
            .collect(),
        early_stopped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Arm set with Gaussian rewards of known means: `g_a(j)` is a
    /// deterministic function of (arm, ref index) built from a hash, so the
    /// empirical mean over all refs is fixed and exact() agrees with it.
    struct SyntheticArms {
        means: Vec<f64>,
        noise: f64,
        n_ref: usize,
    }

    impl SyntheticArms {
        fn g(&self, arm: usize, r: usize) -> f64 {
            // deterministic pseudo-noise in [-0.5, 0.5)
            let mut h = (arm as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (r as u64).wrapping_mul(0xD1B54A32D192ED03);
            h ^= h >> 33;
            h = h.wrapping_mul(0xFF51AFD7ED558CCD);
            h ^= h >> 33;
            let u = (h >> 11) as f64 / (1u64 << 53) as f64 - 0.5;
            self.means[arm] + self.noise * u
        }
    }

    impl ArmSet for SyntheticArms {
        fn n_arms(&self) -> usize {
            self.means.len()
        }
        fn n_ref(&self) -> usize {
            self.n_ref
        }
        fn pull_many(&mut self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
            for (i, &a) in arms.iter().enumerate() {
                for (j, &r) in refs.iter().enumerate() {
                    out[i * refs.len() + j] = self.g(a, r);
                }
            }
        }
        fn exact(&mut self, arm: usize) -> f64 {
            (0..self.n_ref).map(|r| self.g(arm, r)).sum::<f64>() / self.n_ref as f64
        }
    }

    fn exact_best(arms: &mut SyntheticArms) -> usize {
        let n = arms.n_arms();
        (0..n)
            .min_by(|&a, &b| arms.exact(a).partial_cmp(&arms.exact(b)).unwrap())
            .unwrap()
    }

    #[test]
    fn finds_clearly_separated_best_arm() {
        let mut arms = SyntheticArms {
            means: vec![1.0, 0.2, 1.5, 0.9, 1.1],
            noise: 0.3,
            n_ref: 5_000,
        };
        let out = adaptive_search(&mut arms, &AdaptiveConfig::default(), &mut Rng::seed_from(1));
        assert_eq!(out.best, 1);
        // should need far fewer pulls than exhaustive 5 * 5000
        assert!(out.pulls < 25_000, "pulls = {}", out.pulls);
    }

    #[test]
    fn agrees_with_exact_argmin_over_seeds() {
        for seed in 0..20 {
            let mut rng = Rng::seed_from(1000 + seed);
            let means: Vec<f64> = (0..30).map(|_| rng.f64() * 2.0).collect();
            let mut arms = SyntheticArms { means, noise: 0.4, n_ref: 2_000 };
            let want = exact_best(&mut arms);
            let out = adaptive_search(
                &mut arms,
                &AdaptiveConfig { delta: 1e-5, ..Default::default() },
                &mut rng,
            );
            assert_eq!(out.best, want, "seed {seed}");
        }
    }

    #[test]
    fn close_arms_trigger_exact_fallback_and_stay_correct() {
        // Means closer than noise/sqrt(n_ref): elimination cannot finish,
        // so line 14 kicks in and exact computation decides.
        let mut arms = SyntheticArms {
            means: vec![0.5000, 0.5001, 0.9],
            noise: 1.0,
            n_ref: 300,
        };
        let want = exact_best(&mut arms);
        let out = adaptive_search(&mut arms, &AdaptiveConfig::default(), &mut Rng::seed_from(2));
        assert_eq!(out.best, want);
        assert!(out.exact_fallbacks >= 2, "fallbacks {}", out.exact_fallbacks);
    }

    #[test]
    fn single_arm_short_circuits() {
        let mut arms = SyntheticArms { means: vec![3.0], noise: 0.1, n_ref: 100 };
        let out = adaptive_search(&mut arms, &AdaptiveConfig::default(), &mut Rng::seed_from(3));
        assert_eq!(out.best, 0);
        assert_eq!(out.pulls, 0); // loop never entered: |S| == 1 immediately
    }

    #[test]
    fn fixed_permutation_mode_matches_exact_when_exhausted() {
        let mut arms = SyntheticArms {
            means: vec![0.50, 0.50001],
            noise: 2.0,
            n_ref: 500,
        };
        let cfg = AdaptiveConfig {
            sampling: SamplingMode::FixedPermutation,
            ..Default::default()
        };
        let want = exact_best(&mut arms);
        let out = adaptive_search(&mut arms, &cfg, &mut Rng::seed_from(4));
        assert_eq!(out.best, want);
    }

    #[test]
    fn zero_noise_eliminates_after_first_batches() {
        let mut arms = SyntheticArms {
            means: vec![1.0, 2.0, 3.0, 4.0],
            noise: 0.0,
            n_ref: 100_000,
        };
        let out = adaptive_search(&mut arms, &AdaptiveConfig::default(), &mut Rng::seed_from(5));
        assert_eq!(out.best, 0);
        assert!(out.rounds <= 2, "rounds {}", out.rounds);
        assert!(out.pulls <= 2 * 4 * 100);
    }

    #[test]
    fn bernstein_ci_also_finds_best() {
        let mut arms = SyntheticArms {
            means: vec![1.0, 0.1, 0.9],
            noise: 0.5,
            n_ref: 3_000,
        };
        let cfg = AdaptiveConfig { ci: CiKind::EmpiricalBernstein, ..Default::default() };
        let out = adaptive_search(&mut arms, &cfg, &mut Rng::seed_from(6));
        assert_eq!(out.best, 1);
    }

    #[test]
    fn sigma_modes_all_converge() {
        for mode in [
            SigmaMode::PerArmFirstBatch,
            SigmaMode::PerArmRunning,
            SigmaMode::GlobalFirstBatch,
            SigmaMode::Fixed(0.5),
        ] {
            let mut arms = SyntheticArms {
                means: vec![1.0, 0.1, 0.9, 1.4],
                noise: 0.5,
                n_ref: 4_000,
            };
            let cfg = AdaptiveConfig { sigma_mode: mode, ..Default::default() };
            let out = adaptive_search(&mut arms, &cfg, &mut Rng::seed_from(7));
            assert_eq!(out.best, 1, "{mode:?}");
        }
    }

    #[test]
    #[should_panic(expected = "empty arm set")]
    fn empty_arm_set_panics() {
        let mut arms = SyntheticArms { means: vec![], noise: 0.0, n_ref: 10 };
        adaptive_search(&mut arms, &AdaptiveConfig::default(), &mut Rng::seed_from(0));
    }

    /// Stateful wrapper exercising the cross-search API: a shared
    /// permutation plus estimator carry-over between two searches.
    struct CarryArms {
        inner: SyntheticArms,
        perm: Vec<usize>,
        carried: Vec<Option<ArmEstimator>>,
        finished: Vec<ArmEstimator>,
    }

    impl ArmSet for CarryArms {
        fn n_arms(&self) -> usize {
            self.inner.n_arms()
        }
        fn n_ref(&self) -> usize {
            self.inner.n_ref()
        }
        fn pull_many(&mut self, arms: &[usize], refs: &[usize], out: &mut [f64]) {
            self.inner.pull_many(arms, refs, out);
        }
        fn exact(&mut self, arm: usize) -> f64 {
            self.inner.exact(arm)
        }
        fn shared_permutation(&self) -> Option<&[usize]> {
            Some(&self.perm)
        }
        fn warm_estimator(&mut self, arm: usize) -> Option<ArmEstimator> {
            self.carried[arm].take()
        }
        fn finish(&mut self, est: &[ArmEstimator]) {
            self.finished = est.to_vec();
        }
    }

    #[test]
    fn warm_resume_skips_covered_batches_and_agrees() {
        let means: Vec<f64> = vec![1.0, 0.2, 1.5, 0.9, 1.1, 0.8, 1.3];
        let n_arms = means.len();
        let make = |carried: Vec<Option<ArmEstimator>>| CarryArms {
            inner: SyntheticArms { means: means.clone(), noise: 0.4, n_ref: 3_000 },
            perm: {
                let mut p: Vec<usize> = (0..3_000).collect();
                Rng::seed_from(7).shuffle(&mut p);
                p
            },
            carried,
            finished: Vec::new(),
        };
        let cfg = AdaptiveConfig {
            sampling: SamplingMode::FixedPermutation,
            ..Default::default()
        };
        let mut cold = make(vec![None; n_arms]);
        let out_cold = adaptive_search(&mut cold, &cfg, &mut Rng::seed_from(1));
        assert_eq!(out_cold.best, 1);
        assert!(!cold.finished.is_empty(), "finish hook must run");

        // Resume: carry every arm's final estimator. The g-values are a
        // deterministic function of (arm, ref), so the carry contract
        // (bitwise-equal to re-pulling the same prefix) holds exactly.
        let carried = cold.finished.iter().map(|e| Some(e.carry())).collect();
        let mut warm = make(carried);
        let out_warm = adaptive_search(&mut warm, &cfg, &mut Rng::seed_from(2));
        assert_eq!(out_warm.best, out_cold.best);
        assert!(
            out_warm.pulls < out_cold.pulls,
            "warm resume must skip covered batches: {} vs {}",
            out_warm.pulls,
            out_cold.pulls
        );
    }
}
