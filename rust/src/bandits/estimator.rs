//! Per-arm statistics: running mean, count, sigma estimate, CI.

use crate::stats::running::Running;

/// State tracked for each arm in Algorithm 1.
#[derive(Debug, Clone)]
pub struct ArmEstimator {
    stats: Running,
    /// Sub-Gaussian scale parameter `sigma_x`; estimated from the first
    /// batch (paper Eq. 11) unless overridden; `None` until then.
    pub sigma: Option<f64>,
    /// Observed value range (for the empirical-Bernstein CI variant).
    pub min_seen: f64,
    pub max_seen: f64,
    /// Set when the arm's mean was computed exactly (CI is then zero).
    pub exact: Option<f64>,
}

impl Default for ArmEstimator {
    fn default() -> Self {
        ArmEstimator {
            stats: Running::new(),
            sigma: None,
            min_seen: f64::INFINITY,
            max_seen: f64::NEG_INFINITY,
            exact: None,
        }
    }
}

impl ArmEstimator {
    /// Record a batch of g-values.
    pub fn update(&mut self, values: &[f64]) {
        for &v in values {
            self.stats.push(v);
            self.min_seen = self.min_seen.min(v);
            self.max_seen = self.max_seen.max(v);
        }
    }

    /// Current mean estimate (exact value wins when present).
    #[inline]
    pub fn mean(&self) -> f64 {
        self.exact.unwrap_or_else(|| self.stats.mean())
    }

    /// Pulls so far.
    #[inline]
    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    /// Population std of observed values (the paper's Eq. 11 estimator).
    #[inline]
    pub fn std_pop(&self) -> f64 {
        self.stats.std_pop()
    }

    /// Sample variance of observed values.
    #[inline]
    pub fn var(&self) -> f64 {
        self.stats.var()
    }

    /// Observed range (0 when fewer than 2 observations).
    pub fn range(&self) -> f64 {
        if self.count() < 2 {
            0.0
        } else {
            (self.max_seen - self.min_seen).max(0.0)
        }
    }

    /// Copy for cross-search carry-over (BanditPAM++-style SWAP reuse):
    /// keeps the running moments, sigma and observed range — which remain
    /// valid when the arm's g-values over the consumed reference prefix are
    /// unchanged — but clears `exact`, which was computed under the *old*
    /// medoid state and must not suppress the new search's CIs.
    pub fn carry(&self) -> ArmEstimator {
        ArmEstimator { exact: None, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_and_mean() {
        let mut a = ArmEstimator::default();
        a.update(&[1.0, 2.0, 3.0]);
        assert_eq!(a.count(), 3);
        assert!((a.mean() - 2.0).abs() < 1e-12);
        assert_eq!(a.min_seen, 1.0);
        assert_eq!(a.max_seen, 3.0);
        assert_eq!(a.range(), 2.0);
    }

    #[test]
    fn exact_overrides_mean() {
        let mut a = ArmEstimator::default();
        a.update(&[10.0, 20.0]);
        a.exact = Some(-5.0);
        assert_eq!(a.mean(), -5.0);
    }

    #[test]
    fn empty_range_is_zero() {
        let mut a = ArmEstimator::default();
        assert_eq!(a.range(), 0.0);
        a.update(&[4.0]);
        assert_eq!(a.range(), 0.0);
    }

    #[test]
    fn carry_keeps_moments_but_clears_exact() {
        let mut a = ArmEstimator::default();
        a.update(&[1.0, 2.0, 3.0, 4.0]);
        a.sigma = Some(0.7);
        a.exact = Some(2.5);
        let c = a.carry();
        assert_eq!(c.count(), 4);
        assert!((c.mean() - 2.5).abs() < 1e-12); // stats mean, not `exact`
        assert_eq!(c.sigma, Some(0.7));
        assert_eq!(c.min_seen, 1.0);
        assert_eq!(c.max_seen, 4.0);
        assert!(c.exact.is_none());
    }

    #[test]
    fn sigma_estimate_matches_population_std() {
        let mut a = ArmEstimator::default();
        a.update(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((a.std_pop() - 2.0).abs() < 1e-12);
    }
}
