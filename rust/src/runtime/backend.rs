//! The `DistanceBackend` trait and the native (pure-Rust) engine.

use crate::data::Points;
use crate::distance::cache::DistanceCache;
use crate::distance::counter::DistanceCounter;
use crate::distance::{evaluate, Metric};
use std::sync::Arc;

/// A distance engine over a fixed point set.
///
/// All algorithm code computes distances exclusively through this trait, so
/// evaluation counting, caching and the XLA path are transparent to it.
pub trait DistanceBackend {
    /// The point set.
    fn points(&self) -> &Points;

    /// The active metric.
    fn metric(&self) -> Metric;

    /// The shared evaluation counter.
    fn counter(&self) -> &DistanceCounter;

    /// Number of points.
    fn n(&self) -> usize {
        self.points().len()
    }

    /// Distance between points `i` and `j` (counted).
    fn dist(&self, i: usize, j: usize) -> f64;

    /// Dense distance block: `out[t * refs.len() + r] = d(targets[t], refs[r])`.
    ///
    /// `out.len()` must equal `targets.len() * refs.len()`. The default
    /// implementation loops over [`DistanceBackend::dist`]; engines override
    /// it with batched/parallel execution.
    fn block(&self, targets: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), targets.len() * refs.len());
        for (ti, &t) in targets.iter().enumerate() {
            for (ri, &r) in refs.iter().enumerate() {
                out[ti * refs.len() + ri] = self.dist(t, r);
            }
        }
    }

    /// Short engine name for logs/reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust engine: optimized dense kernels + Zhang–Shasha, thread-sharded
/// blocks, optional Appendix-2.2 pairwise cache.
pub struct NativeBackend<'a> {
    points: &'a Points,
    metric: Metric,
    counter: DistanceCounter,
    cache: Option<Arc<DistanceCache>>,
    /// Thread count for [`DistanceBackend::block`]; 1 disables sharding.
    threads: usize,
}

impl<'a> NativeBackend<'a> {
    /// New engine over `points` with `metric`. Panics on an incompatible
    /// metric/storage combination.
    pub fn new(points: &'a Points, metric: Metric) -> Self {
        assert!(
            metric.supports(points),
            "metric {metric} does not support {} points",
            points.kind()
        );
        NativeBackend {
            points,
            metric,
            counter: DistanceCounter::new(),
            cache: None,
            threads: 1,
        }
    }

    /// Enable the pairwise cache with a soft entry capacity.
    pub fn with_cache(mut self, capacity: usize) -> Self {
        self.cache = Some(Arc::new(DistanceCache::new(capacity)));
        self
    }

    /// Enable thread-sharded block evaluation.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Cache statistics, when the cache is enabled: (hits, misses).
    pub fn cache_stats(&self) -> Option<(u64, u64)> {
        self.cache.as_ref().map(|c| c.stats())
    }

    #[inline]
    fn raw(&self, i: usize, j: usize) -> f64 {
        match &self.cache {
            None => {
                self.counter.add(1);
                evaluate(self.metric, self.points, i, j)
            }
            Some(cache) => cache.get_or_compute(i, j, || {
                self.counter.add(1);
                evaluate(self.metric, self.points, i, j)
            }),
        }
    }

    /// Per-element work heuristic used to decide when threading pays off.
    fn elem_cost(&self) -> usize {
        match (self.metric, self.points) {
            (Metric::TreeEdit, _) => 400,
            (_, Points::Dense(m)) => m.cols().max(1),
            _ => 64,
        }
    }
}

impl<'a> DistanceBackend for NativeBackend<'a> {
    fn points(&self) -> &Points {
        self.points
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn counter(&self) -> &DistanceCounter {
        &self.counter
    }

    fn dist(&self, i: usize, j: usize) -> f64 {
        self.raw(i, j)
    }

    fn block(&self, targets: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), targets.len() * refs.len());
        // Cache-less fast path: count the whole block with one atomic add
        // instead of one per distance (measurable on the hot loop — see
        // EXPERIMENTS.md §Perf) and skip the per-element counter code.
        if self.cache.is_none() && self.threads <= 1 {
            self.counter.add((targets.len() * refs.len()) as u64);
            for (ti, &t) in targets.iter().enumerate() {
                for (ri, &r) in refs.iter().enumerate() {
                    out[ti * refs.len() + ri] = evaluate(self.metric, self.points, t, r);
                }
            }
            return;
        }
        let work = targets.len() * refs.len() * self.elem_cost();
        // Threading threshold: below ~1M scalar ops the spawn overhead wins.
        if self.threads <= 1 || work < 1_000_000 || targets.len() < 2 {
            for (ti, &t) in targets.iter().enumerate() {
                for (ri, &r) in refs.iter().enumerate() {
                    out[ti * refs.len() + ri] = self.raw(t, r);
                }
            }
            return;
        }
        let shard = targets.len().div_ceil(self.threads);
        let rn = refs.len();
        std::thread::scope(|scope| {
            let mut rest = out;
            let mut start = 0usize;
            while start < targets.len() {
                let end = (start + shard).min(targets.len());
                let rows = end - start;
                let (chunk, tail) = rest.split_at_mut(rows * rn);
                rest = tail;
                let tgt = &targets[start..end];
                let this = &*self;
                scope.spawn(move || {
                    for (ti, &t) in tgt.iter().enumerate() {
                        for (ri, &r) in refs.iter().enumerate() {
                            chunk[ti * rn + ri] = this.raw(t, r);
                        }
                    }
                });
                start = end;
            }
        });
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Compute the k-medoids loss (Eq. 1) and point assignments for a medoid
/// set: each point contributes its distance to the nearest medoid.
pub fn loss_and_assignments(
    backend: &dyn DistanceBackend,
    medoids: &[usize],
) -> (f64, Vec<usize>) {
    assert!(!medoids.is_empty());
    let n = backend.n();
    let mut loss = 0.0;
    let mut assign = vec![0usize; n];
    for i in 0..n {
        let mut best = f64::INFINITY;
        let mut who = 0;
        for (mi, &m) in medoids.iter().enumerate() {
            let d = backend.dist(m, i);
            if d < best {
                best = d;
                who = mi;
            }
        }
        loss += best;
        assign[i] = who;
    }
    (loss, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn dataset() -> crate::data::Dataset {
        synthetic::gmm(&mut Rng::seed_from(1), 40, 8, 3, 3.0)
    }

    #[test]
    fn dist_counts_evaluations() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        b.dist(0, 1);
        b.dist(2, 3);
        assert_eq!(b.counter().get(), 2);
    }

    #[test]
    fn cache_avoids_recomputation() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L2).with_cache(10_000);
        let d1 = b.dist(0, 1);
        let d2 = b.dist(1, 0);
        assert_eq!(d1, d2);
        assert_eq!(b.counter().get(), 1, "second lookup must hit the cache");
        assert_eq!(b.cache_stats(), Some((1, 1)));
    }

    #[test]
    fn block_matches_dist_single_thread() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L1);
        let targets = [0, 5, 7];
        let refs = [1, 2, 3, 4];
        let mut out = vec![0.0; 12];
        b.block(&targets, &refs, &mut out);
        for (ti, &t) in targets.iter().enumerate() {
            for (ri, &r) in refs.iter().enumerate() {
                assert_eq!(out[ti * 4 + ri], b.dist(t, r));
            }
        }
    }

    #[test]
    fn block_threaded_matches_serial() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 200, 64, 3, 2.0);
        let serial = NativeBackend::new(&ds.points, Metric::L2);
        let threaded = NativeBackend::new(&ds.points, Metric::L2).with_threads(4);
        let targets: Vec<usize> = (0..150).collect();
        let refs: Vec<usize> = (50..200).collect();
        let mut a = vec![0.0; targets.len() * refs.len()];
        let mut b = vec![0.0; targets.len() * refs.len()];
        serial.block(&targets, &refs, &mut a);
        threaded.block(&targets, &refs, &mut b);
        assert_eq!(a, b);
        assert_eq!(serial.counter().get(), threaded.counter().get());
    }

    #[test]
    fn loss_and_assignments_basics() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let (loss, assign) = loss_and_assignments(&b, &[0, 1]);
        assert!(loss > 0.0);
        assert_eq!(assign.len(), 40);
        // medoids are assigned to themselves with distance zero
        assert_eq!(assign[0], 0);
        assert_eq!(assign[1], 1);
        // every assignment is the argmin over medoids
        for i in 0..40 {
            let d0 = b.dist(0, i);
            let d1 = b.dist(1, i);
            let want = if d0 <= d1 { 0 } else { 1 };
            assert_eq!(assign[i], want, "point {i}");
        }
    }

    #[test]
    #[should_panic(expected = "does not support")]
    fn incompatible_metric_panics() {
        let ds = dataset();
        NativeBackend::new(&ds.points, Metric::TreeEdit);
    }
}
