//! The `DistanceBackend` trait and the native (pure-Rust) engine.
//!
//! The native engine's `block` path is the hottest code in the repository
//! (the paper attributes >98% of wall-clock to distance evaluation). It is
//! organized around three ideas — see `rust/PERF.md` for the full design
//! and measured numbers:
//!
//! 1. **Persistent pool** ([`crate::runtime::pool::ThreadPool`]): workers
//!    are spawned once per backend and reused for every block, replacing
//!    the seed's per-call `std::thread::scope`.
//! 2. **Hoisted kernel dispatch**: the `Metric`/`Points` match happens
//!    once per block ([`NativeBackend::kernel`]), and each target row is
//!    filled by a one-to-many row kernel from [`crate::distance::dense`].
//! 3. **Cosine norm table**: squared norms are precomputed per point, so
//!    a cosine pair costs one dot product instead of three reductions.
//!
//! Evaluation counting is batched: one atomic add per block (cache-less)
//! or one per shard of cache misses, never one per distance.

use crate::data::sparse::CsrMatrix;
use crate::data::Points;
use crate::distance::cache::DistanceCache;
use crate::distance::counter::DistanceCounter;
use crate::distance::{dense, evaluate, sparse, Metric};
use crate::error::{Error, Result};
use crate::runtime::pool::ThreadPool;
use crate::util::matrix::Matrix;
use std::sync::Arc;

/// A distance engine over a fixed point set.
///
/// All algorithm code computes distances exclusively through this trait, so
/// evaluation counting, caching and the XLA path are transparent to it.
pub trait DistanceBackend {
    /// The point set.
    fn points(&self) -> &Points;

    /// The active metric.
    fn metric(&self) -> Metric;

    /// The shared evaluation counter.
    fn counter(&self) -> &DistanceCounter;

    /// Number of points.
    fn n(&self) -> usize {
        self.points().len()
    }

    /// Distance between points `i` and `j` (counted).
    fn dist(&self, i: usize, j: usize) -> f64;

    /// Dense distance block: `out[t * refs.len() + r] = d(targets[t], refs[r])`.
    ///
    /// `out.len()` must equal `targets.len() * refs.len()`. The default
    /// implementation loops over [`DistanceBackend::dist`]; engines override
    /// it with batched/parallel execution.
    fn block(&self, targets: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), targets.len() * refs.len());
        for (ti, &t) in targets.iter().enumerate() {
            for (ri, &r) in refs.iter().enumerate() {
                out[ti * refs.len() + ri] = self.dist(t, r);
            }
        }
    }

    /// Short engine name for logs/reports.
    fn name(&self) -> &'static str;

    /// Pairwise-cache effectiveness, when the engine has one:
    /// `(hits, misses)`. Telemetry only — reading it never perturbs the
    /// cache. Engines without a cache return `None` (the default).
    fn cache_stats(&self) -> Option<(u64, u64)> {
        None
    }

    /// Engine-level override for [`loss_and_assignments`]: return the
    /// full `(loss, assignments)` result, or `None` to use the tiled
    /// local fold. Engines that can score more efficiently (the sharded
    /// pool fans the pass out to workers) implement this; the contract is
    /// **bitwise equality** with the local fold — same strict-`<`
    /// first-minimum, same row-order loss accumulation, same eval counts
    /// into [`DistanceBackend::counter`].
    fn score(&self, _medoids: &[usize]) -> Option<(f64, Vec<usize>)> {
        None
    }
}

/// Per-block kernel selection: the `Metric`/`Points` dispatch is resolved
/// once here, so the inner loops run without enum matching or `Points`
/// destructuring per pair.
#[derive(Clone, Copy)]
enum PairKernel<'m> {
    L2(&'m Matrix),
    L1(&'m Matrix),
    /// Cosine over the precomputed squared-norm table.
    Cosine { m: &'m Matrix, sq_norms: &'m [f64] },
    /// Sparse l2 over the squared-norm table (`norms[i] = |row i|^2`).
    SparseL2 { m: &'m CsrMatrix, sq_norms: &'m [f64] },
    /// Sparse l1 over the abs-sum table (`norms[i] = ||row i||_1`).
    SparseL1 { m: &'m CsrMatrix, abs_sums: &'m [f64] },
    /// Sparse cosine over the squared-norm table.
    SparseCosine { m: &'m CsrMatrix, sq_norms: &'m [f64] },
    /// Anything without a dense/sparse fast path (tree edit distance).
    Generic,
}

/// Work (in scalar ops) below which pool dispatch is not worth the wakeup.
/// The persistent pool costs a few microseconds per task — two orders of
/// magnitude below the seed's thread spawning — so this is much lower than
/// the seed's 1M-op threshold.
const POOL_MIN_WORK: usize = 250_000;

/// Pure-Rust engine: optimized dense kernels + Zhang–Shasha, pooled
/// block sharding, optional Appendix-2.2 pairwise cache.
pub struct NativeBackend<'a> {
    points: &'a Points,
    metric: Metric,
    counter: DistanceCounter,
    cache: Option<Arc<DistanceCache>>,
    /// Persistent worker pool for [`DistanceBackend::block`]; `None`
    /// (single-threaded) until [`NativeBackend::with_threads`] or
    /// [`NativeBackend::with_pool`] enables it. `Arc` so a long-lived
    /// server can share one warm pool across per-request backends.
    pool: Option<Arc<ThreadPool>>,
    threads: usize,
    /// Minimum block work (scalar ops) before the pool is used.
    pool_min_work: usize,
    /// Per-point reduction table for the from-parts kernels; empty when the
    /// metric/storage combination has none. Dense cosine and sparse
    /// l2/cosine: squared L2 norms (one dot product per pair instead of
    /// three reductions). Sparse l1: abs sums (the overlap-correction
    /// kernel — see `distance/sparse.rs`).
    norms: Vec<f64>,
    /// Process-metric handles, resolved once at construction so the block
    /// hot path pays two atomic ops — no registry lookups, no allocation.
    obs_blocks: Arc<crate::obs::Counter>,
    obs_block_pairs: Arc<crate::obs::Histogram>,
    /// Per-kernel wall-time histogram (`kernel_us{kernel="l2_dense"}`,
    /// ...): one scoped span per block/block_vs call. Timing only — the
    /// span never touches the data path, so it is bitwise-inert
    /// (asserted in `tests/property_obs.rs`).
    obs_kernel_us: Arc<crate::obs::Histogram>,
}

impl<'a> NativeBackend<'a> {
    /// New engine over `points` with `metric`. Panics on an incompatible
    /// metric/storage combination.
    pub fn new(points: &'a Points, metric: Metric) -> Self {
        assert!(
            metric.supports(points),
            "metric {metric} does not support {} points",
            points.kind()
        );
        let norms = Self::norms_for(metric, points);
        NativeBackend {
            points,
            metric,
            counter: DistanceCounter::new(),
            cache: None,
            pool: None,
            threads: 1,
            pool_min_work: POOL_MIN_WORK,
            norms,
            obs_blocks: crate::obs::global().counter("backend_blocks_total"),
            obs_block_pairs: crate::obs::global().histogram("backend_block_pairs"),
            obs_kernel_us: crate::obs::global().histogram(&format!(
                "kernel_us{{kernel=\"{}_{}\"}}",
                metric.name(),
                points.kind()
            )),
        }
    }

    /// The per-point reduction table `metric` needs over `points` — the
    /// same table [`NativeBackend::new`] builds for its own point set
    /// (dense cosine and sparse l2/cosine: squared L2 norms; sparse l1:
    /// abs sums; empty otherwise). The query-vs-medoids cross path
    /// ([`NativeBackend::block_vs`]) needs a second instance of it for the
    /// query set, computed identically so predict-on-training-set is
    /// bitwise-equal to the training assignments.
    pub fn norms_for(metric: Metric, points: &Points) -> Vec<f64> {
        match (metric, points) {
            (Metric::Cosine, Points::Dense(m)) => {
                (0..m.rows()).map(|i| dense::sq_norm(m.row(i))).collect()
            }
            (Metric::L2 | Metric::Cosine, Points::Sparse(m)) => sparse::sq_norm_table(m),
            (Metric::L1, Points::Sparse(m)) => sparse::abs_sum_table(m),
            _ => Vec::new(),
        }
    }

    /// Enable the pairwise cache with a soft entry capacity.
    pub fn with_cache(mut self, capacity: usize) -> Self {
        self.cache = Some(Arc::new(DistanceCache::new(capacity)));
        self
    }

    /// Enable pooled block evaluation with `threads` execution lanes. The
    /// pool is created once, here, and reused by every subsequent block.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self.pool = if self.threads > 1 {
            Some(Arc::new(ThreadPool::new(self.threads)))
        } else {
            None
        };
        self
    }

    /// Use an existing shared pool instead of spawning a fresh one. The
    /// serve layer creates one warm pool at startup and threads it through
    /// every per-batch backend, so request handling never pays thread
    /// spawn/teardown.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.threads = pool.threads();
        self.pool = if self.threads > 1 { Some(pool) } else { None };
        self
    }

    /// Override the pool's minimum-work threshold (scalar ops). Intended
    /// for tests that need to force pooled execution on tiny blocks.
    #[doc(hidden)]
    pub fn with_pool_min_work(mut self, min_work: usize) -> Self {
        self.pool_min_work = min_work;
        self
    }

    /// Cache statistics, when the cache is enabled: (hits, misses).
    pub fn cache_stats(&self) -> Option<(u64, u64)> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Resolve the block kernel once (hoists the dispatch out of the
    /// inner loops).
    fn kernel(&self) -> PairKernel<'_> {
        match (self.metric, self.points) {
            (Metric::L2, Points::Dense(m)) => PairKernel::L2(m),
            (Metric::L1, Points::Dense(m)) => PairKernel::L1(m),
            (Metric::Cosine, Points::Dense(m)) => {
                PairKernel::Cosine { m, sq_norms: &self.norms }
            }
            (Metric::L2, Points::Sparse(m)) => {
                PairKernel::SparseL2 { m, sq_norms: &self.norms }
            }
            (Metric::L1, Points::Sparse(m)) => {
                PairKernel::SparseL1 { m, abs_sums: &self.norms }
            }
            (Metric::Cosine, Points::Sparse(m)) => {
                PairKernel::SparseCosine { m, sq_norms: &self.norms }
            }
            _ => PairKernel::Generic,
        }
    }

    /// One uncounted pair evaluation through the resolved kernel. The
    /// cosine norm-table path is bitwise-identical to `dense::cosine`
    /// (same per-lane accumulation order), and the sparse merge kernels
    /// are bitwise-identical to the sparse scatter row kernels (see
    /// `distance/sparse.rs`), so `dist` and `block` agree exactly for
    /// every metric/storage combination.
    #[inline]
    fn pair(&self, kern: &PairKernel<'_>, i: usize, j: usize) -> f64 {
        match *kern {
            PairKernel::L2(m) => dense::l2(m.row(i), m.row(j)),
            PairKernel::L1(m) => dense::l1(m.row(i), m.row(j)),
            PairKernel::Cosine { m, sq_norms } => dense::cosine_from_parts(
                dense::dot(m.row(i), m.row(j)),
                sq_norms[i],
                sq_norms[j],
            ),
            PairKernel::SparseL2 { m, sq_norms } => {
                let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
                sparse::l2_from_parts(sq_norms[i], sq_norms[j], sparse::dot(ai, av, bi, bv))
            }
            PairKernel::SparseL1 { m, abs_sums } => {
                let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
                sparse::l1_from_parts(abs_sums[i], abs_sums[j], sparse::l1_corr(ai, av, bi, bv))
            }
            PairKernel::SparseCosine { m, sq_norms } => {
                let ((ai, av), (bi, bv)) = (m.row(i), m.row(j));
                dense::cosine_from_parts(sparse::dot(ai, av, bi, bv), sq_norms[i], sq_norms[j])
            }
            PairKernel::Generic => evaluate(self.metric, self.points, i, j),
        }
    }

    /// Fill one target row `out[r] = d(t, refs[r])` through the row
    /// kernels. Returns the number of evaluations performed through the
    /// cache (0 on the cache-less path, which callers count up front);
    /// callers batch that count into one atomic add per shard.
    fn fill_row(&self, kern: &PairKernel<'_>, t: usize, refs: &[usize], out: &mut [f64]) -> u64 {
        match &self.cache {
            None => {
                match *kern {
                    PairKernel::L2(m) => {
                        dense::l2_row(m.row(t), refs.iter().map(|&r| m.row(r)), out)
                    }
                    PairKernel::L1(m) => {
                        dense::l1_row(m.row(t), refs.iter().map(|&r| m.row(r)), out)
                    }
                    PairKernel::Cosine { m, sq_norms } => dense::cosine_row(
                        m.row(t),
                        sq_norms[t],
                        refs.iter().map(|&r| (m.row(r), sq_norms[r])),
                        out,
                    ),
                    PairKernel::SparseL2 { m, sq_norms } => {
                        sparse::l2_row(m, t, sq_norms, refs, out)
                    }
                    PairKernel::SparseL1 { m, abs_sums } => {
                        sparse::l1_row(m, t, abs_sums, refs, out)
                    }
                    PairKernel::SparseCosine { m, sq_norms } => {
                        sparse::cosine_row(m, t, sq_norms, refs, out)
                    }
                    PairKernel::Generic => {
                        for (o, &r) in out.iter_mut().zip(refs) {
                            *o = evaluate(self.metric, self.points, t, r);
                        }
                    }
                }
                0
            }
            Some(cache) => {
                let mut missed = 0u64;
                for (o, &r) in out.iter_mut().zip(refs) {
                    *o = cache.get_or_compute(t, r, || {
                        missed += 1;
                        self.pair(kern, t, r)
                    });
                }
                missed
            }
        }
    }

    /// Fill one cross row `out[r] = d(points[t], queries[refs[r]])`
    /// through the same row kernels as [`NativeBackend::fill_row`], with
    /// the reference side streamed from `queries` (whose reduction table
    /// is `q_norms`, per [`NativeBackend::norms_for`]). Never cached: the
    /// pairwise cache keys are indices into the *training* point set.
    ///
    /// Panics when the query storage kind does not match the backend's —
    /// [`crate::model::KMedoidsModel::predict`] validates and `Err`s
    /// before reaching this.
    fn fill_row_vs(
        &self,
        kern: &PairKernel<'_>,
        queries: &Points,
        q_norms: &[f64],
        t: usize,
        refs: &[usize],
        out: &mut [f64],
    ) {
        match (*kern, queries) {
            (PairKernel::L2(m), Points::Dense(q)) => {
                dense::l2_row(m.row(t), refs.iter().map(|&r| q.row(r)), out)
            }
            (PairKernel::L1(m), Points::Dense(q)) => {
                dense::l1_row(m.row(t), refs.iter().map(|&r| q.row(r)), out)
            }
            (PairKernel::Cosine { m, sq_norms }, Points::Dense(q)) => dense::cosine_row(
                m.row(t),
                sq_norms[t],
                refs.iter().map(|&r| (q.row(r), q_norms[r])),
                out,
            ),
            (PairKernel::SparseL2 { m, sq_norms }, Points::Sparse(q)) => {
                sparse::l2_row_vs(m.row(t), sq_norms[t], q, q_norms, refs, out)
            }
            (PairKernel::SparseL1 { m, abs_sums }, Points::Sparse(q)) => {
                sparse::l1_row_vs(m.row(t), abs_sums[t], q, q_norms, refs, out)
            }
            (PairKernel::SparseCosine { m, sq_norms }, Points::Sparse(q)) => {
                sparse::cosine_row_vs(m.row(t), sq_norms[t], q, q_norms, refs, out)
            }
            (PairKernel::Generic, Points::Trees(q)) => {
                let Points::Trees(ts) = self.points else {
                    panic!("generic cross kernel requires tree storage on both sides")
                };
                for (o, &r) in out.iter_mut().zip(refs) {
                    *o = crate::distance::tree_edit::ted(&ts[t], &q[r]);
                }
            }
            _ => panic!(
                "query storage {} does not match backend storage {}",
                queries.kind(),
                self.points.kind()
            ),
        }
    }

    /// Query-vs-medoids cross block:
    /// `out[t * refs.len() + r] = d(points[targets[t]], queries[refs[r]])`,
    /// where `targets` index this backend's (training/medoid) point set
    /// and `refs` index `queries` — an *unseen* point set over the same
    /// feature space. `q_norms` must be
    /// `NativeBackend::norms_for(self.metric(), queries)`.
    ///
    /// This is the out-of-sample twin of [`DistanceBackend::block`]: the
    /// same one-to-many row kernels fill each target row, the persistent
    /// pool shards the work, and when `queries` *is* the training point
    /// set the output is bitwise-equal to `block` (the row kernels are
    /// per-reference independent, so sharding cannot change bits).
    /// Sharding runs along the query axis — the predict workload is few
    /// medoid targets against many queries. Evaluations are counted into
    /// this backend's counter (one add per block).
    pub fn block_vs(
        &self,
        targets: &[usize],
        queries: &Points,
        q_norms: &[f64],
        refs: &[usize],
        out: &mut [f64],
    ) {
        assert_eq!(out.len(), targets.len() * refs.len());
        if targets.is_empty() || refs.is_empty() {
            return;
        }
        let rn = refs.len();
        self.counter.add((targets.len() * rn) as u64);
        let _kernel_span = crate::obs::Span::start(&self.obs_kernel_us);
        let kern = self.kernel();
        let work = targets.len() * rn * self.elem_cost();
        let pool = self
            .pool
            .as_ref()
            .filter(|_| work >= self.pool_min_work && rn >= 2);
        let out_ptr = OutPtr(out.as_mut_ptr());
        let body = |r0: usize, r1: usize| {
            for (ti, &t) in targets.iter().enumerate() {
                // SAFETY: chunks cover disjoint `r0..r1` column ranges of
                // row `ti`; no two (ti, chunk) slices alias.
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(ti * rn + r0), r1 - r0)
                };
                self.fill_row_vs(&kern, queries, q_norms, t, &refs[r0..r1], chunk);
            }
        };
        match pool {
            Some(p) => p.run(rn, self.chunk_for(rn), &body),
            None => body(0, rn),
        }
    }

    #[inline]
    fn raw(&self, i: usize, j: usize) -> f64 {
        let kern = self.kernel();
        match &self.cache {
            None => {
                self.counter.add(1);
                self.pair(&kern, i, j)
            }
            Some(cache) => cache.get_or_compute(i, j, || {
                self.counter.add(1);
                self.pair(&kern, i, j)
            }),
        }
    }

    /// Per-element work heuristic used to decide when pooling pays off.
    fn elem_cost(&self) -> usize {
        match (self.metric, self.points) {
            (Metric::TreeEdit, _) => 400,
            (_, Points::Dense(m)) => m.cols().max(1),
            // Scatter/gather row kernels stream O(nnz/row) per pair.
            (_, Points::Sparse(m)) => (m.nnz() / m.rows().max(1)).max(1),
            _ => 64,
        }
    }

    /// Chunk size for dynamic scheduling: several chunks per lane so
    /// uneven rows (tree edit, cache hits) balance.
    fn chunk_for(&self, items: usize) -> usize {
        items.div_ceil(self.threads * 4).max(1)
    }
}

/// Send/Sync wrapper for the output pointer shared across pool chunks.
/// Each chunk writes a disjoint index range, so no two chunks alias.
struct OutPtr(*mut f64);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

impl<'a> DistanceBackend for NativeBackend<'a> {
    fn points(&self) -> &Points {
        self.points
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn counter(&self) -> &DistanceCounter {
        &self.counter
    }

    fn dist(&self, i: usize, j: usize) -> f64 {
        self.raw(i, j)
    }

    fn block(&self, targets: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), targets.len() * refs.len());
        if targets.is_empty() || refs.is_empty() {
            return;
        }
        let rn = refs.len();
        self.obs_blocks.inc();
        self.obs_block_pairs.record((targets.len() * rn) as u64);
        let _kernel_span = crate::obs::Span::start(&self.obs_kernel_us);
        // Cache-less blocks are counted once up front (the cached path
        // counts misses per shard inside `fill_row`).
        if self.cache.is_none() {
            self.counter.add((targets.len() * rn) as u64);
        }
        let kern = self.kernel();
        let work = targets.len() * rn * self.elem_cost();
        let pool = self
            .pool
            .as_ref()
            .filter(|_| work >= self.pool_min_work && targets.len().max(rn) >= 2);
        let out_ptr = OutPtr(out.as_mut_ptr());
        if targets.len() == 1 {
            // Single target (Algorithm 1's exact fallback, BUILD's
            // add-medoid row): parallelize along the reference axis.
            let t = targets[0];
            let body = |r0: usize, r1: usize| {
                // SAFETY: chunks cover disjoint `r0..r1` ranges of `out`.
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(r0), r1 - r0)
                };
                let missed = self.fill_row(&kern, t, &refs[r0..r1], chunk);
                if missed > 0 {
                    self.counter.add(missed); // one add per shard
                }
            };
            match pool {
                Some(p) => p.run(rn, self.chunk_for(rn), &body),
                None => body(0, rn),
            }
        } else {
            // Multi-target: parallelize along the target axis, one row
            // kernel per target.
            let body = |t0: usize, t1: usize| {
                // SAFETY: chunks cover disjoint row ranges of `out`.
                let chunk = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.0.add(t0 * rn), (t1 - t0) * rn)
                };
                let mut missed = 0u64;
                for (ti, &t) in targets[t0..t1].iter().enumerate() {
                    missed +=
                        self.fill_row(&kern, t, refs, &mut chunk[ti * rn..(ti + 1) * rn]);
                }
                if missed > 0 {
                    self.counter.add(missed); // one add per shard
                }
            };
            match pool {
                Some(p) => p.run(targets.len(), self.chunk_for(targets.len()), &body),
                None => body(0, targets.len()),
            }
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn cache_stats(&self) -> Option<(u64, u64)> {
        self.cache.as_ref().map(|c| c.stats())
    }
}

/// References per evaluation tile: bounds the distance scratch of
/// [`loss_and_assignments`] (and its streamed twin) to `k * REF_TILE`
/// f64s. Tile boundaries never change result bits — every distance is
/// computed by a per-reference-independent row kernel, and the loss
/// accumulates strictly in point order `0..n` regardless of tiling.
pub const REF_TILE: usize = 2048;

/// Reusable scratch for the tiled evaluation loops: the reference index
/// tile and the `k x REF_TILE` distance tile. CLARA/BigFit outer loops
/// hold one of these across candidate evaluations so per-sample memory is
/// bounded by the tile, not by `n` (the seed rebuilt a `k x n` block per
/// sample).
#[derive(Debug, Default)]
pub struct EvalBuffers {
    tile_refs: Vec<usize>,
    tile: Vec<f64>,
}

impl EvalBuffers {
    /// Empty scratch; buffers grow to `k * REF_TILE` on first use.
    pub fn new() -> EvalBuffers {
        EvalBuffers::default()
    }

    /// Fill the reference tile with `start..start + cn` and return the
    /// (refs, out) pair sized for a `k x cn` block.
    fn tile_for(&mut self, start: usize, cn: usize, k: usize) -> (&[usize], &mut [f64]) {
        self.tile_refs.clear();
        self.tile_refs.extend(start..start + cn);
        if self.tile.len() < k * cn {
            self.tile.resize(k * cn, 0.0);
        }
        (&self.tile_refs, &mut self.tile[..k * cn])
    }
}

/// Scan one `k x cn` distance tile column-wise, folding each reference
/// point's nearest medoid into `loss`/`assign`. First minimum wins (`<`,
/// lowest medoid row) — the tie-break every evaluation path shares.
#[inline]
fn fold_tile(
    out: &[f64],
    cn: usize,
    base_row: usize,
    loss: &mut f64,
    assign: &mut [usize],
) {
    for ci in 0..cn {
        let mut best = f64::INFINITY;
        let mut who = 0;
        for (mi, row) in out.chunks_exact(cn).enumerate() {
            let d = row[ci];
            if d < best {
                best = d;
                who = mi;
            }
        }
        *loss += best;
        assign[base_row + ci] = who;
    }
}

/// Compute the k-medoids loss (Eq. 1) and point assignments for a medoid
/// set: each point contributes its distance to the nearest medoid.
///
/// Routed through [`DistanceBackend::block`] in reference tiles (rather
/// than n·k `dist` calls), so the native engine's pooled row kernels
/// apply; evaluation counts are unchanged (k·n either way).
pub fn loss_and_assignments(
    backend: &dyn DistanceBackend,
    medoids: &[usize],
) -> (f64, Vec<usize>) {
    loss_and_assignments_with(backend, medoids, &mut EvalBuffers::new())
}

/// [`loss_and_assignments`] with caller-owned scratch: repeated candidate
/// evaluations (CLARA's sample loop) reuse one [`EvalBuffers`] instead of
/// reallocating per call. Bitwise-identical to [`loss_and_assignments`] —
/// same tiles, same order, same kernels.
pub fn loss_and_assignments_with(
    backend: &dyn DistanceBackend,
    medoids: &[usize],
    bufs: &mut EvalBuffers,
) -> (f64, Vec<usize>) {
    assert!(!medoids.is_empty());
    // Engines with a full-pass override (the sharded worker pool) take it
    // here; the contract is bitwise equality with the fold below.
    if let Some(result) = backend.score(medoids) {
        return result;
    }
    let n = backend.n();
    let k = medoids.len();
    let mut loss = 0.0;
    let mut assign = vec![0usize; n];
    let mut start = 0usize;
    while start < n {
        let cn = REF_TILE.min(n - start);
        let (refs, out) = bufs.tile_for(start, cn, k);
        backend.block(medoids, refs, out);
        fold_tile(out, cn, start, &mut loss, &mut assign);
        start += cn;
    }
    (loss, assign)
}

/// Window-at-a-time twin of [`loss_and_assignments`]: folds
/// medoids-vs-window distance tiles over row-windows of a dataset that is
/// never resident as a whole. The backend holds only the k extracted
/// medoid rows; each pushed window is scored through
/// [`NativeBackend::block_vs`] — the same one-to-many row kernels, tiling
/// and first-minimum tie-break as the in-memory path — so the fold is
/// **bitwise-equal to `loss_and_assignments` by construction**:
///
/// * extracted medoid rows are bit-copies of the training rows, and
///   [`NativeBackend::norms_for`] is a per-row reduction, so every
///   (medoid, point) pair sees identical operands;
/// * the cross kernels are the same kernels as the same-matrix path
///   (pinned by `block_vs_matches_block_on_training_set`), and each
///   distance is per-reference independent, so window/tile boundaries
///   cannot change any bit;
/// * the loss accumulates strictly in global row order `0..n` — windows
///   must arrive in order, enforced here — matching the in-memory sum
///   term for term.
///
/// Peak residency: k medoid rows + one window + a `k x REF_TILE` tile.
pub struct WindowFold<'a, 'p> {
    backend: &'a NativeBackend<'p>,
    n: usize,
    next_row: usize,
    loss: f64,
    assign: Vec<usize>,
    targets: Vec<usize>,
    bufs: EvalBuffers,
}

impl<'a, 'p> WindowFold<'a, 'p> {
    /// Start a fold over `n` total rows against `backend`'s point set —
    /// the k medoid rows, all of them.
    pub fn new(backend: &'a NativeBackend<'p>, n: usize) -> WindowFold<'a, 'p> {
        let k = backend.n();
        assert!(k > 0, "WindowFold requires at least one medoid");
        WindowFold {
            backend,
            n,
            next_row: 0,
            loss: 0.0,
            assign: vec![0usize; n],
            targets: (0..k).collect(),
            bufs: EvalBuffers::new(),
        }
    }

    /// Rows folded so far (the next expected `start_row`).
    pub fn rows_seen(&self) -> usize {
        self.next_row
    }

    /// Score one window: rows `[start_row, start_row + window.len())` of
    /// the full dataset. Windows must arrive in order and partition
    /// `[0, n)`; anything else is a clean `Err`.
    pub fn push(&mut self, start_row: usize, window: &Points) -> Result<()> {
        if start_row != self.next_row {
            return Err(Error::data(format!(
                "window starting at row {start_row} arrived out of order (expected {})",
                self.next_row
            )));
        }
        let wn = window.len();
        if start_row + wn > self.n {
            return Err(Error::data(format!(
                "window {start_row}..{} overruns the declared {} rows",
                start_row + wn,
                self.n
            )));
        }
        if wn == 0 {
            return Ok(());
        }
        if window.kind() != self.backend.points().kind() {
            return Err(Error::unsupported(format!(
                "window storage {} does not match the medoid storage {}",
                window.kind(),
                self.backend.points().kind()
            )));
        }
        let q_norms = NativeBackend::norms_for(self.backend.metric(), window);
        let k = self.targets.len();
        let mut start = 0usize;
        while start < wn {
            let cn = REF_TILE.min(wn - start);
            let (refs, out) = self.bufs.tile_for(start, cn, k);
            self.backend.block_vs(&self.targets, window, &q_norms, refs, out);
            fold_tile(out, cn, start_row + start, &mut self.loss, &mut self.assign);
            start += cn;
        }
        self.next_row += wn;
        Ok(())
    }

    /// Finish the fold, yielding `(loss, assignments)`. Errs unless the
    /// pushed windows covered exactly `[0, n)`.
    pub fn finish(self) -> Result<(f64, Vec<usize>)> {
        if self.next_row != self.n {
            return Err(Error::data(format!(
                "windows covered {} of {} rows",
                self.next_row, self.n
            )));
        }
        Ok((self.loss, self.assign))
    }
}

/// Drive a [`WindowFold`] from a window source: `next` yields
/// `(start_row, window)` pairs in row order (`Ok(None)` = exhausted),
/// whether from [`crate::data::stream::CsrChunkReader`] windows or from
/// row-range selections of an in-memory [`Points`] — dense and sparse
/// data evaluate through this same code. Returns the `(loss,
/// assignments)` of the full dataset against `medoid_backend`'s k rows,
/// bitwise-equal to the in-memory [`loss_and_assignments`].
pub fn loss_and_assignments_streamed<F>(
    medoid_backend: &NativeBackend<'_>,
    n: usize,
    mut next: F,
) -> Result<(f64, Vec<usize>)>
where
    F: FnMut() -> Result<Option<(usize, Points)>>,
{
    let mut fold = WindowFold::new(medoid_backend, n);
    while let Some((start_row, window)) = next()? {
        fold.push(start_row, &window)?;
    }
    fold.finish()
}

/// Assign every point of `queries` to its nearest point of the backend's
/// own set (all of them — the backend is expected to hold exactly the k
/// medoid points, as [`crate::model::KMedoidsModel`] builds it). Returns
/// `(assignment, distance)` per query, where `assignment` indexes the
/// backend's rows.
///
/// Mirrors [`loss_and_assignments`] exactly — same reference tiling, same
/// first-minimum tie-breaking (`<`, lowest medoid row wins), same row
/// kernels via [`NativeBackend::block_vs`] — so predicting the training
/// set reproduces the training assignments bit for bit.
pub fn assign_against(
    backend: &NativeBackend<'_>,
    queries: &Points,
) -> (Vec<usize>, Vec<f64>) {
    let k = backend.n();
    assert!(k > 0, "assign_against requires at least one medoid");
    let nq = queries.len();
    let q_norms = NativeBackend::norms_for(backend.metric(), queries);
    const REF_TILE: usize = 2048;
    let targets: Vec<usize> = (0..k).collect();
    let refs: Vec<usize> = (0..nq).collect();
    let mut tile_buf = vec![0.0f64; k * REF_TILE.min(nq.max(1))];
    let mut assign = vec![0usize; nq];
    let mut dists = vec![0.0f64; nq];
    for tile in refs.chunks(REF_TILE) {
        let cn = tile.len();
        let out = &mut tile_buf[..k * cn];
        backend.block_vs(&targets, queries, &q_norms, tile, out);
        for (ci, &j) in tile.iter().enumerate() {
            let mut best = f64::INFINITY;
            let mut who = 0;
            for (mi, row) in out.chunks_exact(cn).enumerate() {
                let d = row[ci];
                if d < best {
                    best = d;
                    who = mi;
                }
            }
            assign[j] = who;
            dists[j] = best;
        }
    }
    (assign, dists)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn dataset() -> crate::data::Dataset {
        synthetic::gmm(&mut Rng::seed_from(1), 40, 8, 3, 3.0)
    }

    #[test]
    fn dist_counts_evaluations() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        b.dist(0, 1);
        b.dist(2, 3);
        assert_eq!(b.counter().get(), 2);
    }

    #[test]
    fn cache_avoids_recomputation() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L2).with_cache(10_000);
        let d1 = b.dist(0, 1);
        let d2 = b.dist(1, 0);
        assert_eq!(d1, d2);
        assert_eq!(b.counter().get(), 1, "second lookup must hit the cache");
        assert_eq!(b.cache_stats(), Some((1, 1)));
    }

    #[test]
    fn block_matches_dist_single_thread() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L1);
        let targets = [0, 5, 7];
        let refs = [1, 2, 3, 4];
        let mut out = vec![0.0; 12];
        b.block(&targets, &refs, &mut out);
        for (ti, &t) in targets.iter().enumerate() {
            for (ri, &r) in refs.iter().enumerate() {
                assert_eq!(out[ti * 4 + ri], b.dist(t, r));
            }
        }
    }

    #[test]
    fn block_pooled_matches_serial() {
        let ds = synthetic::gmm(&mut Rng::seed_from(2), 200, 64, 3, 2.0);
        let serial = NativeBackend::new(&ds.points, Metric::L2);
        let pooled = NativeBackend::new(&ds.points, Metric::L2).with_threads(4);
        let targets: Vec<usize> = (0..150).collect();
        let refs: Vec<usize> = (50..200).collect();
        let mut a = vec![0.0; targets.len() * refs.len()];
        let mut b = vec![0.0; targets.len() * refs.len()];
        serial.block(&targets, &refs, &mut a);
        pooled.block(&targets, &refs, &mut b);
        assert_eq!(a, b);
        assert_eq!(serial.counter().get(), pooled.counter().get());
    }

    #[test]
    fn single_target_block_shards_along_refs() {
        let ds = synthetic::gmm(&mut Rng::seed_from(8), 300, 16, 3, 2.0);
        let serial = NativeBackend::new(&ds.points, Metric::L2);
        let pooled = NativeBackend::new(&ds.points, Metric::L2)
            .with_threads(4)
            .with_pool_min_work(0);
        let refs: Vec<usize> = (0..300).collect();
        let mut a = vec![0.0; 300];
        let mut b = vec![0.0; 300];
        serial.block(&[7], &refs, &mut a);
        pooled.block(&[7], &refs, &mut b);
        assert_eq!(a, b);
        assert_eq!(serial.counter().get(), pooled.counter().get());
    }

    #[test]
    fn cosine_norm_table_agrees_with_direct_kernel() {
        let ds = synthetic::gmm(&mut Rng::seed_from(3), 50, 33, 3, 2.0);
        let b = NativeBackend::new(&ds.points, Metric::Cosine);
        let Points::Dense(m) = &ds.points else { unreachable!() };
        for (i, j) in [(0, 1), (7, 42), (13, 13), (49, 0)] {
            assert_eq!(b.dist(i, j), dense::cosine(m.row(i), m.row(j)));
        }
        // block path uses the same table
        let refs: Vec<usize> = (0..50).collect();
        let mut out = vec![0.0; 50];
        b.block(&[5], &refs, &mut out);
        for (r, &d) in out.iter().enumerate() {
            assert_eq!(d, dense::cosine(m.row(5), m.row(r)));
        }
    }

    #[test]
    fn loss_and_assignments_basics() {
        let ds = dataset();
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let (loss, assign) = loss_and_assignments(&b, &[0, 1]);
        assert!(loss > 0.0);
        assert_eq!(assign.len(), 40);
        // medoids are assigned to themselves with distance zero
        assert_eq!(assign[0], 0);
        assert_eq!(assign[1], 1);
        // every assignment is the argmin over medoids
        for i in 0..40 {
            let d0 = b.dist(0, i);
            let d1 = b.dist(1, i);
            let want = if d0 <= d1 { 0 } else { 1 };
            assert_eq!(assign[i], want, "point {i}");
        }
    }

    #[test]
    fn loss_and_assignments_matches_brute_force() {
        // n > REF_TILE would be slow here; instead check the tiling seam
        // logic via a point count that is not a multiple of the tile by
        // shrinking through the public API: compare against brute force.
        let ds = synthetic::gmm(&mut Rng::seed_from(9), 97, 6, 4, 3.0);
        let b = NativeBackend::new(&ds.points, Metric::L1);
        let medoids = [3usize, 40, 77];
        let (loss, assign) = loss_and_assignments(&b, &medoids);
        let mut want_loss = 0.0;
        for j in 0..97 {
            let (mut best, mut who) = (f64::INFINITY, 0);
            for (mi, &m) in medoids.iter().enumerate() {
                let d = b.dist(m, j);
                if d < best {
                    best = d;
                    who = mi;
                }
            }
            want_loss += best;
            assert_eq!(assign[j], who, "point {j}");
        }
        assert!((loss - want_loss).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "does not support")]
    fn incompatible_metric_panics() {
        let ds = dataset();
        NativeBackend::new(&ds.points, Metric::TreeEdit);
    }

    #[test]
    #[should_panic(expected = "does not support")]
    fn tree_edit_rejects_sparse_points() {
        let pts = Points::Sparse(CsrMatrix::zeros(4, 4));
        NativeBackend::new(&pts, Metric::TreeEdit);
    }

    fn sparse_dataset() -> crate::data::Dataset {
        synthetic::scrna_like(&mut Rng::seed_from(14), 60, 96)
            .to_sparse()
            .unwrap()
    }

    #[test]
    fn sparse_block_matches_dist_bitwise() {
        let ds = sparse_dataset();
        for metric in [Metric::L1, Metric::L2, Metric::Cosine] {
            let b = NativeBackend::new(&ds.points, metric);
            let targets = [0usize, 9, 33];
            let refs: Vec<usize> = (0..60).collect();
            let mut out = vec![0.0; targets.len() * refs.len()];
            b.block(&targets, &refs, &mut out);
            for (ti, &t) in targets.iter().enumerate() {
                for (ri, &r) in refs.iter().enumerate() {
                    // merge pair kernel == scatter row kernel, bit for bit
                    assert_eq!(out[ti * 60 + ri], b.dist(t, r), "{metric} t={t} r={r}");
                }
            }
        }
    }

    #[test]
    fn sparse_pooled_matches_serial() {
        let ds = sparse_dataset();
        for metric in [Metric::L1, Metric::Cosine] {
            let serial = NativeBackend::new(&ds.points, metric);
            let pooled = NativeBackend::new(&ds.points, metric)
                .with_threads(4)
                .with_pool_min_work(0);
            let targets: Vec<usize> = (0..40).collect();
            let refs: Vec<usize> = (10..60).collect();
            let mut a = vec![0.0; targets.len() * refs.len()];
            let mut b = vec![0.0; targets.len() * refs.len()];
            serial.block(&targets, &refs, &mut a);
            pooled.block(&targets, &refs, &mut b);
            assert_eq!(a, b, "{metric}");
            assert_eq!(serial.counter().get(), pooled.counter().get());
        }
    }

    #[test]
    fn sparse_cache_path_matches_uncached_bitwise() {
        // The cached path computes through the merge pair kernel, the
        // uncached block through the scatter row kernel; the two must be
        // bit-identical or cache warm-up order would leak into results.
        let ds = sparse_dataset();
        let plain = NativeBackend::new(&ds.points, Metric::L1);
        let cached = NativeBackend::new(&ds.points, Metric::L1).with_cache(1 << 16);
        let targets = [3usize, 48];
        let refs: Vec<usize> = (0..60).collect();
        let mut a = vec![0.0; targets.len() * refs.len()];
        let mut b = vec![0.0; targets.len() * refs.len()];
        plain.block(&targets, &refs, &mut a);
        cached.block(&targets, &refs, &mut b);
        assert_eq!(a, b);
        // repeat is served from the cache without new evaluations
        let evals = cached.counter().get();
        cached.block(&targets, &refs, &mut b);
        assert_eq!(a, b);
        assert_eq!(cached.counter().get(), evals);
    }

    /// `block_vs` with the training set itself as the query side must be
    /// bitwise-equal to `block` — the cross kernels are the same kernels.
    #[test]
    fn block_vs_matches_block_on_training_set() {
        let dense = synthetic::gmm(&mut Rng::seed_from(21), 90, 33, 3, 2.0);
        let sparse = sparse_dataset();
        for (ds, metrics) in [
            (&dense, &[Metric::L1, Metric::L2, Metric::Cosine][..]),
            (&sparse, &[Metric::L1, Metric::L2, Metric::Cosine][..]),
        ] {
            for &metric in metrics {
                for threads in [1usize, 4] {
                    let b = NativeBackend::new(&ds.points, metric)
                        .with_threads(threads)
                        .with_pool_min_work(0);
                    let targets = [0usize, 7, 13];
                    let refs: Vec<usize> = (0..ds.len()).collect();
                    let mut a = vec![0.0; targets.len() * refs.len()];
                    let mut c = vec![0.0; targets.len() * refs.len()];
                    b.block(&targets, &refs, &mut a);
                    let q_norms = NativeBackend::norms_for(metric, &ds.points);
                    b.block_vs(&targets, &ds.points, &q_norms, &refs, &mut c);
                    assert_eq!(a, c, "{metric} threads={threads} on {}", ds.points.kind());
                }
            }
        }
    }

    /// Assigning the training set against a backend holding only the
    /// extracted medoid rows reproduces the training assignments bitwise.
    #[test]
    fn assign_against_reproduces_training_assignments() {
        for ds in [
            synthetic::gmm(&mut Rng::seed_from(22), 120, 16, 4, 3.0),
            sparse_dataset(),
        ] {
            let metric = Metric::L2;
            let b = NativeBackend::new(&ds.points, metric);
            let medoids = [3usize, 40, 55];
            let (_, want) = loss_and_assignments(&b, &medoids);
            let medoid_points = ds.points.select(&medoids);
            let mb = NativeBackend::new(&medoid_points, metric);
            let (got, dists) = assign_against(&mb, &ds.points);
            assert_eq!(got, want, "{}", ds.points.kind());
            // each medoid is its own nearest medoid at distance zero
            for (mi, &m) in medoids.iter().enumerate() {
                assert_eq!(got[m], mi);
                assert_eq!(dists[m], 0.0);
            }
        }
    }

    /// Reused `EvalBuffers` across candidates of different k must not
    /// change any bit relative to fresh-buffer evaluation.
    #[test]
    fn loss_with_reused_buffers_matches_fresh() {
        let ds = synthetic::gmm(&mut Rng::seed_from(31), 150, 8, 4, 3.0);
        let b = NativeBackend::new(&ds.points, Metric::L2);
        let mut bufs = EvalBuffers::new();
        for medoids in [vec![0usize, 50, 100, 149], vec![7usize, 90], vec![3usize, 4, 5]] {
            let (l1, a1) = loss_and_assignments(&b, &medoids);
            let (l2, a2) = loss_and_assignments_with(&b, &medoids, &mut bufs);
            assert_eq!(l1.to_bits(), l2.to_bits());
            assert_eq!(a1, a2);
        }
    }

    /// The window fold over extracted medoid rows reproduces the
    /// in-memory evaluation bitwise, for dense and sparse storage and any
    /// window partition.
    #[test]
    fn window_fold_matches_in_memory_bitwise() {
        for ds in [
            synthetic::gmm(&mut Rng::seed_from(33), 97, 12, 4, 3.0),
            sparse_dataset(),
        ] {
            let n = ds.len();
            let metric = Metric::L2;
            let b = NativeBackend::new(&ds.points, metric);
            let medoids = [2usize, 30, 55];
            let (want_loss, want_assign) = loss_and_assignments(&b, &medoids);
            let medoid_points = ds.points.select(&medoids);
            let mb = NativeBackend::new(&medoid_points, metric);
            for rows_per_window in [1usize, 7, n] {
                let mut fold = WindowFold::new(&mb, n);
                let mut start = 0usize;
                while start < n {
                    let end = (start + rows_per_window).min(n);
                    let range: Vec<usize> = (start..end).collect();
                    fold.push(start, &ds.points.select(&range)).unwrap();
                    start = end;
                }
                let (loss, assign) = fold.finish().unwrap();
                assert_eq!(loss.to_bits(), want_loss.to_bits(), "{}", ds.points.kind());
                assert_eq!(assign, want_assign, "{}", ds.points.kind());
            }
        }
    }

    /// Out-of-order, overrunning and incomplete window sequences are
    /// clean errors, never silent corruption.
    #[test]
    fn window_fold_rejects_bad_sequences() {
        let ds = synthetic::gmm(&mut Rng::seed_from(34), 20, 4, 2, 2.0);
        let medoid_points = ds.points.select(&[0, 10]);
        let mb = NativeBackend::new(&medoid_points, Metric::L2);
        let w = ds.points.select(&(0..5).collect::<Vec<_>>());
        // out of order
        let mut fold = WindowFold::new(&mb, 20);
        assert!(fold.push(5, &w).is_err());
        // overrun
        let mut fold = WindowFold::new(&mb, 3);
        assert!(fold.push(0, &w).is_err());
        // incomplete coverage
        let mut fold = WindowFold::new(&mb, 20);
        fold.push(0, &w).unwrap();
        assert_eq!(fold.rows_seen(), 5);
        assert!(fold.finish().is_err());
    }

    #[test]
    fn sparse_loss_and_assignments_close_to_densified() {
        let sp = sparse_dataset();
        let dn = sp.to_dense().unwrap();
        let bs = NativeBackend::new(&sp.points, Metric::L1);
        let bd = NativeBackend::new(&dn.points, Metric::L1);
        let (ls, asg_s) = loss_and_assignments(&bs, &[0, 20, 40]);
        let (ld, asg_d) = loss_and_assignments(&bd, &[0, 20, 40]);
        assert!((ls - ld).abs() <= 1e-5 * (1.0 + ld.abs()), "{ls} vs {ld}");
        assert_eq!(asg_s, asg_d);
        assert_eq!(bs.counter().get(), bd.counter().get());
    }
}
