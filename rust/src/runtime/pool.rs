//! Persistent worker pool for the block hot path (see `rust/PERF.md`).
//!
//! The seed implementation spawned fresh OS threads via
//! `std::thread::scope` on **every** batched distance pull; at BanditPAM's
//! batch cadence (hundreds of `block` calls per Algorithm-1 invocation)
//! the spawn/join cost rivalled the kernel work for mid-sized blocks.
//! This pool is created once per [`crate::runtime::backend::NativeBackend`]
//! and reused across all `block` calls: workers park on a condvar between
//! tasks, and dispatching a task costs one mutex lock plus a wakeup.
//!
//! Scheduling is dynamic ("work-stealing-ish" without per-thread deques):
//! a task is an index range `0..items` cut into fixed-size chunks, and
//! every participant — the submitting thread included — claims the next
//! chunk from a shared atomic cursor until the range is exhausted. Uneven
//! per-chunk cost (e.g. tree-edit distances of wildly different tree
//! sizes) therefore balances automatically.
//!
//! # Borrowed closures
//!
//! [`ThreadPool::run`] accepts a closure borrowing stack data (the output
//! block, the point matrix). Internally the reference is lifetime-erased
//! to hand it to the persistent workers; this is sound because `run` does
//! not return until every chunk has finished executing, so the erased
//! reference never outlives the borrow it came from. Panics inside a
//! chunk are caught, the task still completes (the rendezvous never
//! deadlocks on a poisoned chunk), and `run` re-raises the **original
//! panic payload** on the submitting thread — so a serving layer that
//! wraps a kernel call in `catch_unwind` observes the real panic message,
//! not a generic pool wrapper. When several chunks panic in one task, the
//! first captured payload wins and the rest are dropped.
//!
//! `run` must not be called from inside a running task (the nested call
//! would wait for the current task to retire while holding one of its
//! chunks — deadlock). The backend's kernels never re-enter the pool.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Lifetime-erased shared closure: `f(start, end)` processes items
/// `start..end` of the current task.
type RawJob = *const (dyn Fn(usize, usize) + Sync);

/// One submitted task: the erased closure plus its claim/completion state.
struct Task {
    job: RawJob,
    items: usize,
    chunk: usize,
    epoch: u64,
    /// Next unclaimed item index (grows by `chunk` per claim).
    next: AtomicUsize,
    /// Items whose chunk has finished executing.
    done: AtomicUsize,
}

// SAFETY: `job` points at a `Sync` closure, and the pool guarantees (by
// blocking in `run`) that the pointee outlives every dereference.
unsafe impl Send for Task {}
unsafe impl Sync for Task {}

struct State {
    /// The in-flight task, if any. At most one task runs at a time;
    /// further submitters wait on `done` for the slot.
    task: Option<Arc<Task>>,
    /// Epoch of the most recently installed task.
    epoch: u64,
    /// Epoch of the most recently completed task.
    done_epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new task (or shutdown).
    work: Condvar,
    /// Submitters wait here for task completion / a free slot.
    done: Condvar,
    /// First panic payload captured from a chunk; `run` re-raises it (via
    /// `resume_unwind`) after the task completes, preserving the original
    /// message for `catch_unwind` at higher layers.
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Persistent thread pool executing chunked index-range tasks.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    threads: usize,
    /// Process-metric handle, resolved once so `run` pays one atomic add.
    obs_tasks: Arc<crate::obs::Counter>,
}

impl ThreadPool {
    /// Pool with `threads` total execution lanes. The submitting thread
    /// participates in every task, so `threads - 1` workers are spawned;
    /// `threads <= 1` spawns none and [`ThreadPool::run`] executes inline.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                task: None,
                epoch: 0,
                done_epoch: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            panic_payload: Mutex::new(None),
        });
        let handles = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("banditpam-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker thread")
            })
            .collect();
        ThreadPool {
            shared,
            handles,
            threads,
            obs_tasks: crate::obs::global().counter("pool_tasks_total"),
        }
    }

    /// Total execution lanes (spawned workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(start, end)` over `0..items` in chunks of `chunk`
    /// items, in parallel across the pool. Blocks until every chunk has
    /// run; if any chunk panicked, re-raises the first captured payload
    /// here on the submitting thread (the pool itself survives).
    pub fn run(&self, items: usize, chunk: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if items == 0 {
            return;
        }
        self.obs_tasks.inc();
        let chunk = chunk.max(1);
        if self.handles.is_empty() {
            // No workers: run inline (still chunked, for identical
            // traversal order and panic behavior).
            let mut start = 0;
            while start < items {
                let end = (start + chunk).min(items);
                f(start, end);
                start = end;
            }
            return;
        }
        // SAFETY: erase the borrow's lifetime to store it in the shared
        // task slot. `run` blocks below until `done_epoch` covers this
        // task, i.e. until no worker can touch `job` again, so the
        // reference never outlives `f`.
        let job: RawJob =
            unsafe { std::mem::transmute::<&(dyn Fn(usize, usize) + Sync), RawJob>(f) };
        let (task, my_epoch) = {
            let mut st = self.shared.state.lock().unwrap();
            while st.task.is_some() {
                st = self.shared.done.wait(st).unwrap();
            }
            st.epoch += 1;
            let task = Arc::new(Task {
                job,
                items,
                chunk,
                epoch: st.epoch,
                next: AtomicUsize::new(0),
                done: AtomicUsize::new(0),
            });
            st.task = Some(Arc::clone(&task));
            self.shared.work.notify_all();
            (task, st.epoch)
        };
        // The submitter is a full participant: claim chunks like a worker.
        execute(&self.shared, &task);
        let mut st = self.shared.state.lock().unwrap();
        while st.done_epoch < my_epoch {
            st = self.shared.done.wait(st).unwrap();
        }
        drop(st);
        if let Some(payload) = self.shared.panic_payload.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim and execute chunks of `task` until its range is exhausted. The
/// participant that finishes the final chunk retires the task and wakes
/// submitters.
fn execute(shared: &Shared, task: &Arc<Task>) {
    loop {
        let start = task.next.fetch_add(task.chunk, Ordering::Relaxed);
        if start >= task.items {
            return;
        }
        let end = (start + task.chunk).min(task.items);
        // SAFETY: the reference is materialized only after a successful
        // chunk claim. A claimed-but-uncompleted chunk keeps `done` below
        // `items`, so the task cannot retire and `run` cannot return —
        // the pointee (and the `Sync` closure behind it) is still alive.
        // A stale worker whose task already completed gets `start >=
        // items` above and never touches `job`.
        let f = unsafe { &*task.job };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(start, end))) {
            let mut slot = shared.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        // AcqRel: the final increment must observe (and order after) every
        // other chunk's writes, so the submitter's post-`run` reads of the
        // output buffer see all of them.
        let finished = task.done.fetch_add(end - start, Ordering::AcqRel) + (end - start);
        if finished == task.items {
            let mut st = shared.state.lock().unwrap();
            if st.task.as_ref().is_some_and(|t| Arc::ptr_eq(t, task)) {
                st.task = None;
            }
            st.done_epoch = st.done_epoch.max(task.epoch);
            shared.done.notify_all();
        }
    }
}

/// Worker body: wait for an unseen task, help execute it, repeat.
fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(t) = st.task.as_ref() {
                    if t.epoch > seen {
                        break Arc::clone(t);
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        seen = task.epoch;
        execute(shared, &task);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicU64::new(0);
        pool.run(100, 7, &|start, end| {
            sum.fetch_add((start..end).map(|i| i as u64).sum(), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn parallel_sum_covers_every_item_exactly_once() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.threads(), 4);
        let sum = AtomicU64::new(0);
        let chunks = AtomicU64::new(0);
        pool.run(10_001, 13, &|start, end| {
            chunks.fetch_add(1, Ordering::Relaxed);
            sum.fetch_add((start..end).map(|i| i as u64).sum(), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10_000 * 10_001 / 2);
        assert_eq!(chunks.load(Ordering::Relaxed), 10_001u64.div_ceil(13));
    }

    #[test]
    fn writes_to_disjoint_output_ranges_are_visible_after_run() {
        let pool = ThreadPool::new(3);
        let mut out = vec![0u64; 5000];
        struct Ptr(*mut u64);
        unsafe impl Send for Ptr {}
        unsafe impl Sync for Ptr {}
        let ptr = Ptr(out.as_mut_ptr());
        pool.run(out.len(), 17, &|start, end| {
            // SAFETY: chunks are disjoint index ranges of `out`.
            let chunk =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), end - start) };
            for (off, v) in chunk.iter_mut().enumerate() {
                *v = (start + off) as u64 * 3;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 * 3, "item {i}");
        }
    }

    #[test]
    fn pool_is_reusable_across_many_tasks() {
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(97, 5, &|start, end| {
                total.fetch_add((end - start) as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 97);
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(100, 10, &|start, _end| {
                if start == 50 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // The pool must remain fully functional afterwards.
        let sum = AtomicU64::new(0);
        pool.run(64, 8, &|start, end| {
            sum.fetch_add((end - start) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn panic_payload_is_preserved_for_the_caller() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(100, 10, &|start, _end| {
                if start == 30 {
                    panic!("kernel exploded at row {start}");
                }
            });
        }));
        let payload = result.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| payload.downcast_ref::<&str>().copied())
            .expect("payload should be the original panic message");
        assert_eq!(msg, "kernel exploded at row 30");
        // The payload slot must be cleared: the next task succeeds.
        let sum = AtomicU64::new(0);
        pool.run(32, 4, &|start, end| {
            sum.fetch_add((end - start) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn zero_items_is_a_no_op() {
        let pool = ThreadPool::new(2);
        pool.run(0, 16, &|_s, _e| panic!("must not be called"));
    }
}
