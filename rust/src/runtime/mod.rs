//! Runtime: distance backends and the PJRT bridge to the AOT artifacts.
//!
//! Two interchangeable engines implement [`backend::DistanceBackend`]:
//!
//! * [`backend::NativeBackend`] — optimized in-process Rust kernels
//!   (required for tree edit distance; used by the large benchmark sweeps).
//!   Parallelizes big blocks across threads internally and optionally
//!   consults the Appendix-2.2 pairwise cache.
//! * [`xla_backend::XlaBackend`] — routes dense-vector metrics through the
//!   HLO-text artifacts produced by `python/compile/aot.py` (Pallas kernels
//!   lowered at build time), executed on the PJRT CPU client via the `xla`
//!   crate. Python is never on this path.
//!
//! Both count every evaluated distance through the same
//! [`crate::distance::counter::DistanceCounter`], so the paper's
//! distance-evaluation metrics are backend-invariant.

//! Block-level parallelism is provided by [`pool`]: a persistent worker
//! pool owned by the native backend (one spawn per backend, not one per
//! block — see `rust/PERF.md` for the architecture and measurements).

pub mod backend;
pub mod executable;
pub mod manifest;
pub mod pool;
pub mod xla_backend;
