//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. `artifacts/manifest.json` lists every lowered HLO module
//! with its graph kind, metric and fixed tile shape.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub kind: String,
    pub metric: String,
    /// Target tile rows.
    pub t: usize,
    /// Reference tile rows.
    pub r: usize,
    /// Feature dimension.
    pub d: usize,
    /// Medoid-count axis for `swap_delta` artifacts (0 otherwise).
    pub k: usize,
    pub name: String,
    /// Absolute path to the `.hlo.txt` file.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let field = |k: &str| -> Result<&Json> {
                a.get(k).ok_or_else(|| anyhow!("artifact missing field {k:?}"))
            };
            let spec = ArtifactSpec {
                kind: field("kind")?.as_str().unwrap_or_default().to_string(),
                metric: field("metric")?.as_str().unwrap_or_default().to_string(),
                t: field("t")?.as_usize().ok_or_else(|| anyhow!("bad t"))?,
                r: field("r")?.as_usize().ok_or_else(|| anyhow!("bad r"))?,
                d: field("d")?.as_usize().ok_or_else(|| anyhow!("bad d"))?,
                k: a.get("k").and_then(Json::as_usize).unwrap_or(0),
                name: field("name")?.as_str().unwrap_or_default().to_string(),
                path: dir.join(
                    field("file")?.as_str().ok_or_else(|| anyhow!("bad file"))?,
                ),
            };
            artifacts.push(spec);
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    /// Default artifact directory: `$BANDITPAM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("BANDITPAM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Best `pairwise` artifact for `metric` and feature dim `d`: the one
    /// with the smallest artifact dim `>= d` (inputs are zero-padded up).
    pub fn find_pairwise(&self, metric: &str, d: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "pairwise" && a.metric == metric && a.d >= d)
            .min_by_key(|a| a.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("banditpam_manifest_{tag}_{}", std::process::id()));
        p
    }

    const GOOD: &str = r#"{
      "version": 1,
      "artifacts": [
        {"kind": "pairwise", "metric": "l2", "t": 64, "r": 128, "d": 16,
         "name": "p16", "file": "p16.hlo.txt"},
        {"kind": "pairwise", "metric": "l2", "t": 64, "r": 128, "d": 784,
         "name": "p784", "file": "p784.hlo.txt"},
        {"kind": "swap_delta", "metric": "l2", "t": 64, "r": 128, "d": 784,
         "k": 8, "name": "sd", "file": "sd.hlo.txt"}
      ]
    }"#;

    #[test]
    fn load_and_select() {
        let dir = tmpdir("good");
        write_manifest(&dir, GOOD);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        // d=10 should pick the 16-dim artifact, not 784
        let a = m.find_pairwise("l2", 10).unwrap();
        assert_eq!(a.d, 16);
        let b = m.find_pairwise("l2", 100).unwrap();
        assert_eq!(b.d, 784);
        assert!(m.find_pairwise("l2", 1000).is_none());
        assert!(m.find_pairwise("l1", 4).is_none());
        assert_eq!(m.artifacts[2].k, 8);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_bad_version() {
        let dir = tmpdir("badver");
        write_manifest(&dir, r#"{"version": 2, "artifacts": []}"#);
        assert!(Manifest::load(&dir).unwrap_err().to_string().contains("version"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_missing_fields() {
        let dir = tmpdir("missing");
        write_manifest(
            &dir,
            r#"{"version": 1, "artifacts": [{"kind": "pairwise"}]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_file_mentions_make_artifacts() {
        let dir = tmpdir("nofile");
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
