//! The XLA distance engine: dense-metric blocks through AOT Pallas kernels.
//!
//! Routes [`DistanceBackend::block`] calls to the HLO-text artifacts lowered
//! by `python/compile/aot.py`. Requests of arbitrary size are tiled into the
//! artifact's fixed `[T, R, D]` shape: target/reference rows are gathered
//! into zero-padded staging buffers (zero padding is distance-neutral for
//! l2/l1 and norm-neutral for cosine — padded *columns*; padded *rows*
//! produce garbage entries which are simply not scattered back).
//!
//! This engine exists to prove the three-layer story end to end (the
//! `mnist_clustering` example runs BanditPAM entirely through it, with the
//! same medoids as the native engine); the big sweeps use `NativeBackend`,
//! whose per-distance cost is far below the interpret-mode HLO's.

use crate::data::Points;
use crate::distance::counter::DistanceCounter;
use crate::distance::Metric;
use crate::runtime::backend::DistanceBackend;
use crate::runtime::executable::{Client, Executable, Input};
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::util::matrix::Matrix;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::path::Path;

/// Distance engine executing AOT-compiled Pallas/HLO kernels via PJRT.
pub struct XlaBackend<'a> {
    points: &'a Points,
    matrix: &'a Matrix,
    metric: Metric,
    counter: DistanceCounter,
    spec: ArtifactSpec,
    exe: Executable,
    /// Reused staging buffers (allocation-free steady state).
    stage: RefCell<Stage>,
    /// PJRT executions performed (for perf accounting).
    executions: std::cell::Cell<u64>,
}

struct Stage {
    x: Vec<f32>,
    y: Vec<f32>,
}

impl std::fmt::Debug for XlaBackend<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaBackend")
            .field("metric", &self.metric)
            .field("artifact", &self.spec.name)
            .finish_non_exhaustive()
    }
}

impl<'a> XlaBackend<'a> {
    /// Build from the artifact directory (`make artifacts` output).
    ///
    /// Fails fast when no artifact covers (metric, feature-dim) — e.g. tree
    /// edit distance, or `d` larger than every lowered shape.
    pub fn new(
        client: &Client,
        artifacts_dir: &Path,
        points: &'a Points,
        metric: Metric,
    ) -> Result<Self> {
        let matrix = match points {
            Points::Dense(m) => m,
            _ => {
                return Err(anyhow!(
                    "XlaBackend supports dense points only (got {})",
                    points.kind()
                ))
            }
        };
        let manifest = Manifest::load(artifacts_dir)?;
        let spec = manifest
            .find_pairwise(metric.name(), matrix.cols())
            .ok_or_else(|| {
                anyhow!(
                    "no pairwise artifact for metric={} d={} (have: {})",
                    metric.name(),
                    matrix.cols(),
                    manifest
                        .artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?
            .clone();
        let exe = client
            .compile_hlo_text(&spec.path)
            .map_err(|e| e.context(format!("loading artifact {}", spec.name)))?;
        let stage = Stage {
            x: vec![0.0; spec.t * spec.d],
            y: vec![0.0; spec.r * spec.d],
        };
        Ok(XlaBackend {
            points,
            matrix,
            metric,
            counter: DistanceCounter::new(),
            spec,
            exe,
            stage: RefCell::new(stage),
            executions: std::cell::Cell::new(0),
        })
    }

    /// The artifact powering this backend.
    pub fn artifact(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// PJRT executions so far.
    pub fn executions(&self) -> u64 {
        self.executions.get()
    }

    /// Execute one padded tile; scatter `rows x cols` of the result into
    /// `out` at stride `out_stride` starting at `out_offset`.
    fn run_tile(
        &self,
        targets: &[usize],
        refs: &[usize],
        out: &mut [f64],
        out_stride: usize,
        out_row0: usize,
        out_col0: usize,
    ) -> Result<()> {
        let (t, r, d) = (self.spec.t, self.spec.r, self.spec.d);
        let dim = self.matrix.cols();
        let mut stage = self.stage.borrow_mut();
        stage.x.iter_mut().for_each(|v| *v = 0.0);
        stage.y.iter_mut().for_each(|v| *v = 0.0);
        for (i, &ti) in targets.iter().enumerate() {
            stage.x[i * d..i * d + dim].copy_from_slice(self.matrix.row(ti));
        }
        for (j, &rj) in refs.iter().enumerate() {
            stage.y[j * d..j * d + dim].copy_from_slice(self.matrix.row(rj));
        }
        let outputs = self.exe.run_f32(&[
            Input { data: &stage.x, shape: &[t as i64, d as i64] },
            Input { data: &stage.y, shape: &[r as i64, d as i64] },
        ])?;
        self.executions.set(self.executions.get() + 1);
        let block = &outputs[0]; // [t, r] row-major
        for (i, _) in targets.iter().enumerate() {
            for (j, _) in refs.iter().enumerate() {
                out[(out_row0 + i) * out_stride + out_col0 + j] = block[i * r + j] as f64;
            }
        }
        self.counter.add((targets.len() * refs.len()) as u64);
        Ok(())
    }
}

impl<'a> DistanceBackend for XlaBackend<'a> {
    fn points(&self) -> &Points {
        self.points
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn counter(&self) -> &DistanceCounter {
        &self.counter
    }

    fn dist(&self, i: usize, j: usize) -> f64 {
        let mut out = [0.0f64];
        self.run_tile(&[i], &[j], &mut out, 1, 0, 0)
            .expect("PJRT execution failed");
        out[0]
    }

    fn block(&self, targets: &[usize], refs: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), targets.len() * refs.len());
        let stride = refs.len();
        for (bi, tchunk) in targets.chunks(self.spec.t).enumerate() {
            for (bj, rchunk) in refs.chunks(self.spec.r).enumerate() {
                self.run_tile(
                    tchunk,
                    rchunk,
                    out,
                    stride,
                    bi * self.spec.t,
                    bj * self.spec.r,
                )
                .expect("PJRT execution failed");
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
