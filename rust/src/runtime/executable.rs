//! PJRT executable loading: HLO text -> compiled, callable computation.
//!
//! Follows the /opt/xla-example/load_hlo pattern: the interchange format is
//! HLO **text** (jax >= 0.5 emits 64-bit instruction ids in serialized
//! protos, which xla_extension 0.5.1 rejects; the text parser reassigns
//! ids). Each artifact compiles once and is then executed with concrete
//! `f32` buffers from the Rust hot path.
//!
//! The `xla` crate is not in the offline build cache, so the PJRT bridge
//! is gated behind the `xla` cargo feature. The default build substitutes
//! a stub whose constructors return errors; everything that consumes this
//! module ([`crate::runtime::xla_backend`], the CLI `info` command, the
//! benches) already handles "PJRT unavailable" gracefully, so the native
//! engine remains fully functional.

/// A concrete f32 input tensor.
pub struct Input<'a> {
    pub data: &'a [f32],
    pub shape: &'a [i64],
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::Input;
    use anyhow::{Context, Result};
    use std::path::Path;

    /// Shared PJRT CPU client (one per process is plenty).
    pub struct Client {
        inner: xla::PjRtClient,
    }

    impl Client {
        /// Create the PJRT CPU client.
        pub fn cpu() -> Result<Client> {
            let inner = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Client { inner })
        }

        /// Platform string, e.g. "cpu" (for logs).
        pub fn platform(&self) -> String {
            self.inner.platform_name()
        }

        /// Load an HLO-text artifact and compile it on this client.
        pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-UTF-8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .inner
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable { exe })
        }
    }

    /// A compiled computation plus its buffer plumbing.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    impl std::fmt::Debug for Executable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Executable").finish_non_exhaustive()
        }
    }

    impl Executable {
        /// Execute with f32 inputs; returns the flattened f32 outputs of the
        /// (single-tuple) result, one `Vec` per tuple element.
        pub fn run_f32(&self, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for inp in inputs {
                let expect: i64 = inp.shape.iter().product();
                anyhow::ensure!(
                    expect as usize == inp.data.len(),
                    "input shape {:?} does not match buffer length {}",
                    inp.shape,
                    inp.data.len()
                );
                let lit = xla::Literal::vec1(inp.data)
                    .reshape(inp.shape)
                    .context("reshaping input literal")?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("executing PJRT computation")?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            // aot.py lowers with return_tuple=True: unpack the tuple.
            let elems = result.to_tuple().context("untupling result")?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>().context("reading f32 output")?);
            }
            Ok(out)
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{Client, Executable};

#[cfg(not(feature = "xla"))]
mod stub {
    use super::Input;
    use anyhow::{anyhow, Result};
    use std::path::Path;

    const UNAVAILABLE: &str = "PJRT/XLA support is not compiled into this \
        binary (the `xla` crate is unavailable offline; build with \
        `--features xla` once it is vendored)";

    /// Stub PJRT client: every constructor reports XLA as unavailable.
    pub struct Client;

    impl Client {
        /// Always fails in the default (offline) build.
        pub fn cpu() -> Result<Client> {
            Err(anyhow!("{UNAVAILABLE}"))
        }

        /// Platform string placeholder.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Always fails in the default (offline) build.
        pub fn compile_hlo_text(&self, _path: &Path) -> Result<Executable> {
            Err(anyhow!("{UNAVAILABLE}"))
        }
    }

    /// Stub compiled computation; cannot be constructed through [`Client`].
    pub struct Executable;

    impl std::fmt::Debug for Executable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Executable").finish_non_exhaustive()
        }
    }

    impl Executable {
        /// Always fails in the default (offline) build.
        pub fn run_f32(&self, _inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
            Err(anyhow!("{UNAVAILABLE}"))
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{Client, Executable};

#[cfg(test)]
mod tests {
    //! Compiling real artifacts is covered by `rust/tests/integration_runtime.rs`
    //! (it needs `make artifacts` to have run). Here we only check error paths
    //! that do not require a PJRT client.

    use super::*;
    use std::path::Path;

    #[test]
    fn missing_artifact_is_an_error() {
        let client = match Client::cpu() {
            Ok(c) => c,
            Err(_) => return, // PJRT unavailable in this environment
        };
        let err = client
            .compile_hlo_text(Path::new("/nonexistent/foo.hlo.txt"))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("foo.hlo.txt"), "{msg}");
    }
}
