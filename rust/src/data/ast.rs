//! HOC4-like abstract syntax trees and their generator.
//!
//! The paper's HOC4 dataset is 3,360 unique student solutions to the
//! fourth Hour-of-Code exercise on Code.org, represented as ASTs and
//! compared with tree edit distance. The raw corpus is not publicly
//! downloadable, so we generate a statistically analogous corpus: a small
//! block-language grammar (the Hour-of-Code blocks: move/turn/repeat/if),
//! a handful of canonical "solution" prototypes, and a mutation process
//! that produces a cloud of variants around each prototype — mimicking the
//! real corpus's structure of a few correct solutions plus thousands of
//! near-miss variants.

use crate::util::rng::Rng;

/// An ordered, labelled tree (AST node).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tree {
    pub label: u32,
    pub children: Vec<Tree>,
}

/// Block-language vocabulary (labels for [`Tree::label`]).
pub mod blocks {
    pub const PROGRAM: u32 = 0;
    pub const MOVE_FORWARD: u32 = 1;
    pub const TURN_LEFT: u32 = 2;
    pub const TURN_RIGHT: u32 = 3;
    pub const REPEAT: u32 = 4;
    pub const IF_PATH_AHEAD: u32 = 5;
    pub const IF_PATH_LEFT: u32 = 6;
    pub const NUMBER_BASE: u32 = 16; // NUMBER_BASE + i encodes literal i

    /// Printable name for a label.
    pub fn name(label: u32) -> String {
        match label {
            PROGRAM => "program".into(),
            MOVE_FORWARD => "move_forward".into(),
            TURN_LEFT => "turn_left".into(),
            TURN_RIGHT => "turn_right".into(),
            REPEAT => "repeat".into(),
            IF_PATH_AHEAD => "if_path_ahead".into(),
            IF_PATH_LEFT => "if_path_left".into(),
            n if n >= NUMBER_BASE => format!("{}", n - NUMBER_BASE),
            n => format!("label{n}"),
        }
    }
}

impl Tree {
    /// Leaf constructor.
    pub fn leaf(label: u32) -> Tree {
        Tree { label, children: vec![] }
    }

    /// Internal-node constructor.
    pub fn node(label: u32, children: Vec<Tree>) -> Tree {
        Tree { label, children }
    }

    /// Total number of nodes.
    pub fn size(&self) -> usize {
        1 + self.children.iter().map(Tree::size).sum::<usize>()
    }

    /// Depth (a single node has depth 1).
    pub fn depth(&self) -> usize {
        1 + self.children.iter().map(Tree::depth).max().unwrap_or(0)
    }

    /// S-expression rendering, e.g. `(program move_forward (repeat 4 ...))`.
    pub fn render(&self) -> String {
        if self.children.is_empty() {
            blocks::name(self.label)
        } else {
            let ch: Vec<String> = self.children.iter().map(Tree::render).collect();
            format!("({} {})", blocks::name(self.label), ch.join(" "))
        }
    }

    /// Collect mutable pointers is not possible without unsafe; instead we
    /// address nodes by preorder index for mutation.
    fn count(&self) -> usize {
        self.size()
    }

    fn get_mut(&mut self, idx: usize) -> Option<&mut Tree> {
        fn walk<'a>(t: &'a mut Tree, idx: &mut usize) -> Option<&'a mut Tree> {
            if *idx == 0 {
                return Some(t);
            }
            *idx -= 1;
            for c in &mut t.children {
                if let Some(found) = walk(c, idx) {
                    return Some(found);
                }
            }
            None
        }
        let mut i = idx;
        walk(self, &mut i)
    }
}

/// Canonical "solutions" to the HOC4-like maze task.
pub fn prototypes() -> Vec<Tree> {
    use blocks::*;
    vec![
        // move, turn left, move, move
        Tree::node(
            PROGRAM,
            vec![
                Tree::leaf(MOVE_FORWARD),
                Tree::leaf(TURN_LEFT),
                Tree::leaf(MOVE_FORWARD),
                Tree::leaf(MOVE_FORWARD),
            ],
        ),
        // repeat 2 { move }, turn left, repeat 2 { move }
        Tree::node(
            PROGRAM,
            vec![
                Tree::node(
                    REPEAT,
                    vec![Tree::leaf(NUMBER_BASE + 2), Tree::leaf(MOVE_FORWARD)],
                ),
                Tree::leaf(TURN_LEFT),
                Tree::node(
                    REPEAT,
                    vec![Tree::leaf(NUMBER_BASE + 2), Tree::leaf(MOVE_FORWARD)],
                ),
            ],
        ),
        // repeat 4 { if path-ahead { move } else-ish turn }
        Tree::node(
            PROGRAM,
            vec![Tree::node(
                REPEAT,
                vec![
                    Tree::leaf(NUMBER_BASE + 4),
                    Tree::node(IF_PATH_AHEAD, vec![Tree::leaf(MOVE_FORWARD)]),
                    Tree::node(IF_PATH_LEFT, vec![Tree::leaf(TURN_LEFT)]),
                ],
            )],
        ),
        // long literal solution
        Tree::node(
            PROGRAM,
            vec![
                Tree::leaf(MOVE_FORWARD),
                Tree::leaf(MOVE_FORWARD),
                Tree::leaf(TURN_RIGHT),
                Tree::leaf(TURN_LEFT),
                Tree::leaf(MOVE_FORWARD),
                Tree::leaf(MOVE_FORWARD),
            ],
        ),
    ]
}

const MUTATION_LABELS: &[u32] = &[
    blocks::MOVE_FORWARD,
    blocks::TURN_LEFT,
    blocks::TURN_RIGHT,
];

/// Apply one random edit (relabel / insert-leaf / delete-leaf) in place.
pub fn mutate(t: &mut Tree, rng: &mut Rng) {
    let n = t.count();
    match rng.below(3) {
        0 => {
            // relabel a random non-root node to a random action block
            if n > 1 {
                let idx = rng.range(1, n);
                if let Some(node) = t.get_mut(idx) {
                    if node.label != blocks::REPEAT && node.children.is_empty() {
                        node.label = *rng.choose(MUTATION_LABELS);
                    }
                }
            }
        }
        1 => {
            // insert a new action leaf under a random internal-capable node
            let idx = rng.below(n);
            if let Some(node) = t.get_mut(idx) {
                if node.label == blocks::PROGRAM || node.label == blocks::REPEAT {
                    let pos = rng.below(node.children.len() + 1);
                    node
                        .children
                        .insert(pos, Tree::leaf(*rng.choose(MUTATION_LABELS)));
                }
            }
        }
        _ => {
            // delete a random leaf (never the root, keep >= 1 child)
            let idx = rng.below(n);
            if let Some(node) = t.get_mut(idx) {
                if node.children.len() > 1 {
                    let pos = rng.below(node.children.len());
                    if node.children[pos].children.is_empty() {
                        node.children.remove(pos);
                    }
                }
            }
        }
    }
}

/// Generate an HOC4-like corpus of `n` ASTs (and their prototype labels).
///
/// Each sample picks a prototype (geometric-ish popularity skew, like real
/// student data where a few solutions dominate) and applies
/// `Poisson(edit_rate)` random edits.
pub fn generate(n: usize, edit_rate: f64, rng: &mut Rng) -> (Vec<Tree>, Vec<usize>) {
    let protos = prototypes();
    let mut trees = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    // popularity weights 8:4:2:1
    let weights = [8usize, 4, 2, 1];
    let total: usize = weights.iter().sum();
    for _ in 0..n {
        let mut pick = rng.below(total);
        let mut proto_idx = 0;
        for (i, &w) in weights.iter().enumerate() {
            if pick < w {
                proto_idx = i;
                break;
            }
            pick -= w;
        }
        let mut t = protos[proto_idx].clone();
        let edits = rng.poisson(edit_rate);
        for _ in 0..edits {
            mutate(&mut t, rng);
        }
        trees.push(t);
        labels.push(proto_idx);
    }
    (trees, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_and_depth() {
        let t = Tree::node(0, vec![Tree::leaf(1), Tree::node(2, vec![Tree::leaf(3)])]);
        assert_eq!(t.size(), 4);
        assert_eq!(t.depth(), 3);
    }

    #[test]
    fn render_sexpr() {
        let t = prototypes()[0].clone();
        let s = t.render();
        assert!(s.starts_with("(program"));
        assert!(s.contains("move_forward"));
    }

    #[test]
    fn prototypes_are_distinct() {
        let ps = prototypes();
        for i in 0..ps.len() {
            for j in (i + 1)..ps.len() {
                assert_ne!(ps[i], ps[j]);
            }
        }
    }

    #[test]
    fn mutate_keeps_valid_tree() {
        let mut rng = Rng::seed_from(3);
        let mut t = prototypes()[1].clone();
        for _ in 0..200 {
            mutate(&mut t, &mut rng);
            assert_eq!(t.label, blocks::PROGRAM);
            assert!(t.size() >= 1);
            assert!(t.size() < 500, "runaway growth");
        }
    }

    #[test]
    fn generate_shapes_and_label_range() {
        let mut rng = Rng::seed_from(4);
        let (trees, labels) = generate(100, 2.0, &mut rng);
        assert_eq!(trees.len(), 100);
        assert_eq!(labels.len(), 100);
        assert!(labels.iter().all(|&l| l < prototypes().len()));
        // popularity skew: prototype 0 should dominate
        let c0 = labels.iter().filter(|&&l| l == 0).count();
        assert!(c0 > 30, "c0 = {c0}");
    }

    #[test]
    fn zero_edit_rate_reproduces_prototypes() {
        let mut rng = Rng::seed_from(5);
        let (trees, labels) = generate(20, 0.0, &mut rng);
        let ps = prototypes();
        for (t, &l) in trees.iter().zip(&labels) {
            assert_eq!(*t, ps[l]);
        }
    }
}
