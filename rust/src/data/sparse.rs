//! Compressed sparse row (CSR) storage for high-dimensional sparse points.
//!
//! The paper's flagship large-scale workload — the 10x Genomics 68k PBMC
//! scRNA-seq dataset under l1 — is >90% zeros, so dense `O(d)` kernels
//! waste most of their cycles multiplying zeros. [`CsrMatrix`] stores only
//! the nonzeros (one sorted `(column, value)` run per row) and the sparse
//! kernels in [`crate::distance::sparse`] evaluate a pair in
//! `O(nnz_a + nnz_b)` (merge) or `O(nnz_b)` (scatter/gather row path) —
//! see `rust/PERF.md` §7.
//!
//! Invariants (enforced by [`CsrMatrix::from_parts`], preserved by every
//! constructor):
//!
//! * `indptr` has `rows + 1` monotonically non-decreasing entries with
//!   `indptr[0] == 0` and `indptr[rows] == indices.len() == values.len()`;
//! * within each row, column indices are strictly increasing (sorted,
//!   no duplicates) and `< cols`;
//! * stored values are nonzero (constructors strip explicit zeros — the
//!   kernels stay correct with them, but they waste space and cycles);
//! * stored values are finite (NaN/±inf would silently corrupt the
//!   nearest-medoid argmin comparisons downstream).

use crate::util::matrix::Matrix;

/// Row-major compressed sparse row matrix (`f32` values, `u32` columns).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    /// Row start offsets into `indices`/`values`; `rows + 1` entries.
    indptr: Vec<usize>,
    /// Column index of each stored value, strictly increasing per row.
    indices: Vec<u32>,
    /// Stored (nonzero) values.
    values: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl CsrMatrix {
    /// Build from raw CSR arrays, validating every invariant listed in the
    /// module docs. Panics on violation (programmer error, not input
    /// error — file loaders go through [`CsrMatrix::from_triplets`], and
    /// untrusted on-disk payloads through [`CsrMatrix::try_from_parts`]).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> CsrMatrix {
        match CsrMatrix::try_from_parts(rows, cols, indptr, indices, values) {
            Ok(m) => m,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible variant of [`CsrMatrix::from_parts`]: returns a descriptive
    /// `Err` instead of panicking on an invariant violation. This is the
    /// entry point for *untrusted* CSR payloads (the model file loader),
    /// where a corrupt file must surface as a clean error.
    pub fn try_from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<CsrMatrix, String> {
        if cols > u32::MAX as usize {
            return Err(format!("cols {cols} exceeds u32 column space"));
        }
        if indptr.len() != rows + 1 {
            return Err(format!(
                "indptr must have rows+1 entries (rows = {rows}, got {})",
                indptr.len()
            ));
        }
        if indptr[0] != 0 {
            return Err(format!("indptr[0] must be 0 (got {})", indptr[0]));
        }
        if *indptr.last().unwrap() != indices.len() {
            return Err(format!(
                "indptr end/nnz mismatch ({} vs {})",
                indptr[rows],
                indices.len()
            ));
        }
        if indices.len() != values.len() {
            return Err(format!(
                "indices/values length mismatch ({} vs {})",
                indices.len(),
                values.len()
            ));
        }
        // Full monotonicity first: with `indptr[rows] == nnz` already
        // checked, this bounds every entry by nnz, so the row slicing
        // below cannot go out of range even on hostile input.
        if indptr.windows(2).any(|w| w[0] > w[1]) {
            return Err("indptr must be non-decreasing".to_string());
        }
        for r in 0..rows {
            let row = &indices[indptr[r]..indptr[r + 1]];
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r}: columns must be strictly increasing"));
                }
            }
            if let Some(&last) = row.last() {
                if last as usize >= cols {
                    return Err(format!("row {r}: column {last} >= cols {cols}"));
                }
            }
        }
        // Finite values only: a stored NaN poisons every distance
        // comparison downstream (NaN < best is always false, so medoid
        // argmins silently pick garbage), and ±inf overflows reductions.
        if let Some(&v) = values.iter().find(|v| !v.is_finite()) {
            return Err(format!("non-finite value {v} stored"));
        }
        // No explicit zeros: nnz()/density()/PartialEq all assume stored
        // values are structural nonzeros (the kernels would stay correct,
        // but two equal-data matrices would compare unequal).
        if !values.iter().all(|&v| v != 0.0) {
            return Err("explicit zero value stored (strip zeros before from_parts)".to_string());
        }
        Ok(CsrMatrix { indptr, indices, values, rows, cols })
    }

    /// Empty matrix (no stored values).
    pub fn zeros(rows: usize, cols: usize) -> CsrMatrix {
        CsrMatrix::from_parts(rows, cols, vec![0; rows + 1], Vec::new(), Vec::new())
    }

    /// Build from `(row, col, value)` triplets in any order. Duplicate
    /// coordinates are summed (Matrix Market semantics); entries that are
    /// (or sum to) zero are dropped. Panics on out-of-bounds coordinates.
    ///
    /// The sort is **stable**, so duplicate coordinates sum in input
    /// order. This makes the result a function of the triplet *sequence*
    /// restricted to each row: partitioning the rows, building each part
    /// from its own triplet subsequence and concatenating yields the same
    /// bits as one global build. The out-of-core window reader
    /// ([`crate::data::stream`]) is bitwise-identical to the in-memory
    /// loader because of exactly this property.
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f32)]) -> CsrMatrix {
        CsrMatrix::from_triplet_vec(rows, cols, triplets.to_vec())
    }

    /// Owning variant of [`CsrMatrix::from_triplets`]: sorts the vector in
    /// place instead of cloning it first. The loaders use this on the
    /// memory-sensitive `.mtx` paths; peak transient triplet memory is
    /// ~1.5 copies (the parity-critical *stable* sort allocates an
    /// auxiliary buffer of up to half the slice), not the 2 copies the
    /// borrow-then-clone form costs.
    pub fn from_triplet_vec(
        rows: usize,
        cols: usize,
        mut sorted: Vec<(usize, usize, f32)>,
    ) -> CsrMatrix {
        sorted.sort_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(sorted.len());
        let mut values: Vec<f32> = Vec::with_capacity(sorted.len());
        let mut prev: Option<(usize, usize)> = None;
        // Close out a (possibly zero-sum) coordinate run.
        let finish_run = |prev: Option<(usize, usize)>,
                          indptr: &mut Vec<usize>,
                          indices: &mut Vec<u32>,
                          values: &mut Vec<f32>| {
            if let Some((pr, _)) = prev {
                if values.last() == Some(&0.0) {
                    values.pop();
                    indices.pop();
                    indptr[pr + 1] -= 1;
                }
            }
        };
        for &(r, c, v) in &sorted {
            assert!(r < rows && c < cols, "triplet ({r}, {c}) out of {rows}x{cols}");
            if prev == Some((r, c)) {
                *values.last_mut().unwrap() += v;
                continue;
            }
            finish_run(prev, &mut indptr, &mut indices, &mut values);
            indptr[r + 1] += 1;
            indices.push(c as u32);
            values.push(v);
            prev = Some((r, c));
        }
        finish_run(prev, &mut indptr, &mut indices, &mut values);
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        CsrMatrix::from_parts(rows, cols, indptr, indices, values)
    }

    /// Compress a dense matrix (exact zeros are dropped).
    pub fn from_dense(m: &Matrix) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(m.rows() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix::from_parts(m.rows(), m.cols(), indptr, indices, values)
    }

    /// Expand to a dense matrix.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let row = m.row_mut(i);
            for (&j, &v) in idx.iter().zip(val) {
                row[j as usize] = v;
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored values.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Stored values of row `i`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Fraction of entries stored (0 for a degenerate 0-entry shape).
    pub fn density(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Row `i` as parallel `(column indices, values)` slices, columns
    /// strictly increasing.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        debug_assert!(i < self.rows);
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Select a subset of rows into a new matrix (same column space).
    pub fn select_rows(&self, idx: &[usize]) -> CsrMatrix {
        let nnz: usize = idx.iter().map(|&i| self.row_nnz(i)).sum();
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for &i in idx {
            let (ci, cv) = self.row(i);
            indices.extend_from_slice(ci);
            values.extend_from_slice(cv);
            indptr.push(indices.len());
        }
        CsrMatrix::from_parts(idx.len(), self.cols, indptr, indices, values)
    }

    /// The raw CSR arrays as `(indptr, indices, values)` slices — the
    /// inverse of [`CsrMatrix::from_parts`]. Used by the out-of-core
    /// window assembler and the bitwise parity tests.
    pub fn parts(&self) -> (&[usize], &[u32], &[f32]) {
        (&self.indptr, &self.indices, &self.values)
    }

    /// Iterate all stored entries as `(row, col, value)` in row-major order
    /// (the Matrix Market writer's canonical order).
    pub fn triplets(&self) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        (0..self.rows).flat_map(move |i| {
            let (idx, val) = self.row(i);
            idx.iter().zip(val).map(move |(&j, &v)| (i, j as usize, v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> CsrMatrix {
        // [[1, 0, 2], [0, 0, 0], [0, 3, 0], [4, 5, 6]]
        CsrMatrix::from_triplets(
            4,
            3,
            &[(0, 0, 1.0), (0, 2, 2.0), (2, 1, 3.0), (3, 0, 4.0), (3, 1, 5.0), (3, 2, 6.0)],
        )
    }

    #[test]
    fn triplet_construction_sorts_and_shapes() {
        let m = CsrMatrix::from_triplets(2, 3, &[(1, 2, 5.0), (0, 1, 1.0), (1, 0, 2.0)]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[1u32][..], &[1.0f32][..]));
        assert_eq!(m.row(1), (&[0u32, 2][..], &[2.0f32, 5.0][..]));
        // the owning (no-clone) variant is the same constructor
        let v = CsrMatrix::from_triplet_vec(2, 3, vec![(1, 2, 5.0), (0, 1, 1.0), (1, 0, 2.0)]);
        assert_eq!(v, m);
    }

    #[test]
    fn duplicate_triplets_sum_and_zero_sums_drop() {
        let m = CsrMatrix::from_triplets(
            2,
            2,
            &[(0, 0, 1.5), (0, 0, 0.5), (1, 1, 2.0), (1, 1, -2.0), (1, 0, 3.0)],
        );
        assert_eq!(m.row(0), (&[0u32][..], &[2.0f32][..]));
        assert_eq!(m.row(1), (&[0u32][..], &[3.0f32][..]));
        assert_eq!(m.nnz(), 2);
    }

    /// Duplicate summation is order-sensitive in f32; the stable sort pins
    /// it to input order. 1e8 + 1.0 rounds back to 1e8, so summing in input
    /// order cancels to exactly zero (run dropped); any reordering that
    /// sums 1e8 - 1e8 first would keep a 1.0.
    #[test]
    fn duplicate_summation_is_input_ordered() {
        let m = CsrMatrix::from_triplets(
            1,
            2,
            &[(0, 0, 1e8), (0, 0, 1.0), (0, 0, -1e8), (0, 1, 5.0)],
        );
        assert_eq!(m.row(0), (&[1u32][..], &[5.0f32][..]));
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn parts_roundtrip_through_from_parts() {
        let m = fixture();
        let (indptr, indices, values) = m.parts();
        let rebuilt = CsrMatrix::from_parts(
            m.rows(),
            m.cols(),
            indptr.to_vec(),
            indices.to_vec(),
            values.to_vec(),
        );
        assert_eq!(rebuilt, m);
    }

    #[test]
    fn explicit_zero_triplets_are_dropped() {
        let m = CsrMatrix::from_triplets(2, 2, &[(0, 0, 0.0), (1, 1, 1.0)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_nnz(0), 0);
    }

    #[test]
    fn dense_roundtrip() {
        let d = Matrix::from_vec(vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 3.0, 0.0], 3, 3);
        let s = CsrMatrix::from_dense(&d);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.row_nnz(1), 0);
        assert_eq!(s.to_dense(), d);
        assert!((s.density() - 3.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn select_rows_preserves_content() {
        let m = fixture();
        let s = m.select_rows(&[3, 1, 0]);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.row(0), m.row(3));
        assert_eq!(s.row_nnz(1), 0);
        assert_eq!(s.row(2), m.row(0));
    }

    #[test]
    fn triplets_iterate_row_major() {
        let m = fixture();
        let t: Vec<_> = m.triplets().collect();
        assert_eq!(
            t,
            vec![(0, 0, 1.0), (0, 2, 2.0), (2, 1, 3.0), (3, 0, 4.0), (3, 1, 5.0), (3, 2, 6.0)]
        );
        let rebuilt = CsrMatrix::from_triplets(4, 3, &t);
        assert_eq!(rebuilt, m);
    }

    #[test]
    fn zeros_is_empty() {
        let m = CsrMatrix::zeros(5, 7);
        assert_eq!(m.rows(), 5);
        assert_eq!(m.cols(), 7);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.density(), 0.0);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn unsorted_row_rejected() {
        CsrMatrix::from_parts(1, 4, vec![0, 2], vec![2, 1], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "explicit zero")]
    fn explicit_zero_value_rejected() {
        CsrMatrix::from_parts(1, 4, vec![0, 2], vec![1, 2], vec![1.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn out_of_bounds_triplet_rejected() {
        CsrMatrix::from_triplets(2, 2, &[(0, 5, 1.0)]);
    }

    type CsrMatrixPartsCase = (usize, usize, Vec<usize>, Vec<u32>, Vec<f32>);

    /// `try_from_parts` is the untrusted-input entry: every invariant
    /// violation is an `Err`, never a panic.
    #[test]
    fn try_from_parts_rejects_each_invariant_violation() {
        let ok = CsrMatrix::try_from_parts(2, 3, vec![0, 1, 2], vec![1, 0], vec![1.0, 2.0]);
        assert!(ok.is_ok());
        let cases: Vec<(CsrMatrixPartsCase, &str)> = vec![
            ((1, 4, vec![0, 1, 2], vec![1, 2], vec![1.0, 2.0]), "rows+1"),
            ((1, 4, vec![1, 2], vec![1], vec![1.0]), "indptr[0]"),
            ((1, 4, vec![0, 1], vec![1, 2], vec![1.0, 2.0]), "mismatch"),
            ((1, 4, vec![0, 2], vec![1, 2], vec![1.0]), "length mismatch"),
            // hostile indptr: decreasing run whose end still equals nnz —
            // must Err without slicing out of bounds
            ((2, 4, vec![0, 2, 1], vec![1], vec![1.0]), "non-decreasing"),
            ((1, 4, vec![0, 2], vec![2, 1], vec![1.0, 2.0]), "strictly increasing"),
            ((1, 4, vec![0, 2], vec![1, 1], vec![1.0, 2.0]), "strictly increasing"),
            ((1, 2, vec![0, 1], vec![5], vec![1.0]), ">= cols"),
            ((1, 4, vec![0, 1], vec![1], vec![0.0]), "explicit zero"),
            ((1, 4, vec![0, 1], vec![1], vec![f32::NAN]), "non-finite"),
            ((1, 4, vec![0, 2], vec![1, 2], vec![1.0, f32::INFINITY]), "non-finite"),
            ((1, 4, vec![0, 1], vec![1], vec![f32::NEG_INFINITY]), "non-finite"),
        ];
        for ((rows, cols, indptr, indices, values), needle) in cases {
            let err = CsrMatrix::try_from_parts(rows, cols, indptr, indices, values)
                .unwrap_err();
            assert!(err.contains(needle), "expected {needle:?} in {err:?}");
        }
    }
}
