//! Synthetic dataset generators standing in for the paper's corpora.
//!
//! Each generator is engineered to match the *statistics that BanditPAM's
//! behaviour depends on* — the spread of arm means `mu_x` and per-arm
//! sub-Gaussian parameters `sigma_x` (paper Appendix Figures 1–4) — not the
//! semantic content of the original data. See DESIGN.md §Substitutions.

use crate::data::sparse::CsrMatrix;
use crate::data::{ast, Dataset, Points};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Generic isotropic Gaussian mixture: `k` components in `d` dims with unit
/// prototypes at scale `sep`. The workhorse of the unit tests.
pub fn gmm(rng: &mut Rng, n: usize, d: usize, k: usize, sep: f64) -> Dataset {
    assert!(k >= 1 && d >= 1);
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.normal() * sep).collect())
        .collect();
    let mut m = Matrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(k);
        labels.push(c);
        let row = m.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r = (centers[c][j] + rng.normal()) as f32;
        }
    }
    Dataset {
        points: Points::Dense(m),
        labels: Some(labels),
        name: format!("gmm(n={n}, d={d}, k={k})"),
    }
}

/// MNIST-like images: 10 "digit" prototypes in `[0,1]^784`.
///
/// Prototypes are spatially smooth random stroke patterns (sums of random
/// axis-aligned Gaussian bumps on the 28x28 grid), pixels are clipped to
/// [0, 1] and ~75–85% of pixels are near zero — matching MNIST's sparsity
/// and giving l2/cosine arm-mean distributions with the broad unimodal
/// shape of Appendix Figure 2 (top row).
pub fn mnist_like(rng: &mut Rng, n: usize) -> Dataset {
    const SIDE: usize = 28;
    const D: usize = SIDE * SIDE;
    const K: usize = 10;
    // Build K prototype images from random strokes. Crucially, prototypes
    // differ strongly in *ink amount* (stroke count and thickness), like
    // real digits ("1" vs "8") — this is what gives MNIST its wide spread
    // of arm means mu_x (paper App Fig 2 top-left spans ~7.2..11), which
    // in turn is what Algorithm 1's elimination feeds on.
    let mut protos = vec![[0.0f64; D]; K];
    for (ci, proto) in protos.iter_mut().enumerate() {
        let bumps = 2 + ci; // 2..=11 strokes: systematic ink gradient
        for _ in 0..bumps {
            let cx = 4.0 + rng.f64() * 20.0;
            let cy = 4.0 + rng.f64() * 20.0;
            let sx = 1.0 + rng.f64() * 3.0;
            let sy = 1.0 + rng.f64() * 3.0;
            let amp = 0.6 + rng.f64() * 0.8;
            for y in 0..SIDE {
                for x in 0..SIDE {
                    let dx = (x as f64 - cx) / sx;
                    let dy = (y as f64 - cy) / sy;
                    proto[y * SIDE + x] += amp * (-0.5 * (dx * dx + dy * dy)).exp();
                }
            }
        }
    }
    let mut m = Matrix::zeros(n, D);
    let mut labels = Vec::with_capacity(n);
    let mut stroke = [0.0f64; D];
    for i in 0..n {
        let c = rng.below(K);
        labels.push(c);
        // Per-image *continuous* style variation — wide pen-pressure gain
        // plus 0-2 extra strokes. This dominates the within-class spread of
        // arm means, so the mu_x distribution across arms is smooth and
        // unimodal (paper App Fig 2) rather than atomic at each prototype;
        // Theorem 2's sub-Gaussian-mu assumption needs that thin left tail.
        let gain = 0.55 + rng.f64() * 0.9;
        // Per-image noise *scale* ("messiness"): isotropic constant-scale
        // noise in 784-d would concentrate all within-class distances at
        // one value (every class member equidistant => statistically tied
        // medoid candidates, which real MNIST does not exhibit). Clean and
        // messy images give the within-class distance spread real
        // handwriting has, putting a thin continuous tail at the minimum
        // of the arm-mean distribution.
        let u = rng.f64();
        let noise_scale = 0.05 + 0.30 * u * u;
        stroke.iter_mut().for_each(|v| *v = 0.0);
        for _ in 0..rng.below(3) {
            let cx = 4.0 + rng.f64() * 20.0;
            let cy = 4.0 + rng.f64() * 20.0;
            let s = 1.0 + rng.f64() * 2.0;
            let amp = 0.4 + rng.f64() * 0.6;
            for y in 0..SIDE {
                for x in 0..SIDE {
                    let dx = (x as f64 - cx) / s;
                    let dy = (y as f64 - cy) / s;
                    stroke[y * SIDE + x] += amp * (-0.5 * (dx * dx + dy * dy)).exp();
                }
            }
        }
        let row = m.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            let v = gain * protos[c][j] + stroke[j] + rng.normal() * noise_scale;
            // threshold small values to zero to match MNIST sparsity
            let v = if v < 0.15 { 0.0 } else { v.min(1.0) };
            *r = v as f32;
        }
    }
    Dataset {
        points: Points::Dense(m),
        labels: Some(labels),
        name: format!("mnist_like(n={n})"),
    }
}

/// scRNA-seq-like expression matrix: log-normal expression with dropout.
///
/// `genes` defaults to 1,024 in the benches (the paper's 10,170 is a pure
/// constant factor per Remark 3; pass 10_170 to reproduce it exactly).
/// ~11 cell-type prototypes with type-specific marker genes; heavy
/// zero-inflation (dropout) as in real UMI counts. Under l1 this produces
/// the long-tailed arm-mean distribution of Appendix Figure 2 (bottom left).
pub fn scrna_like(rng: &mut Rng, n: usize, genes: usize) -> Dataset {
    const K: usize = 11;
    // Prototype log-expression per type: most genes off, marker genes high.
    let mut protos = vec![vec![0.0f64; genes]; K];
    for proto in protos.iter_mut() {
        for v in proto.iter_mut() {
            if rng.bool(0.10) {
                *v = rng.lognormal(1.2, 0.6); // expressed gene
            }
        }
        // strong markers
        for _ in 0..(genes / 64).max(4) {
            let g = rng.below(genes);
            proto[g] = rng.lognormal(2.2, 0.4);
        }
    }
    let mut m = Matrix::zeros(n, genes);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(K);
        labels.push(c);
        let row = m.row_mut(i);
        for (g, r) in row.iter_mut().enumerate() {
            let base = protos[c][g];
            if base == 0.0 {
                // background noise: rare spurious counts
                if rng.bool(0.01) {
                    *r = rng.lognormal(0.0, 0.5) as f32;
                }
                continue;
            }
            // dropout: observed zero despite expression
            if rng.bool(0.35) {
                continue;
            }
            *r = (base * rng.lognormal(0.0, 0.35)) as f32;
        }
    }
    Dataset {
        points: Points::Dense(m),
        labels: Some(labels),
        name: format!("scrna_like(n={n}, g={genes})"),
    }
}

/// CSR-native scRNA-seq-like generator: the same distribution as
/// [`scrna_like`] — log-normal expression, marker genes, dropout — built
/// directly in compressed sparse row form, without ever materializing the
/// `n x genes` dense matrix (the point for 68k-cell / 10k-gene scale).
///
/// `express_p` is the per-gene expression probability of the prototype
/// stage (pre-dropout); [`scrna_like`] hardcodes `0.10`, and at that value
/// this generator consumes the **identical rng stream** and produces the
/// exact same data (`to_dense()` equals the [`scrna_like`] matrix
/// bit-for-bit) — the sparse-vs-densified parity tests depend on this.
/// Observed density lands near `0.65 * express_p` plus markers/background.
pub fn scrna_sparse(rng: &mut Rng, n: usize, genes: usize, express_p: f64) -> Dataset {
    const K: usize = 11;
    let mut protos = vec![vec![0.0f64; genes]; K];
    for proto in protos.iter_mut() {
        for v in proto.iter_mut() {
            if rng.bool(express_p) {
                *v = rng.lognormal(1.2, 0.6); // expressed gene
            }
        }
        // strong markers
        for _ in 0..(genes / 64).max(4) {
            let g = rng.below(genes);
            proto[g] = rng.lognormal(2.2, 0.4);
        }
    }
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut labels = Vec::with_capacity(n);
    indptr.push(0);
    for _ in 0..n {
        let c = rng.below(K);
        labels.push(c);
        for (g, &base) in protos[c].iter().enumerate() {
            let v = if base == 0.0 {
                // background noise: rare spurious counts
                if !rng.bool(0.01) {
                    continue;
                }
                rng.lognormal(0.0, 0.5) as f32
            } else {
                // dropout: observed zero despite expression
                if rng.bool(0.35) {
                    continue;
                }
                (base * rng.lognormal(0.0, 0.35)) as f32
            };
            // lognormal draws are strictly positive, but guard the f32
            // cast underflow so the CSR no-stored-zeros invariant holds
            if v != 0.0 {
                indices.push(g as u32);
                values.push(v);
            }
        }
        indptr.push(indices.len());
    }
    Dataset {
        points: Points::Sparse(CsrMatrix::from_parts(n, genes, indptr, indices, values)),
        labels: Some(labels),
        name: format!("scrna_sparse(n={n}, g={genes}, p={express_p})"),
    }
}

/// HOC4-like AST corpus wrapped as a [`Dataset`].
pub fn hoc4_like(rng: &mut Rng, n: usize) -> Dataset {
    let (trees, labels) = ast::generate(n, 2.5, rng);
    Dataset {
        points: Points::Trees(trees),
        labels: Some(labels),
        name: format!("hoc4_like(n={n})"),
    }
}

/// The scRNA-PCA pathology dataset (paper Appendix 1.3): project
/// [`scrna_like`] onto its top `pcs` principal components. Arm means
/// concentrate near the minimum and reward tails fatten, degrading
/// BanditPAM's scaling to ~n^1.2 (Appendix Figure 5).
pub fn scrna_pca(rng: &mut Rng, n: usize, genes: usize, pcs: usize) -> Dataset {
    let base = scrna_like(rng, n, genes);
    let m = match &base.points {
        Points::Dense(m) => m,
        _ => unreachable!(),
    };
    let projected = crate::data::pca::project(m, pcs, rng);
    Dataset {
        points: Points::Dense(projected),
        labels: base.labels,
        name: format!("scrna_pca(n={n}, g={genes}, pcs={pcs})"),
    }
}

/// One CLI-selectable synthetic dataset, as `--synthetic NAME` sees it.
pub struct SyntheticSpec {
    /// The accepted `--synthetic` spelling.
    pub name: &'static str,
    /// One-line description for `help` output.
    pub note: &'static str,
    /// Generator at the CLI's default shapes: `(rng, n, density)` —
    /// `density` is only consumed by `scrna-sparse`.
    pub make: fn(&mut Rng, usize, f64) -> Dataset,
}

/// Registry of the CLI's synthetic datasets (paper-default shapes).
/// `main.rs` dispatch and its `help` text both read this table, so the
/// accepted names can never drift from the documented ones.
pub const REGISTRY: &[SyntheticSpec] = &[
    SyntheticSpec {
        name: "gmm",
        note: "isotropic Gaussian mixture, d=16, 5 components (default)",
        make: |rng, n, _| gmm(rng, n, 16, 5, 3.0),
    },
    SyntheticSpec {
        name: "mnist",
        note: "MNIST-like 28x28 stroke images",
        make: |rng, n, _| mnist_like(rng, n),
    },
    SyntheticSpec {
        name: "scrna",
        note: "zero-inflated scRNA expression, 1024 genes (dense)",
        make: |rng, n, _| scrna_like(rng, n, 1024),
    },
    SyntheticSpec {
        name: "scrna-sparse",
        note: "scRNA expression generated directly as CSR (--density)",
        make: |rng, n, density| scrna_sparse(rng, n, 1024, density),
    },
    SyntheticSpec {
        name: "scrna-pca",
        note: "scRNA projected to 10 principal components",
        make: |rng, n, _| scrna_pca(rng, n, 1024, 10),
    },
    SyntheticSpec {
        name: "hoc4",
        note: "HOC4-like program ASTs (tree edit distance)",
        make: |rng, n, _| hoc4_like(rng, n),
    },
];

/// Generate a registry dataset by name (the `--synthetic` dispatch).
pub fn by_name(
    name: &str,
    rng: &mut Rng,
    n: usize,
    density: f64,
) -> crate::error::Result<Dataset> {
    REGISTRY
        .iter()
        .find(|spec| spec.name == name)
        .map(|spec| (spec.make)(rng, n, density))
        .ok_or_else(|| {
            crate::error::Error::invalid_argument(format!(
                "unknown synthetic dataset {name:?} (expected one of: {})",
                names()
            ))
        })
}

/// The accepted synthetic dataset names, comma-separated.
pub fn names() -> String {
    REGISTRY
        .iter()
        .map(|s| s.name)
        .collect::<Vec<_>>()
        .join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::{dense, evaluate, Metric};

    #[test]
    fn gmm_shapes_and_determinism() {
        let a = gmm(&mut Rng::seed_from(1), 50, 4, 3, 2.0);
        let b = gmm(&mut Rng::seed_from(1), 50, 4, 3, 2.0);
        assert_eq!(a.len(), 50);
        assert_eq!(a.points.dim(), Some(4));
        if let (Points::Dense(ma), Points::Dense(mb)) = (&a.points, &b.points) {
            assert_eq!(ma.as_slice(), mb.as_slice());
        }
    }

    #[test]
    fn gmm_clusters_are_separated() {
        let d = gmm(&mut Rng::seed_from(2), 200, 8, 2, 8.0);
        let (m, labels) = match (&d.points, &d.labels) {
            (Points::Dense(m), Some(l)) => (m, l),
            _ => unreachable!(),
        };
        // mean within-cluster distance < mean across-cluster distance
        let mut within = (0.0, 0u32);
        let mut across = (0.0, 0u32);
        for i in 0..50 {
            for j in (i + 1)..50 {
                let dist = dense::l2(m.row(i), m.row(j));
                if labels[i] == labels[j] {
                    within = (within.0 + dist, within.1 + 1);
                } else {
                    across = (across.0 + dist, across.1 + 1);
                }
            }
        }
        assert!(within.0 / (within.1 as f64) < across.0 / (across.1 as f64));
    }

    #[test]
    fn mnist_like_pixel_range_and_sparsity() {
        let d = mnist_like(&mut Rng::seed_from(3), 64);
        assert_eq!(d.points.dim(), Some(784));
        let m = match &d.points {
            Points::Dense(m) => m,
            _ => unreachable!(),
        };
        let all = m.as_slice();
        assert!(all.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let zeros = all.iter().filter(|&&v| v == 0.0).count() as f64 / all.len() as f64;
        assert!(zeros > 0.4 && zeros < 0.95, "sparsity {zeros}");
    }

    #[test]
    fn scrna_like_nonnegative_and_sparse() {
        let d = scrna_like(&mut Rng::seed_from(4), 40, 256);
        let m = match &d.points {
            Points::Dense(m) => m,
            _ => unreachable!(),
        };
        let all = m.as_slice();
        assert!(all.iter().all(|&v| v >= 0.0));
        let zeros = all.iter().filter(|&&v| v == 0.0).count() as f64 / all.len() as f64;
        assert!(zeros > 0.6, "sparsity {zeros}");
    }

    #[test]
    fn hoc4_like_trees_vary() {
        let d = hoc4_like(&mut Rng::seed_from(5), 30);
        assert_eq!(d.len(), 30);
        // tree edit distance works end to end and some pairs differ
        let mut nonzero = 0;
        for j in 1..10 {
            if evaluate(Metric::TreeEdit, &d.points, 0, j) > 0.0 {
                nonzero += 1;
            }
        }
        assert!(nonzero > 0);
    }

    #[test]
    fn scrna_sparse_is_bitwise_the_csr_of_scrna_like() {
        let dense = scrna_like(&mut Rng::seed_from(9), 50, 128);
        let sp = scrna_sparse(&mut Rng::seed_from(9), 50, 128, 0.10);
        assert_eq!(sp.labels, dense.labels);
        let (Points::Dense(dm), Points::Sparse(sm)) = (&dense.points, &sp.points) else {
            unreachable!()
        };
        assert_eq!(sm.to_dense().as_slice(), dm.as_slice());
        assert!(sm.density() < 0.35, "density {}", sm.density());
    }

    #[test]
    fn scrna_sparse_density_knob_scales_nnz() {
        let lo = scrna_sparse(&mut Rng::seed_from(10), 40, 256, 0.02);
        let hi = scrna_sparse(&mut Rng::seed_from(10), 40, 256, 0.40);
        let (Points::Sparse(lm), Points::Sparse(hm)) = (&lo.points, &hi.points) else {
            unreachable!()
        };
        assert!(lm.nnz() * 3 < hm.nnz(), "{} vs {}", lm.nnz(), hm.nnz());
    }

    #[test]
    fn scrna_pca_projects_to_low_dim() {
        let d = scrna_pca(&mut Rng::seed_from(6), 60, 128, 10);
        assert_eq!(d.points.dim(), Some(10));
        assert_eq!(d.len(), 60);
    }

    /// The registry dispatch consumes the identical rng stream as a direct
    /// generator call at the CLI-default shapes — `--synthetic gmm` before
    /// and after the registry refactor produces the same bits.
    #[test]
    fn registry_matches_direct_calls_bitwise() {
        let via = by_name("gmm", &mut Rng::seed_from(3), 30, 0.10).unwrap();
        let direct = gmm(&mut Rng::seed_from(3), 30, 16, 5, 3.0);
        let (Points::Dense(a), Points::Dense(b)) = (&via.points, &direct.points) else {
            unreachable!()
        };
        assert_eq!(a.as_slice(), b.as_slice());
        let sp = by_name("scrna-sparse", &mut Rng::seed_from(4), 20, 0.05).unwrap();
        let sp_direct = scrna_sparse(&mut Rng::seed_from(4), 20, 1024, 0.05);
        assert_eq!(sp.labels, sp_direct.labels);
        let err = by_name("imagenet", &mut Rng::seed_from(0), 10, 0.1).unwrap_err();
        assert!(err.to_string().contains("gmm"), "{err}");
        for spec in REGISTRY {
            assert!(names().contains(spec.name));
        }
    }
}
