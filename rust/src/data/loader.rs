//! File loaders: CSV feature matrices, MNIST IDX images and Matrix Market
//! (`.mtx`) sparse triplets.
//!
//! The bench suite runs on the synthetic generators, but real data drops in
//! via these loaders: `banditpam cluster --data points.csv`, an IDX file
//! (`train-images-idx3-ubyte`) if the user supplies the original MNIST, or
//! a 10x Genomics-style `matrix.mtx` (`--format mtx`, typically with
//! `--transpose` since 10x ships genes x cells) for the scRNA workload.

use crate::data::sparse::CsrMatrix;
use crate::data::{stream, Dataset, Points};
use crate::error::{Error, Result};
use crate::util::matrix::Matrix;
use std::fmt::Display;
use std::io::Read;
use std::path::Path;

/// Dataset-I/O error with the path folded in.
fn io_err(path: &Path, e: impl Display) -> Error {
    Error::data(format!("{}: {e}", path.display()))
}

/// Fold an error from the (internally `anyhow`-based) streaming reader
/// into the public [`Error::Data`] category, keeping its context chain.
fn stream_err(e: anyhow::Error) -> Error {
    Error::data(format!("{e:#}"))
}

/// Load a headerless CSV of floats (rows = points).
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).map_err(|e| io_err(path, e))?;
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row: std::result::Result<Vec<f32>, _> =
            line.split(',').map(|f| f.trim().parse::<f32>()).collect();
        let row = row.map_err(|e| {
            Error::data(format!("line {} of {}: {e}", lineno + 1, path.display()))
        })?;
        // Rust's f32 parser accepts "NaN"/"inf" spellings; a NaN feature
        // silently corrupts every nearest-medoid comparison downstream, so
        // reject non-finite values at the ingest boundary.
        if let Some(v) = row.iter().find(|v| !v.is_finite()) {
            return Err(Error::data(format!(
                "line {} of {}: non-finite value {v}",
                lineno + 1,
                path.display()
            )));
        }
        if let Some(first) = rows.first() {
            if row.len() != first.len() {
                return Err(Error::data(format!(
                    "ragged CSV: line {} has {} fields, expected {}",
                    lineno + 1,
                    row.len(),
                    first.len()
                )));
            }
        }
        rows.push(row);
    }
    if rows.is_empty() {
        return Err(Error::data(format!("empty CSV {}", path.display())));
    }
    let (n, d) = (rows.len(), rows[0].len());
    let flat: Vec<f32> = rows.into_iter().flatten().collect();
    Ok(Dataset::dense(
        Matrix::from_vec(flat, n, d),
        path.display().to_string(),
    ))
}

/// Save a dense dataset as CSV (row per point). Used by `generate-data`.
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    use std::io::Write;
    let m = match &ds.points {
        crate::data::Points::Dense(m) => m,
        other => {
            return Err(Error::unsupported(format!(
                "save_csv supports dense datasets only (got {})",
                other.kind()
            )))
        }
    };
    let write = || -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for i in 0..m.rows() {
            let row: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    };
    write().map_err(|e| io_err(path, e))
}

/// Load a Matrix Market coordinate (triplet) file as a sparse dataset,
/// materializing every triplet in memory.
///
/// Supports the 10x Genomics flavor: `%%MatrixMarket matrix coordinate
/// {real|integer|pattern} general`, `%`-comment lines, a `rows cols nnz`
/// size line, then 1-based `row col [value]` entries (`pattern` files get
/// value 1). Duplicate coordinates are summed in file order and explicit
/// zeros dropped ([`CsrMatrix::from_triplets`] semantics). `transpose`
/// swaps the axes on ingest — 10x matrices are genes x cells, and points
/// must be rows. `limit` caps the output rows (**post-transpose**, so it
/// counts cells, not genes, on a transposed 10x file; 0 = all) — the
/// chunked reader in [`crate::data::stream`] applies it identically.
///
/// The grammar (and every accept/reject decision) is shared with the
/// out-of-core reader via [`stream::MtxScanner`]; the two paths are
/// bitwise-interchangeable, and [`load_mtx_auto`] picks between them by
/// file size.
pub fn load_mtx(path: &Path, transpose: bool, limit: usize) -> Result<Dataset> {
    let file = std::fs::File::open(path).map_err(|e| io_err(path, e))?;
    let mut scanner = stream::MtxScanner::open(std::io::BufReader::new(file), path)
        .map_err(stream_err)?;
    let (full_rows, cols) = if transpose {
        (scanner.cols(), scanner.rows())
    } else {
        (scanner.rows(), scanner.cols())
    };
    let rows = stream::effective_rows(full_rows, limit);
    // Cap the reserve so a lying size line cannot force a huge allocation
    // before the (validating) scan finds the mismatch.
    let mut triplets: Vec<(usize, usize, f32)> =
        Vec::with_capacity(scanner.nnz().min(1 << 24));
    while let Some((i, j, v)) = scanner.next_entry().map_err(stream_err)? {
        let (r, c) = if transpose { (j, i) } else { (i, j) };
        if r < rows {
            triplets.push((r, c, v));
        }
    }
    let csr = CsrMatrix::from_triplet_vec(rows, cols, triplets);
    Ok(Dataset::sparse(csr, stream::mtx_name(path, rows, cols)))
}

/// `.mtx` files at or above this many bytes stream through the chunked
/// out-of-core reader by default instead of materializing every triplet
/// (see [`load_mtx_auto`]).
pub const MTX_STREAM_THRESHOLD_BYTES: u64 = 256 << 20;

/// Load a `.mtx` file, picking the in-memory reader for small files and
/// the chunked streaming reader (default window budget) once the file
/// size reaches [`MTX_STREAM_THRESHOLD_BYTES`]. The two paths return
/// bitwise-identical datasets, so the switch is purely a memory-profile
/// decision; `--stream` on the CLI forces the chunked path regardless.
pub fn load_mtx_auto(path: &Path, transpose: bool, limit: usize) -> Result<Dataset> {
    let bytes = std::fs::metadata(path).map_err(|e| io_err(path, e))?.len();
    if bytes >= MTX_STREAM_THRESHOLD_BYTES {
        let opts = stream::StreamOptions { transpose, limit, ..Default::default() };
        Ok(stream::load_mtx_streamed(path, &opts).map_err(stream_err)?.0)
    } else {
        load_mtx(path, transpose, limit)
    }
}

/// Save a dataset as a Matrix Market coordinate file (points = rows).
/// Dense datasets are compressed on the way out; trees are rejected.
pub fn save_mtx(ds: &Dataset, path: &Path) -> Result<()> {
    use std::io::Write;
    let owned;
    let m = match &ds.points {
        Points::Sparse(m) => m,
        Points::Dense(d) => {
            owned = CsrMatrix::from_dense(d);
            &owned
        }
        other => {
            return Err(Error::unsupported(format!(
                "save_mtx supports vector datasets only (got {})",
                other.kind()
            )))
        }
    };
    let write = || -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
        writeln!(f, "% written by banditpam (points = rows)")?;
        writeln!(f, "{} {} {}", m.rows(), m.cols(), m.nnz())?;
        for (i, j, v) in m.triplets() {
            writeln!(f, "{} {} {v}", i + 1, j + 1)?;
        }
        Ok(())
    };
    write().map_err(|e| io_err(path, e))
}

/// Load an MNIST IDX3 image file (magic 0x00000803) as flattened rows
/// scaled to [0, 1]. `limit` caps the number of images read (0 = all).
///
/// IDX pixel bytes map to `b / 255.0` — always finite — so unlike the
/// CSV/MTX text loaders this path needs no non-finite rejection.
pub fn load_idx_images(path: &Path, limit: usize) -> Result<Dataset> {
    let mut f = std::fs::File::open(path).map_err(|e| io_err(path, e))?;
    let mut header = [0u8; 16];
    f.read_exact(&mut header)
        .map_err(|e| io_err(path, format!("IDX header: {e}")))?;
    let magic = u32::from_be_bytes(header[0..4].try_into().unwrap());
    if magic != 0x0000_0803 {
        return Err(Error::data(format!(
            "not an IDX3 image file (magic {magic:#x})"
        )));
    }
    let n = u32::from_be_bytes(header[4..8].try_into().unwrap()) as usize;
    let h = u32::from_be_bytes(header[8..12].try_into().unwrap()) as usize;
    let w = u32::from_be_bytes(header[12..16].try_into().unwrap()) as usize;
    let take = if limit == 0 { n } else { limit.min(n) };
    let mut buf = vec![0u8; take * h * w];
    f.read_exact(&mut buf)
        .map_err(|e| io_err(path, format!("IDX pixel data: {e}")))?;
    let data: Vec<f32> = buf.into_iter().map(|b| b as f32 / 255.0).collect();
    Ok(Dataset::dense(
        Matrix::from_vec(data, take, h * w),
        format!("{}[{}]", path.display(), take),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Points;
    use std::io::Write;

    fn tmpfile(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("banditpam_test_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(contents).unwrap();
        p
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmpfile("a.csv", b"1.0,2.0\n3.5,4.5\n# comment\n\n5.0,6.0\n");
        let d = load_csv(&p).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.points.dim(), Some(2));
        if let Points::Dense(m) = &d.points {
            assert_eq!(m.get(1, 1), 4.5);
        }
        let out = tmpfile("b.csv", b"");
        save_csv(&d, &out).unwrap();
        let d2 = load_csv(&out).unwrap();
        if let (Points::Dense(a), Points::Dense(b)) = (&d.points, &d2.points) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(out);
    }

    #[test]
    fn ragged_csv_rejected() {
        let p = tmpfile("ragged.csv", b"1,2\n3\n");
        assert!(load_csv(&p).unwrap_err().to_string().contains("ragged"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn csv_rejects_non_finite_values() {
        for (name, contents) in [
            ("nan.csv", &b"1.0,NaN\n"[..]),
            ("inf.csv", b"inf,2.0\n"),
            ("ninf.csv", b"1.0,2.0\n3.0,-inf\n"),
        ] {
            let p = tmpfile(name, contents);
            let err = load_csv(&p).unwrap_err();
            assert_eq!(err.kind(), "data", "{name}");
            assert!(err.message().contains("non-finite"), "{name}: {err}");
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn empty_csv_rejected() {
        let p = tmpfile("empty.csv", b"\n# only comments\n");
        assert!(load_csv(&p).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn idx_loader_parses_synthetic_file() {
        // 2 images of 2x3 pixels
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        bytes.extend_from_slice(&2u32.to_be_bytes());
        bytes.extend_from_slice(&2u32.to_be_bytes());
        bytes.extend_from_slice(&3u32.to_be_bytes());
        bytes.extend((0u8..12).map(|i| i * 20));
        let p = tmpfile("images.idx", &bytes);
        let d = load_idx_images(&p, 0).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d.points.dim(), Some(6));
        if let Points::Dense(m) = &d.points {
            assert!((m.get(0, 1) - 20.0 / 255.0).abs() < 1e-6);
        }
        let limited = load_idx_images(&p, 1).unwrap();
        assert_eq!(limited.len(), 1);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn idx_loader_rejects_bad_magic() {
        let p = tmpfile("bad.idx", &[0u8; 16]);
        assert!(load_idx_images(&p, 0).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_loads_coordinate_real() {
        let p = tmpfile(
            "a.mtx",
            b"%%MatrixMarket matrix coordinate real general\n\
              % a comment\n\
              3 4 3\n\
              1 1 1.5\n\
              3 4 -2\n\
              2 2 0.25\n",
        );
        let d = load_mtx(&p, false, 0).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.points.dim(), Some(4));
        let Points::Sparse(m) = &d.points else { unreachable!() };
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[0u32][..], &[1.5f32][..]));
        assert_eq!(m.row(1), (&[1u32][..], &[0.25f32][..]));
        assert_eq!(m.row(2), (&[3u32][..], &[-2.0f32][..]));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_transpose_swaps_axes() {
        // 10x layout: genes x cells; transpose makes cells the points
        let p = tmpfile(
            "t.mtx",
            b"%%MatrixMarket matrix coordinate integer general\n2 3 2\n1 3 7\n2 1 5\n",
        );
        let d = load_mtx(&p, true, 0).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.points.dim(), Some(2));
        let Points::Sparse(m) = &d.points else { unreachable!() };
        assert_eq!(m.row(0), (&[1u32][..], &[5.0f32][..]));
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.row(2), (&[0u32][..], &[7.0f32][..]));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_pattern_entries_get_unit_values() {
        let p = tmpfile(
            "p.mtx",
            b"%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n",
        );
        let d = load_mtx(&p, false, 0).unwrap();
        let Points::Sparse(m) = &d.points else { unreachable!() };
        assert_eq!(m.row(0), (&[1u32][..], &[1.0f32][..]));
        assert_eq!(m.row(1), (&[0u32][..], &[1.0f32][..]));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_roundtrip_sparse_and_dense() {
        let mut rng = crate::util::rng::Rng::seed_from(17);
        let ds = crate::data::synthetic::scrna_sparse(&mut rng, 12, 40, 0.10);
        let p = tmpfile("rt.mtx", b"");
        save_mtx(&ds, &p).unwrap();
        let back = load_mtx(&p, false, 0).unwrap();
        let (Points::Sparse(a), Points::Sparse(b)) = (&ds.points, &back.points) else {
            unreachable!()
        };
        assert_eq!(a, b);
        // dense datasets are compressed on save
        let dn = ds.to_dense().unwrap();
        save_mtx(&dn, &p).unwrap();
        let back2 = load_mtx(&p, false, 0).unwrap();
        let Points::Sparse(c) = &back2.points else { unreachable!() };
        assert_eq!(a, c);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_rejects_bad_headers_and_counts() {
        for (name, contents) in [
            ("h1.mtx", &b"not a header\n1 1 0\n"[..]),
            ("h2.mtx", b"%%MatrixMarket matrix array real general\n1 1\n1\n"),
            ("h3.mtx", b"%%MatrixMarket matrix coordinate real symmetric\n1 1 0\n"),
            ("h4.mtx", b"%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1\n"),
            ("h5.mtx", b"%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n"),
        ] {
            let p = tmpfile(name, contents);
            assert!(load_mtx(&p, false, 0).is_err(), "{name} should be rejected");
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn mtx_rejects_non_finite_values() {
        for (name, contents) in [
            ("nan.mtx", &b"%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 nan\n"[..]),
            ("inf.mtx", b"%%MatrixMarket matrix coordinate real general\n2 2 1\n2 1 inf\n"),
            ("ninf.mtx", b"%%MatrixMarket matrix coordinate real general\n2 2 1\n1 2 -inf\n"),
        ] {
            let p = tmpfile(name, contents);
            let err = load_mtx(&p, false, 0).unwrap_err();
            assert_eq!(err.kind(), "data", "{name}");
            assert!(err.message().contains("non-finite"), "{name}: {err}");
            let _ = std::fs::remove_file(p);
        }
    }
}
