//! File loaders: CSV feature matrices, MNIST IDX images and Matrix Market
//! (`.mtx`) sparse triplets.
//!
//! The bench suite runs on the synthetic generators, but real data drops in
//! via these loaders: `banditpam cluster --data points.csv`, an IDX file
//! (`train-images-idx3-ubyte`) if the user supplies the original MNIST, or
//! a 10x Genomics-style `matrix.mtx` (`--format mtx`, typically with
//! `--transpose` since 10x ships genes x cells) for the scRNA workload.

use crate::data::sparse::CsrMatrix;
use crate::data::{Dataset, Points};
use crate::util::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// Load a headerless CSV of floats (rows = points).
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row: Result<Vec<f32>, _> =
            line.split(',').map(|f| f.trim().parse::<f32>()).collect();
        let row = row.with_context(|| format!("line {} of {}", lineno + 1, path.display()))?;
        if let Some(first) = rows.first() {
            if row.len() != first.len() {
                bail!(
                    "ragged CSV: line {} has {} fields, expected {}",
                    lineno + 1,
                    row.len(),
                    first.len()
                );
            }
        }
        rows.push(row);
    }
    if rows.is_empty() {
        bail!("empty CSV {}", path.display());
    }
    let (n, d) = (rows.len(), rows[0].len());
    let flat: Vec<f32> = rows.into_iter().flatten().collect();
    Ok(Dataset::dense(
        Matrix::from_vec(flat, n, d),
        path.display().to_string(),
    ))
}

/// Save a dense dataset as CSV (row per point). Used by `generate-data`.
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    use std::io::Write;
    let m = match &ds.points {
        crate::data::Points::Dense(m) => m,
        _ => bail!("save_csv supports dense datasets only"),
    };
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Load a Matrix Market coordinate (triplet) file as a sparse dataset.
///
/// Supports the 10x Genomics flavor: `%%MatrixMarket matrix coordinate
/// {real|integer|pattern} general`, `%`-comment lines, a `rows cols nnz`
/// size line, then 1-based `row col [value]` entries (`pattern` files get
/// value 1). Duplicate coordinates are summed and explicit zeros dropped
/// ([`CsrMatrix::from_triplets`] semantics). `transpose` swaps the axes on
/// ingest — 10x matrices are genes x cells, and points must be rows.
pub fn load_mtx(path: &Path, transpose: bool) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines().enumerate();
    let (_, header) = lines.next().context("empty .mtx file")?;
    let header = header.to_ascii_lowercase();
    if !header.starts_with("%%matrixmarket") {
        bail!("{}: missing %%MatrixMarket header", path.display());
    }
    if !header.contains("coordinate") {
        bail!("{}: only coordinate (triplet) .mtx is supported", path.display());
    }
    if header.contains("symmetric") || header.contains("skew") || header.contains("hermitian") {
        bail!("{}: only `general` symmetry is supported", path.display());
    }
    if header.contains("complex") {
        bail!("{}: complex values are not supported", path.display());
    }
    let pattern = header.contains("pattern");

    let mut size: Option<(usize, usize, usize)> = None;
    let mut triplets: Vec<(usize, usize, f32)> = Vec::new();
    for (lineno, line) in lines {
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let mut fields = line.split_whitespace();
        let at = |f: Option<&str>| {
            f.with_context(|| format!("line {} of {}: missing field", lineno + 1, path.display()))
        };
        if size.is_none() {
            let r: usize = at(fields.next())?.parse().context("size line rows")?;
            let c: usize = at(fields.next())?.parse().context("size line cols")?;
            let nnz: usize = at(fields.next())?.parse().context("size line nnz")?;
            size = Some((r, c, nnz));
            triplets.reserve(nnz);
            continue;
        }
        let Some((rows, cols, _)) = size else { unreachable!() };
        let i: usize = at(fields.next())?.parse().context("entry row")?;
        let j: usize = at(fields.next())?.parse().context("entry col")?;
        let v: f32 = if pattern {
            1.0
        } else {
            at(fields.next())?.parse().context("entry value")?
        };
        if i == 0 || j == 0 || i > rows || j > cols {
            bail!(
                "line {} of {}: entry ({i}, {j}) outside 1..={rows} x 1..={cols}",
                lineno + 1,
                path.display()
            );
        }
        // to 0-based, transposing on ingest if requested
        if transpose {
            triplets.push((j - 1, i - 1, v));
        } else {
            triplets.push((i - 1, j - 1, v));
        }
    }
    let (rows, cols, nnz) = size.with_context(|| format!("{}: missing size line", path.display()))?;
    if triplets.len() != nnz {
        bail!(
            "{}: size line promises {nnz} entries, found {}",
            path.display(),
            triplets.len()
        );
    }
    let (rows, cols) = if transpose { (cols, rows) } else { (rows, cols) };
    let csr = CsrMatrix::from_triplets(rows, cols, &triplets);
    Ok(Dataset::sparse(csr, format!("{}[{}x{}]", path.display(), rows, cols)))
}

/// Save a dataset as a Matrix Market coordinate file (points = rows).
/// Dense datasets are compressed on the way out; trees are rejected.
pub fn save_mtx(ds: &Dataset, path: &Path) -> Result<()> {
    use std::io::Write;
    let owned;
    let m = match &ds.points {
        Points::Sparse(m) => m,
        Points::Dense(d) => {
            owned = CsrMatrix::from_dense(d);
            &owned
        }
        _ => bail!("save_mtx supports vector datasets only (got {})", ds.points.kind()),
    };
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by banditpam (points = rows)")?;
    writeln!(f, "{} {} {}", m.rows(), m.cols(), m.nnz())?;
    for (i, j, v) in m.triplets() {
        writeln!(f, "{} {} {v}", i + 1, j + 1)?;
    }
    Ok(())
}

/// Load an MNIST IDX3 image file (magic 0x00000803) as flattened rows
/// scaled to [0, 1]. `limit` caps the number of images read (0 = all).
pub fn load_idx_images(path: &Path, limit: usize) -> Result<Dataset> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut header = [0u8; 16];
    f.read_exact(&mut header).context("IDX header")?;
    let magic = u32::from_be_bytes(header[0..4].try_into().unwrap());
    if magic != 0x0000_0803 {
        bail!("not an IDX3 image file (magic {magic:#x})");
    }
    let n = u32::from_be_bytes(header[4..8].try_into().unwrap()) as usize;
    let h = u32::from_be_bytes(header[8..12].try_into().unwrap()) as usize;
    let w = u32::from_be_bytes(header[12..16].try_into().unwrap()) as usize;
    let take = if limit == 0 { n } else { limit.min(n) };
    let mut buf = vec![0u8; take * h * w];
    f.read_exact(&mut buf).context("IDX pixel data")?;
    let data: Vec<f32> = buf.into_iter().map(|b| b as f32 / 255.0).collect();
    Ok(Dataset::dense(
        Matrix::from_vec(data, take, h * w),
        format!("{}[{}]", path.display(), take),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Points;
    use std::io::Write;

    fn tmpfile(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("banditpam_test_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(contents).unwrap();
        p
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmpfile("a.csv", b"1.0,2.0\n3.5,4.5\n# comment\n\n5.0,6.0\n");
        let d = load_csv(&p).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.points.dim(), Some(2));
        if let Points::Dense(m) = &d.points {
            assert_eq!(m.get(1, 1), 4.5);
        }
        let out = tmpfile("b.csv", b"");
        save_csv(&d, &out).unwrap();
        let d2 = load_csv(&out).unwrap();
        if let (Points::Dense(a), Points::Dense(b)) = (&d.points, &d2.points) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(out);
    }

    #[test]
    fn ragged_csv_rejected() {
        let p = tmpfile("ragged.csv", b"1,2\n3\n");
        assert!(load_csv(&p).unwrap_err().to_string().contains("ragged"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn empty_csv_rejected() {
        let p = tmpfile("empty.csv", b"\n# only comments\n");
        assert!(load_csv(&p).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn idx_loader_parses_synthetic_file() {
        // 2 images of 2x3 pixels
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        bytes.extend_from_slice(&2u32.to_be_bytes());
        bytes.extend_from_slice(&2u32.to_be_bytes());
        bytes.extend_from_slice(&3u32.to_be_bytes());
        bytes.extend((0u8..12).map(|i| i * 20));
        let p = tmpfile("images.idx", &bytes);
        let d = load_idx_images(&p, 0).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d.points.dim(), Some(6));
        if let Points::Dense(m) = &d.points {
            assert!((m.get(0, 1) - 20.0 / 255.0).abs() < 1e-6);
        }
        let limited = load_idx_images(&p, 1).unwrap();
        assert_eq!(limited.len(), 1);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn idx_loader_rejects_bad_magic() {
        let p = tmpfile("bad.idx", &[0u8; 16]);
        assert!(load_idx_images(&p, 0).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_loads_coordinate_real() {
        let p = tmpfile(
            "a.mtx",
            b"%%MatrixMarket matrix coordinate real general\n\
              % a comment\n\
              3 4 3\n\
              1 1 1.5\n\
              3 4 -2\n\
              2 2 0.25\n",
        );
        let d = load_mtx(&p, false).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.points.dim(), Some(4));
        let Points::Sparse(m) = &d.points else { unreachable!() };
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[0u32][..], &[1.5f32][..]));
        assert_eq!(m.row(1), (&[1u32][..], &[0.25f32][..]));
        assert_eq!(m.row(2), (&[3u32][..], &[-2.0f32][..]));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_transpose_swaps_axes() {
        // 10x layout: genes x cells; transpose makes cells the points
        let p = tmpfile(
            "t.mtx",
            b"%%MatrixMarket matrix coordinate integer general\n2 3 2\n1 3 7\n2 1 5\n",
        );
        let d = load_mtx(&p, true).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.points.dim(), Some(2));
        let Points::Sparse(m) = &d.points else { unreachable!() };
        assert_eq!(m.row(0), (&[1u32][..], &[5.0f32][..]));
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.row(2), (&[0u32][..], &[7.0f32][..]));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_pattern_entries_get_unit_values() {
        let p = tmpfile(
            "p.mtx",
            b"%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n",
        );
        let d = load_mtx(&p, false).unwrap();
        let Points::Sparse(m) = &d.points else { unreachable!() };
        assert_eq!(m.row(0), (&[1u32][..], &[1.0f32][..]));
        assert_eq!(m.row(1), (&[0u32][..], &[1.0f32][..]));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_roundtrip_sparse_and_dense() {
        let mut rng = crate::util::rng::Rng::seed_from(17);
        let ds = crate::data::synthetic::scrna_sparse(&mut rng, 12, 40, 0.10);
        let p = tmpfile("rt.mtx", b"");
        save_mtx(&ds, &p).unwrap();
        let back = load_mtx(&p, false).unwrap();
        let (Points::Sparse(a), Points::Sparse(b)) = (&ds.points, &back.points) else {
            unreachable!()
        };
        assert_eq!(a, b);
        // dense datasets are compressed on save
        let dn = ds.to_dense().unwrap();
        save_mtx(&dn, &p).unwrap();
        let back2 = load_mtx(&p, false).unwrap();
        let Points::Sparse(c) = &back2.points else { unreachable!() };
        assert_eq!(a, c);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn mtx_rejects_bad_headers_and_counts() {
        for (name, contents) in [
            ("h1.mtx", &b"not a header\n1 1 0\n"[..]),
            ("h2.mtx", b"%%MatrixMarket matrix array real general\n1 1\n1\n"),
            ("h3.mtx", b"%%MatrixMarket matrix coordinate real symmetric\n1 1 0\n"),
            ("h4.mtx", b"%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1\n"),
            ("h5.mtx", b"%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n"),
        ] {
            let p = tmpfile(name, contents);
            assert!(load_mtx(&p, false).is_err(), "{name} should be rejected");
            let _ = std::fs::remove_file(p);
        }
    }
}
