//! File loaders: CSV feature matrices and MNIST IDX images.
//!
//! The bench suite runs on the synthetic generators, but real data drops in
//! via these loaders: `banditpam cluster --data points.csv` or an IDX file
//! (`train-images-idx3-ubyte`) if the user supplies the original MNIST.

use crate::data::Dataset;
use crate::util::matrix::Matrix;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// Load a headerless CSV of floats (rows = points).
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row: Result<Vec<f32>, _> =
            line.split(',').map(|f| f.trim().parse::<f32>()).collect();
        let row = row.with_context(|| format!("line {} of {}", lineno + 1, path.display()))?;
        if let Some(first) = rows.first() {
            if row.len() != first.len() {
                bail!(
                    "ragged CSV: line {} has {} fields, expected {}",
                    lineno + 1,
                    row.len(),
                    first.len()
                );
            }
        }
        rows.push(row);
    }
    if rows.is_empty() {
        bail!("empty CSV {}", path.display());
    }
    let (n, d) = (rows.len(), rows[0].len());
    let flat: Vec<f32> = rows.into_iter().flatten().collect();
    Ok(Dataset::dense(
        Matrix::from_vec(flat, n, d),
        path.display().to_string(),
    ))
}

/// Save a dense dataset as CSV (row per point). Used by `generate-data`.
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    use std::io::Write;
    let m = match &ds.points {
        crate::data::Points::Dense(m) => m,
        _ => bail!("save_csv supports dense datasets only"),
    };
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Load an MNIST IDX3 image file (magic 0x00000803) as flattened rows
/// scaled to [0, 1]. `limit` caps the number of images read (0 = all).
pub fn load_idx_images(path: &Path, limit: usize) -> Result<Dataset> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut header = [0u8; 16];
    f.read_exact(&mut header).context("IDX header")?;
    let magic = u32::from_be_bytes(header[0..4].try_into().unwrap());
    if magic != 0x0000_0803 {
        bail!("not an IDX3 image file (magic {magic:#x})");
    }
    let n = u32::from_be_bytes(header[4..8].try_into().unwrap()) as usize;
    let h = u32::from_be_bytes(header[8..12].try_into().unwrap()) as usize;
    let w = u32::from_be_bytes(header[12..16].try_into().unwrap()) as usize;
    let take = if limit == 0 { n } else { limit.min(n) };
    let mut buf = vec![0u8; take * h * w];
    f.read_exact(&mut buf).context("IDX pixel data")?;
    let data: Vec<f32> = buf.into_iter().map(|b| b as f32 / 255.0).collect();
    Ok(Dataset::dense(
        Matrix::from_vec(data, take, h * w),
        format!("{}[{}]", path.display(), take),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Points;
    use std::io::Write;

    fn tmpfile(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("banditpam_test_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(contents).unwrap();
        p
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmpfile("a.csv", b"1.0,2.0\n3.5,4.5\n# comment\n\n5.0,6.0\n");
        let d = load_csv(&p).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.points.dim(), Some(2));
        if let Points::Dense(m) = &d.points {
            assert_eq!(m.get(1, 1), 4.5);
        }
        let out = tmpfile("b.csv", b"");
        save_csv(&d, &out).unwrap();
        let d2 = load_csv(&out).unwrap();
        if let (Points::Dense(a), Points::Dense(b)) = (&d.points, &d2.points) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(out);
    }

    #[test]
    fn ragged_csv_rejected() {
        let p = tmpfile("ragged.csv", b"1,2\n3\n");
        assert!(load_csv(&p).unwrap_err().to_string().contains("ragged"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn empty_csv_rejected() {
        let p = tmpfile("empty.csv", b"\n# only comments\n");
        assert!(load_csv(&p).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn idx_loader_parses_synthetic_file() {
        // 2 images of 2x3 pixels
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        bytes.extend_from_slice(&2u32.to_be_bytes());
        bytes.extend_from_slice(&2u32.to_be_bytes());
        bytes.extend_from_slice(&3u32.to_be_bytes());
        bytes.extend((0u8..12).map(|i| i * 20));
        let p = tmpfile("images.idx", &bytes);
        let d = load_idx_images(&p, 0).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d.points.dim(), Some(6));
        if let Points::Dense(m) = &d.points {
            assert!((m.get(0, 1) - 20.0 / 255.0).abs() < 1e-6);
        }
        let limited = load_idx_images(&p, 1).unwrap();
        assert_eq!(limited.len(), 1);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn idx_loader_rejects_bad_magic() {
        let p = tmpfile("bad.idx", &[0u8; 16]);
        assert!(load_idx_images(&p, 0).is_err());
        let _ = std::fs::remove_file(p);
    }
}
