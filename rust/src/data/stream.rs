//! Out-of-core (chunked) streaming for Matrix Market sparse data.
//!
//! The paper's headline sparse workload — 10x Genomics-style scRNA-seq
//! matrices — is exactly the data that stops fitting in memory first, yet
//! the in-memory loader ([`crate::data::loader::load_mtx`]) materializes
//! every triplet before `subsample` ever runs. BanditPAM itself only needs
//! a bounded working set per iteration, and the experimental protocol only
//! ever fits a *subsample* per repetition, so the data plane can match the
//! algorithm's memory profile: [`CsrChunkReader`] reads the `.mtx` header,
//! then yields validated [`CsrMatrix`] **row-windows** under a configurable
//! raw-entry budget ([`StreamOptions::chunk_nnz`]); the streamed
//! subsampler ([`CsrChunkReader::subsample_rows`]) pre-draws the identical
//! index set as [`crate::data::Dataset::subsample`] (same rng stream) and
//! collects it in one forward pass, holding only
//! `selected nnz + current window nnz` values.
//!
//! Window invariants (see `rust/PERF.md` §8 for the design rationale):
//!
//! * windows partition the output row range `[0, rows)` in order; a window
//!   never splits a row, always contains at least one row, and its raw
//!   entry count exceeds `chunk_nnz` only when a single row does;
//! * each window's triplet subsequence preserves **file order**, so
//!   per-window [`CsrMatrix::from_triplets`] (stable sort + input-order
//!   duplicate summation) concatenates to the exact bits the in-memory
//!   loader produces from one global build;
//! * `transpose` (10x files are genes x cells) and any row `limit` are
//!   applied on ingest, *before* windowing, so the streamed and in-memory
//!   readers agree on what a "row" is.
//!
//! Files whose (post-transpose) entries already arrive grouped by
//! non-decreasing output row — our own writer's row-major output, or a
//! column-major 10x file read with `--transpose` — stream straight off a
//! second text pass. Anything else goes through an on-disk two-pass
//! row-bucketing spill: pass 1 counts entries per output row (an O(rows)
//! index array, no values), pass 2 scatters fixed-width binary records
//! into per-window byte ranges of a temp file, preserving file order
//! within each window; windows are then read back sequentially.

use crate::data::sparse::CsrMatrix;
use crate::data::Dataset;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{HashMap, HashSet};
use std::fs::File;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Default per-window raw-entry budget: ~12 MiB of spill records, a few
/// hundred thousand cells' worth of a 10x matrix per window.
pub const DEFAULT_CHUNK_NNZ: usize = 1 << 20;

/// Largest accepted `.mtx` dimension per axis (rows or columns). Loading
/// a matrix takes O(rows) index memory no matter the path (`indptr` alone
/// is rows+1 words), so a lying size line must be rejected before it can
/// force an allocation-failure abort; 2^27 is ~2000x the paper's largest
/// corpus while capping `indptr` near 1 GiB.
pub const MAX_DIM: usize = 1 << 27;

/// How the chunked reader ingests a `.mtx` file.
#[derive(Debug, Clone)]
pub struct StreamOptions {
    /// Raw-entry budget per row-window (clamped to >= 1). A window may
    /// exceed it only when one row alone does — rows are never split.
    pub chunk_nnz: usize,
    /// Swap the axes on ingest (10x files are genes x cells; points must
    /// be rows).
    pub transpose: bool,
    /// Cap on output rows (**post-transpose**, matching the in-memory
    /// loader); 0 = all rows.
    pub limit: usize,
}

impl Default for StreamOptions {
    fn default() -> StreamOptions {
        StreamOptions { chunk_nnz: DEFAULT_CHUNK_NNZ, transpose: false, limit: 0 }
    }
}

/// Counters describing a completed streaming pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamStats {
    /// Row-windows the reader planned (and yields).
    pub windows: usize,
    /// The raw-entry budget the plan used.
    pub chunk_nnz: usize,
    /// Entries the size line declared (pre-limit).
    pub total_nnz: usize,
    /// Raw entries within the row limit (what the windows cover).
    pub kept_nnz: usize,
    /// Largest raw entry count of any single window — the per-window
    /// working set the bounded-memory claim is about.
    pub peak_window_nnz: usize,
    /// For [`CsrChunkReader::subsample_rows`]: the largest
    /// `selected-so-far + current-window` value count held at once. For
    /// [`CsrChunkReader::read_all`] this is the final assembled nnz (the
    /// full matrix is the deliverable there).
    pub peak_resident_nnz: usize,
    /// Whether the on-disk row-bucketing spill was needed (entries not
    /// already grouped by output row).
    pub spilled: bool,
}

/// One yielded row-window: rows `[start_row, start_row + matrix.rows())`
/// of the full (post-transpose, post-limit) matrix, full column space.
#[derive(Debug, Clone)]
pub struct CsrWindow {
    pub start_row: usize,
    pub matrix: CsrMatrix,
}

/// `rows` capped by a `limit` option (0 = uncapped).
pub(crate) fn effective_rows(rows: usize, limit: usize) -> usize {
    if limit == 0 {
        rows
    } else {
        rows.min(limit)
    }
}

/// The canonical dataset name both loaders use: `"{path}[{rows}x{cols}]"`.
pub(crate) fn mtx_name(path: &Path, rows: usize, cols: usize) -> String {
    format!("{}[{}x{}]", path.display(), rows, cols)
}

fn parse_field<T: std::str::FromStr>(
    field: Option<&str>,
    what: &str,
    lineno: usize,
    display: &str,
) -> Result<T> {
    let s = field
        .with_context(|| format!("line {lineno} of {display}: missing {what}"))?;
    s.parse::<T>()
        .map_err(|_| anyhow::anyhow!("line {lineno} of {display}: bad {what} {s:?}"))
}

/// Incremental Matrix Market coordinate parser: the single grammar both
/// the in-memory and chunked readers consume, so they accept and reject
/// exactly the same files. Yields 0-based `(row, col, value)` entries in
/// **file coordinates** (callers apply `transpose`/`limit`), validating
/// the header, the size line (shape within the [`MAX_DIM`] per-axis
/// ceiling; an unparseable nnz is a clean error), every entry's range, and the
/// promised-vs-found entry count (truncated or over-full bodies are
/// errors, not panics).
pub(crate) struct MtxScanner<B: BufRead> {
    src: B,
    line: String,
    lineno: usize,
    display: String,
    pattern: bool,
    rows: usize,
    cols: usize,
    nnz: usize,
    read: usize,
}

impl<B: BufRead> MtxScanner<B> {
    pub(crate) fn open(mut src: B, path: &Path) -> Result<MtxScanner<B>> {
        let display = path.display().to_string();
        let mut line = String::new();
        let mut lineno = 1usize;
        if src.read_line(&mut line)? == 0 {
            bail!("empty .mtx file {display}");
        }
        let header = line.trim().to_ascii_lowercase();
        if !header.starts_with("%%matrixmarket") {
            bail!("{display}: missing %%MatrixMarket header");
        }
        if !header.contains("coordinate") {
            bail!("{display}: only coordinate (triplet) .mtx is supported");
        }
        if header.contains("symmetric") || header.contains("skew") || header.contains("hermitian")
        {
            bail!("{display}: only `general` symmetry is supported");
        }
        if header.contains("complex") {
            bail!("{display}: complex values are not supported");
        }
        let pattern = header.contains("pattern");

        // Size line: first non-comment, non-blank line after the header.
        let (rows, cols, nnz) = loop {
            line.clear();
            lineno += 1;
            if src.read_line(&mut line)? == 0 {
                bail!("{display}: missing size line");
            }
            let trimmed = line.trim();
            if trimmed.is_empty() || trimmed.starts_with('%') {
                continue;
            }
            let mut fields = trimmed.split_whitespace();
            let rows: usize = parse_field(fields.next(), "size line rows", lineno, &display)?;
            let cols: usize = parse_field(fields.next(), "size line cols", lineno, &display)?;
            let nnz: usize = parse_field(fields.next(), "size line nnz", lineno, &display)?;
            break (rows, cols, nnz);
        };
        // Guard the declared shape before any O(rows) allocation: both
        // readers eventually build rows+1 `indptr` entries (and the
        // chunked reader an O(rows) counting pass), so a lying size line
        // must not force a multi-GB allocation from a 50-byte file —
        // that aborts, not Errs. `MAX_DIM` (2^27 per axis, ~1 GiB of
        // indptr at the ceiling) is far above any workload this crate
        // targets and keeps either axis within the CSR's u32 column
        // space under --transpose. A declared nnz larger than rows*cols
        // is *not* rejected — duplicate coordinates are legal and summed
        // — and a lying nnz cannot force allocation either: neither
        // reader sizes a buffer by the declared count (the in-memory
        // loader caps its reserve; the chunked reader counts actual
        // entries), and an unparseable nnz already failed above.
        if rows > MAX_DIM || cols > MAX_DIM {
            bail!(
                "{display}: shape {rows} x {cols} exceeds the supported {MAX_DIM} per-axis ceiling"
            );
        }
        Ok(MtxScanner { src, line, lineno, display, pattern, rows, cols, nnz, read: 0 })
    }

    pub(crate) fn rows(&self) -> usize {
        self.rows
    }

    pub(crate) fn cols(&self) -> usize {
        self.cols
    }

    pub(crate) fn nnz(&self) -> usize {
        self.nnz
    }

    /// Next 0-based `(row, col, value)` entry in file coordinates, or
    /// `None` at a well-formed end of body.
    pub(crate) fn next_entry(&mut self) -> Result<Option<(usize, usize, f32)>> {
        loop {
            self.line.clear();
            self.lineno += 1;
            if self.src.read_line(&mut self.line)? == 0 {
                if self.read != self.nnz {
                    bail!(
                        "{}: size line promises {} entries, found {}",
                        self.display,
                        self.nnz,
                        self.read
                    );
                }
                return Ok(None);
            }
            let trimmed = self.line.trim();
            if trimmed.is_empty() || trimmed.starts_with('%') {
                continue;
            }
            if self.read == self.nnz {
                bail!(
                    "{}: size line promises {} entries, found more at line {}",
                    self.display,
                    self.nnz,
                    self.lineno
                );
            }
            let lineno = self.lineno;
            let mut fields = trimmed.split_whitespace();
            let i: usize = parse_field(fields.next(), "entry row", lineno, &self.display)?;
            let j: usize = parse_field(fields.next(), "entry col", lineno, &self.display)?;
            let v: f32 = if self.pattern {
                1.0
            } else {
                parse_field(fields.next(), "entry value", lineno, &self.display)?
            };
            // Rust's f32 parser accepts "nan"/"inf" spellings; reject them
            // here so both the in-memory and streamed loaders agree.
            if !v.is_finite() {
                bail!("line {lineno} of {}: non-finite value {v}", self.display);
            }
            if i == 0 || j == 0 || i > self.rows || j > self.cols {
                bail!(
                    "line {lineno} of {}: entry ({i}, {j}) outside 1..={} x 1..={}",
                    self.display,
                    self.rows,
                    self.cols
                );
            }
            self.read += 1;
            return Ok(Some((i - 1, j - 1, v)));
        }
    }
}

/// One planned row-window: output rows `[start, end)` holding `raw`
/// pre-dedup entries.
#[derive(Debug, Clone, Copy)]
struct Window {
    start: usize,
    end: usize,
    raw: usize,
}

/// Spill record layout: `row: u32 | col: u32 | value: f32`, little-endian.
const SPILL_REC: usize = 12;

static SPILL_COUNTER: AtomicU64 = AtomicU64::new(0);

enum Body {
    /// Entries arrive grouped by non-decreasing output row: window `w+1`'s
    /// entries follow window `w`'s in the text itself, so a second
    /// sequential parse suffices.
    Ordered(MtxScanner<BufReader<File>>),
    /// Row-bucketed binary spill (sequential per-window byte ranges).
    Spill(BufReader<File>),
}

/// Chunked `.mtx` reader: parses the header eagerly, plans row-windows
/// under the `chunk_nnz` budget from an O(rows) counting pass, then yields
/// validated [`CsrMatrix`] windows one at a time. Peak *value* residency
/// is one window (plus its raw triplet buffer) — never the full matrix.
pub struct CsrChunkReader {
    path: PathBuf,
    opts: StreamOptions,
    rows: usize,
    cols: usize,
    total_nnz: usize,
    kept_nnz: usize,
    windows: Vec<Window>,
    body: Body,
    cursor: usize,
    peak_window_nnz: usize,
    peak_resident_nnz: usize,
    spilled: bool,
    spill_path: Option<PathBuf>,
    /// Process-metric handles, resolved once at open.
    obs_windows: std::sync::Arc<crate::obs::Counter>,
    obs_window_nnz: std::sync::Arc<crate::obs::Histogram>,
}

impl CsrChunkReader {
    /// Open and validate `path`, plan the row-windows, and (only when the
    /// file's entries are not already grouped by output row) build the
    /// on-disk spill. Every input-validation failure is a clean `Err`.
    pub fn open(path: &Path, opts: StreamOptions) -> Result<CsrChunkReader> {
        let opts = StreamOptions { chunk_nnz: opts.chunk_nnz.max(1), ..opts };
        let open_scanner = || -> Result<MtxScanner<BufReader<File>>> {
            let file = File::open(path)
                .with_context(|| format!("opening {}", path.display()))?;
            MtxScanner::open(BufReader::new(file), path)
        };

        // Pass 1: count raw entries per output row and detect grouping.
        let mut scanner = open_scanner()?;
        let (full_rows, cols) = if opts.transpose {
            (scanner.cols(), scanner.rows())
        } else {
            (scanner.rows(), scanner.cols())
        };
        let rows = effective_rows(full_rows, opts.limit);
        let total_nnz = scanner.nnz();
        let mut counts = vec![0usize; rows];
        let mut kept_nnz = 0usize;
        let mut ordered = true;
        let mut last_row: Option<usize> = None;
        while let Some((i, j, _)) = scanner.next_entry()? {
            let r = if opts.transpose { j } else { i };
            if r >= rows {
                continue;
            }
            counts[r] += 1;
            kept_nnz += 1;
            if last_row.is_some_and(|last| r < last) {
                ordered = false;
            }
            last_row = Some(r);
        }

        // Window plan: accumulate whole rows while the raw budget holds;
        // a window always takes at least one row.
        let mut windows = Vec::new();
        let mut start = 0usize;
        while start < rows {
            let mut end = start;
            let mut raw = 0usize;
            while end < rows && (end == start || raw + counts[end] <= opts.chunk_nnz) {
                raw += counts[end];
                end += 1;
            }
            windows.push(Window { start, end, raw });
            start = end;
        }
        let peak_window_nnz = windows.iter().map(|w| w.raw).max().unwrap_or(0);

        let (body, spill_path) = if ordered {
            (Body::Ordered(open_scanner()?), None)
        } else {
            let (reader, spill_path) = build_spill(path, &opts, rows, &windows)?;
            (Body::Spill(reader), Some(spill_path))
        };
        Ok(CsrChunkReader {
            path: path.to_path_buf(),
            spilled: !ordered,
            opts,
            rows,
            cols,
            total_nnz,
            kept_nnz,
            windows,
            body,
            cursor: 0,
            peak_window_nnz,
            peak_resident_nnz: 0,
            spill_path,
            obs_windows: crate::obs::global().counter("stream_windows_total"),
            obs_window_nnz: crate::obs::global().histogram("stream_window_nnz"),
        })
    }

    /// Output rows (post-transpose, post-limit).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Output columns (post-transpose).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Entries the size line declared (pre-limit, pre-dedup).
    pub fn declared_nnz(&self) -> usize {
        self.total_nnz
    }

    /// The dataset name the in-memory loader would assign to this source.
    pub fn source_name(&self) -> String {
        mtx_name(&self.path, self.rows, self.cols)
    }

    /// Counters for the pass so far (windows/peaks are fixed by the plan).
    pub fn stats(&self) -> StreamStats {
        StreamStats {
            windows: self.windows.len(),
            chunk_nnz: self.opts.chunk_nnz,
            total_nnz: self.total_nnz,
            kept_nnz: self.kept_nnz,
            peak_window_nnz: self.peak_window_nnz,
            peak_resident_nnz: self.peak_resident_nnz,
            spilled: self.spilled,
        }
    }

    /// Yield the next row-window, or `None` once the row range is covered.
    pub fn next_window(&mut self) -> Result<Option<CsrWindow>> {
        if self.cursor == self.windows.len() {
            return Ok(None);
        }
        let Window { start, end, raw } = self.windows[self.cursor];
        let mut triplets: Vec<(usize, usize, f32)> = Vec::with_capacity(raw);
        match &mut self.body {
            Body::Ordered(scanner) => {
                while triplets.len() < raw {
                    let Some((i, j, v)) = scanner.next_entry()? else {
                        bail!(
                            "{}: body ended mid-window (file changed between passes?)",
                            self.path.display()
                        );
                    };
                    let r = if self.opts.transpose { j } else { i };
                    if r >= self.rows {
                        continue;
                    }
                    if r < start || r >= end {
                        bail!(
                            "{}: entries reordered between passes (row {r} outside window {start}..{end})",
                            self.path.display()
                        );
                    }
                    let c = if self.opts.transpose { i } else { j };
                    triplets.push((r - start, c, v));
                }
            }
            Body::Spill(reader) => {
                let mut rec = [0u8; SPILL_REC];
                for _ in 0..raw {
                    reader
                        .read_exact(&mut rec)
                        .with_context(|| "reading streaming spill")?;
                    let r = u32::from_le_bytes(rec[0..4].try_into().unwrap()) as usize;
                    let c = u32::from_le_bytes(rec[4..8].try_into().unwrap()) as usize;
                    let v = f32::from_le_bytes(rec[8..12].try_into().unwrap());
                    ensure!(
                        r >= start && r < end && c < self.cols,
                        "corrupt streaming spill record ({r}, {c}) for window {start}..{end}"
                    );
                    triplets.push((r - start, c, v));
                }
            }
        }
        self.cursor += 1;
        let matrix = CsrMatrix::from_triplet_vec(end - start, self.cols, triplets);
        // Raw window iteration (the BigFit evaluation pass) holds one
        // window at a time; record that so `stats().peak_resident_nnz`
        // reflects every consumption pattern, not just the helpers below
        // (which overwrite this with their larger selected+window /
        // full-assembly figures).
        self.peak_resident_nnz = self.peak_resident_nnz.max(matrix.nnz());
        self.obs_windows.inc();
        self.obs_window_nnz.record(matrix.nnz() as u64);
        Ok(Some(CsrWindow { start_row: start, matrix }))
    }

    /// Drain every window into one full matrix — bitwise equal to the
    /// in-memory loader's result (stable per-window triplet builds
    /// concatenate to the global build; see the module docs). Transient
    /// overhead on top of the growing output is one window. Covers the
    /// full row range, so it must run on a freshly opened reader; a
    /// partially consumed one returns a clean `Err`.
    pub fn read_all(&mut self) -> Result<CsrMatrix> {
        ensure!(
            self.cursor == 0,
            "{}: read_all requires a freshly opened reader ({} of {} windows already consumed)",
            self.path.display(),
            self.cursor,
            self.windows.len()
        );
        let mut indptr = Vec::with_capacity(self.rows + 1);
        indptr.push(0usize);
        let mut indices: Vec<u32> = Vec::new();
        let mut values: Vec<f32> = Vec::new();
        while let Some(w) = self.next_window()? {
            let (wp, wi, wv) = w.matrix.parts();
            let offset = *indptr.last().unwrap();
            indptr.extend(wp[1..].iter().map(|p| p + offset));
            indices.extend_from_slice(wi);
            values.extend_from_slice(wv);
        }
        ensure!(
            indptr.len() == self.rows + 1,
            "{}: windows covered {} rows, expected {}",
            self.path.display(),
            indptr.len() - 1,
            self.rows
        );
        self.peak_resident_nnz = self.peak_resident_nnz.max(values.len());
        Ok(CsrMatrix::from_parts(self.rows, self.cols, indptr, indices, values))
    }

    /// Subsample `n` rows without replacement, drawing the **identical
    /// index set and rng stream** as `Dataset::subsample` on the fully
    /// loaded matrix: the index draw is the one `rng.sample_indices(rows,
    /// n)` call (reservoir-free — the header gives `rows` up front), then
    /// a single forward pass over the windows collects the selected rows,
    /// and assembly in draw order reproduces `CsrMatrix::select_rows`
    /// bitwise. Peak value residency: selected-so-far + one window. Like
    /// [`CsrChunkReader::read_all`], requires a freshly opened reader (a
    /// selected row in an already-consumed window would be unreachable).
    pub fn subsample_rows(&mut self, n: usize, rng: &mut Rng) -> Result<(CsrMatrix, Vec<usize>)> {
        ensure!(
            self.cursor == 0,
            "{}: subsample_rows requires a freshly opened reader ({} of {} windows already consumed)",
            self.path.display(),
            self.cursor,
            self.windows.len()
        );
        ensure!(n <= self.rows, "subsample({n}) > rows({})", self.rows);
        let idx = rng.sample_indices(self.rows, n);
        let selected: HashSet<usize> = idx.iter().copied().collect();
        let mut kept: HashMap<usize, (Vec<u32>, Vec<f32>)> = HashMap::with_capacity(n);
        let mut resident = 0usize;
        while let Some(w) = self.next_window()? {
            let raw = self.windows[self.cursor - 1].raw;
            for local in 0..w.matrix.rows() {
                let global = w.start_row + local;
                if selected.contains(&global) {
                    let (ci, cv) = w.matrix.row(local);
                    resident += cv.len();
                    kept.insert(global, (ci.to_vec(), cv.to_vec()));
                }
            }
            self.peak_resident_nnz = self.peak_resident_nnz.max(resident + raw);
        }
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let mut indices: Vec<u32> = Vec::new();
        let mut values: Vec<f32> = Vec::new();
        for g in &idx {
            let (ci, cv) = kept.get(g).expect("window pass covered every selected row");
            indices.extend_from_slice(ci);
            values.extend_from_slice(cv);
            indptr.push(indices.len());
        }
        Ok((CsrMatrix::from_parts(n, self.cols, indptr, indices, values), idx))
    }
}

impl Drop for CsrChunkReader {
    fn drop(&mut self) {
        if let Some(p) = &self.spill_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Pass 2 for unordered input: scatter entries into per-window byte
/// ranges of a temp file. Exact destinations are known from the pass-1
/// counts, so each window's range fills front to back in file order
/// (per-window append buffers flush at their running offsets). Buffered
/// residency across all windows is capped at `max(chunk_nnz, 2^16)`
/// records (~768 KiB at the floor).
fn build_spill(
    path: &Path,
    opts: &StreamOptions,
    rows: usize,
    windows: &[Window],
) -> Result<(BufReader<File>, PathBuf)> {
    let mut window_of_row = vec![0u32; rows];
    let mut base = Vec::with_capacity(windows.len());
    let mut acc = 0usize;
    for (w, win) in windows.iter().enumerate() {
        for r in win.start..win.end {
            window_of_row[r] = w as u32;
        }
        base.push(acc);
        acc += win.raw;
    }

    let spill_path = std::env::temp_dir().join(format!(
        "banditpam_stream_spill_{}_{}.bin",
        std::process::id(),
        SPILL_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let mut spill = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .create_new(true)
        .open(&spill_path)
        .with_context(|| format!("creating spill file {}", spill_path.display()))?;
    // Wrap so the spill file never leaks, even on a mid-build error.
    let result = write_spill(path, opts, rows, windows, &base, &window_of_row, &mut spill);
    match result {
        Ok(()) => {
            spill.seek(SeekFrom::Start(0))?;
            Ok((BufReader::new(spill), spill_path))
        }
        Err(e) => {
            drop(spill);
            let _ = std::fs::remove_file(&spill_path);
            Err(e)
        }
    }
}

fn write_spill(
    path: &Path,
    opts: &StreamOptions,
    rows: usize,
    windows: &[Window],
    base: &[usize],
    window_of_row: &[u32],
    spill: &mut File,
) -> Result<()> {
    // The 2^16 floor keeps the spill pass efficient even under a tiny
    // window budget: each flush touches only the windows that actually
    // buffered records (the dirty list, not an O(windows) scan) and
    // amortizes at least 64k records of parsing per round of seeks.
    let flush_cap = opts.chunk_nnz.max(1 << 16);
    let mut bufs: Vec<Vec<u8>> = vec![Vec::new(); windows.len()];
    let mut written = vec![0usize; windows.len()];
    let mut dirty: Vec<usize> = Vec::new();
    let mut buffered = 0usize;

    fn flush_dirty(
        spill: &mut File,
        base: &[usize],
        bufs: &mut [Vec<u8>],
        written: &mut [usize],
        dirty: &mut Vec<usize>,
        buffered: &mut usize,
    ) -> Result<()> {
        // Ascending window order = ascending file offsets for the seeks.
        dirty.sort_unstable();
        for &w in dirty.iter() {
            let buf = &mut bufs[w];
            let offset = ((base[w] + written[w]) * SPILL_REC) as u64;
            spill.seek(SeekFrom::Start(offset))?;
            spill.write_all(buf)?;
            written[w] += buf.len() / SPILL_REC;
            buf.clear();
        }
        dirty.clear();
        *buffered = 0;
        Ok(())
    }

    let file =
        File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut scanner = MtxScanner::open(BufReader::new(file), path)?;
    while let Some((i, j, v)) = scanner.next_entry()? {
        let r = if opts.transpose { j } else { i };
        if r >= rows {
            continue;
        }
        let c = if opts.transpose { i } else { j };
        let w = window_of_row[r] as usize;
        let buf = &mut bufs[w];
        if buf.is_empty() {
            dirty.push(w);
        }
        buf.extend_from_slice(&(r as u32).to_le_bytes());
        buf.extend_from_slice(&(c as u32).to_le_bytes());
        buf.extend_from_slice(&v.to_le_bytes());
        buffered += 1;
        if buffered >= flush_cap {
            flush_dirty(spill, base, &mut bufs, &mut written, &mut dirty, &mut buffered)?;
        }
    }
    flush_dirty(spill, base, &mut bufs, &mut written, &mut dirty, &mut buffered)?;
    Ok(())
}

/// Stream-load a whole `.mtx` file: bitwise-identical dataset to
/// [`crate::data::loader::load_mtx`] with the same `transpose`/`limit`,
/// assembled window by window.
pub fn load_mtx_streamed(path: &Path, opts: &StreamOptions) -> Result<(Dataset, StreamStats)> {
    let mut reader = CsrChunkReader::open(path, opts.clone())?;
    let ds = Dataset::from_stream(&mut reader)?;
    Ok((ds, reader.stats()))
}

/// Stream-subsample `n` rows of a `.mtx` file: bitwise-identical dataset
/// (matrix, name, rng stream position) to `load_mtx(...).subsample(n,
/// rng)`, holding only `max(selected, window)`-scale values in memory.
pub fn subsample_mtx_streamed(
    path: &Path,
    opts: &StreamOptions,
    n: usize,
    rng: &mut Rng,
) -> Result<(Dataset, StreamStats)> {
    let mut reader = CsrChunkReader::open(path, opts.clone())?;
    let base_name = reader.source_name();
    let (matrix, idx) = reader.subsample_rows(n, rng)?;
    let name = format!("{base_name}[sub {}]", idx.len());
    Ok((Dataset::sparse(matrix, name), reader.stats()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader;
    use crate::data::synthetic;
    use crate::data::Points;

    fn tmpfile(name: &str, contents: &[u8]) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "banditpam_stream_test_{}_{name}",
            std::process::id()
        ));
        std::fs::write(&p, contents).unwrap();
        p
    }

    const SHUFFLED: &[u8] = b"%%MatrixMarket matrix coordinate real general\n\
        % shuffled rows, duplicates, an explicit zero\n\
        5 4 9\n\
        3 2 1.25\n1 1 0.5\n5 4 -2.75\n2 3 0\n3 2 0.75\n1 4 3.5\n4 1 0.001\n1 1 0.25\n5 1 7\n";

    #[test]
    fn window_plan_respects_budget_and_never_splits_rows() {
        let p = tmpfile("plan.mtx", SHUFFLED);
        let r = CsrChunkReader::open(
            &p,
            StreamOptions { chunk_nnz: 3, ..StreamOptions::default() },
        )
        .unwrap();
        assert_eq!(r.rows(), 5);
        assert_eq!(r.cols(), 4);
        let starts: Vec<usize> = r.windows.iter().map(|w| w.start).collect();
        let ends: Vec<usize> = r.windows.iter().map(|w| w.end).collect();
        // windows partition [0, 5) in order
        assert_eq!(starts[0], 0);
        assert_eq!(*ends.last().unwrap(), 5);
        for i in 1..starts.len() {
            assert_eq!(starts[i], ends[i - 1]);
        }
        for w in &r.windows {
            assert!(w.end > w.start, "window must hold at least one row");
            // raw > budget only for single-row windows
            assert!(w.raw <= 3 || w.end - w.start == 1);
        }
        assert_eq!(r.stats().kept_nnz, 9);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn unordered_body_spills_and_matches_in_memory() {
        let p = tmpfile("spill.mtx", SHUFFLED);
        let mem = loader::load_mtx(&p, false, 0).unwrap();
        let Points::Sparse(expect) = &mem.points else { unreachable!() };
        for chunk in [1usize, 2, 4, 64] {
            let mut r = CsrChunkReader::open(
                &p,
                StreamOptions { chunk_nnz: chunk, ..StreamOptions::default() },
            )
            .unwrap();
            assert!(r.stats().spilled, "shuffled rows must take the spill path");
            let got = r.read_all().unwrap();
            assert_eq!(&got, expect, "chunk={chunk}");
        }
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn row_major_body_streams_without_spill() {
        let ds = synthetic::scrna_sparse(&mut Rng::seed_from(3), 30, 48, 0.10);
        let p = tmpfile("ordered.mtx", b"");
        loader::save_mtx(&ds, &p).unwrap();
        let mut r = CsrChunkReader::open(
            &p,
            StreamOptions { chunk_nnz: 17, ..StreamOptions::default() },
        )
        .unwrap();
        assert!(!r.stats().spilled, "row-major writer output must not spill");
        let got = r.read_all().unwrap();
        let Points::Sparse(expect) = &ds.points else { unreachable!() };
        assert_eq!(&got, expect);
        // ... while the same file under --transpose must spill
        let r2 = CsrChunkReader::open(
            &p,
            StreamOptions { chunk_nnz: 17, transpose: true, ..StreamOptions::default() },
        )
        .unwrap();
        assert!(r2.stats().spilled);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn windows_cover_empty_rows_and_empty_matrices() {
        let p = tmpfile(
            "empty_rows.mtx",
            b"%%MatrixMarket matrix coordinate real general\n4 3 1\n2 2 5.5\n",
        );
        let mut r = CsrChunkReader::open(
            &p,
            StreamOptions { chunk_nnz: 1, ..StreamOptions::default() },
        )
        .unwrap();
        let m = r.read_all().unwrap();
        assert_eq!(m.rows(), 4);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(1), (&[1u32][..], &[5.5f32][..]));

        let p0 = tmpfile(
            "no_entries.mtx",
            b"%%MatrixMarket matrix coordinate real general\n0 7 0\n",
        );
        let mut r0 = CsrChunkReader::open(&p0, StreamOptions::default()).unwrap();
        let m0 = r0.read_all().unwrap();
        assert_eq!((m0.rows(), m0.cols(), m0.nnz()), (0, 7, 0));
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_file(p0);
    }

    #[test]
    fn subsample_matches_in_memory_bitwise_and_rng_stream() {
        let ds = synthetic::scrna_sparse(&mut Rng::seed_from(21), 60, 40, 0.10);
        let p = tmpfile("sub.mtx", b"");
        loader::save_mtx(&ds, &p).unwrap();
        let mem = loader::load_mtx(&p, false, 0).unwrap();
        let mut rng_mem = Rng::seed_from(77);
        let sub_mem = mem.subsample(25, &mut rng_mem);
        let mut rng_st = Rng::seed_from(77);
        let (sub_st, stats) = subsample_mtx_streamed(
            &p,
            &StreamOptions { chunk_nnz: 23, ..StreamOptions::default() },
            25,
            &mut rng_st,
        )
        .unwrap();
        let (Points::Sparse(a), Points::Sparse(b)) = (&sub_mem.points, &sub_st.points) else {
            unreachable!()
        };
        assert_eq!(a, b);
        assert_eq!(sub_mem.name, sub_st.name);
        // rng streams stay in lockstep after the draw
        assert_eq!(rng_mem.next_u64(), rng_st.next_u64());
        // bounded residency: selected + one window, never the whole matrix
        assert!(stats.peak_resident_nnz <= a.nnz() + stats.peak_window_nnz);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn spill_file_is_cleaned_up_on_drop() {
        let p = tmpfile("cleanup.mtx", SHUFFLED);
        let spill_path = {
            let r = CsrChunkReader::open(
                &p,
                StreamOptions { chunk_nnz: 2, ..StreamOptions::default() },
            )
            .unwrap();
            let sp = r.spill_path.clone().expect("spill expected");
            assert!(sp.exists());
            sp
        };
        assert!(!spill_path.exists(), "spill must be removed on drop");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn limit_applies_to_post_transpose_rows() {
        // 2 genes x 3 cells; transpose makes cells rows, limit keeps 2 cells.
        let p = tmpfile(
            "limit.mtx",
            b"%%MatrixMarket matrix coordinate real general\n2 3 4\n1 1 1\n2 1 2\n1 2 3\n2 3 4\n",
        );
        let mut r = CsrChunkReader::open(
            &p,
            StreamOptions { chunk_nnz: 2, transpose: true, limit: 2 },
        )
        .unwrap();
        assert_eq!(r.rows(), 2);
        assert_eq!(r.cols(), 2);
        let m = r.read_all().unwrap();
        assert_eq!(m.row(0), (&[0u32, 1][..], &[1.0f32, 2.0][..]));
        assert_eq!(m.row(1), (&[0u32][..], &[3.0f32][..]));
        let _ = std::fs::remove_file(p);
    }
}
