//! PCA via block power iteration (for the scRNA-PCA dataset, Appendix 1.3).
//!
//! Computes the top-`k` principal components of a centered `n x d` matrix
//! without forming the `d x d` covariance: each iteration applies
//! `v <- X^T (X v) / n` (O(n d k) per sweep) followed by Gram–Schmidt
//! re-orthonormalization. Enough accuracy for a dataset projection —
//! downstream only the *distribution* of projected distances matters.

use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Project the rows of `m` onto the top `k` principal components.
/// Returns an `n x k` matrix of scores.
pub fn project(m: &Matrix, k: usize, rng: &mut Rng) -> Matrix {
    let comps = components(m, k, rng, 40);
    let means = m.col_means();
    let (n, d) = (m.rows(), m.cols());
    let mut out = Matrix::zeros(n, k);
    for i in 0..n {
        let row = m.row(i);
        for (c, comp) in comps.iter().enumerate() {
            let mut s = 0.0f64;
            for j in 0..d {
                s += (row[j] as f64 - means[j]) * comp[j];
            }
            out.set(i, c, s as f32);
        }
    }
    out
}

/// Top-`k` principal directions (unit d-vectors), via block power iteration.
pub fn components(m: &Matrix, k: usize, rng: &mut Rng, sweeps: usize) -> Vec<Vec<f64>> {
    let (n, d) = (m.rows(), m.cols());
    assert!(k <= d, "k={k} > d={d}");
    let means = m.col_means();
    // centered row access closure cost is dominated by the matvec anyway
    let mut basis: Vec<Vec<f64>> =
        (0..k).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
    orthonormalize(&mut basis);
    let mut scores = vec![0.0f64; n];
    for _ in 0..sweeps {
        for v in basis.iter_mut() {
            // scores = X v (centered)
            for (i, s) in scores.iter_mut().enumerate() {
                let row = m.row(i);
                let mut acc = 0.0;
                for j in 0..d {
                    acc += (row[j] as f64 - means[j]) * v[j];
                }
                *s = acc;
            }
            // v = X^T scores
            v.iter_mut().for_each(|x| *x = 0.0);
            for (i, &s) in scores.iter().enumerate() {
                if s == 0.0 {
                    continue;
                }
                let row = m.row(i);
                for j in 0..d {
                    v[j] += (row[j] as f64 - means[j]) * s;
                }
            }
        }
        orthonormalize(&mut basis);
    }
    basis
}

/// Modified Gram–Schmidt in place; re-randomizes degenerate vectors is not
/// needed for our use (random init, k << d).
fn orthonormalize(vs: &mut [Vec<f64>]) {
    for i in 0..vs.len() {
        for j in 0..i {
            let dot: f64 = vs[i].iter().zip(&vs[j]).map(|(a, b)| a * b).sum();
            let (head, tail) = vs.split_at_mut(i);
            tail[0]
                .iter_mut()
                .zip(&head[j])
                .for_each(|(a, b)| *a -= dot * b);
        }
        let norm: f64 = vs[i].iter().map(|a| a * a).sum::<f64>().sqrt();
        if norm > 1e-12 {
            vs[i].iter_mut().for_each(|a| *a /= norm);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Data stretched along a known direction: PC1 must recover it.
    #[test]
    fn recovers_dominant_direction() {
        let mut rng = Rng::seed_from(7);
        let d = 8;
        let n = 400;
        let dir: Vec<f64> = {
            let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let norm = v.iter().map(|a| a * a).sum::<f64>().sqrt();
            v.iter_mut().for_each(|a| *a /= norm);
            v
        };
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            let t = rng.normal() * 10.0; // big variance along dir
            for j in 0..d {
                m.set(i, j, (t * dir[j] + rng.normal() * 0.1) as f32);
            }
        }
        let comps = components(&m, 1, &mut rng, 30);
        let cos: f64 = comps[0].iter().zip(&dir).map(|(a, b)| a * b).sum();
        assert!(cos.abs() > 0.99, "cos = {cos}");
    }

    #[test]
    fn components_are_orthonormal() {
        let mut rng = Rng::seed_from(8);
        let m = Matrix::from_fn(100, 6, |_, _| rng.normal() as f32);
        let comps = components(&m, 3, &mut rng, 20);
        for i in 0..3 {
            let n: f64 = comps[i].iter().map(|a| a * a).sum();
            assert!((n - 1.0).abs() < 1e-8, "norm {n}");
            for j in 0..i {
                let dot: f64 = comps[i].iter().zip(&comps[j]).map(|(a, b)| a * b).sum();
                assert!(dot.abs() < 1e-6, "dot {dot}");
            }
        }
    }

    #[test]
    fn projection_shape_and_centering() {
        let mut rng = Rng::seed_from(9);
        let m = Matrix::from_fn(50, 12, |_, _| (rng.normal() + 5.0) as f32);
        let p = project(&m, 4, &mut rng);
        assert_eq!(p.rows(), 50);
        assert_eq!(p.cols(), 4);
        // projected scores are centered (mean ~ 0 per component)
        for c in 0..4 {
            let mean: f64 =
                (0..50).map(|i| p.get(i, c) as f64).sum::<f64>() / 50.0;
            assert!(mean.abs() < 0.5, "mean {mean}");
        }
    }

    #[test]
    fn variance_explained_is_decreasing() {
        let mut rng = Rng::seed_from(10);
        // anisotropic data: variance 9, 4, 1 in first three axes
        let mut m = Matrix::zeros(300, 5);
        for i in 0..300 {
            m.set(i, 0, (rng.normal() * 3.0) as f32);
            m.set(i, 1, (rng.normal() * 2.0) as f32);
            m.set(i, 2, rng.normal() as f32);
        }
        let p = project(&m, 3, &mut rng);
        let var = |c: usize| -> f64 {
            let mean: f64 = (0..300).map(|i| p.get(i, c) as f64).sum::<f64>() / 300.0;
            (0..300)
                .map(|i| (p.get(i, c) as f64 - mean).powi(2))
                .sum::<f64>()
                / 300.0
        };
        assert!(var(0) > var(1));
        assert!(var(1) > var(2));
    }
}
