//! Dataset abstraction and the synthetic stand-ins for the paper's corpora.
//!
//! The paper evaluates on MNIST (l2/cosine), the 10x Genomics 68k PBMC
//! scRNA-seq dataset (l1), its 10-PC projection (l2), and HOC4 Code.org
//! abstract syntax trees (tree edit distance). None of those are available
//! offline, so [`synthetic`], [`ast`] and [`pca`] generate statistical
//! equivalents — see DESIGN.md §Substitutions for the preservation
//! argument (Theorems 1–2 depend on the data only through the arm-mean and
//! sigma distributions).

pub mod ast;
pub mod loader;
pub mod pca;
pub mod synthetic;

use crate::util::matrix::Matrix;
use ast::Tree;

/// Point storage: dense feature vectors or ASTs.
#[derive(Debug, Clone)]
pub enum Points {
    /// `n x d` dense matrix (one point per row).
    Dense(Matrix),
    /// Ordered labelled trees (HOC4-like).
    Trees(Vec<Tree>),
}

impl Points {
    /// Number of points.
    pub fn len(&self) -> usize {
        match self {
            Points::Dense(m) => m.rows(),
            Points::Trees(t) => t.len(),
        }
    }

    /// True when there are no points.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimensionality (dense only).
    pub fn dim(&self) -> Option<usize> {
        match self {
            Points::Dense(m) => Some(m.cols()),
            Points::Trees(_) => None,
        }
    }

    /// Storage kind name for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Points::Dense(_) => "dense",
            Points::Trees(_) => "trees",
        }
    }
}

/// A dataset: points plus (for synthetic data) ground-truth component
/// labels, used by the examples to report cluster purity.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub points: Points,
    /// Generating component of each point, when known.
    pub labels: Option<Vec<usize>>,
    /// Human-readable provenance (e.g. "mnist_like(n=1000, seed=7)").
    pub name: String,
}

impl Dataset {
    /// Wrap a dense matrix with no labels.
    pub fn dense(m: Matrix, name: impl Into<String>) -> Dataset {
        Dataset { points: Points::Dense(m), labels: None, name: name.into() }
    }

    /// Wrap existing points with no labels (name "anonymous").
    pub fn dense_from_points(points: Points) -> Dataset {
        Dataset { points, labels: None, name: "anonymous".into() }
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Subsample `n` points uniformly without replacement (the paper's
    /// experimental protocol subsamples each dataset per repetition).
    pub fn subsample(&self, n: usize, rng: &mut crate::util::rng::Rng) -> Dataset {
        assert!(n <= self.len(), "subsample({n}) > len({})", self.len());
        let idx = rng.sample_indices(self.len(), n);
        self.select(&idx)
    }

    /// Select points by index.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let points = match &self.points {
            Points::Dense(m) => Points::Dense(m.select_rows(idx)),
            Points::Trees(t) => {
                Points::Trees(idx.iter().map(|&i| t[i].clone()).collect())
            }
        };
        let labels = self
            .labels
            .as_ref()
            .map(|l| idx.iter().map(|&i| l[i]).collect());
        Dataset { points, labels, name: format!("{}[sub {}]", self.name, idx.len()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn len_and_dim() {
        let d = Dataset::dense(Matrix::zeros(5, 3), "z");
        assert_eq!(d.len(), 5);
        assert_eq!(d.points.dim(), Some(3));
        assert!(!d.is_empty());
    }

    #[test]
    fn subsample_preserves_labels() {
        let m = Matrix::from_fn(10, 2, |i, _| i as f32);
        let mut d = Dataset::dense(m, "t");
        d.labels = Some((0..10).collect());
        let mut rng = Rng::seed_from(1);
        let s = d.subsample(4, &mut rng);
        assert_eq!(s.len(), 4);
        let labels = s.labels.unwrap();
        if let Points::Dense(m) = &s.points {
            for (r, &lab) in labels.iter().enumerate() {
                assert_eq!(m.get(r, 0) as usize, lab);
            }
        }
    }

    #[test]
    #[should_panic(expected = "subsample")]
    fn oversample_panics() {
        let d = Dataset::dense(Matrix::zeros(3, 1), "t");
        d.subsample(4, &mut Rng::seed_from(0));
    }
}
