//! Dataset abstraction and the synthetic stand-ins for the paper's corpora.
//!
//! The paper evaluates on MNIST (l2/cosine), the 10x Genomics 68k PBMC
//! scRNA-seq dataset (l1), its 10-PC projection (l2), and HOC4 Code.org
//! abstract syntax trees (tree edit distance). None of those are available
//! offline, so [`synthetic`], [`ast`] and [`pca`] generate statistical
//! equivalents — see DESIGN.md §Substitutions for the preservation
//! argument (Theorems 1–2 depend on the data only through the arm-mean and
//! sigma distributions).

pub mod ast;
pub mod loader;
pub mod pca;
pub mod sparse;
pub mod stream;
pub mod synthetic;

use crate::util::matrix::Matrix;
use ast::Tree;
use sparse::CsrMatrix;

/// Point storage: dense feature vectors, sparse (CSR) feature vectors,
/// or ASTs.
#[derive(Debug, Clone)]
pub enum Points {
    /// `n x d` dense matrix (one point per row).
    Dense(Matrix),
    /// `n x d` compressed sparse row matrix (one point per row); the
    /// scRNA-seq regime, where >90% of entries are zeros.
    Sparse(CsrMatrix),
    /// Ordered labelled trees (HOC4-like).
    Trees(Vec<Tree>),
}

impl Points {
    /// Number of points.
    pub fn len(&self) -> usize {
        match self {
            Points::Dense(m) => m.rows(),
            Points::Sparse(m) => m.rows(),
            Points::Trees(t) => t.len(),
        }
    }

    /// True when there are no points.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimensionality.
    ///
    /// Contract: `Some(d)` for vector storage (`Dense`, `Sparse`) and
    /// `None` for storage without a fixed feature space (`Trees`). The
    /// shape is a property of the *storage*, not of the points in it, so
    /// an **empty** dense/sparse dataset still reports its column count
    /// (`Matrix::zeros(0, d).cols() == d`) and an empty tree corpus still
    /// reports `None`. Callers must not use `dim()` as an emptiness or
    /// storage-kind probe — that is what [`Points::is_empty`] and
    /// [`Points::kind`] are for.
    pub fn dim(&self) -> Option<usize> {
        match self {
            Points::Dense(m) => Some(m.cols()),
            Points::Sparse(m) => Some(m.cols()),
            Points::Trees(_) => None,
        }
    }

    /// Storage kind name for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Points::Dense(_) => "dense",
            Points::Sparse(_) => "sparse",
            Points::Trees(_) => "trees",
        }
    }

    /// Select points by index into a new `Points` of the same storage kind
    /// (indices may repeat or reorder). This is how
    /// [`crate::model::KMedoidsModel`] extracts its owned medoid rows from
    /// a training set, and what [`Dataset::select`] routes through.
    pub fn select(&self, idx: &[usize]) -> Points {
        match self {
            Points::Dense(m) => Points::Dense(m.select_rows(idx)),
            Points::Sparse(m) => Points::Sparse(m.select_rows(idx)),
            Points::Trees(t) => {
                Points::Trees(idx.iter().map(|&i| t[i].clone()).collect())
            }
        }
    }

    /// Convert dense storage to CSR (`None` for trees; sparse is returned
    /// as a clone). Exact zeros are dropped; `to_dense` restores them, so
    /// the round trip is lossless.
    pub fn to_sparse(&self) -> Option<Points> {
        match self {
            Points::Dense(m) => Some(Points::Sparse(CsrMatrix::from_dense(m))),
            Points::Sparse(m) => Some(Points::Sparse(m.clone())),
            Points::Trees(_) => None,
        }
    }

    /// Convert sparse storage to dense (`None` for trees; dense is
    /// returned as a clone).
    pub fn to_dense(&self) -> Option<Points> {
        match self {
            Points::Dense(m) => Some(Points::Dense(m.clone())),
            Points::Sparse(m) => Some(Points::Dense(m.to_dense())),
            Points::Trees(_) => None,
        }
    }
}

/// A dataset: points plus (for synthetic data) ground-truth component
/// labels, used by the examples to report cluster purity.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub points: Points,
    /// Generating component of each point, when known.
    pub labels: Option<Vec<usize>>,
    /// Human-readable provenance (e.g. "mnist_like(n=1000, seed=7)").
    pub name: String,
}

impl Dataset {
    /// Wrap a dense matrix with no labels.
    pub fn dense(m: Matrix, name: impl Into<String>) -> Dataset {
        Dataset { points: Points::Dense(m), labels: None, name: name.into() }
    }

    /// Wrap a CSR matrix with no labels.
    pub fn sparse(m: CsrMatrix, name: impl Into<String>) -> Dataset {
        Dataset { points: Points::Sparse(m), labels: None, name: name.into() }
    }

    /// This dataset with its points converted to CSR storage (`None` for
    /// trees). Labels and name are preserved.
    pub fn to_sparse(&self) -> Option<Dataset> {
        Some(Dataset {
            points: self.points.to_sparse()?,
            labels: self.labels.clone(),
            name: self.name.clone(),
        })
    }

    /// This dataset with its points converted to dense storage (`None`
    /// for trees). Labels and name are preserved.
    pub fn to_dense(&self) -> Option<Dataset> {
        Some(Dataset {
            points: self.points.to_dense()?,
            labels: self.labels.clone(),
            name: self.name.clone(),
        })
    }

    /// Wrap existing points with no labels (name "anonymous").
    pub fn dense_from_points(points: Points) -> Dataset {
        Dataset { points, labels: None, name: "anonymous".into() }
    }

    /// Assemble a dataset from an out-of-core chunked reader
    /// ([`stream::CsrChunkReader`]), window by window — bitwise-identical
    /// to loading the same file in memory, but only ever holding one
    /// row-window of values beyond the growing result.
    pub fn from_stream(reader: &mut stream::CsrChunkReader) -> crate::error::Result<Dataset> {
        let name = reader.source_name();
        let csr = reader
            .read_all()
            .map_err(|e| crate::error::Error::data(format!("{e:#}")))?;
        Ok(Dataset::sparse(csr, name))
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Subsample `n` points uniformly without replacement (the paper's
    /// experimental protocol subsamples each dataset per repetition).
    pub fn subsample(&self, n: usize, rng: &mut crate::util::rng::Rng) -> Dataset {
        assert!(n <= self.len(), "subsample({n}) > len({})", self.len());
        let idx = rng.sample_indices(self.len(), n);
        self.select(&idx)
    }

    /// Select points by index.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let points = self.points.select(idx);
        let labels = self
            .labels
            .as_ref()
            .map(|l| idx.iter().map(|&i| l[i]).collect());
        Dataset { points, labels, name: format!("{}[sub {}]", self.name, idx.len()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn len_and_dim() {
        let d = Dataset::dense(Matrix::zeros(5, 3), "z");
        assert_eq!(d.len(), 5);
        assert_eq!(d.points.dim(), Some(3));
        assert!(!d.is_empty());
    }

    #[test]
    fn subsample_preserves_labels() {
        let m = Matrix::from_fn(10, 2, |i, _| i as f32);
        let mut d = Dataset::dense(m, "t");
        d.labels = Some((0..10).collect());
        let mut rng = Rng::seed_from(1);
        let s = d.subsample(4, &mut rng);
        assert_eq!(s.len(), 4);
        let labels = s.labels.unwrap();
        if let Points::Dense(m) = &s.points {
            for (r, &lab) in labels.iter().enumerate() {
                assert_eq!(m.get(r, 0) as usize, lab);
            }
        }
    }

    #[test]
    #[should_panic(expected = "subsample")]
    fn oversample_panics() {
        let d = Dataset::dense(Matrix::zeros(3, 1), "t");
        d.subsample(4, &mut Rng::seed_from(0));
    }

    /// The `dim()` contract (see the method docs): `Some(cols)` for vector
    /// storage even with zero points, `None` for trees always.
    #[test]
    fn dim_contract_across_variants_and_empty_datasets() {
        // non-empty
        assert_eq!(Points::Dense(Matrix::zeros(5, 3)).dim(), Some(3));
        assert_eq!(Points::Sparse(CsrMatrix::zeros(5, 7)).dim(), Some(7));
        assert_eq!(Points::Trees(vec![ast::Tree::leaf(0)]).dim(), None);
        // empty datasets keep their feature space
        let empty_dense = Points::Dense(Matrix::zeros(0, 3));
        assert!(empty_dense.is_empty());
        assert_eq!(empty_dense.dim(), Some(3));
        let empty_sparse = Points::Sparse(CsrMatrix::zeros(0, 9));
        assert!(empty_sparse.is_empty());
        assert_eq!(empty_sparse.dim(), Some(9));
        let empty_trees = Points::Trees(Vec::new());
        assert!(empty_trees.is_empty());
        assert_eq!(empty_trees.dim(), None);
        // kind() is the storage probe, not dim()
        assert_eq!(empty_sparse.kind(), "sparse");
    }

    #[test]
    fn sparse_select_and_subsample_preserve_rows_and_labels() {
        let dense = Matrix::from_fn(10, 4, |i, j| if j == 0 { i as f32 } else { 0.0 });
        let mut d = Dataset::sparse(CsrMatrix::from_dense(&dense), "s");
        d.labels = Some((0..10).collect());
        let s = d.select(&[7, 2, 0]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.labels, Some(vec![7, 2, 0]));
        let Points::Sparse(m) = &s.points else { unreachable!() };
        assert_eq!(m.row(0), (&[0u32][..], &[7.0f32][..]));
        assert_eq!(m.row_nnz(2), 0); // row 0 of the source is all-zero
        let sub = d.subsample(4, &mut Rng::seed_from(3));
        assert_eq!(sub.len(), 4);
        assert_eq!(sub.points.kind(), "sparse");
    }

    #[test]
    fn dense_sparse_roundtrip_via_dataset() {
        let mut rng = Rng::seed_from(11);
        let base = synthetic::scrna_like(&mut rng, 12, 64);
        let sp = base.to_sparse().unwrap();
        assert_eq!(sp.points.kind(), "sparse");
        assert_eq!(sp.labels, base.labels);
        let back = sp.to_dense().unwrap();
        let (Points::Dense(a), Points::Dense(b)) = (&base.points, &back.points) else {
            unreachable!()
        };
        assert_eq!(a.as_slice(), b.as_slice());
        // trees have no vector form
        let trees = synthetic::hoc4_like(&mut rng, 3);
        assert!(trees.to_sparse().is_none());
        assert!(trees.to_dense().is_none());
    }
}
