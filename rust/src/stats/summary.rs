//! Batch summaries: quantiles, five-number boxplot summaries, 95% CIs.
//!
//! Used by the experiment harness for the paper's "each parameter setting
//! was repeated 10 times … 95% confidence intervals are provided" protocol
//! and by the Appendix-Figure-1 sigma boxplots.

/// Five-number summary + mean (boxplot data).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
}

/// Linear-interpolation quantile of a sorted slice (q in [0, 1]).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Quantile of an unsorted slice (copies + sorts).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

impl Summary {
    /// Compute from raw observations. Panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary of empty slice");
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Summary {
            n: v.len(),
            min: v[0],
            q1: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q3: quantile_sorted(&v, 0.75),
            max: *v.last().unwrap(),
            mean,
        }
    }
}

/// Mean and normal-approximation 95% confidence half-width.
///
/// Returns `(mean, half_width)`; half-width is `1.96 * s / sqrt(n)`
/// (0 when n < 2). With the paper's 10 repeats the normal approximation is
/// what the reference plots use.
pub fn mean_ci95(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, 1.96 * (var / n).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_sequence() {
        let xs: Vec<f64> = (1..=5).map(|i| i as f64).collect();
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.3) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_five_numbers() {
        let xs = [7.0, 1.0, 3.0, 5.0, 9.0];
        let s = Summary::of(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.max, 9.0);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| (i % 2) as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 2) as f64).collect();
        let (_, wa) = mean_ci95(&a);
        let (_, wb) = mean_ci95(&b);
        assert!(wb < wa);
        assert!(wa > 0.0);
    }

    #[test]
    fn ci_degenerate_cases() {
        assert_eq!(mean_ci95(&[]), (0.0, 0.0));
        assert_eq!(mean_ci95(&[2.0]), (2.0, 0.0));
        let (m, w) = mean_ci95(&[3.0, 3.0, 3.0]);
        assert_eq!(m, 3.0);
        assert_eq!(w, 0.0);
    }
}
