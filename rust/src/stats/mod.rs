//! Statistics substrate: running moments, summaries, CIs, regression,
//! histograms — everything the experiment harness needs to report the
//! paper's tables/figures (means ± 95% CI, log–log slopes, boxplots).

pub mod histogram;
pub mod regression;
pub mod running;
pub mod summary;

pub use histogram::Histogram;
pub use regression::{loglog_slope, LinearFit};
pub use running::Running;
pub use summary::{mean_ci95, quantile, Summary};
