//! Least-squares line fitting.
//!
//! The paper's scaling claims are stated as slopes of lines of best fit on
//! log–log plots (e.g. Figure 2: slope 0.984 for MNIST/l2/k=5, Appendix
//! Figure 5: slope 1.204 for scRNA-PCA). [`loglog_slope`] reproduces that
//! readout for our benchmark sweeps.

/// Result of a simple linear regression `y = slope * x + intercept`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Ordinary least squares on `(x, y)` pairs. Panics if `xs.len() < 2` or
/// lengths disagree.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len(), "length mismatch");
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    assert!(sxx > 0.0, "degenerate x values");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    LinearFit { slope, intercept, r2 }
}

/// Slope of the line of best fit on the log–log plot of `(x, y)` —
/// the empirical scaling exponent. All values must be positive.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> LinearFit {
    let lx: Vec<f64> = xs.iter().map(|&x| {
        assert!(x > 0.0, "loglog_slope needs positive x");
        x.ln()
    }).collect();
    let ly: Vec<f64> = ys.iter().map(|&y| {
        assert!(y > 0.0, "loglog_slope needs positive y");
        y.ln()
    }).collect();
    linear_fit(&lx, &ly)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn power_law_recovered_by_loglog() {
        // y = 3 x^1.7
        let xs: Vec<f64> = (1..=10).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 3.0 * x.powf(1.7)).collect();
        let f = loglog_slope(&xs, &ys);
        assert!((f.slope - 1.7).abs() < 1e-9, "slope {}", f.slope);
        assert!((f.intercept - 3f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn noisy_fit_r2_below_one() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [1.1, 1.9, 3.2, 3.8, 5.1];
        let f = linear_fit(&xs, &ys);
        assert!(f.r2 > 0.97 && f.r2 < 1.0);
        assert!((f.slope - 1.0).abs() < 0.1);
    }

    #[test]
    #[should_panic(expected = "at least two")]
    fn single_point_panics() {
        linear_fit(&[1.0], &[1.0]);
    }

    #[test]
    #[should_panic(expected = "positive x")]
    fn loglog_rejects_nonpositive() {
        loglog_slope(&[0.0, 1.0], &[1.0, 2.0]);
    }
}
