//! Fixed-bin histograms with terminal rendering.
//!
//! Used by the Appendix-Figure-2/3/4 experiments (distribution of true arm
//! parameters and of per-arm rewards) to print the paper's histograms as
//! ASCII bars.

/// Equal-width histogram over `[lo, hi]`.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    n: u64,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    /// Create with `bins` equal-width bins spanning `[lo, hi]`.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo, "bad histogram spec");
        Histogram { lo, hi, counts: vec![0; bins], n: 0, underflow: 0, overflow: 0 }
    }

    /// Create spanning the observed min/max of `xs`, then fill.
    pub fn fit(xs: &[f64], bins: usize) -> Self {
        assert!(!xs.is_empty());
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let hi = if hi > lo { hi } else { lo + 1.0 };
        let mut h = Histogram::new(lo, hi, bins);
        xs.iter().for_each(|&x| h.push(x));
        h
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x > self.hi {
            self.overflow += 1;
            return;
        }
        let bins = self.counts.len();
        let idx = (((x - self.lo) / (self.hi - self.lo)) * bins as f64) as usize;
        self.counts[idx.min(bins - 1)] += 1;
    }

    /// Bin counts.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total observations (including under/overflow).
    pub fn total(&self) -> u64 {
        self.n
    }

    /// Center of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// ASCII rendering: one line per bin, bars scaled to `width` chars.
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!(
                "{:>12.4} | {:<w$} {}\n",
                self.bin_center(i),
                bar,
                c,
                w = width
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_fill_correctly() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        assert!(h.counts().iter().all(|&c| c == 1));
        assert_eq!(h.total(), 10);
    }

    #[test]
    fn boundary_values() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(0.0); // first bin
        h.push(1.0); // clamped into last bin
        h.push(-0.1); // underflow
        h.push(1.1); // overflow
        assert_eq!(h.counts()[0], 1);
        assert_eq!(h.counts()[3], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
    }

    #[test]
    fn fit_spans_data() {
        let xs = [-2.0, 0.0, 4.0, 4.0];
        let h = Histogram::fit(&xs, 3);
        assert_eq!(h.total(), 4);
        assert_eq!(h.underflow + h.overflow, 0);
        assert_eq!(h.counts().iter().sum::<u64>(), 4);
    }

    #[test]
    fn render_contains_bars() {
        let h = Histogram::fit(&[1.0, 1.0, 1.0, 5.0], 2);
        let s = h.render(10);
        assert!(s.contains('#'));
        assert_eq!(s.lines().count(), 2);
    }

    #[test]
    fn constant_data_does_not_panic() {
        let h = Histogram::fit(&[3.0, 3.0, 3.0], 4);
        assert_eq!(h.total(), 3);
    }
}
