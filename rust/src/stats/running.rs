//! Numerically stable running mean/variance (Welford), the estimator behind
//! every bandit arm in Algorithm 1 (`mu_hat_x`, `sigma_hat_x`).

/// Welford running moments accumulator.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Running {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Add many observations.
    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.push(x);
        }
    }

    /// Number of observations.
    #[inline]
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 when empty).
    #[inline]
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (divide by n). 0 when n < 1.
    #[inline]
    pub fn var_pop(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (divide by n-1). 0 when n < 2.
    #[inline]
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    #[inline]
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Population standard deviation (what the paper's Eq. 11 uses for
    /// `sigma_x = STD_{y in batch} g_x(y)`).
    #[inline]
    pub fn std_pop(&self) -> f64 {
        self.var_pop().sqrt()
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        r.extend(xs.iter().copied());
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var_pop() - 4.0).abs() < 1e-12);
        assert!((r.std_pop() - 2.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        let mut r = Running::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.var(), 0.0);
        r.push(3.5);
        assert_eq!(r.mean(), 3.5);
        assert_eq!(r.var(), 0.0);
        assert_eq!(r.var_pop(), 0.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Running::new();
        whole.extend(xs.iter().copied());
        let mut a = Running::new();
        let mut b = Running::new();
        a.extend(xs[..37].iter().copied());
        b.extend(xs[37..].iter().copied());
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.var() - whole.var()).abs() < 1e-9);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = Running::new();
        a.extend([1.0, 2.0]);
        let b = Running::new();
        let mut a2 = a.clone();
        a2.merge(&b);
        assert!((a2.mean() - a.mean()).abs() < 1e-15);
        let mut c = Running::new();
        c.merge(&a);
        assert!((c.mean() - a.mean()).abs() < 1e-15);
    }

    #[test]
    fn stable_for_large_offset() {
        // Catastrophic-cancellation check: variance of tiny noise on a huge
        // offset should still be ~variance of the noise.
        let mut r = Running::new();
        for i in 0..1000 {
            r.push(1e9 + (i % 2) as f64);
        }
        assert!((r.var_pop() - 0.25).abs() < 1e-6, "var {}", r.var_pop());
    }
}
