//! Shard worker: the server side of the dist protocol.
//!
//! A worker owns one or more contiguous row shards (installed by `Load`
//! or `LoadFile`), and answers distance tiles (`Block`) and nearest-medoid
//! partials (`Score`) against them. All kernels are the exact in-process
//! ones — [`NativeBackend::block_vs`] over the shipped target rows and
//! [`assign_against`] for scoring — so every distance a worker returns is
//! bit-identical to the value the single-process path would compute
//! (pinned by `block_vs_matches_block_on_training_set`).
//!
//! No floating-point accumulation happens here: responses carry raw
//! per-pair / per-row distances, never partial sums, which is what makes
//! the coordinator's shard-order fold bitwise worker-count-invariant
//! (`rust/DIST.md`).
//!
//! Failure discipline mirrors serve: framing-level corruption kills the
//! connection ([`FrameError`] tier), body-level garbage is answered with
//! a recoverable [`Response::Error`] echoing the request id. A
//! deterministic [`FaultPlan`] can kill the worker at a pinned work
//! request (Block/Score are counted; Load/Ping are not) to exercise the
//! coordinator's recovery path.

use crate::data::stream::{CsrChunkReader, StreamOptions};
use crate::data::Points;
use crate::dist::protocol::{
    encode_response, parse_request, read_frame, BlockRequest, LoadFileRequest, LoadRequest,
    Request, Response, ScoreRequest,
};
use crate::distance::Metric;
use crate::error::{Error, Result};
use crate::runtime::backend::{assign_against, NativeBackend};
use crate::serve::faults::FaultPlan;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::path::Path;

/// Worker runtime knobs.
#[derive(Debug, Clone, Default)]
pub struct WorkerOptions {
    /// Deterministic fault plan; `should_panic` is consulted against the
    /// 1-based sequence of *work* requests (Block/Score).
    pub faults: FaultPlan,
    /// Suppress stderr chatter.
    pub quiet: bool,
}

/// How a worker loop ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerExit {
    /// Clean `Shutdown` request was acknowledged.
    Shutdown,
    /// The coordinator hung up at a frame boundary.
    Eof,
    /// The fault plan killed the worker (writer dropped, no ack).
    Killed,
}

/// One installed shard: the rows, their metric, and the precomputed
/// per-row norm table `block_vs` kernels consume.
struct ShardState {
    metric: Metric,
    points: Points,
    norms: Vec<f64>,
}

impl ShardState {
    fn install(metric: Metric, points: Points) -> std::result::Result<ShardState, String> {
        if matches!(points, Points::Trees(_)) {
            return Err("tree shards are not supported over the wire".into());
        }
        if !metric.supports(&points) {
            return Err(format!("metric {} does not support {} points", metric.name(), points.kind()));
        }
        let norms = NativeBackend::norms_for(metric, &points);
        Ok(ShardState { metric, points, norms })
    }
}

/// Serve one connection: read request frames from `r`, answer on `w`.
///
/// Returns how the loop ended; framing-tier corruption is the only error
/// path. Dropping the writer (on `Killed` or return) is what the
/// coordinator observes as worker death.
pub fn run_worker(mut r: impl Read, mut w: impl Write, opts: &WorkerOptions) -> Result<WorkerExit> {
    let mut shards: HashMap<u32, ShardState> = HashMap::new();
    let mut work_seq: u64 = 0;
    loop {
        let (kind, body) = match read_frame(&mut r) {
            Ok(Some(frame)) => frame,
            Ok(None) => return Ok(WorkerExit::Eof),
            Err(e) => return Err(Error::data(format!("dist worker: fatal frame error: {e}"))),
        };
        let req = match parse_request(kind, &body) {
            Ok(req) => req,
            Err(fail) => {
                let resp = Response::Error { id: fail.id, message: fail.message };
                w.write_all(&encode_response(&resp))?;
                w.flush()?;
                continue;
            }
        };
        if matches!(req, Request::Block(_) | Request::Score(_)) {
            work_seq += 1;
            if let Some(delay) = opts.faults.stall() {
                std::thread::sleep(delay);
            }
            if opts.faults.should_panic(work_seq) {
                if !opts.quiet {
                    eprintln!("dist worker: injected kill at work request {work_seq}");
                }
                return Ok(WorkerExit::Killed);
            }
        }
        let shutdown = matches!(req, Request::Shutdown { .. });
        let resp = handle(&mut shards, req);
        w.write_all(&encode_response(&resp))?;
        w.flush()?;
        if shutdown {
            return Ok(WorkerExit::Shutdown);
        }
    }
}

/// TCP mode (`worker --listen addr`): serve connections one at a time,
/// forever. Each connection gets fresh shard state and a fresh fault
/// sequence, so reconnect-after-kill behaves deterministically.
pub fn listen_tcp(addr: &str, opts: &WorkerOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::invalid_argument(format!("dist worker: binding {addr}: {e}")))?;
    if !opts.quiet {
        eprintln!(
            "dist worker listening on {}",
            listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.to_string())
        );
    }
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) => {
                if !opts.quiet {
                    eprintln!("dist worker: accept failed: {e}");
                }
                continue;
            }
        };
        stream.set_nodelay(true).ok();
        let write_half = match stream.try_clone() {
            Ok(half) => half,
            Err(_) => continue,
        };
        match run_worker(stream, write_half, opts) {
            Ok(exit) => {
                if !opts.quiet {
                    eprintln!("dist worker: connection from {peer} ended: {exit:?}");
                }
            }
            Err(e) => {
                if !opts.quiet {
                    eprintln!("dist worker: connection from {peer} failed: {}", e.message());
                }
            }
        }
    }
}

fn handle(shards: &mut HashMap<u32, ShardState>, req: Request) -> Response {
    match req {
        Request::Load(r) => handle_load(shards, r),
        Request::LoadFile(r) => handle_load_file(shards, r),
        Request::Block(r) => handle_block(shards, r),
        Request::Score(r) => handle_score(shards, r),
        Request::Ping { id } => Response::Pong { id },
        Request::Shutdown { id } => Response::ShutdownAck { id },
    }
}

fn handle_load(shards: &mut HashMap<u32, ShardState>, r: LoadRequest) -> Response {
    let LoadRequest { id, shard, metric, points } = r;
    let rows = points.len() as u64;
    match ShardState::install(metric, points) {
        Ok(state) => {
            // Re-Load of a live shard id replaces it: loads are idempotent
            // so the coordinator can retry them blindly.
            shards.insert(shard, state);
            Response::Loaded { id, shard, rows }
        }
        Err(message) => Response::Error { id, message },
    }
}

fn handle_load_file(shards: &mut HashMap<u32, ShardState>, r: LoadFileRequest) -> Response {
    let LoadFileRequest { id, shard, metric, start_row, end_row, chunk_nnz, path } = r;
    match read_file_window(&path, start_row, end_row, chunk_nnz) {
        Ok(points) => {
            let rows = points.len() as u64;
            match ShardState::install(metric, points) {
                Ok(state) => {
                    shards.insert(shard, state);
                    Response::Loaded { id, shard, rows }
                }
                Err(message) => Response::Error { id, message },
            }
        }
        Err(message) => Response::Error { id, message },
    }
}

/// Read rows `[start_row, end_row)` of an `.mtx` file through the
/// bounded-memory window reader, splicing window slices into one shard
/// CSR. Peak memory is the shard plus one in-flight window.
fn read_file_window(
    path: &str,
    start_row: u64,
    end_row: u64,
    chunk_nnz: u64,
) -> std::result::Result<Points, String> {
    let start = usize::try_from(start_row).map_err(|_| "start row exceeds address space")?;
    let end = usize::try_from(end_row).map_err(|_| "end row exceeds address space")?;
    let opts = StreamOptions {
        chunk_nnz: usize::try_from(chunk_nnz).unwrap_or(usize::MAX).max(1),
        // `limit` caps total rows read, so the reader stops at the window
        // end instead of scanning the whole file.
        limit: end,
        ..StreamOptions::default()
    };
    let mut reader = CsrChunkReader::open(Path::new(path), opts)
        .map_err(|e| format!("opening shard file {path}: {}", e.message()))?;
    if end > reader.rows() {
        return Err(format!(
            "shard window [{start}, {end}) exceeds file rows {}",
            reader.rows()
        ));
    }
    let cols = reader.cols();
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    while let Some(window) = reader
        .next_window()
        .map_err(|e| format!("reading shard file {path}: {}", e.message()))?
    {
        let wstart = window.start_row;
        let wend = wstart + window.matrix.rows();
        if wend <= start {
            continue;
        }
        if wstart >= end {
            break;
        }
        let lo = start.max(wstart);
        let hi = end.min(wend);
        let (ip, ix, vs) = window.matrix.parts();
        for row in (lo - wstart)..(hi - wstart) {
            let (a, b) = (ip[row], ip[row + 1]);
            indices.extend_from_slice(&ix[a..b]);
            values.extend_from_slice(&vs[a..b]);
            indptr.push(indices.len());
        }
    }
    let rows = indptr.len() - 1;
    if rows != end - start {
        return Err(format!(
            "shard window [{start}, {end}) produced {rows} rows (file shorter than claimed)"
        ));
    }
    let matrix = crate::data::sparse::CsrMatrix::try_from_parts(rows, cols, indptr, indices, values)
        .map_err(|e| format!("spliced shard window is not valid CSR: {e}"))?;
    Ok(Points::Sparse(matrix))
}

fn handle_block(shards: &mut HashMap<u32, ShardState>, r: BlockRequest) -> Response {
    let BlockRequest { id, shard, targets, refs } = r;
    let Some(state) = shards.get(&shard) else {
        return Response::Error { id, message: format!("unknown shard {shard}") };
    };
    if targets.is_empty() || refs.is_empty() {
        return Response::Distances { id, shard, evals: 0, dists: Vec::new() };
    }
    if targets.kind() != state.points.kind() {
        return Response::Error {
            id,
            message: format!(
                "target storage {} does not match shard storage {}",
                targets.kind(),
                state.points.kind()
            ),
        };
    }
    if targets.dim() != state.points.dim() {
        return Response::Error {
            id,
            message: format!(
                "target dim {} does not match shard dim {}",
                targets.dim(),
                state.points.dim()
            ),
        };
    }
    let rows = state.points.len();
    if let Some(bad) = refs.iter().find(|&&j| j as usize >= rows) {
        return Response::Error {
            id,
            message: format!("ref index {bad} out of range for shard with {rows} rows"),
        };
    }
    // The shipped target rows become their own backend; `block_vs` against
    // the shard rows runs the exact kernels the one-process path uses.
    let backend = NativeBackend::new(&targets, state.metric);
    let tidx: Vec<usize> = (0..targets.len()).collect();
    let local: Vec<usize> = refs.iter().map(|&j| j as usize).collect();
    let mut dists = vec![0.0f64; targets.len() * local.len()];
    backend.block_vs(&tidx, &state.points, &state.norms, &local, &mut dists);
    let evals = backend.counter().get();
    Response::Distances { id, shard, evals, dists }
}

fn handle_score(shards: &mut HashMap<u32, ShardState>, r: ScoreRequest) -> Response {
    let ScoreRequest { id, shard, medoids } = r;
    let Some(state) = shards.get(&shard) else {
        return Response::Error { id, message: format!("unknown shard {shard}") };
    };
    if medoids.is_empty() {
        return Response::Error { id, message: "empty medoid set".into() };
    }
    if medoids.kind() != state.points.kind() || medoids.dim() != state.points.dim() {
        return Response::Error {
            id,
            message: format!(
                "medoid payload {}x{} does not match shard {}x{}",
                medoids.kind(),
                medoids.dim(),
                state.points.kind(),
                state.points.dim()
            ),
        };
    }
    let backend = NativeBackend::new(&medoids, state.metric);
    let (assign, dists) = assign_against(&backend, &state.points);
    let evals = backend.counter().get();
    let assign: Vec<u32> = assign.into_iter().map(|a| a as u32).collect();
    Response::ScorePartial { id, shard, evals, assign, dists }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::dist::protocol::{encode_request, parse_response, ScoreRequest};
    use crate::runtime::backend::{loss_and_assignments, DistanceBackend};
    use crate::util::rng::Rng;

    fn run(frames: &[Request], opts: &WorkerOptions) -> (Vec<Response>, WorkerExit) {
        let mut input = Vec::new();
        for req in frames {
            input.extend_from_slice(&encode_request(req));
        }
        let mut out = Vec::new();
        let exit = run_worker(&input[..], &mut out, opts).unwrap();
        let mut responses = Vec::new();
        let mut r = &out[..];
        while let Some((kind, body)) = read_frame(&mut r).unwrap() {
            responses.push(parse_response(kind, &body).unwrap());
        }
        (responses, exit)
    }

    #[test]
    fn load_block_score_shutdown_round_trip() {
        let data = synthetic::gmm(&mut Rng::seed_from(7), 20, 4, 3, 2.0);
        let shard = data.points.select(&(5..15).collect::<Vec<_>>());
        let targets = data.points.select(&[0, 1]);
        let frames = vec![
            Request::Load(LoadRequest { id: 1, shard: 0, metric: Metric::L2, points: shard }),
            Request::Block(BlockRequest {
                id: 2,
                shard: 0,
                targets: targets.clone(),
                refs: vec![0, 3, 9],
            }),
            Request::Score(ScoreRequest { id: 3, shard: 0, medoids: targets }),
            Request::Shutdown { id: 4 },
        ];
        let (responses, exit) = run(&frames, &WorkerOptions::default());
        assert_eq!(exit, WorkerExit::Shutdown);
        assert_eq!(responses.len(), 4);
        assert_eq!(responses[0], Response::Loaded { id: 1, shard: 0, rows: 10 });
        let Response::Distances { evals, dists, .. } = &responses[1] else {
            panic!("expected distances, got {:?}", responses[1])
        };
        assert_eq!(*evals, 6);
        assert_eq!(dists.len(), 6);
        // Bitwise parity with the direct in-process block on the same rows.
        let backend = NativeBackend::new(&data.points, Metric::L2);
        let mut want = vec![0.0f64; 6];
        backend.block(&[0, 1], &[5, 8, 14], &mut want);
        assert_eq!(
            dists.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|d| d.to_bits()).collect::<Vec<_>>()
        );
        let Response::ScorePartial { assign, dists, evals, .. } = &responses[2] else {
            panic!("expected score partial, got {:?}", responses[2])
        };
        assert_eq!(assign.len(), 10);
        assert_eq!(dists.len(), 10);
        assert_eq!(*evals, 20);
        assert_eq!(responses[3], Response::ShutdownAck { id: 4 });
    }

    #[test]
    fn score_partial_matches_loss_and_assignments_per_row() {
        let data = synthetic::gmm(&mut Rng::seed_from(11), 24, 5, 3, 2.5);
        let medoid_rows = [2usize, 7, 19];
        let medoids = data.points.select(&medoid_rows);
        let frames = vec![
            Request::Load(LoadRequest {
                id: 1,
                shard: 0,
                metric: Metric::L1,
                points: data.points.clone(),
            }),
            Request::Score(ScoreRequest { id: 2, shard: 0, medoids }),
        ];
        let (responses, _) = run(&frames, &WorkerOptions::default());
        let Response::ScorePartial { assign, dists, .. } = &responses[1] else {
            panic!("expected score partial")
        };
        let backend = NativeBackend::new(&data.points, Metric::L1);
        let (want_loss, want_assign) = loss_and_assignments(&backend, &medoid_rows);
        assert_eq!(assign.iter().map(|&a| a as usize).collect::<Vec<_>>(), want_assign);
        let mut loss = 0.0f64;
        for d in dists {
            loss += d;
        }
        assert_eq!(loss.to_bits(), want_loss.to_bits());
    }

    #[test]
    fn body_garbage_is_answered_and_the_connection_survives() {
        let ping = encode_request(&Request::Ping { id: 2 });
        // Unknown request kind, then a healthy ping on the same stream.
        let mut input = encode_request(&Request::Ping { id: 1 });
        input[3] = 0x7E; // unknown kind; body stays a valid id
        input.extend_from_slice(&ping);
        let mut out = Vec::new();
        let exit = run_worker(&input[..], &mut out, &WorkerOptions::default()).unwrap();
        assert_eq!(exit, WorkerExit::Eof);
        let mut r = &out[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        let Response::Error { id, .. } = parse_response(kind, &body).unwrap() else {
            panic!("expected error response")
        };
        assert_eq!(id, 1);
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(parse_response(kind, &body).unwrap(), Response::Pong { id: 2 });
    }

    #[test]
    fn fault_plan_kills_at_the_pinned_work_request_without_ack() {
        let data = synthetic::gmm(&mut Rng::seed_from(3), 12, 4, 2, 2.0);
        let medoids = data.points.select(&[0, 5]);
        let frames = vec![
            Request::Load(LoadRequest {
                id: 1,
                shard: 0,
                metric: Metric::L2,
                points: data.points.clone(),
            }),
            Request::Score(ScoreRequest { id: 2, shard: 0, medoids: medoids.clone() }),
            Request::Score(ScoreRequest { id: 3, shard: 0, medoids }),
        ];
        let opts = WorkerOptions {
            faults: FaultPlan { panic_on_batches: vec![2], ..Default::default() },
            quiet: true,
        };
        let (responses, exit) = run(&frames, &opts);
        assert_eq!(exit, WorkerExit::Killed);
        // Load + first score answered; the second work request dies silently.
        assert_eq!(responses.len(), 2);
        assert_eq!(responses[0], Response::Loaded { id: 1, shard: 0, rows: 12 });
        assert!(matches!(responses[1], Response::ScorePartial { id: 2, .. }));
    }

    #[test]
    fn unknown_shard_and_bad_refs_are_recoverable_errors() {
        let data = synthetic::gmm(&mut Rng::seed_from(5), 8, 3, 2, 2.0);
        let targets = data.points.select(&[0]);
        let frames = vec![
            Request::Block(BlockRequest {
                id: 1,
                shard: 9,
                targets: targets.clone(),
                refs: vec![0],
            }),
            Request::Load(LoadRequest {
                id: 2,
                shard: 0,
                metric: Metric::L2,
                points: data.points.clone(),
            }),
            Request::Block(BlockRequest { id: 3, shard: 0, targets, refs: vec![99] }),
        ];
        let (responses, _) = run(&frames, &WorkerOptions::default());
        assert!(matches!(&responses[0], Response::Error { id: 1, message } if message.contains("unknown shard")));
        assert!(matches!(responses[1], Response::Loaded { .. }));
        assert!(matches!(&responses[2], Response::Error { id: 3, message } if message.contains("out of range")));
    }
}
