//! Coordinator side of the dist subsystem: the [`WorkerPool`] scheduler
//! and the [`ShardedBackend`] that plugs it into any
//! [`crate::algorithms::KMedoids`] fit.
//!
//! ## Bitwise parity
//!
//! Workers never sum anything. A `Block` response carries raw per-pair
//! distances and a `Score` response carries per-row (nearest medoid,
//! distance) pairs; the coordinator folds them **in shard order**, which
//! is global row order because shards are contiguous ascending row
//! ranges. The loss accumulator therefore adds the exact same `f64`
//! values in the exact same sequence as the single-process fold, and the
//! strict-`<` first-minimum runs worker-side over the same medoid order —
//! so N workers produce bit-identical medoids/assignments/loss to one
//! process (`rust/DIST.md` has the full argument).
//!
//! ## Robustness
//!
//! Every request has a deadline and an idempotent id. Worker death
//! (EOF, frame corruption, timeout budget exhausted) triggers recovery:
//! spawned children and TCP peers are respawned/reconnected and their
//! shards re-loaded; in-memory pipe transports have their shards
//! reassigned to a surviving worker. Retried requests reuse their id, so
//! a duplicate answer from a slow-but-alive worker is indistinguishable
//! from the retry's (deterministic workers return identical bytes).
//! If the pool cannot recover, [`ShardedBackend`] falls back to local
//! evaluation — degraded, never wrong.

use crate::data::Points;
use crate::dist::protocol::{
    encode_request, parse_response, read_frame, BlockRequest, LoadRequest, Request, Response,
    ScoreRequest,
};
use crate::distance::counter::DistanceCounter;
use crate::distance::Metric;
use crate::error::{Error, Result};
use crate::obs::{Counter, Histogram, TraceSink, TraceValue};
use crate::runtime::backend::{DistanceBackend, NativeBackend};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Per-request deadline; a worker that misses it `max_retries` times
    /// is declared dead.
    pub deadline: Duration,
    /// Recovery budget per request (timeouts + worker deaths) before the
    /// request errors out and the caller falls back to local compute.
    pub max_retries: u32,
    /// Worker binary for `spawn_local` (defaults to the current
    /// executable; tests point it at `CARGO_BIN_EXE_banditpam`).
    pub program: Option<PathBuf>,
    /// Extra CLI args for spawned workers (deterministic fault
    /// injection: `--inject-exit-on N`, `--stall-ms N`, ...).
    pub worker_args: Vec<String>,
}

impl Default for PoolOptions {
    fn default() -> PoolOptions {
        PoolOptions {
            deadline: Duration::from_secs(30),
            max_retries: 3,
            program: None,
            worker_args: Vec::new(),
        }
    }
}

/// What a dead worker gets replaced with.
enum WorkerKind {
    /// Locally spawned child over stdio pipes: respawn on death.
    Child { child: Child },
    /// In-memory transport (tests/benches): shards reassign to survivors.
    Pipe,
    /// Remote TCP worker: reconnect on death.
    Tcp { addr: String },
}

enum Event {
    Frame(u8, Vec<u8>),
    Closed(String),
}

struct WorkerHandle {
    writer: Option<Box<dyn Write + Send>>,
    events: Receiver<Event>,
    reader: Option<JoinHandle<()>>,
    /// Parsed responses whose id didn't match the active wait (other
    /// in-flight requests on this worker, or duplicates after a retry).
    stash: Vec<Response>,
    kind: WorkerKind,
    alive: bool,
}

impl WorkerHandle {
    fn new(
        writer: Box<dyn Write + Send>,
        reader: impl Read + Send + 'static,
        kind: WorkerKind,
    ) -> WorkerHandle {
        let (tx, rx) = mpsc::channel();
        let handle = thread::Builder::new()
            .name("dist-reader".into())
            .spawn(move || {
                let mut reader = reader;
                loop {
                    match read_frame(&mut reader) {
                        Ok(Some((kind, body))) => {
                            if tx.send(Event::Frame(kind, body)).is_err() {
                                return;
                            }
                        }
                        Ok(None) => {
                            let _ = tx.send(Event::Closed("worker EOF".into()));
                            return;
                        }
                        Err(e) => {
                            let _ = tx.send(Event::Closed(format!("worker stream corrupt: {e}")));
                            return;
                        }
                    }
                }
            })
            .expect("spawning dist reader thread");
        WorkerHandle {
            writer: Some(writer),
            events: rx,
            reader: Some(handle),
            stash: Vec::new(),
            kind,
            alive: true,
        }
    }

    fn send(&mut self, frame: &[u8]) -> std::result::Result<(), String> {
        let Some(w) = self.writer.as_mut() else {
            return Err("writer already closed".into());
        };
        w.write_all(frame).and_then(|_| w.flush()).map_err(|e| format!("worker write: {e}"))
    }
}

enum Wait {
    Got(Response),
    Dead(String),
    Timeout,
}

fn wait_response(worker: &mut WorkerHandle, id: u64, deadline: Duration) -> Wait {
    if let Some(i) = worker.stash.iter().position(|r| r.id() == id) {
        return Wait::Got(worker.stash.remove(i));
    }
    let until = Instant::now() + deadline;
    loop {
        let now = Instant::now();
        if now >= until {
            return Wait::Timeout;
        }
        match worker.events.recv_timeout(until - now) {
            Ok(Event::Frame(kind, body)) => match parse_response(kind, &body) {
                Ok(resp) if resp.id() == id => return Wait::Got(resp),
                Ok(resp) => worker.stash.push(resp),
                Err(e) => return Wait::Dead(format!("unparseable worker response: {e}")),
            },
            Ok(Event::Closed(reason)) => return Wait::Dead(reason),
            Err(RecvTimeoutError::Timeout) => return Wait::Timeout,
            Err(RecvTimeoutError::Disconnected) => return Wait::Dead("reader thread gone".into()),
        }
    }
}

struct PoolInner {
    workers: Vec<WorkerHandle>,
    /// shard index -> worker index.
    owner: Vec<usize>,
    next_id: u64,
}

impl PoolInner {
    fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }
}

/// One in-flight request for one shard.
struct Pending {
    shard: usize,
    req: Request,
    attempts: u32,
    started: Instant,
}

/// A fleet of shard workers plus the scheduling/recovery logic to drive
/// them. Holds the full dataset so it can (re)load shards on spawn,
/// respawn and reassignment.
pub struct WorkerPool<'d> {
    points: &'d Points,
    metric: Metric,
    /// Contiguous ascending row ranges, one per shard: shard order is
    /// global row order, which the parity argument relies on.
    shards: Vec<(usize, usize)>,
    opts: PoolOptions,
    inner: Mutex<PoolInner>,
    retries: AtomicU64,
    respawns: AtomicU64,
    fallbacks: AtomicU64,
    obs_requests: Arc<Counter>,
    obs_retries: Arc<Counter>,
    obs_respawns: Arc<Counter>,
    obs_shard_us: Arc<Histogram>,
    trace: Mutex<Option<Arc<TraceSink>>>,
}

/// Contiguous even row split: shard `i` of `s` owns `[i*n/s, (i+1)*n/s)`.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let s = shards.max(1);
    (0..s).map(|i| (i * n / s, (i + 1) * n / s)).collect()
}

impl<'d> WorkerPool<'d> {
    /// Spawn `workers` local children of this binary (`worker --stdio`)
    /// over stdio pipes, one shard each, and load the shards.
    pub fn spawn_local(
        points: &'d Points,
        metric: Metric,
        workers: usize,
        opts: PoolOptions,
    ) -> Result<WorkerPool<'d>> {
        let workers = workers.max(1).min(points.len().max(1));
        let program = match &opts.program {
            Some(p) => p.clone(),
            None => std::env::current_exe()
                .map_err(|e| Error::data(format!("dist: locating worker binary: {e}")))?,
        };
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(spawn_child(&program, &opts.worker_args)?);
        }
        let mut opts = opts;
        opts.program = Some(program);
        WorkerPool::assemble(points, metric, opts, handles)
    }

    /// Build a pool over caller-provided transports (in-memory pipes in
    /// tests/benches; the worker end runs [`super::worker::run_worker`]
    /// on its own thread). Transport workers cannot be respawned — their
    /// shards reassign to survivors on death.
    #[allow(clippy::type_complexity)]
    pub fn from_transports(
        points: &'d Points,
        metric: Metric,
        transports: Vec<(Box<dyn Write + Send>, Box<dyn Read + Send>)>,
        opts: PoolOptions,
    ) -> Result<WorkerPool<'d>> {
        if transports.is_empty() {
            return Err(Error::invalid_argument("dist: at least one worker transport required"));
        }
        let handles = transports
            .into_iter()
            .map(|(w, r)| WorkerHandle::new(w, r, WorkerKind::Pipe))
            .collect();
        WorkerPool::assemble(points, metric, opts, handles)
    }

    /// Connect to remote workers (`worker --listen host:port`), one
    /// shard per host.
    pub fn connect_tcp(
        points: &'d Points,
        metric: Metric,
        hosts: &[String],
        opts: PoolOptions,
    ) -> Result<WorkerPool<'d>> {
        if hosts.is_empty() {
            return Err(Error::invalid_argument("dist: at least one worker host required"));
        }
        let mut handles = Vec::with_capacity(hosts.len());
        for addr in hosts {
            handles.push(connect_worker(addr)?);
        }
        WorkerPool::assemble(points, metric, opts, handles)
    }

    fn assemble(
        points: &'d Points,
        metric: Metric,
        opts: PoolOptions,
        handles: Vec<WorkerHandle>,
    ) -> Result<WorkerPool<'d>> {
        if matches!(points, Points::Trees(_)) || metric == Metric::TreeEdit {
            return Err(Error::unsupported("dist: tree points/metrics have no wire form"));
        }
        let shards = shard_ranges(points.len(), handles.len());
        let owner = (0..shards.len()).collect();
        let obs = crate::obs::global();
        let pool = WorkerPool {
            points,
            metric,
            shards,
            opts,
            inner: Mutex::new(PoolInner { workers: handles, owner, next_id: 0 }),
            retries: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
            obs_requests: obs.counter("dist_requests_total"),
            obs_retries: obs.counter("dist_retries_total"),
            obs_respawns: obs.counter("dist_respawns_total"),
            obs_shard_us: obs.histogram("dist_shard_us"),
            trace: Mutex::new(None),
        };
        {
            let mut inner = pool.inner.lock().unwrap();
            for shard in 0..pool.shards.len() {
                pool.load_shard(&mut inner, shard)?;
            }
        }
        Ok(pool)
    }

    /// Number of workers (== shards).
    pub fn n_workers(&self) -> usize {
        self.inner.lock().unwrap().workers.len()
    }

    /// Shard layout (contiguous ascending row ranges).
    pub fn shards(&self) -> &[(usize, usize)] {
        &self.shards
    }

    /// Total rows the pool shards over.
    pub fn n_rows(&self) -> usize {
        self.points.len()
    }

    /// The pool's metric.
    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Request retries performed (timeouts + deaths), for tests.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Workers respawned/reconnected or shards reassigned, for tests.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Times the caller had to fall back to local evaluation.
    pub fn fallbacks(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Attach a trace sink: per-shard request spans (`dist_shard`
    /// events) land in `--trace-out`.
    pub fn set_trace(&self, sink: Option<Arc<TraceSink>>) {
        *self.trace.lock().unwrap() = sink;
    }

    pub(crate) fn note_fallback(&self) {
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Ping every worker (health check; used by the CLI after spawn).
    pub fn ping(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        for w in 0..inner.workers.len() {
            let id = inner.fresh_id();
            let frame = encode_request(&Request::Ping { id });
            if let Err(e) = inner.workers[w].send(&frame) {
                return Err(Error::data(format!("dist: worker {w} unreachable: {e}")));
            }
            match wait_response(&mut inner.workers[w], id, self.opts.deadline) {
                Wait::Got(Response::Pong { .. }) => {}
                Wait::Got(other) => {
                    return Err(Error::data(format!("dist: worker {w} bad pong: {other:?}")))
                }
                Wait::Dead(reason) => {
                    return Err(Error::data(format!("dist: worker {w} died: {reason}")))
                }
                Wait::Timeout => {
                    return Err(Error::data(format!("dist: worker {w} ping timed out")))
                }
            }
        }
        Ok(())
    }

    /// (Re)load `shard` onto its current owner: ship the rows, await the
    /// `Loaded` ack. Loads are idempotent, so recovery can replay them.
    fn load_shard(&self, inner: &mut PoolInner, shard: usize) -> Result<()> {
        let (start, end) = self.shards[shard];
        let idx: Vec<usize> = (start..end).collect();
        let points = self.points.select(&idx);
        let id = inner.fresh_id();
        let req = Request::Load(LoadRequest { id, shard: shard as u32, metric: self.metric, points });
        let frame = encode_request(&req);
        let w = inner.owner[shard];
        if let Err(e) = inner.workers[w].send(&frame) {
            inner.workers[w].alive = false;
            return Err(Error::data(format!("dist: loading shard {shard}: {e}")));
        }
        match wait_response(&mut inner.workers[w], id, self.opts.deadline) {
            Wait::Got(Response::Loaded { rows, .. }) => {
                let want = (end - start) as u64;
                if rows != want {
                    return Err(Error::data(format!(
                        "dist: shard {shard} loaded {rows} rows, expected {want}"
                    )));
                }
                Ok(())
            }
            Wait::Got(Response::Error { message, .. }) => {
                Err(Error::data(format!("dist: worker rejected shard {shard}: {message}")))
            }
            Wait::Got(other) => {
                Err(Error::data(format!("dist: loading shard {shard}: bad response {other:?}")))
            }
            Wait::Dead(reason) => {
                inner.workers[w].alive = false;
                Err(Error::data(format!("dist: loading shard {shard}: worker died: {reason}")))
            }
            Wait::Timeout => {
                inner.workers[w].alive = false;
                Err(Error::data(format!("dist: loading shard {shard}: timed out")))
            }
        }
    }

    /// Replace or retire a dead worker and re-home every shard it owned.
    fn recover(&self, inner: &mut PoolInner, dead: usize) -> Result<()> {
        enum Plan {
            Respawn(PathBuf),
            Reconnect(String),
            Reassign,
        }
        inner.workers[dead].alive = false;
        let plan = match &inner.workers[dead].kind {
            WorkerKind::Child { .. } => Plan::Respawn(
                self.opts.program.clone().expect("child pools always record their program"),
            ),
            WorkerKind::Tcp { addr } => Plan::Reconnect(addr.clone()),
            WorkerKind::Pipe => Plan::Reassign,
        };
        let revived = match plan {
            Plan::Respawn(program) => {
                // Reap the corpse before replacing it.
                if let WorkerKind::Child { child } = &mut inner.workers[dead].kind {
                    child.kill().ok();
                    child.wait().ok();
                }
                match spawn_child(&program, &self.opts.worker_args) {
                    Ok(handle) => {
                        inner.workers[dead] = handle;
                        true
                    }
                    Err(_) => false,
                }
            }
            Plan::Reconnect(addr) => match connect_worker(&addr) {
                Ok(handle) => {
                    inner.workers[dead] = handle;
                    true
                }
                Err(_) => false,
            },
            Plan::Reassign => false,
        };
        if revived {
            self.respawns.fetch_add(1, Ordering::Relaxed);
            self.obs_respawns.inc();
            let owned: Vec<usize> =
                (0..self.shards.len()).filter(|&s| inner.owner[s] == dead).collect();
            for shard in owned {
                self.load_shard(inner, shard)?;
            }
            return Ok(());
        }
        // No respawn possible: reassign the dead worker's shards to the
        // first survivor.
        let Some(survivor) = inner.workers.iter().position(|w| w.alive) else {
            return Err(Error::data("dist: all workers dead, cannot recover"));
        };
        self.respawns.fetch_add(1, Ordering::Relaxed);
        self.obs_respawns.inc();
        let owned: Vec<usize> =
            (0..self.shards.len()).filter(|&s| inner.owner[s] == dead).collect();
        for shard in owned {
            inner.owner[shard] = survivor;
            self.load_shard(inner, shard)?;
        }
        Ok(())
    }

    fn send_pending(&self, inner: &mut PoolInner, p: &Pending) {
        self.obs_requests.inc();
        let w = inner.owner[p.shard];
        if !inner.workers[w].alive {
            return; // collect() will recover first
        }
        let frame = encode_request(&p.req);
        if inner.workers[w].send(&frame).is_err() {
            inner.workers[w].alive = false;
        }
    }

    /// Drive one pending request to a response, recovering through
    /// worker deaths and timeouts. Retries reuse the request id
    /// (idempotent), so duplicate answers are harmless.
    fn collect(&self, inner: &mut PoolInner, p: &mut Pending) -> Result<Response> {
        loop {
            let w = inner.owner[p.shard];
            if !inner.workers[w].alive {
                self.bump_retry(p)?;
                self.recover(inner, w)?;
                self.send_pending(inner, p);
                continue;
            }
            match wait_response(&mut inner.workers[w], p.req.id(), self.opts.deadline) {
                Wait::Got(Response::Error { message, .. }) => {
                    return Err(Error::data(format!(
                        "dist: worker rejected request for shard {}: {message}",
                        p.shard
                    )));
                }
                Wait::Got(resp) => {
                    let elapsed = p.started.elapsed();
                    self.obs_shard_us.record_duration(elapsed);
                    if let Some(sink) = self.trace.lock().unwrap().as_ref() {
                        sink.emit(
                            "dist_shard",
                            &[
                                ("shard", TraceValue::from(p.shard)),
                                ("worker", TraceValue::from(w)),
                                ("kind", TraceValue::from(request_kind(&p.req))),
                                ("us", TraceValue::from(elapsed.as_micros() as u64)),
                                ("attempts", TraceValue::from(u64::from(p.attempts) + 1)),
                            ],
                        );
                    }
                    return Ok(resp);
                }
                Wait::Dead(_) => {
                    inner.workers[w].alive = false;
                    self.bump_retry(p)?;
                    self.recover(inner, w)?;
                    self.send_pending(inner, p);
                }
                Wait::Timeout => {
                    self.bump_retry(p)?;
                    // The worker may be stalled rather than dead: resend
                    // once with the same id; a second timeout on the same
                    // request declares it dead.
                    if p.attempts >= 2 {
                        inner.workers[w].alive = false;
                    } else {
                        self.send_pending(inner, p);
                    }
                }
            }
        }
    }

    fn bump_retry(&self, p: &mut Pending) -> Result<()> {
        p.attempts += 1;
        if p.attempts > self.opts.max_retries {
            return Err(Error::data(format!(
                "dist: request for shard {} exhausted its retry budget ({})",
                p.shard, self.opts.max_retries
            )));
        }
        self.retries.fetch_add(1, Ordering::Relaxed);
        self.obs_retries.inc();
        Ok(())
    }

    /// Sharded distance block with single-process bit parity:
    /// `out[t * refs.len() + r] = d(targets[t], refs[r])`, evals added to
    /// `counter` only on full success (so a failed attempt stays
    /// side-effect free and the caller can fall back cleanly).
    pub fn block(
        &self,
        targets: &[usize],
        refs: &[usize],
        counter: &DistanceCounter,
        out: &mut [f64],
    ) -> Result<()> {
        assert_eq!(out.len(), targets.len() * refs.len(), "dist block shape mismatch");
        if targets.is_empty() || refs.is_empty() {
            return Ok(());
        }
        let target_points = self.points.select(targets);
        // Group refs by owning shard, preserving encounter order and the
        // original output positions (refs can be any permutation slice).
        let mut groups: BTreeMap<usize, (Vec<u32>, Vec<usize>)> = BTreeMap::new();
        for (pos, &r) in refs.iter().enumerate() {
            let shard = self.shard_of(r);
            let (start, _) = self.shards[shard];
            let entry = groups.entry(shard).or_default();
            entry.0.push((r - start) as u32);
            entry.1.push(pos);
        }
        let mut inner = self.inner.lock().unwrap();
        let mut pendings: Vec<Pending> = groups
            .iter()
            .map(|(&shard, (locals, _))| {
                let id = inner.fresh_id();
                Pending {
                    shard,
                    req: Request::Block(BlockRequest {
                        id,
                        shard: shard as u32,
                        targets: target_points.clone(),
                        refs: locals.clone(),
                    }),
                    attempts: 0,
                    started: Instant::now(),
                }
            })
            .collect();
        for p in &pendings {
            self.send_pending(&mut inner, p);
        }
        let mut evals_total = 0u64;
        let tn = targets.len();
        let rn = refs.len();
        for p in &mut pendings {
            let (locals, positions) = &groups[&p.shard];
            let resp = self.collect(&mut inner, p)?;
            let Response::Distances { evals, dists, .. } = resp else {
                return Err(Error::data(format!(
                    "dist: shard {} answered a block with the wrong frame",
                    p.shard
                )));
            };
            if dists.len() != tn * locals.len() {
                return Err(Error::data(format!(
                    "dist: shard {} block returned {} distances, expected {}",
                    p.shard,
                    dists.len(),
                    tn * locals.len()
                )));
            }
            for ti in 0..tn {
                let row = &dists[ti * locals.len()..(ti + 1) * locals.len()];
                for (j, &pos) in positions.iter().enumerate() {
                    out[ti * rn + pos] = row[j];
                }
            }
            evals_total += evals;
        }
        counter.add(evals_total);
        Ok(())
    }

    /// Sharded `loss_and_assignments`: ship the medoid rows to every
    /// shard, fold the per-row partials in shard (== global row) order.
    pub fn score(
        &self,
        medoid_points: &Points,
        counter: &DistanceCounter,
    ) -> Result<(f64, Vec<usize>)> {
        let n = self.points.len();
        let mut inner = self.inner.lock().unwrap();
        let mut pendings: Vec<Pending> = (0..self.shards.len())
            .map(|shard| {
                let id = inner.fresh_id();
                Pending {
                    shard,
                    req: Request::Score(ScoreRequest {
                        id,
                        shard: shard as u32,
                        medoids: medoid_points.clone(),
                    }),
                    attempts: 0,
                    started: Instant::now(),
                }
            })
            .collect();
        for p in &pendings {
            self.send_pending(&mut inner, p);
        }
        let mut loss = 0.0f64;
        let mut assignments = vec![0usize; n];
        let mut evals_total = 0u64;
        for p in &mut pendings {
            let (start, end) = self.shards[p.shard];
            let resp = self.collect(&mut inner, p)?;
            let Response::ScorePartial { evals, assign, dists, .. } = resp else {
                return Err(Error::data(format!(
                    "dist: shard {} answered a score with the wrong frame",
                    p.shard
                )));
            };
            if assign.len() != end - start || dists.len() != end - start {
                return Err(Error::data(format!(
                    "dist: shard {} score returned {} rows, expected {}",
                    p.shard,
                    assign.len(),
                    end - start
                )));
            }
            // Shard order is global row order: this `+=` sequence is the
            // exact single-process accumulation.
            for (i, (&a, &d)) in assign.iter().zip(dists.iter()).enumerate() {
                loss += d;
                assignments[start + i] = a as usize;
            }
            evals_total += evals;
        }
        counter.add(evals_total);
        Ok((loss, assignments))
    }

    /// Owning shard of global row `r` (shards are contiguous ascending).
    fn shard_of(&self, r: usize) -> usize {
        debug_assert!(r < self.points.len());
        match self.shards.binary_search_by(|&(start, end)| {
            if r < start {
                std::cmp::Ordering::Greater
            } else if r >= end {
                std::cmp::Ordering::Less
            } else {
                std::cmp::Ordering::Equal
            }
        }) {
            Ok(s) => s,
            Err(_) => unreachable!("row {r} outside every shard"),
        }
    }
}

impl Drop for WorkerPool<'_> {
    fn drop(&mut self) {
        let Ok(mut inner) = self.inner.lock() else { return };
        for w in inner.workers.iter_mut() {
            if w.alive {
                let frame = encode_request(&Request::Shutdown { id: u64::MAX });
                let _ = w.send(&frame);
            }
            // Dropping the writer EOFs the worker's read loop.
            w.writer = None;
        }
        for w in inner.workers.iter_mut() {
            if let WorkerKind::Child { child } = &mut w.kind {
                // Give the child a moment to exit cleanly, then reap hard.
                let deadline = Instant::now() + Duration::from_secs(2);
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            thread::sleep(Duration::from_millis(20))
                        }
                        _ => {
                            child.kill().ok();
                            child.wait().ok();
                            break;
                        }
                    }
                }
            }
            if let Some(handle) = w.reader.take() {
                handle.join().ok();
            }
        }
    }
}

fn spawn_child(program: &std::path::Path, extra_args: &[String]) -> Result<WorkerHandle> {
    let mut cmd = Command::new(program);
    cmd.arg("worker").arg("--stdio").arg("--quiet").args(extra_args);
    cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::inherit());
    let mut child = cmd
        .spawn()
        .map_err(|e| Error::data(format!("dist: spawning worker {}: {e}", program.display())))?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = child.stdout.take().expect("piped stdout");
    Ok(WorkerHandle::new(Box::new(stdin), stdout, WorkerKind::Child { child }))
}

fn connect_worker(addr: &str) -> Result<WorkerHandle> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::data(format!("dist: connecting worker {addr}: {e}")))?;
    stream.set_nodelay(true).ok();
    let write_half = stream
        .try_clone()
        .map_err(|e| Error::data(format!("dist: cloning worker stream {addr}: {e}")))?;
    Ok(WorkerHandle::new(
        Box::new(write_half),
        stream,
        WorkerKind::Tcp { addr: addr.to_string() },
    ))
}

fn request_kind(req: &Request) -> &'static str {
    match req {
        Request::Load(_) => "load",
        Request::LoadFile(_) => "load_file",
        Request::Block(_) => "block",
        Request::Score(_) => "score",
        Request::Ping { .. } => "ping",
        Request::Shutdown { .. } => "shutdown",
    }
}

/// A [`DistanceBackend`] that routes batched work through a
/// [`WorkerPool`] and everything else (single distances, norms, caching
/// semantics) through the in-process [`NativeBackend`] over the same
/// points. If the pool cannot recover from worker failures, block and
/// score calls fall back to local evaluation — identical bits, identical
/// eval counts, just slower.
pub struct ShardedBackend<'d> {
    local: NativeBackend<'d>,
    pool: &'d WorkerPool<'d>,
}

impl<'d> ShardedBackend<'d> {
    /// Backend over `points` (the same rows the pool sharded).
    pub fn new(points: &'d Points, metric: Metric, pool: &'d WorkerPool<'d>) -> ShardedBackend<'d> {
        assert_eq!(points.len(), pool.n_rows(), "pool shards a different row count");
        assert_eq!(metric, pool.metric(), "pool uses a different metric");
        ShardedBackend { local: NativeBackend::new(points, metric), pool }
    }

    /// Thread count for the local fallback path.
    pub fn with_threads(mut self, threads: usize) -> ShardedBackend<'d> {
        self.local = self.local.with_threads(threads);
        self
    }

    /// The pool driving this backend.
    pub fn pool(&self) -> &WorkerPool<'d> {
        self.pool
    }
}

impl DistanceBackend for ShardedBackend<'_> {
    fn points(&self) -> &Points {
        self.local.points()
    }

    fn metric(&self) -> Metric {
        self.local.metric()
    }

    fn counter(&self) -> &DistanceCounter {
        self.local.counter()
    }

    fn dist(&self, i: usize, j: usize) -> f64 {
        self.local.dist(i, j)
    }

    fn block(&self, targets: &[usize], refs: &[usize], out: &mut [f64]) {
        match self.pool.block(targets, refs, self.local.counter(), out) {
            Ok(()) => {}
            Err(e) => {
                self.pool.note_fallback();
                eprintln!("dist: falling back to local block: {}", e.message());
                self.local.block(targets, refs, out);
            }
        }
    }

    fn score(&self, medoids: &[usize]) -> Option<(f64, Vec<usize>)> {
        let medoid_points = self.local.points().select(medoids);
        match self.pool.score(&medoid_points, self.local.counter()) {
            Ok(result) => Some(result),
            Err(e) => {
                self.pool.note_fallback();
                eprintln!("dist: falling back to local scoring: {}", e.message());
                None
            }
        }
    }

    fn name(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::dist::worker::{run_worker, WorkerOptions};
    use crate::runtime::backend::loss_and_assignments;
    use crate::serve::faults::{pipe, FaultPlan};
    use crate::util::rng::Rng;

    /// In-process pool: each worker is a thread running the real worker
    /// loop over the real wire codec (the exact socket code path).
    fn pipe_pool<'d>(
        points: &'d Points,
        metric: Metric,
        workers: usize,
        plans: &[FaultPlan],
    ) -> WorkerPool<'d> {
        let mut transports: Vec<(Box<dyn Write + Send>, Box<dyn Read + Send>)> = Vec::new();
        for i in 0..workers {
            let (cw, sr) = pipe();
            let (sw, cr) = pipe();
            let opts = WorkerOptions {
                faults: plans.get(i).cloned().unwrap_or_default(),
                quiet: true,
            };
            thread::spawn(move || {
                let _ = run_worker(sr, sw, &opts);
            });
            transports.push((Box::new(cw), Box::new(cr)));
        }
        WorkerPool::from_transports(points, metric, transports, PoolOptions::default()).unwrap()
    }

    #[test]
    fn shard_ranges_are_contiguous_and_cover() {
        for (n, s) in [(10, 3), (7, 7), (5, 1), (16, 4)] {
            let ranges = shard_ranges(n, s);
            assert_eq!(ranges.len(), s);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[s - 1].1, n);
            for pair in ranges.windows(2) {
                assert_eq!(pair[0].1, pair[1].0);
            }
        }
    }

    #[test]
    fn sharded_block_matches_local_block_bitwise() {
        let data = synthetic::gmm(&mut Rng::seed_from(9), 30, 6, 3, 2.0);
        let pool = pipe_pool(&data.points, Metric::L2, 3, &[]);
        let local = NativeBackend::new(&data.points, Metric::L2);
        let targets = [1usize, 17];
        // Deliberately unsorted refs spanning all shards.
        let refs = [29usize, 0, 10, 4, 22, 11];
        let mut want = vec![0.0f64; targets.len() * refs.len()];
        local.block(&targets, &refs, &mut want);
        let counter = DistanceCounter::default();
        let mut got = vec![0.0f64; want.len()];
        pool.block(&targets, &refs, &counter, &mut got).unwrap();
        assert_eq!(
            got.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|d| d.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(counter.get(), (targets.len() * refs.len()) as u64);
    }

    #[test]
    fn sharded_score_matches_loss_and_assignments_bitwise() {
        let data = synthetic::gmm(&mut Rng::seed_from(21), 40, 5, 4, 2.0);
        for workers in [1usize, 2, 4] {
            let pool = pipe_pool(&data.points, Metric::Cosine, workers, &[]);
            let local = NativeBackend::new(&data.points, Metric::Cosine);
            let medoid_rows = [3usize, 11, 26, 39];
            let (want_loss, want_assign) = loss_and_assignments(&local, &medoid_rows);
            let counter = DistanceCounter::default();
            let medoids = data.points.select(&medoid_rows);
            let (loss, assign) = pool.score(&medoids, &counter).unwrap();
            assert_eq!(loss.to_bits(), want_loss.to_bits(), "workers={workers}");
            assert_eq!(assign, want_assign, "workers={workers}");
            assert_eq!(counter.get(), (medoid_rows.len() * data.points.len()) as u64);
        }
    }

    #[test]
    fn pipe_worker_death_reassigns_the_shard_to_a_survivor() {
        let data = synthetic::gmm(&mut Rng::seed_from(5), 20, 4, 2, 2.0);
        // Worker 0 dies on its 2nd work request; worker 1 stays healthy.
        let plans =
            vec![FaultPlan { panic_on_batches: vec![2], ..Default::default() }, FaultPlan::default()];
        let pool = pipe_pool(&data.points, Metric::L2, 2, &plans);
        let local = NativeBackend::new(&data.points, Metric::L2);
        let medoid_rows = [1usize, 12];
        let medoids = data.points.select(&medoid_rows);
        let (want_loss, want_assign) = loss_and_assignments(&local, &medoid_rows);
        for round in 0..3 {
            let counter = DistanceCounter::default();
            let (loss, assign) = pool.score(&medoids, &counter).unwrap();
            assert_eq!(loss.to_bits(), want_loss.to_bits(), "round {round}");
            assert_eq!(assign, want_assign, "round {round}");
            assert_eq!(counter.get(), (medoid_rows.len() * data.points.len()) as u64);
        }
        assert!(pool.respawns() >= 1, "the dead worker's shard must be reassigned");
        assert!(pool.retries() >= 1);
    }

    #[test]
    fn sharded_backend_score_hook_serves_loss_and_assignments() {
        let data = synthetic::gmm(&mut Rng::seed_from(13), 25, 4, 3, 2.0);
        let pool = pipe_pool(&data.points, Metric::L1, 2, &[]);
        let backend = ShardedBackend::new(&data.points, Metric::L1, &pool);
        let local = NativeBackend::new(&data.points, Metric::L1);
        let medoids = [2usize, 9, 20];
        let (want_loss, want_assign) = loss_and_assignments(&local, &medoids);
        let (loss, assign) = loss_and_assignments(&backend, &medoids);
        assert_eq!(loss.to_bits(), want_loss.to_bits());
        assert_eq!(assign, want_assign);
        assert_eq!(backend.counter().get(), local.counter().get());
    }
}
