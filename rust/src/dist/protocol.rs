//! Wire dialect for the shard-worker protocol ("BD" frames).
//!
//! Same framing discipline as the serve protocol (`serve/protocol.rs`),
//! distinct dialect: every frame is an 8-byte header — magic `"BD"`,
//! version, kind, `u32` little-endian body length — followed by the body.
//! The length is validated against [`MAX_FRAME_BODY`] *before* any
//! allocation, so a hostile peer cannot make the process reserve memory
//! it never sends.
//!
//! Two error tiers, mirroring serve:
//!
//! * [`FrameError`] — framing-level corruption (bad magic/version,
//!   oversized length, truncated stream). The connection is unusable;
//!   the coordinator treats the worker as dead.
//! * [`ParseFailure`] — the frame arrived intact but the body grammar is
//!   invalid. Recoverable: the worker answers [`Response::Error`] echoing
//!   the request id and keeps serving.
//!
//! Payload layouts are byte-compatible with the serve predict body where
//! they overlap (points payload: storage tag, `u32` rows/cols, then dense
//! `f32` values or sparse `u64` nnz + `u64` indptr + `u32` indices +
//! `f32` values), so the two dialects stay mutually intelligible to
//! fixture generators. See `rust/DIST.md` for the full grammar.

use crate::data::sparse::CsrMatrix;
use crate::data::Points;
use crate::distance::Metric;
use crate::util::matrix::Matrix;
use std::fmt;
use std::io::{ErrorKind, Read, Write};

/// Frame magic: "BD" (banditpam dist).
pub const MAGIC: [u8; 2] = *b"BD";
/// Wire version; bump on breaking changes.
pub const VERSION: u8 = 1;
/// Hard cap on a frame body, checked before allocation (64 MiB).
pub const MAX_FRAME_BODY: usize = 64 << 20;
/// Cap on a shard-file path in a `LoadFile` request.
pub const MAX_PATH: usize = 4096;
/// Cap on an error-message payload.
pub const MAX_ERROR_MSG: usize = 1024;

/// Request frame kinds (coordinator -> worker).
pub mod req {
    /// Install an in-memory shard: metric + points payload.
    pub const LOAD: u8 = 1;
    /// Install a shard backed by a row window of an `.mtx` file.
    pub const LOAD_FILE: u8 = 2;
    /// Evaluate a targets-vs-shard-rows distance tile.
    pub const BLOCK: u8 = 3;
    /// Assign every shard row to its nearest medoid.
    pub const SCORE: u8 = 4;
    pub const PING: u8 = 5;
    pub const SHUTDOWN: u8 = 6;
}

/// Response frame kinds (worker -> coordinator).
pub mod resp {
    pub const LOADED: u8 = 0x81;
    pub const DISTANCES: u8 = 0x82;
    pub const SCORE_PARTIAL: u8 = 0x83;
    pub const PONG: u8 = 0x84;
    pub const ERROR: u8 = 0x85;
    pub const SHUTDOWN_ACK: u8 = 0x86;
}

/// Framing-level corruption: the connection is not recoverable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameError(pub String);

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for FrameError {}

/// Body-grammar failure: the frame is rejected, the connection lives.
/// `id` echoes the request id when enough of the body parsed to know it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseFailure {
    pub id: u64,
    pub message: String,
}

impl fmt::Display for ParseFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for ParseFailure {}

/// Install an in-memory shard on a worker. The points are the shard's
/// rows (bit-copies of the coordinator's rows `base..base+rows`); block
/// and score requests address them by shard-local index.
#[derive(Debug, Clone)]
pub struct LoadRequest {
    pub id: u64,
    pub shard: u32,
    pub metric: Metric,
    pub points: Points,
}

/// Install a shard backed by rows `[start_row, end_row)` of an `.mtx`
/// file the worker reads itself (bounded-memory via `CsrChunkReader`).
#[derive(Debug, Clone, PartialEq)]
pub struct LoadFileRequest {
    pub id: u64,
    pub shard: u32,
    pub metric: Metric,
    pub start_row: u64,
    pub end_row: u64,
    pub chunk_nnz: u64,
    pub path: String,
}

/// Evaluate `targets` (shipped rows) against shard-local rows `refs`.
/// The response carries raw per-pair distances — never partial sums —
/// so every floating-point accumulation happens coordinator-side in the
/// single-process order (the bitwise-parity argument in `DIST.md`).
#[derive(Debug, Clone)]
pub struct BlockRequest {
    pub id: u64,
    pub shard: u32,
    pub targets: Points,
    pub refs: Vec<u32>,
}

/// Assign every row of the shard to its nearest of the shipped medoids
/// (strict-`<` first-minimum, same as the in-process fold).
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    pub id: u64,
    pub shard: u32,
    pub medoids: Points,
}

/// Coordinator -> worker frames.
#[derive(Debug, Clone)]
pub enum Request {
    Load(LoadRequest),
    LoadFile(LoadFileRequest),
    Block(BlockRequest),
    Score(ScoreRequest),
    Ping { id: u64 },
    Shutdown { id: u64 },
}

impl Request {
    /// The request id (echoed by every response).
    pub fn id(&self) -> u64 {
        match self {
            Request::Load(r) => r.id,
            Request::LoadFile(r) => r.id,
            Request::Block(r) => r.id,
            Request::Score(r) => r.id,
            Request::Ping { id } | Request::Shutdown { id } => *id,
        }
    }
}

/// Worker -> coordinator frames.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Shard installed; `rows` is the shard's row count.
    Loaded { id: u64, shard: u32, rows: u64 },
    /// Block result: `dists[t * refs.len() + j]` row-major over the
    /// request's target x ref grid; `evals` is the worker-side distance
    /// evaluation count for the request.
    Distances { id: u64, shard: u32, evals: u64, dists: Vec<f64> },
    /// Score result: per shard row (in shard order) the nearest-medoid
    /// index and distance. No sums cross the wire.
    ScorePartial { id: u64, shard: u32, evals: u64, assign: Vec<u32>, dists: Vec<f64> },
    Pong { id: u64 },
    /// Recoverable rejection of one request (body-tier).
    Error { id: u64, message: String },
    ShutdownAck { id: u64 },
}

impl Response {
    /// The echoed request id.
    pub fn id(&self) -> u64 {
        match self {
            Response::Loaded { id, .. }
            | Response::Distances { id, .. }
            | Response::ScorePartial { id, .. }
            | Response::Pong { id }
            | Response::Error { id, .. }
            | Response::ShutdownAck { id } => *id,
        }
    }
}

/// Metric wire tag (`None` for metrics with no wire form: tree edit
/// distance ships trees, which have no dist payload encoding).
pub fn metric_to_wire(metric: Metric) -> Option<u8> {
    match metric {
        Metric::L2 => Some(0),
        Metric::L1 => Some(1),
        Metric::Cosine => Some(2),
        Metric::TreeEdit => None,
    }
}

fn metric_from_wire(c: &Cur, tag: u8) -> Result<Metric, ParseFailure> {
    match tag {
        0 => Ok(Metric::L2),
        1 => Ok(Metric::L1),
        2 => Ok(Metric::Cosine),
        other => Err(c.fail(format!("unknown metric tag {other}"))),
    }
}

/// Bounds-checked little-endian cursor over a frame body (same contract
/// as the serve cursor, which is private to that module).
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
    id: u64,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0, id: 0 }
    }

    fn fail(&self, message: impl Into<String>) -> ParseFailure {
        ParseFailure { id: self.id, message: message.into() }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], ParseFailure> {
        if self.remaining() < n {
            return Err(self.fail(format!(
                "truncated body: {what} needs {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, ParseFailure> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, ParseFailure> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, ParseFailure> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Leading request id; every body starts with one so error replies
    /// can correlate.
    fn id_field(&mut self) -> Result<u64, ParseFailure> {
        let id = self.u64("request id")?;
        self.id = id;
        Ok(id)
    }

    /// Decode `count` items of `size` bytes, with the byte total checked
    /// against the remaining body *before* the vector is reserved.
    fn vec<T>(
        &mut self,
        count: usize,
        size: usize,
        what: &str,
        decode: impl Fn(&[u8]) -> T,
    ) -> Result<Vec<T>, ParseFailure> {
        let total = count
            .checked_mul(size)
            .ok_or_else(|| self.fail(format!("{what} length overflow ({count} items)")))?;
        let bytes = self.take(total, what)?;
        Ok(bytes.chunks_exact(size).map(decode).collect())
    }

    /// `u32`-length-prefixed UTF-8 text with an explicit cap.
    fn text(&mut self, what: &str, max: usize) -> Result<String, ParseFailure> {
        let len = self.u32(&format!("{what} length"))? as usize;
        if len > max {
            return Err(self.fail(format!("{what} length {len} exceeds cap {max}")));
        }
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| self.fail(format!("{what} is not valid UTF-8")))
    }

    /// Reject trailing bytes: a frame must be exactly its grammar.
    fn finish(self) -> Result<(), ParseFailure> {
        if self.remaining() != 0 {
            return Err(self.fail(format!("{} trailing bytes after body", self.remaining())));
        }
        Ok(())
    }
}

/// Read one frame. `Ok(None)` is a clean EOF at a frame boundary; EOF
/// mid-frame and every header violation are [`FrameError`]s.
pub fn read_frame(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>, FrameError> {
    let mut header = [0u8; 8];
    let mut got = 0;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(FrameError(format!("EOF inside frame header ({got}/8 bytes)"))),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError(format!("reading frame header: {e}"))),
        }
    }
    if header[..2] != MAGIC {
        return Err(FrameError(format!(
            "bad frame magic {:02x}{:02x} (expected \"BD\")",
            header[0], header[1]
        )));
    }
    if header[2] != VERSION {
        return Err(FrameError(format!(
            "unsupported protocol version {} (expected {VERSION})",
            header[2]
        )));
    }
    let kind = header[3];
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BODY {
        return Err(FrameError(format!(
            "frame body length {len} exceeds cap {MAX_FRAME_BODY}"
        )));
    }
    let mut body = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut body[got..]) {
            Ok(0) => return Err(FrameError(format!("EOF inside frame body ({got}/{len} bytes)"))),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError(format!("reading frame body: {e}"))),
        }
    }
    Ok(Some((kind, body)))
}

/// Write one frame (header + body).
pub fn write_frame(w: &mut impl Write, kind: u8, body: &[u8]) -> std::io::Result<()> {
    debug_assert!(body.len() <= MAX_FRAME_BODY, "frame body exceeds cap");
    let mut header = [0u8; 8];
    header[..2].copy_from_slice(&MAGIC);
    header[2] = VERSION;
    header[3] = kind;
    header[4..8].copy_from_slice(&(body.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(body)
}

fn frame(kind: u8, body: Vec<u8>) -> Vec<u8> {
    assert!(body.len() <= MAX_FRAME_BODY, "frame body exceeds cap");
    let mut out = Vec::with_capacity(8 + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

fn push_text(body: &mut Vec<u8>, text: &str) {
    body.extend_from_slice(&(text.len() as u32).to_le_bytes());
    body.extend_from_slice(text.as_bytes());
}

/// Points payload: storage tag, rows, cols, then storage-specific data.
/// Byte-identical layout to the serve predict query payload.
fn encode_points(body: &mut Vec<u8>, points: &Points) {
    match points {
        Points::Dense(m) => {
            body.push(0);
            body.extend_from_slice(&(m.rows() as u32).to_le_bytes());
            body.extend_from_slice(&(m.cols() as u32).to_le_bytes());
            for v in m.as_slice() {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        Points::Sparse(m) => {
            body.push(1);
            body.extend_from_slice(&(m.rows() as u32).to_le_bytes());
            body.extend_from_slice(&(m.cols() as u32).to_le_bytes());
            let (indptr, indices, values) = m.parts();
            body.extend_from_slice(&(indices.len() as u64).to_le_bytes());
            for p in indptr {
                body.extend_from_slice(&(*p as u64).to_le_bytes());
            }
            for j in indices {
                body.extend_from_slice(&j.to_le_bytes());
            }
            for v in values {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        Points::Trees(_) => unreachable!("tree points have no wire form"),
    }
}

fn parse_points(c: &mut Cur<'_>, what: &str) -> Result<Points, ParseFailure> {
    let storage = c.u8(&format!("{what} storage tag"))?;
    let n = c.u32(&format!("{what} row count"))? as usize;
    let dim = c.u32(&format!("{what} dim"))? as usize;
    match storage {
        0 => {
            let total = n
                .checked_mul(dim)
                .ok_or_else(|| c.fail(format!("{what} size overflow ({n} x {dim})")))?;
            let values =
                c.vec(total, 4, &format!("{what} values"), |b| {
                    f32::from_le_bytes(b.try_into().unwrap())
                })?;
            if let Some(bad) = values.iter().find(|v| !v.is_finite()) {
                return Err(c.fail(format!("non-finite value {bad} in {what}")));
            }
            Ok(Points::Dense(Matrix::from_vec(values, n, dim)))
        }
        1 => {
            let nnz = c.u64(&format!("{what} nnz"))?;
            let nnz = usize::try_from(nnz)
                .map_err(|_| c.fail(format!("{what} nnz {nnz} exceeds address space")))?;
            let rows_plus_one = n
                .checked_add(1)
                .ok_or_else(|| c.fail(format!("{what} row count overflow")))?;
            let indptr = c.vec(rows_plus_one, 8, &format!("{what} indptr"), |b| {
                u64::from_le_bytes(b.try_into().unwrap()) as usize
            })?;
            let indices = c.vec(nnz, 4, &format!("{what} indices"), |b| {
                u32::from_le_bytes(b.try_into().unwrap())
            })?;
            let values = c.vec(nnz, 4, &format!("{what} values"), |b| {
                f32::from_le_bytes(b.try_into().unwrap())
            })?;
            let m = CsrMatrix::try_from_parts(n, dim, indptr, indices, values)
                .map_err(|e| c.fail(format!("corrupt CSR {what}: {e}")))?;
            Ok(Points::Sparse(m))
        }
        other => Err(c.fail(format!("unknown {what} storage tag {other}"))),
    }
}

/// Encode a request into a complete frame (header + body).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut body = req.id().to_le_bytes().to_vec();
    let kind = match req {
        Request::Load(r) => {
            body.extend_from_slice(&r.shard.to_le_bytes());
            body.push(metric_to_wire(r.metric).expect("metric has no wire form"));
            encode_points(&mut body, &r.points);
            req::LOAD
        }
        Request::LoadFile(r) => {
            body.extend_from_slice(&r.shard.to_le_bytes());
            body.push(metric_to_wire(r.metric).expect("metric has no wire form"));
            body.extend_from_slice(&r.start_row.to_le_bytes());
            body.extend_from_slice(&r.end_row.to_le_bytes());
            body.extend_from_slice(&r.chunk_nnz.to_le_bytes());
            push_text(&mut body, &r.path);
            req::LOAD_FILE
        }
        Request::Block(r) => {
            body.extend_from_slice(&r.shard.to_le_bytes());
            encode_points(&mut body, &r.targets);
            body.extend_from_slice(&(r.refs.len() as u32).to_le_bytes());
            for j in &r.refs {
                body.extend_from_slice(&j.to_le_bytes());
            }
            req::BLOCK
        }
        Request::Score(r) => {
            body.extend_from_slice(&r.shard.to_le_bytes());
            encode_points(&mut body, &r.medoids);
            req::SCORE
        }
        Request::Ping { .. } => req::PING,
        Request::Shutdown { .. } => req::SHUTDOWN,
    };
    frame(kind, body)
}

/// Parse a request body (the `kind` comes from the frame header).
pub fn parse_request(kind: u8, body: &[u8]) -> Result<Request, ParseFailure> {
    let mut c = Cur::new(body);
    let id = c.id_field()?;
    let req = match kind {
        req::LOAD => {
            let shard = c.u32("shard id")?;
            let tag = c.u8("metric tag")?;
            let metric = metric_from_wire(&c, tag)?;
            let points = parse_points(&mut c, "shard payload")?;
            Request::Load(LoadRequest { id, shard, metric, points })
        }
        req::LOAD_FILE => {
            let shard = c.u32("shard id")?;
            let tag = c.u8("metric tag")?;
            let metric = metric_from_wire(&c, tag)?;
            let start_row = c.u64("start row")?;
            let end_row = c.u64("end row")?;
            let chunk_nnz = c.u64("chunk nnz")?;
            let path = c.text("shard path", MAX_PATH)?;
            if end_row <= start_row {
                return Err(c.fail(format!("empty file window [{start_row}, {end_row})")));
            }
            Request::LoadFile(LoadFileRequest {
                id,
                shard,
                metric,
                start_row,
                end_row,
                chunk_nnz,
                path,
            })
        }
        req::BLOCK => {
            let shard = c.u32("shard id")?;
            let targets = parse_points(&mut c, "target payload")?;
            let count = c.u32("ref count")? as usize;
            let refs = c.vec(count, 4, "ref indices", |b| {
                u32::from_le_bytes(b.try_into().unwrap())
            })?;
            Request::Block(BlockRequest { id, shard, targets, refs })
        }
        req::SCORE => {
            let shard = c.u32("shard id")?;
            let medoids = parse_points(&mut c, "medoid payload")?;
            Request::Score(ScoreRequest { id, shard, medoids })
        }
        req::PING => Request::Ping { id },
        req::SHUTDOWN => Request::Shutdown { id },
        other => return Err(c.fail(format!("unknown request kind 0x{other:02x}"))),
    };
    c.finish()?;
    Ok(req)
}

/// Encode a response into a complete frame (header + body).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut body = resp.id().to_le_bytes().to_vec();
    let kind = match resp {
        Response::Loaded { shard, rows, .. } => {
            body.extend_from_slice(&shard.to_le_bytes());
            body.extend_from_slice(&rows.to_le_bytes());
            resp::LOADED
        }
        Response::Distances { shard, evals, dists, .. } => {
            body.extend_from_slice(&shard.to_le_bytes());
            body.extend_from_slice(&evals.to_le_bytes());
            body.extend_from_slice(&(dists.len() as u32).to_le_bytes());
            for d in dists {
                body.extend_from_slice(&d.to_le_bytes());
            }
            resp::DISTANCES
        }
        Response::ScorePartial { shard, evals, assign, dists, .. } => {
            assert_eq!(assign.len(), dists.len(), "score partial shape mismatch");
            body.extend_from_slice(&shard.to_le_bytes());
            body.extend_from_slice(&evals.to_le_bytes());
            body.extend_from_slice(&(assign.len() as u32).to_le_bytes());
            for a in assign {
                body.extend_from_slice(&a.to_le_bytes());
            }
            for d in dists {
                body.extend_from_slice(&d.to_le_bytes());
            }
            resp::SCORE_PARTIAL
        }
        Response::Pong { .. } => resp::PONG,
        Response::Error { message, .. } => {
            let mut msg = message.clone();
            msg.truncate(MAX_ERROR_MSG);
            push_text(&mut body, &msg);
            resp::ERROR
        }
        Response::ShutdownAck { .. } => resp::SHUTDOWN_ACK,
    };
    frame(kind, body)
}

/// Parse a response body (the `kind` comes from the frame header).
pub fn parse_response(kind: u8, body: &[u8]) -> Result<Response, ParseFailure> {
    let mut c = Cur::new(body);
    let id = c.id_field()?;
    let resp = match kind {
        resp::LOADED => {
            let shard = c.u32("shard id")?;
            let rows = c.u64("shard rows")?;
            Response::Loaded { id, shard, rows }
        }
        resp::DISTANCES => {
            let shard = c.u32("shard id")?;
            let evals = c.u64("eval count")?;
            let count = c.u32("distance count")? as usize;
            let dists = c.vec(count, 8, "distances", |b| {
                f64::from_le_bytes(b.try_into().unwrap())
            })?;
            Response::Distances { id, shard, evals, dists }
        }
        resp::SCORE_PARTIAL => {
            let shard = c.u32("shard id")?;
            let evals = c.u64("eval count")?;
            let n = c.u32("row count")? as usize;
            let assign = c.vec(n, 4, "assignments", |b| {
                u32::from_le_bytes(b.try_into().unwrap())
            })?;
            let dists = c.vec(n, 8, "distances", |b| {
                f64::from_le_bytes(b.try_into().unwrap())
            })?;
            Response::ScorePartial { id, shard, evals, assign, dists }
        }
        resp::PONG => Response::Pong { id },
        resp::ERROR => {
            let message = c.text("error message", MAX_ERROR_MSG)?;
            Response::Error { id, message }
        }
        resp::SHUTDOWN_ACK => Response::ShutdownAck { id },
        other => return Err(c.fail(format!("unknown response kind 0x{other:02x}"))),
    };
    c.finish()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_points() -> Points {
        Points::Dense(Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3))
    }

    fn sparse_points() -> Points {
        Points::Sparse(
            CsrMatrix::try_from_parts(2, 4, vec![0, 2, 3], vec![0, 3, 1], vec![1.5, -2.0, 0.25])
                .unwrap(),
        )
    }

    fn roundtrip_request(req: &Request) -> Request {
        let bytes = encode_request(req);
        let mut r = &bytes[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        assert!(read_frame(&mut r).unwrap().is_none());
        parse_request(kind, &body).unwrap()
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let bytes = encode_response(resp);
        let mut r = &bytes[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        parse_response(kind, &body).unwrap()
    }

    #[test]
    fn load_round_trips_dense_and_sparse() {
        for points in [dense_points(), sparse_points()] {
            let req = Request::Load(LoadRequest {
                id: 3,
                shard: 1,
                metric: Metric::Cosine,
                points: points.clone(),
            });
            let Request::Load(got) = roundtrip_request(&req) else { panic!("wrong variant") };
            assert_eq!(got.id, 3);
            assert_eq!(got.shard, 1);
            assert_eq!(got.metric, Metric::Cosine);
            assert_eq!(got.points.len(), points.len());
            assert_eq!(got.points.kind(), points.kind());
        }
    }

    #[test]
    fn load_file_round_trips() {
        let req = Request::LoadFile(LoadFileRequest {
            id: 9,
            shard: 2,
            metric: Metric::L1,
            start_row: 100,
            end_row: 250,
            chunk_nnz: 4096,
            path: "data/cells.mtx".into(),
        });
        let Request::LoadFile(got) = roundtrip_request(&req) else { panic!("wrong variant") };
        assert_eq!(got.start_row, 100);
        assert_eq!(got.end_row, 250);
        assert_eq!(got.path, "data/cells.mtx");
    }

    #[test]
    fn block_and_score_round_trip() {
        let req = Request::Block(BlockRequest {
            id: 4,
            shard: 0,
            targets: dense_points(),
            refs: vec![0, 2, 5],
        });
        let Request::Block(got) = roundtrip_request(&req) else { panic!("wrong variant") };
        assert_eq!(got.refs, vec![0, 2, 5]);

        let req = Request::Score(ScoreRequest { id: 5, shard: 3, medoids: sparse_points() });
        let Request::Score(got) = roundtrip_request(&req) else { panic!("wrong variant") };
        assert_eq!((got.id, got.shard), (5, 3));
    }

    #[test]
    fn responses_round_trip_bit_exactly() {
        let cases = [
            Response::Loaded { id: 1, shard: 0, rows: 42 },
            Response::Distances { id: 2, shard: 1, evals: 6, dists: vec![0.5, 1.25, f64::MIN_POSITIVE] },
            Response::ScorePartial {
                id: 3,
                shard: 2,
                evals: 8,
                assign: vec![0, 1, 1, 0],
                dists: vec![0.1, 0.2, 0.3, 0.4],
            },
            Response::Pong { id: 4 },
            Response::Error { id: 5, message: "nope".into() },
            Response::ShutdownAck { id: 6 },
        ];
        for resp in cases {
            assert_eq!(roundtrip_response(&resp), resp);
        }
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        let mut bytes = encode_request(&Request::Ping { id: 1 });
        bytes[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut &bytes[..]).unwrap_err();
        assert!(err.0.contains("exceeds cap"), "{err}");
    }

    #[test]
    fn serve_dialect_frames_are_rejected_at_the_framing_tier() {
        // A "BQ" frame against the "BD" parser: wrong dialect, dead link.
        let mut bytes = encode_request(&Request::Ping { id: 1 });
        bytes[..2].copy_from_slice(b"BQ");
        let err = read_frame(&mut &bytes[..]).unwrap_err();
        assert!(err.0.contains("magic"), "{err}");
    }

    #[test]
    fn truncated_body_is_a_framing_error() {
        let bytes = encode_request(&Request::Ping { id: 7 });
        let err = read_frame(&mut &bytes[..bytes.len() - 3]).unwrap_err();
        assert!(err.0.contains("EOF inside frame body"), "{err}");
    }

    #[test]
    fn body_failures_echo_the_request_id() {
        // Block with a lying ref count: id parsed before the violation.
        let req = Request::Block(BlockRequest {
            id: 77,
            shard: 0,
            targets: dense_points(),
            refs: vec![1],
        });
        let mut bytes = encode_request(&req);
        let len = bytes.len();
        bytes.truncate(len - 2);
        let body_len = (len - 8 - 2) as u32;
        bytes[4..8].copy_from_slice(&body_len.to_le_bytes());
        let mut r = &bytes[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        let fail = parse_request(kind, &body).unwrap_err();
        assert_eq!(fail.id, 77);
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut bytes = encode_request(&Request::Ping { id: 1 });
        bytes.push(0);
        bytes[4..8].copy_from_slice(&9u32.to_le_bytes());
        let mut r = &bytes[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        let fail = parse_request(kind, &body).unwrap_err();
        assert!(fail.message.contains("trailing"), "{fail}");
    }

    #[test]
    fn non_finite_shard_values_are_rejected() {
        let req = Request::Load(LoadRequest {
            id: 8,
            shard: 0,
            metric: Metric::L2,
            points: dense_points(),
        });
        let mut bytes = encode_request(&req);
        // Overwrite the first f32 value with NaN: body starts at 8, then
        // id(8) + shard(4) + metric(1) + tag(1) + rows(4) + cols(4).
        let off = 8 + 8 + 4 + 1 + 1 + 4 + 4;
        bytes[off..off + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        let mut r = &bytes[..];
        let (kind, body) = read_frame(&mut r).unwrap().unwrap();
        let fail = parse_request(kind, &body).unwrap_err();
        assert!(fail.message.contains("non-finite"), "{fail}");
    }
}
