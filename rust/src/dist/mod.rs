//! `dist` — sharded multi-worker fit: data-parallel distance evaluation
//! over a wire protocol, with fault-tolerant workers and bitwise
//! single-process parity.
//!
//! Three pieces:
//!
//! * [`protocol`] — the "BD" length-prefixed wire dialect (same framing
//!   discipline as serve: magic/version, length checks before
//!   allocation, fatal-vs-recoverable error tiers).
//! * [`worker`] — the shard server (`banditpam worker` subcommand):
//!   owns contiguous row shards, answers distance tiles and
//!   nearest-medoid partials with the exact in-process kernels.
//! * [`coordinator`] — the [`coordinator::WorkerPool`] scheduler
//!   (deadlines, idempotent retries, respawn/reassign recovery) and
//!   [`coordinator::ShardedBackend`], a drop-in
//!   [`crate::runtime::backend::DistanceBackend`] so `--workers N` works
//!   with every algorithm arm.
//!
//! The design contract is **N workers == 1 process, bitwise**: workers
//! return raw distances (never partial sums), the coordinator folds
//! per-shard partials in shard order — which is global row order — and
//! eval counters merge exactly. `rust/DIST.md` spells out the argument
//! and the failure semantics.

pub mod coordinator;
pub mod protocol;
pub mod worker;

pub use coordinator::{PoolOptions, ShardedBackend, WorkerPool};
pub use worker::{run_worker, WorkerOptions};
