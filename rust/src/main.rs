//! `banditpam` CLI — leader entrypoint for the BanditPAM coordinator.
//!
//! Subcommands:
//!   cluster        fit k medoids on a CSV / synthetic dataset
//!   bigfit         bounded-memory CLARA-style fit over a streamed .mtx
//!   predict        assign points to the medoids of a saved model
//!   serve          long-lived prediction server over saved models
//!   worker         dist shard server (spawned by `cluster --workers N`)
//!   experiment     regenerate a paper table/figure (see DESIGN.md)
//!   generate-data  write a synthetic dataset to CSV
//!   info           runtime / artifact diagnostics
//!
//! Run `banditpam help` for full usage. Algorithm and synthetic-dataset
//! dispatch go through [`banditpam::algorithms::REGISTRY`] and
//! [`banditpam::data::synthetic::REGISTRY`], and the help text is rendered
//! from the same tables — the accepted names cannot drift from the
//! documented ones.
//!
//! Every failure exits with a one-line `error: ...` on stderr; usage
//! errors (bad flags, mismatched inputs, unsupported combinations) exit
//! with code 2, operational failures (missing files, corrupt data,
//! internal errors) with code 1 — see [`banditpam::Error::exit_code`].

use banditpam::algorithms::{make_algorithm, KMedoids};
use banditpam::bench::Scale;
use banditpam::data::stream::{self, StreamOptions};
use banditpam::data::{loader, synthetic, Dataset, Points};
use banditpam::dist::{PoolOptions, ShardedBackend, WorkerOptions, WorkerPool};
use banditpam::distance::Metric;
use banditpam::model::{Fit, KMedoidsModel};
use banditpam::obs::{TraceSink, TraceValue};
use banditpam::runtime::backend::NativeBackend;
use banditpam::runtime::executable::Client;
use banditpam::runtime::manifest::Manifest;
use banditpam::runtime::xla_backend::XlaBackend;
use banditpam::serve::{
    install_sighup_handler, serve_tcp, AdmissionConfig, Registry, ServeOptions, Server,
};
use banditpam::serve::faults::FaultPlan;
use banditpam::util::cli::{Args, DataFormat};
use banditpam::util::rng::Rng;
use banditpam::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Full usage text, rendered from the algorithm/synthetic registries.
fn help() -> String {
    let algorithms: Vec<String> = banditpam::algorithms::REGISTRY
        .iter()
        .map(|s| format!("  {:<10} {}", s.name, s.note))
        .collect();
    let synthetics: Vec<String> = synthetic::REGISTRY
        .iter()
        .map(|s| format!("  {:<13} {}", s.name, s.note))
        .collect();
    format!(
        "\
banditpam — almost linear time k-medoids clustering via multi-armed bandits

USAGE:
  banditpam cluster [--data FILE | --synthetic NAME] [--format csv|mtx|idx]
                    [--limit L] [--transpose] [--sparse] [--density P]
                    [--stream] [--chunk-nnz B]
                    [--n N] [--k K]
                    [--metric l2|l1|cosine|tree] [--algo NAME] [--seed S]
                    [--backend native|xla] [--threads T] [--verbose]
                    [--workers N | --worker-hosts H:P,...] [--worker-deadline-ms MS]
                    [--save-model FILE] [--trace-out FILE] [--metrics-dump FILE]
  banditpam bigfit  [--data FILE | --synthetic NAME] [--format csv|mtx|idx]
                    [--limit L] [--transpose] [--stream] [--chunk-nnz B]
                    [--n N] [--k K] [--metric l2|l1|cosine|tree] [--algo NAME]
                    [--samples S] [--sample-size Z] [--seed S] [--threads T]
                    [--workers N | --worker-hosts H:P,...] [--worker-deadline-ms MS]
                    [--save-model FILE] [--verbose]
                    [--trace-out FILE] [--metrics-dump FILE]
  banditpam predict --model FILE [--data FILE | --synthetic NAME]
                    [--format csv|mtx|idx] [--limit L] [--transpose]
                    [--n N] [--seed S] [--threads T] [--out FILE] [--verbose]
  banditpam serve   [--stdio | --listen HOST:PORT] NAME=FILE.bpmodel ...
                    [--threads T] [--max-queue-requests N] [--max-queue-points N]
                    [--max-batch-points N] [--retry-after-ms MS]
                    [--quarantine-threshold N] [--quiet] [--metrics-dump FILE]
  banditpam worker  [--stdio | --listen HOST:PORT] [--quiet]
  banditpam experiment <id|all> [--scale smoke|quick|paper] [--seed S] [--csv]
  banditpam generate-data --synthetic NAME --n N --out FILE[.csv|.mtx]
                    [--format csv|mtx] [--seed S]
  banditpam info

ALGORITHMS (--algo):
{}
SYNTHETIC DATASETS (--synthetic):
{}
MODELS:      `cluster --save-model FILE` persists the fitted medoids +
             metadata to the versioned binary format (rust/MODEL.md);
             `predict --model FILE` reloads it and assigns any dataset —
             no training data needed. Queries are auto-converted to the
             model's storage kind (dense <-> CSR).
SERVING:     `serve` loads one or more models (NAME=FILE, or a bare FILE
             named by its stem) and answers assignment batches over the
             binary protocol in rust/SERVE.md — on stdin/stdout (--stdio,
             the default) or a TCP socket (--listen). Requests are
             coalesced per model, deadlines and backpressure are
             enforced, batch panics are isolated, and SIGHUP (or a
             reload frame) hot-swaps models with zero downtime.
SPARSE DATA: --format mtx loads Matrix Market triplets as CSR points
             (--transpose for 10x genes x cells files); --sparse converts
             any dense dataset to CSR; --density P sets the scrna-sparse
             generator's expression probability (default 0.10); --limit L
             caps the rows read (post-transpose, so cells on a 10x file)
STREAMING:   .mtx files >= 256 MiB stream through the out-of-core chunked
             reader automatically; --stream forces it and --chunk-nnz B
             sets the per-window entry budget (default 1048576, implies
             --stream) — results are bitwise-identical to the in-memory
             loader
BIGFIT:      CLARA-style outer loop around any --algo: draw --samples
             subsamples of --sample-size rows (0 = classic 40+2k), fit
             each in memory, score every candidate against the full
             dataset window by window, keep the best. With --stream /
             --chunk-nnz on an .mtx file the full dataset is never
             resident — peak memory is the sample, the k medoid rows and
             one window — and the result is bitwise-identical to the
             in-memory run with the same seed.
DIST:        `cluster`/`bigfit --workers N` shard the dataset rows over N
             locally spawned worker processes (`banditpam worker` children
             over stdio pipes); --worker-hosts H:P,... uses remote workers
             started with `worker --listen HOST:PORT` instead. Results are
             bitwise-identical to the single-process fit — same medoids,
             loss bits and eval counts. Worker death is detected and
             recovered (respawn / reconnect / reassign) with idempotent
             retries; --worker-deadline-ms bounds each request (default
             30000). Wire dialect and the parity argument: rust/DIST.md.
EXPERIMENTS: fig1a fig1b fig2 fig3 appfig1 appfig2 appfig34 appfig5
             headline ablations (see DESIGN.md for the paper mapping)
TELEMETRY:   --trace-out FILE writes structured JSONL phase spans (one
             event per BUILD round / SWAP iteration, per BigFit sample
             and eval window — schema in rust/OBS.md); --metrics-dump
             FILE writes the process-wide metric registry as Prometheus
             text exposition when the command finishes. Both are inert
             when omitted: results are bitwise-identical either way.
",
        algorithms.join("\n"),
        synthetics.join("\n"),
    )
}

/// Dataset-selection options shared by every subcommand that builds its
/// input through [`make_dataset`].
const DATASET_KEYS: &[&str] =
    &["data", "synthetic", "format", "limit", "n", "density", "chunk-nnz"];
const DATASET_FLAGS: &[&str] = &["sparse", "stream", "transpose"];

/// Reject any option/flag the subcommand does not read. The parser accepts
/// anything shaped like `--key value`, so without a declared accepted set a
/// misspelled option (`--chunk-nzz`, `--sample_size`) silently does nothing
/// — the same failure class as the `.mtx --limit` bug. Exits 2 through
/// [`Error::InvalidArgument`], like every other usage error.
fn check_known_options(args: &Args) -> Result<()> {
    let Some(sub) = args.subcommand.as_deref() else { return Ok(()) };
    let mut keys: Vec<&str> = Vec::new();
    let mut flags: Vec<&str> = vec!["help"];
    match sub {
        "cluster" | "bigfit" => {
            keys.extend_from_slice(DATASET_KEYS);
            keys.extend_from_slice(&[
                "k",
                "metric",
                "algo",
                "seed",
                "threads",
                "save-model",
                "trace-out",
                "metrics-dump",
                "workers",
                "worker-hosts",
                "worker-deadline-ms",
                // Undocumented fault-injection knob for the dist smoke
                // harness: forwarded to spawned workers as
                // `--inject-exit-on N` (see rust/DIST.md §faults).
                "dist-inject-exit-on",
            ]);
            if sub == "cluster" {
                keys.push("backend");
            } else {
                keys.extend_from_slice(&["samples", "sample-size"]);
            }
            flags.extend_from_slice(DATASET_FLAGS);
            flags.push("verbose");
        }
        "predict" => {
            keys.extend_from_slice(DATASET_KEYS);
            keys.extend_from_slice(&["model", "out", "seed", "threads"]);
            flags.extend_from_slice(DATASET_FLAGS);
            flags.push("verbose");
        }
        "serve" => {
            keys.extend_from_slice(&[
                "listen",
                "threads",
                "max-queue-requests",
                "max-queue-points",
                "max-batch-points",
                "retry-after-ms",
                "quarantine-threshold",
                "inject-panic-every",
                "stall-ms",
                "metrics-dump",
            ]);
            flags.extend_from_slice(&["stdio", "quiet"]);
        }
        "worker" => {
            keys.extend_from_slice(&[
                "listen",
                "inject-exit-on",
                "inject-exit-every",
                "stall-ms",
            ]);
            flags.extend_from_slice(&["stdio", "quiet"]);
        }
        "experiment" => {
            keys.extend_from_slice(&["scale", "seed"]);
            flags.push("csv");
        }
        "generate-data" => {
            keys.extend_from_slice(DATASET_KEYS);
            keys.extend_from_slice(&["out", "seed"]);
            flags.extend_from_slice(DATASET_FLAGS);
        }
        "info" | "help" => {}
        // unknown subcommands get their own error in `run`
        _ => return Ok(()),
    }
    args.check_known(sub, &keys, &flags)?;
    Ok(())
}

fn make_dataset(args: &Args, rng: &mut Rng) -> Result<Dataset> {
    let n: usize = args.get_parsed("n", 1000usize)?;
    let density: f64 = args.get_parsed("density", 0.10)?;
    if (args.flag("stream") || args.get("chunk-nnz").is_some()) && args.get("data").is_none() {
        return Err(Error::invalid_argument(
            "--stream/--chunk-nnz require --data FILE.mtx (synthetic datasets are generated in memory)",
        ));
    }
    let ds = if let Some(path) = args.get("data") {
        let format = match args.get("format") {
            Some(s) => DataFormat::parse(s)
                .ok_or_else(|| Error::invalid_argument(format!("bad --format {s:?} (csv|mtx|idx)")))?,
            None => DataFormat::infer(path),
        };
        let path = PathBuf::from(path);
        // `--limit` caps how many points a file loader reads (0 = all);
        // `--n` is the synthetic-size knob and is ignored for files.
        let limit: usize = args.get_parsed("limit", 0usize)?;
        if (args.flag("stream") || args.get("chunk-nnz").is_some())
            && format != DataFormat::Mtx
        {
            return Err(Error::invalid_argument(format!(
                "--stream/--chunk-nnz require --format mtx (got {format})"
            )));
        }
        match format {
            DataFormat::Csv => loader::load_csv(&path)?,
            DataFormat::Mtx => {
                let transpose = args.flag("transpose");
                // An explicit window budget implies the streamed path —
                // --chunk-nnz must never be silently dropped.
                if args.flag("stream") || args.get("chunk-nnz").is_some() {
                    let opts = StreamOptions {
                        chunk_nnz: args.get_parsed("chunk-nnz", stream::DEFAULT_CHUNK_NNZ)?,
                        transpose,
                        limit,
                    };
                    let (ds, stats) = stream::load_mtx_streamed(&path, &opts)?;
                    println!(
                        "streamed load: {} windows of <= {} entries, peak window {} nnz{}",
                        stats.windows,
                        stats.chunk_nnz,
                        stats.peak_window_nnz,
                        if stats.spilled { " (row-bucketing spill)" } else { "" }
                    );
                    ds
                } else {
                    loader::load_mtx_auto(&path, transpose, limit)?
                }
            }
            DataFormat::Idx => loader::load_idx_images(&path, limit)?,
        }
    } else {
        let name = args.get("synthetic").unwrap_or("gmm");
        synthetic::by_name(name, rng, n, density)?
    };
    if args.flag("sparse") && !matches!(ds.points, Points::Sparse(_)) {
        return ds.to_sparse().ok_or_else(|| {
            Error::invalid_argument(format!(
                "--sparse: {} points have no CSR form",
                ds.points.kind()
            ))
        });
    }
    Ok(ds)
}

/// `--trace-out FILE`: open the JSONL trace sink, or `None` when the
/// flag is absent (the zero-cost default — no sink, no allocations on
/// the hot paths).
fn open_trace(args: &Args) -> Result<Option<Arc<TraceSink>>> {
    match args.get("trace-out") {
        Some(path) => Ok(Some(TraceSink::to_path(path)?)),
        None => Ok(None),
    }
}

/// `--metrics-dump FILE`: write the process-wide metric registry as
/// Prometheus text exposition once the command finishes. `to_stderr`
/// keeps the confirmation line off stdout for `serve --stdio`, whose
/// stdout carries protocol frames.
fn dump_metrics(args: &Args, to_stderr: bool) -> Result<()> {
    if let Some(path) = args.get("metrics-dump") {
        std::fs::write(path, banditpam::obs::global().render_prometheus())?;
        if to_stderr {
            eprintln!("metrics dump  : {path}");
        } else {
            println!("metrics dump  : {path}");
        }
    }
    Ok(())
}

/// Whether `--workers`/`--worker-hosts` ask for a sharded fit.
fn dist_requested(args: &Args) -> Result<bool> {
    Ok(args.get_parsed("workers", 0usize)? > 0 || args.get("worker-hosts").is_some())
}

/// Build the worker pool for a sharded fit: local children over stdio
/// pipes (`--workers N`) or remote TCP workers (`--worker-hosts`).
fn build_pool<'d>(args: &Args, points: &'d Points, metric: Metric) -> Result<WorkerPool<'d>> {
    let opts = PoolOptions {
        deadline: std::time::Duration::from_millis(
            args.get_parsed("worker-deadline-ms", 30_000u64)?,
        ),
        worker_args: match args.get("dist-inject-exit-on") {
            Some(n) => vec!["--inject-exit-on".to_string(), n.to_string()],
            None => Vec::new(),
        },
        ..PoolOptions::default()
    };
    match args.get("worker-hosts") {
        Some(hosts) => {
            let hosts: Vec<String> = hosts
                .split(',')
                .map(|h| h.trim().to_string())
                .filter(|h| !h.is_empty())
                .collect();
            WorkerPool::connect_tcp(points, metric, &hosts, opts)
        }
        None => {
            WorkerPool::spawn_local(points, metric, args.get_parsed("workers", 1usize)?, opts)
        }
    }
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let seed: u64 = args.get_parsed("seed", 42u64)?;
    let mut rng = Rng::seed_from(seed);
    let ds = make_dataset(args, &mut rng)?;
    let k: usize = args.get_parsed("k", 5usize)?;
    let metric = Metric::parse(args.get("metric").unwrap_or("l2"))
        .ok_or_else(|| Error::invalid_argument("bad --metric (l2|l1|cosine|tree)"))?;
    // The backend constructors assert support; reject the combination
    // here so a bad flag pairing is a usage error, not a panic.
    if !metric.supports(&ds.points) {
        return Err(Error::invalid_argument(format!(
            "--metric {metric} does not support {} points (dataset {})",
            ds.points.kind(),
            ds.name
        )));
    }
    let algo_name = args.get("algo").unwrap_or("banditpam").to_string();
    let threads: usize = args.get_parsed(
        "threads",
        banditpam::experiments::harness::default_threads(),
    )?;

    let backend_kind = args.get("backend").unwrap_or("native");
    let distributed = dist_requested(args)?;
    if distributed && backend_kind != "native" {
        return Err(Error::invalid_argument(
            "--workers/--worker-hosts require --backend native (workers run the native kernels)",
        ));
    }
    let sink = open_trace(args)?;
    // The banditpam coordinator emits its own per-round spans when a sink
    // is attached; constructing it directly here (same config as the
    // registry's `default_paper`) is the only algorithm-specific branch.
    let mut algo: Box<dyn KMedoids> = match &sink {
        Some(s) if algo_name == "banditpam" => {
            let mut a = banditpam::coordinator::banditpam::BanditPam::default_paper();
            a.set_trace_sink(Some(s.clone()));
            Box::new(a)
        }
        _ => make_algorithm(&algo_name)?,
    };
    println!(
        "dataset {} (n={}, metric={metric}, k={k}, algo={algo_name}, backend={backend_kind})",
        ds.name,
        ds.len()
    );
    if let Points::Sparse(m) = &ds.points {
        println!(
            "sparse storage: {} nnz, density {:.2}% (CSR kernels active)",
            m.nnz(),
            100.0 * m.density()
        );
    }
    let fit = match backend_kind {
        "native" if distributed => {
            let pool = build_pool(args, &ds.points, metric)?;
            pool.set_trace(sink.clone());
            println!(
                "dist          : {} worker(s), {} shard(s) over {} rows",
                pool.n_workers(),
                pool.shards().len(),
                pool.n_rows()
            );
            let backend = ShardedBackend::new(&ds.points, metric, &pool).with_threads(threads);
            let fit = algo.fit(&backend, k, &mut rng)?;
            if pool.retries() + pool.respawns() + pool.fallbacks() > 0 {
                println!(
                    "dist recovery : {} retries, {} respawns, {} local fallbacks",
                    pool.retries(),
                    pool.respawns(),
                    pool.fallbacks()
                );
            }
            fit
        }
        "native" => {
            let backend = NativeBackend::new(&ds.points, metric).with_threads(threads);
            algo.fit(&backend, k, &mut rng)?
        }
        "xla" => {
            let client = Client::cpu()?;
            let backend =
                XlaBackend::new(&client, &Manifest::default_dir(), &ds.points, metric)?;
            println!(
                "xla backend: artifact {} on {}",
                backend.artifact().name,
                client.platform()
            );
            algo.fit(&backend, k, &mut rng)?
        }
        other => {
            return Err(Error::invalid_argument(format!(
                "unknown backend {other:?} (native|xla)"
            )))
        }
    };

    println!("medoids       : {:?}", fit.medoids);
    println!("loss          : {:.4}", fit.loss);
    println!("distance evals: {}", fit.stats.distance_evals);
    println!(
        "evals/iter    : {:.1} ({} swap iters)",
        fit.stats.evals_per_iter(),
        fit.stats.swap_iters
    );
    println!("wall time     : {:.3}s", fit.stats.wall_secs);
    if args.flag("verbose") {
        let mut sizes = vec![0usize; k];
        for &a in &fit.assignments {
            sizes[a] += 1;
        }
        println!("cluster sizes : {sizes:?}");
        match fit.stats.cache_hit_rate() {
            Some(rate) => println!(
                "distance cache: {} hits / {} misses ({:.1}% hit rate)",
                fit.stats.cache_hits,
                fit.stats.cache_misses,
                100.0 * rate
            ),
            None => println!("distance cache: off"),
        }
        println!(
            "swap reuse    : {} evals served from session cache",
            fit.stats.swap_evals_saved
        );
    }
    if let Some(s) = &sink {
        // The banditpam coordinator writes its own `fit_summary`; every
        // other algorithm gets one here so a trace file is never empty.
        if algo_name != "banditpam" {
            s.emit(
                "fit_summary",
                &[
                    ("algo", TraceValue::from(algo_name.as_str())),
                    ("n", TraceValue::from(ds.len())),
                    ("k", TraceValue::from(k)),
                    ("loss", TraceValue::from(fit.loss)),
                    ("distance_evals", TraceValue::from(fit.stats.distance_evals)),
                    ("swap_iters", TraceValue::from(fit.stats.swap_iters)),
                    ("wall_secs", TraceValue::from(fit.stats.wall_secs)),
                ],
            );
        }
        s.flush()?;
        println!("trace         : {} events", s.len());
    }
    if let Some(path) = args.get("save-model") {
        let fingerprint = format!(
            "algo={algo_name} metric={metric} k={k} seed={seed} threads={threads} \
             backend={backend_kind} data={}",
            ds.name
        );
        let model = KMedoidsModel::from_fit(
            &ds.points,
            metric,
            fit.clone(),
            algo_name.as_str(),
            fingerprint,
        )?;
        model.save(Path::new(path))?;
        println!("model saved   : {path} ({} bytes)", std::fs::metadata(path)?.len());
    }
    dump_metrics(args, false)?;
    Ok(())
}

/// `banditpam bigfit`: the bounded-memory CLARA-style outer loop. With
/// `--stream`/`--chunk-nnz` on an `.mtx` file the dataset is consumed as
/// row-windows and never loaded whole; otherwise it runs in memory over
/// any dataset `cluster` accepts — same result bits either way.
fn cmd_bigfit(args: &Args) -> Result<()> {
    let seed: u64 = args.get_parsed("seed", 42u64)?;
    let k: usize = args.get_parsed("k", 5usize)?;
    let metric = Metric::parse(args.get("metric").unwrap_or("l2"))
        .ok_or_else(|| Error::invalid_argument("bad --metric (l2|l1|cosine|tree)"))?;
    let algo_name = args.get("algo").unwrap_or("banditpam").to_string();
    let threads: usize = args.get_parsed(
        "threads",
        banditpam::experiments::harness::default_threads(),
    )?;
    let samples: usize = args.get_parsed("samples", 5usize)?;
    let sample_size: usize = args.get_parsed("sample-size", 0usize)?;
    let sink = open_trace(args)?;
    let mut fit = Fit::algorithm(&algo_name)?
        .metric(metric)
        .k(k)
        .seed(seed)
        .threads(threads);
    if let Some(s) = &sink {
        fit = fit.trace_sink(s.clone());
    }
    let big = fit.big().samples(samples).sample_size(sample_size);

    let streamed = args.flag("stream") || args.get("chunk-nnz").is_some();
    let distributed = dist_requested(args)?;
    if distributed && streamed {
        return Err(Error::invalid_argument(
            "--workers/--worker-hosts and --stream are mutually exclusive (workers hold \
             in-memory row shards; see rust/DIST.md for the sharded-sources follow-on)",
        ));
    }
    let (model, stats, source) = if streamed {
        let path = args.get("data").ok_or_else(|| {
            Error::invalid_argument(
                "--stream/--chunk-nnz require --data FILE.mtx (synthetic datasets are generated in memory)",
            )
        })?;
        let format = match args.get("format") {
            Some(s) => DataFormat::parse(s).ok_or_else(|| {
                Error::invalid_argument(format!("bad --format {s:?} (csv|mtx|idx)"))
            })?,
            None => DataFormat::infer(path),
        };
        if format != DataFormat::Mtx {
            return Err(Error::invalid_argument(format!(
                "--stream/--chunk-nnz require --format mtx (got {format})"
            )));
        }
        let opts = StreamOptions {
            chunk_nnz: args.get_parsed("chunk-nnz", stream::DEFAULT_CHUNK_NNZ)?,
            transpose: args.flag("transpose"),
            limit: args.get_parsed("limit", 0usize)?,
        };
        let (model, stats) = big.fit_streamed(Path::new(path), &opts)?;
        (model, stats, format!("{path} (streamed)"))
    } else {
        let mut rng = Rng::seed_from(seed);
        let ds = make_dataset(args, &mut rng)?;
        if !metric.supports(&ds.points) {
            return Err(Error::invalid_argument(format!(
                "--metric {metric} does not support {} points (dataset {})",
                ds.points.kind(),
                ds.name
            )));
        }
        let name = ds.name.clone();
        let (model, stats) = if distributed {
            let pool = build_pool(args, &ds.points, metric)?;
            pool.set_trace(sink.clone());
            println!(
                "dist          : {} worker(s), {} shard(s) over {} rows",
                pool.n_workers(),
                pool.shards().len(),
                pool.n_rows()
            );
            big.fit_with_workers(&ds, &pool)?
        } else {
            big.fit_with_stats(&ds)?
        };
        (model, stats, name)
    };

    println!(
        "bigfit        : {source} (n={}, algo={algo_name}, metric={metric}, k={k}, \
         {} samples x {} rows)",
        stats.n_rows, stats.samples, stats.sample_size
    );
    println!("medoids       : {:?}", model.clustering().medoids);
    println!("loss          : {:.4}", model.loss());
    println!(
        "distance evals: {} ({} sample fits + {} full-dataset scoring)",
        model.clustering().stats.distance_evals,
        model.clustering().stats.build_evals,
        model.clustering().stats.eval_evals
    );
    if stats.total_nnz > 0 {
        println!(
            "residency     : peak {} of {} nnz ({:.1}%), peak window {} nnz",
            stats.peak_resident_nnz,
            stats.total_nnz,
            100.0 * stats.peak_resident_nnz as f64 / stats.total_nnz.max(1) as f64,
            stats.peak_window_nnz
        );
    }
    println!("wall time     : {:.3}s", stats.wall_secs);
    if args.flag("verbose") {
        for t in &stats.trajectory {
            println!(
                "  sample {:>2}  : loss {:.4} (draw {:.3}s, fit {:.3}s, eval {:.3}s)",
                t.sample, t.loss, t.subsample_secs, t.fit_secs, t.eval_secs
            );
        }
    }
    if let Some(s) = &sink {
        println!("trace         : {} events", s.len());
    }
    if let Some(path) = args.get("save-model") {
        model.save(Path::new(path))?;
        println!("model saved   : {path} ({} bytes)", std::fs::metadata(path)?.len());
    }
    dump_metrics(args, false)?;
    Ok(())
}

/// `banditpam predict --model FILE [--data ... | --synthetic ...]`: reload
/// a saved model and assign a dataset to its medoids — no training data,
/// rerun or refit involved.
fn cmd_predict(args: &Args) -> Result<()> {
    let model_path = args
        .get("model")
        .ok_or_else(|| Error::invalid_argument("--model FILE required"))?;
    let model = KMedoidsModel::load(Path::new(model_path))?;
    println!(
        "model         : {model_path} (algo={}, metric={}, k={}, dim={}, n_train={}, loss={:.4})",
        model.algorithm(),
        model.metric(),
        model.k(),
        model.dim().map_or("-".to_string(), |d| d.to_string()),
        model.n_train(),
        model.loss()
    );
    if args.flag("verbose") {
        println!("fingerprint   : {}", model.config_fingerprint());
    }
    let seed: u64 = args.get_parsed("seed", 42u64)?;
    let mut rng = Rng::seed_from(seed);
    let ds = make_dataset(args, &mut rng)?;
    // Convert the queries to the model's storage kind when they disagree
    // (a dense CSV against a CSR model, or vice versa); tree/vector
    // mismatches have no conversion and surface as predict errors. When
    // the kinds already match, borrow the loaded points as-is — no copy
    // of a potentially multi-GB query set.
    let converted = if ds.points.kind() == model.medoid_points().kind() {
        None
    } else {
        let c = match model.medoid_points() {
            Points::Dense(_) => ds.points.to_dense(),
            Points::Sparse(_) => ds.points.to_sparse(),
            Points::Trees(_) => None,
        };
        if let Some(p) = &c {
            println!(
                "queries       : converted {} -> {} to match the model",
                ds.points.kind(),
                p.kind()
            );
        }
        c
    };
    let queries: &Points = converted.as_ref().unwrap_or(&ds.points);
    let threads: usize = args.get_parsed(
        "threads",
        banditpam::experiments::harness::default_threads(),
    )?;
    let model = model.with_threads(threads);
    let (assign, dists) = model.predict_with_dists(queries)?;
    let mut sizes = vec![0usize; model.k()];
    for &a in &assign {
        sizes[a] += 1;
    }
    let mean = dists.iter().sum::<f64>() / dists.len().max(1) as f64;
    let max = dists.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "assigned      : {} points (dataset {})",
        assign.len(),
        ds.name
    );
    println!("cluster sizes : {sizes:?}");
    println!("distance      : mean {mean:.4}, max {max:.4}");
    if let Some(out) = args.get("out") {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(out)?);
        writeln!(f, "point,assignment,medoid_train_index,distance")?;
        for (i, (&a, &d)) in assign.iter().zip(&dists).enumerate() {
            writeln!(f, "{i},{a},{},{d}", model.clustering().medoids[a])?;
        }
        println!("wrote         : {out}");
    }
    Ok(())
}

/// `banditpam serve [--stdio | --listen HOST:PORT] NAME=FILE.bpmodel ...`:
/// the long-lived prediction server (see `rust/SERVE.md` for the wire
/// protocol and the serving guarantees).
fn cmd_serve(args: &Args) -> Result<()> {
    let mut specs: Vec<(String, PathBuf)> = Vec::new();
    for spec in &args.positional {
        // NAME=FILE pins the registry name; a bare FILE is named by its
        // stem (models.bpmodel -> "models"). Positionals rather than a
        // repeated --model flag: the option map keeps one value per key.
        let (name, path) = match spec.split_once('=') {
            Some((name, path)) => (name.to_string(), PathBuf::from(path)),
            None => {
                let path = PathBuf::from(spec);
                let name = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or("")
                    .to_string();
                (name, path)
            }
        };
        specs.push((name, path));
    }
    if specs.is_empty() {
        return Err(Error::invalid_argument(
            "serve needs at least one model: banditpam serve [--stdio | --listen HOST:PORT] NAME=FILE.bpmodel ...",
        ));
    }
    let defaults = AdmissionConfig::default();
    let admission = AdmissionConfig {
        max_queue_requests: args
            .get_parsed("max-queue-requests", defaults.max_queue_requests)?,
        max_queue_points: args.get_parsed("max-queue-points", defaults.max_queue_points)?,
        max_batch_points: args.get_parsed("max-batch-points", defaults.max_batch_points)?,
        retry_after_ms: args.get_parsed("retry-after-ms", defaults.retry_after_ms)?,
        quarantine_threshold: args
            .get_parsed("quarantine-threshold", defaults.quarantine_threshold)?,
    };
    // Undocumented fault-injection knobs for the smoke harness (see
    // rust/SERVE.md §faults); inert unless set.
    let faults = FaultPlan {
        panic_on_batches: Vec::new(),
        panic_every: match args.get_parsed("inject-panic-every", 0u64)? {
            0 => None,
            n => Some(n),
        },
        stall_ms: args.get_parsed("stall-ms", 0u64)?,
    };
    let threads: usize = args.get_parsed(
        "threads",
        banditpam::experiments::harness::default_threads(),
    )?;
    let listen = args.get("listen");
    if listen.is_some() && args.flag("stdio") {
        return Err(Error::invalid_argument(
            "--stdio and --listen are mutually exclusive",
        ));
    }

    let registry = Registry::open(&specs)?;
    install_sighup_handler();
    let server = Server::new(registry, ServeOptions { threads, admission, faults });
    if !args.flag("quiet") {
        let names: Vec<&str> = server.registry().names().collect();
        eprintln!(
            "serve: {} model(s) [{}], {threads} predictor thread(s)",
            names.len(),
            names.join(", ")
        );
    }
    match listen {
        Some(addr) => serve_tcp(&server, addr)?,
        None => server.handle_connection(std::io::stdin(), std::io::stdout()),
    }
    server.begin_shutdown();
    server.join();
    if !args.flag("quiet") {
        eprintln!("serve: final stats {}", server.stats.snapshot_json());
    }
    dump_metrics(args, true)?;
    Ok(())
}

/// `banditpam worker`: the dist shard server. Normally spawned by the
/// coordinator (`cluster --workers N` launches children of the current
/// binary over stdio pipes), or started by hand with `--listen` for
/// multi-host fits. Speaks the "BD" wire dialect in rust/DIST.md.
fn cmd_worker(args: &Args) -> Result<()> {
    // Deterministic fault-injection knobs for tests/CI (inert unless
    // set): `--inject-exit-on N` kills the worker on its N-th work
    // request, `--inject-exit-every N` on every N-th, `--stall-ms MS`
    // sleeps before each work request. Counted over Block/Score requests
    // only, so load order does not shift the kill site.
    let faults = FaultPlan {
        panic_on_batches: match args.get_parsed("inject-exit-on", 0u64)? {
            0 => Vec::new(),
            n => vec![n],
        },
        panic_every: match args.get_parsed("inject-exit-every", 0u64)? {
            0 => None,
            n => Some(n),
        },
        stall_ms: args.get_parsed("stall-ms", 0u64)?,
    };
    let opts = WorkerOptions { faults, quiet: args.flag("quiet") };
    match args.get("listen") {
        Some(addr) => banditpam::dist::worker::listen_tcp(addr, &opts),
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            let exit = banditpam::dist::run_worker(stdin.lock(), stdout.lock(), &opts)?;
            if !args.flag("quiet") {
                eprintln!("worker: exit {exit:?}");
            }
            Ok(())
        }
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| Error::invalid_argument("usage: banditpam experiment <id|all>"))?;
    let scale = match args.get("scale").unwrap_or("quick") {
        "smoke" => Scale::Smoke,
        "quick" => Scale::Quick,
        "paper" => Scale::Paper,
        other => {
            return Err(Error::invalid_argument(format!("bad --scale {other:?}")))
        }
    };
    let seed: u64 = args.get_parsed("seed", 42u64)?;
    let ids: Vec<&str> = if id == "all" {
        banditpam::experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        for table in banditpam::experiments::run(id, scale, seed)? {
            if args.flag("csv") {
                print!("{}", table.to_csv());
            } else {
                table.print();
            }
        }
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| Error::invalid_argument("--out FILE.csv|FILE.mtx required"))?;
    let seed: u64 = args.get_parsed("seed", 42u64)?;
    let mut rng = Rng::seed_from(seed);
    let ds = make_dataset(args, &mut rng)?;
    let format = match args.get("format") {
        Some(s) => DataFormat::parse(s)
            .ok_or_else(|| Error::invalid_argument(format!("bad --format {s:?} (csv|mtx)")))?,
        None => DataFormat::infer(out),
    };
    match format {
        DataFormat::Csv if matches!(ds.points, Points::Dense(_)) => {
            loader::save_csv(&ds, &PathBuf::from(out))?;
        }
        DataFormat::Csv => {
            let dense = ds.to_dense().ok_or_else(|| {
                Error::invalid_argument(format!(
                    "CSV output needs vector points ({})",
                    ds.points.kind()
                ))
            })?;
            loader::save_csv(&dense, &PathBuf::from(out))?;
        }
        DataFormat::Mtx => loader::save_mtx(&ds, &PathBuf::from(out))?,
        DataFormat::Idx => {
            return Err(Error::invalid_argument(
                "generate-data cannot write IDX; use csv or mtx",
            ))
        }
    }
    println!("wrote {} points to {out} ({format})", ds.len());
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("banditpam v{}", env!("CARGO_PKG_VERSION"));
    println!(
        "threads available: {}",
        banditpam::experiments::harness::default_threads()
    );
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "artifacts dir: {} ({} artifacts)",
                dir.display(),
                m.artifacts.len()
            );
            for a in &m.artifacts {
                println!(
                    "  {:<36} kind={} metric={} [{} x {} x {}]",
                    a.name, a.kind, a.metric, a.t, a.r, a.d
                );
            }
        }
        Err(e) => println!("artifacts dir: {} (unavailable: {e})", dir.display()),
    }
    match Client::cpu() {
        Ok(c) => println!("PJRT client: {}", c.platform()),
        Err(e) => println!("PJRT client: unavailable ({e})"),
    }
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    // `--help` anywhere prints usage (it would otherwise be silently
    // accepted as an inert flag on every subcommand).
    if args.flag("help") {
        print!("{}", help());
        return Ok(());
    }
    check_known_options(args)?;
    match args.subcommand.as_deref() {
        Some("cluster") => cmd_cluster(args),
        Some("bigfit") => cmd_bigfit(args),
        Some("predict") => cmd_predict(args),
        Some("serve") => cmd_serve(args),
        Some("worker") => cmd_worker(args),
        Some("experiment") => cmd_experiment(args),
        Some("generate-data") => cmd_generate(args),
        Some("info") => cmd_info(),
        Some("help") | None => {
            print!("{}", help());
            Ok(())
        }
        Some(other) => Err(Error::invalid_argument(format!(
            "unknown subcommand {other:?} (run `banditpam help` for usage)"
        ))),
    }
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        // One line, typed category prefix, no debug formatting; the exit
        // code distinguishes usage errors (2) from operational ones (1).
        let line = e.to_string().replace('\n', "; ");
        eprintln!("error: {line}");
        std::process::exit(e.exit_code());
    }
}
