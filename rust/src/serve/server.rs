//! The serve loop: connection handling, the single-threaded dispatcher,
//! panic isolation and hot reload.
//!
//! # Threading model
//!
//! * One **reader** per connection (the calling thread for stdio, a
//!   spawned thread per TCP accept) parses frames and answers control
//!   requests inline; predict requests are validated and submitted to
//!   the shared [`Batcher`].
//! * One **writer thread** per connection owns the write half; every
//!   response (inline or from the dispatcher) goes through its channel,
//!   so frames are never interleaved. The writer sends a pending
//!   `ShutdownAck` *after* its channel disconnects — and since every
//!   in-flight [`PendingRequest`] holds a sender clone, the channel only
//!   disconnects once all admitted work has been answered: the ack is
//!   provably last (the clean-drain guarantee).
//! * One **dispatcher thread** per server pops coalesced batches,
//!   expires deadlines, and computes through a warm [`ThreadPool`]
//!   shared across batches (the warm predictor pool — no per-request
//!   thread spawning).
//!
//! # Panic isolation
//!
//! Each batch computes under `catch_unwind`; a panic (a poisoned model,
//! a kernel bug, an injected fault) becomes an `Internal` error response
//! for every request in the batch and increments the slot's
//! consecutive-failure count — after `quarantine_threshold` failures the
//! model is quarantined (fast `Quarantined` rejects) until a reload
//! clears it. The server itself never dies with a client.
//!
//! # Hot swap
//!
//! A reload (control frame, or SIGHUP on unix) loads the new file off
//! the slot lock and swaps the `Arc` atomically. A batch clones its
//! model `Arc` *before* computing, so in-flight batches finish on the
//! generation they started with; the next batch sees the new one.
//! Responses are bitwise-identical to single-shot `predict` against
//! whichever generation served them.

use super::batcher::{AdmissionConfig, Batcher, PendingRequest, Submit};
use super::faults::FaultPlan;
use super::protocol::{self, ErrorCode, PredictRequest, Request, Response};
use super::registry::Registry;
use crate::data::sparse::CsrMatrix;
use crate::data::Points;
use crate::runtime::pool::ThreadPool;
use crate::util::json;
use crate::util::matrix::Matrix;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Monotonic server counters; snapshot as JSON via the `stats` request.
pub struct ServeStats {
    /// Predict requests admitted to the queue.
    pub admitted: AtomicU64,
    /// Predict requests shed by backpressure.
    pub shed: AtomicU64,
    /// Admitted requests whose deadline expired before dispatch.
    pub deadline_expired: AtomicU64,
    /// Batches dispatched (after coalescing).
    pub batches: AtomicU64,
    /// Batches that panicked (isolated, answered `Internal`).
    pub panics: AtomicU64,
    /// Predict requests answered with assignments.
    pub served_ok: AtomicU64,
    /// Malformed frames / bodies answered with `BadRequest`.
    pub bad_requests: AtomicU64,
    /// Reload operations performed (control frame or SIGHUP).
    pub reloads: AtomicU64,
    /// Requests fast-rejected because their model was quarantined.
    pub quarantined: AtomicU64,
    /// Server start time; `uptime_secs` in the snapshot.
    pub started: Instant,
    /// Predict requests routed per model name (known models only).
    pub per_model: Mutex<BTreeMap<String, u64>>,
}

impl Default for ServeStats {
    fn default() -> ServeStats {
        ServeStats {
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            served_ok: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            started: Instant::now(),
            per_model: Mutex::new(BTreeMap::new()),
        }
    }
}

impl ServeStats {
    /// JSON object with every counter, stable key order. The pre-existing
    /// keys never change; `uptime_secs`, `queue_depth` and `per_model`
    /// are appended after them.
    pub fn snapshot_json(&self) -> String {
        self.snapshot_json_at(
            self.started.elapsed().as_secs(),
            crate::obs::global().gauge("serve_queue_depth").get(),
        )
    }

    /// Deterministic core of [`ServeStats::snapshot_json`]: the two live
    /// values (uptime, queue depth) are supplied by the caller, so for
    /// fixed counters the output is byte-deterministic — the per-model
    /// section iterates a `BTreeMap`, i.e. is sorted by model id. Pinned
    /// by the `valid_stats_response.bin` golden fixture.
    pub fn snapshot_json_at(&self, uptime_secs: u64, queue_depth: u64) -> String {
        let pairs = [
            ("admitted", self.admitted.load(Ordering::Relaxed)),
            ("shed", self.shed.load(Ordering::Relaxed)),
            ("deadline_expired", self.deadline_expired.load(Ordering::Relaxed)),
            ("batches", self.batches.load(Ordering::Relaxed)),
            ("panics", self.panics.load(Ordering::Relaxed)),
            ("served_ok", self.served_ok.load(Ordering::Relaxed)),
            ("bad_requests", self.bad_requests.load(Ordering::Relaxed)),
            ("reloads", self.reloads.load(Ordering::Relaxed)),
            ("quarantined", self.quarantined.load(Ordering::Relaxed)),
        ];
        let mut body: Vec<String> = pairs
            .iter()
            .map(|(k, v)| format!("\"{}\":{v}", json::escape(k)))
            .collect();
        body.push(format!("\"uptime_secs\":{uptime_secs}"));
        body.push(format!("\"queue_depth\":{queue_depth}"));
        let per_model = self.per_model.lock().unwrap();
        let entries: Vec<String> = per_model
            .iter()
            .map(|(k, v)| format!("\"{}\":{v}", json::escape(k)))
            .collect();
        body.push(format!("\"per_model\":{{{}}}", entries.join(",")));
        format!("{{{}}}", body.join(","))
    }
}

/// Server construction options.
pub struct ServeOptions {
    /// Threads in the shared predictor pool.
    pub threads: usize,
    pub admission: AdmissionConfig,
    pub faults: FaultPlan,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            threads: 1,
            admission: AdmissionConfig::default(),
            faults: FaultPlan::default(),
        }
    }
}

/// A running prediction server: registry + admission queue + dispatcher.
pub struct Server {
    registry: Registry,
    batcher: Batcher,
    pub stats: ServeStats,
    pool: Arc<ThreadPool>,
    admission: AdmissionConfig,
    faults: FaultPlan,
    shutting_down: AtomicBool,
    dispatcher: Mutex<Option<thread::JoinHandle<()>>>,
    /// Latency histograms (microseconds), resolved once at startup:
    /// admission→dispatch, dispatch→computed, admission→reply.
    obs_queue_us: Arc<crate::obs::Histogram>,
    obs_handle_us: Arc<crate::obs::Histogram>,
    obs_request_us: Arc<crate::obs::Histogram>,
}

impl Server {
    /// Build the server and start its dispatcher thread.
    pub fn new(registry: Registry, opts: ServeOptions) -> Arc<Server> {
        let server = Arc::new(Server {
            registry,
            batcher: Batcher::new(&opts.admission),
            stats: ServeStats::default(),
            pool: Arc::new(ThreadPool::new(opts.threads.max(1))),
            admission: opts.admission,
            faults: opts.faults,
            shutting_down: AtomicBool::new(false),
            dispatcher: Mutex::new(None),
            obs_queue_us: crate::obs::global().histogram("serve_queue_us"),
            obs_handle_us: crate::obs::global().histogram("serve_handle_us"),
            obs_request_us: crate::obs::global().histogram("serve_request_us"),
        });
        let handle = {
            let server = Arc::clone(&server);
            thread::Builder::new()
                .name("serve-dispatcher".into())
                .spawn(move || server.dispatch_loop())
                .expect("spawning the dispatcher")
        };
        *server.dispatcher.lock().unwrap() = Some(handle);
        server
    }

    /// The model registry (reload, describe).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Whether a shutdown has been initiated.
    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down.load(Ordering::Acquire)
    }

    /// Stop admitting predict work; the dispatcher drains the queue and
    /// exits. Idempotent.
    pub fn begin_shutdown(&self) {
        self.shutting_down.store(true, Ordering::Release);
        self.batcher.shutdown();
    }

    /// Wait for the dispatcher to drain and exit.
    pub fn join(&self) {
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            h.join().expect("the dispatcher never panics");
        }
    }

    /// Reload models (empty name = all); the `ReloadAck` text reports
    /// per-slot outcomes.
    pub fn request_reload(&self, name: &str) -> Result<String, crate::error::Error> {
        let report = self.registry.reload(name)?;
        self.stats.reloads.fetch_add(1, Ordering::Relaxed);
        Ok(report)
    }

    /// Service a pending SIGHUP (unix): reload every model. Called from
    /// reader loops and the dispatcher between batches.
    pub fn poll_reload(&self) {
        if take_pending_sighup() {
            // Failures are reported per-slot in the log line; old
            // generations keep serving.
            match self.request_reload("") {
                Ok(report) => eprintln!("serve: SIGHUP reload\n{report}"),
                Err(e) => eprintln!("serve: SIGHUP reload failed: {e}"),
            }
        }
    }

    // ---- dispatcher ----------------------------------------------------

    fn dispatch_loop(&self) {
        let mut seq: u64 = 0;
        while let Some(batch) = self.batcher.next_batch() {
            seq += 1;
            self.poll_reload();
            self.process_batch(seq, batch);
        }
    }

    fn process_batch(&self, seq: u64, batch: Vec<PendingRequest>) {
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        let dispatched = Instant::now();
        for req in &batch {
            self.obs_queue_us.record_duration(dispatched.duration_since(req.admitted));
        }
        let slot = Arc::clone(&batch[0].slot);

        if slot.is_quarantined() {
            self.stats.quarantined.fetch_add(batch.len() as u64, Ordering::Relaxed);
            for req in batch {
                let _ = req.reply.send(Response::Error {
                    id: req.id,
                    code: ErrorCode::Quarantined,
                    retry_after_ms: 0,
                    message: format!(
                        "model {:?} is quarantined after repeated failures; reload to clear",
                        slot.name()
                    ),
                });
            }
            return;
        }

        // Pin the model generation before any stall: a reload landing
        // mid-batch must not change the bytes this batch computes on.
        let loaded = slot.current();

        if let Some(stall) = self.faults.stall() {
            thread::sleep(stall);
        }

        // Expire deadlines at dispatch (after the injected stall, so the
        // fault harness can force expiry deterministically).
        let now = Instant::now();
        let (batch, expired): (Vec<_>, Vec<_>) = batch
            .into_iter()
            .partition(|req| req.deadline.map_or(true, |d| now < d));
        self.stats.deadline_expired.fetch_add(expired.len() as u64, Ordering::Relaxed);
        for req in expired {
            let _ = req.reply.send(Response::Error {
                id: req.id,
                code: ErrorCode::DeadlineExceeded,
                retry_after_ms: 0,
                message: "deadline expired before dispatch".into(),
            });
        }
        if batch.is_empty() {
            return;
        }

        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if self.faults.should_panic(seq) {
                panic!("injected fault: forced kernel panic (batch {seq})");
            }
            let queries = concat_queries(&batch);
            loaded
                .model
                .predictor_with_pool(Arc::clone(&self.pool))
                .predict_with_dists(queries.as_ref().unwrap_or(&batch[0].queries))
        }));
        self.obs_handle_us.record_duration(dispatched.elapsed());

        match outcome {
            Ok(Ok((assign, dists))) => {
                slot.record_success();
                self.stats.served_ok.fetch_add(batch.len() as u64, Ordering::Relaxed);
                let mut offset = 0;
                for req in batch {
                    let n = req.queries.len();
                    let _ = req.reply.send(Response::Assignments {
                        id: req.id,
                        assign: assign[offset..offset + n]
                            .iter()
                            .map(|&a| a as u32)
                            .collect(),
                        dists: dists[offset..offset + n].to_vec(),
                    });
                    self.obs_request_us.record_duration(req.admitted.elapsed());
                    offset += n;
                }
            }
            Ok(Err(e)) => {
                // A typed predict error (post-reload storage/dim drift):
                // the request, not the server, is at fault.
                self.stats.bad_requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
                for req in batch {
                    let _ = req.reply.send(Response::Error {
                        id: req.id,
                        code: ErrorCode::BadRequest,
                        retry_after_ms: 0,
                        message: e.to_string(),
                    });
                    self.obs_request_us.record_duration(req.admitted.elapsed());
                }
            }
            Err(payload) => {
                self.stats.panics.fetch_add(1, Ordering::Relaxed);
                let text = panic_text(payload.as_ref());
                if slot.record_panic(self.admission.quarantine_threshold) {
                    eprintln!(
                        "serve: model {:?} quarantined after {} consecutive batch panics",
                        slot.name(),
                        self.admission.quarantine_threshold
                    );
                }
                for req in batch {
                    let _ = req.reply.send(Response::Error {
                        id: req.id,
                        code: ErrorCode::Internal,
                        retry_after_ms: 0,
                        message: format!("batch panicked: {text}"),
                    });
                    self.obs_request_us.record_duration(req.admitted.elapsed());
                }
            }
        }
    }

    // ---- connection handling -------------------------------------------

    /// Serve one connection: parse frames off `reader` on the calling
    /// thread, write responses through a dedicated writer thread.
    /// Returns once the client hangs up, breaks framing, or sends a
    /// shutdown frame — with every admitted request answered and, on
    /// shutdown, the `ShutdownAck` written last.
    pub fn handle_connection<R, W>(self: &Arc<Server>, mut reader: R, writer: W)
    where
        R: Read,
        W: Write + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Response>();
        let ack_id: Arc<Mutex<Option<u64>>> = Arc::new(Mutex::new(None));
        let writer_handle = {
            let ack_id = Arc::clone(&ack_id);
            thread::spawn(move || {
                let mut writer = writer;
                // Write errors (client gone) are ignored but the channel
                // keeps draining, so senders never block on a dead peer.
                for resp in rx {
                    let _ = writer.write_all(&protocol::encode_response(&resp));
                    let _ = writer.flush();
                }
                // The channel is disconnected: every sender clone —
                // including those held by in-flight requests — is gone,
                // so the ack really is the last frame.
                if let Some(id) = ack_id.lock().unwrap().take() {
                    let _ =
                        writer.write_all(&protocol::encode_response(&Response::ShutdownAck {
                            id,
                        }));
                    let _ = writer.flush();
                }
            })
        };

        loop {
            self.poll_reload();
            let frame = match protocol::read_frame(&mut reader) {
                Ok(Some(frame)) => frame,
                Ok(None) => break, // clean EOF at a frame boundary
                Err(e) => {
                    // Framing is lost: best-effort error, then close.
                    self.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Response::Error {
                        id: 0,
                        code: ErrorCode::BadRequest,
                        retry_after_ms: 0,
                        message: e.0,
                    });
                    break;
                }
            };
            let req = match protocol::parse_request(frame.0, &frame.1) {
                Ok(req) => req,
                Err(fail) => {
                    // Well-framed but malformed: recoverable.
                    self.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Response::Error {
                        id: fail.id,
                        code: ErrorCode::BadRequest,
                        retry_after_ms: 0,
                        message: fail.message,
                    });
                    continue;
                }
            };
            match req {
                Request::Ping { id } => {
                    let _ = tx.send(Response::Pong { id });
                }
                Request::Stats { id } => {
                    let _ = tx.send(Response::Stats {
                        id,
                        text: self.stats.snapshot_json(),
                    });
                }
                Request::Metrics { id } => {
                    let _ = tx.send(Response::Metrics {
                        id,
                        text: crate::obs::global().render_prometheus(),
                    });
                }
                Request::ListModels { id } => {
                    let _ = tx.send(Response::ModelList {
                        id,
                        text: self.registry.describe(),
                    });
                }
                Request::Reload { id, name } => match self.request_reload(&name) {
                    Ok(text) => {
                        let _ = tx.send(Response::ReloadAck { id, text });
                    }
                    Err(e) => {
                        let _ = tx.send(Response::Error {
                            id,
                            code: ErrorCode::BadRequest,
                            retry_after_ms: 0,
                            message: e.to_string(),
                        });
                    }
                },
                Request::Shutdown { id } => {
                    *ack_id.lock().unwrap() = Some(id);
                    self.begin_shutdown();
                    break;
                }
                Request::Predict(p) => self.admit_predict(p, &tx),
            }
        }

        drop(tx);
        let _ = writer_handle.join();
    }

    /// Validate and enqueue one predict request, answering rejects
    /// inline through `tx`.
    fn admit_predict(&self, p: PredictRequest, tx: &mpsc::Sender<Response>) {
        let send_err = |id, code, retry_after_ms, message: String| {
            let _ = tx.send(Response::Error { id, code, retry_after_ms, message });
        };
        if self.is_shutting_down() {
            send_err(
                p.id,
                ErrorCode::ShuttingDown,
                0,
                "the server is draining".into(),
            );
            return;
        }
        let Some(slot) = self.registry.get(&p.model) else {
            send_err(
                p.id,
                ErrorCode::UnknownModel,
                0,
                format!("unknown model {:?}", p.model),
            );
            return;
        };
        *self
            .stats
            .per_model
            .lock()
            .unwrap()
            .entry(p.model.clone())
            .or_insert(0) += 1;
        if slot.is_quarantined() {
            self.stats.quarantined.fetch_add(1, Ordering::Relaxed);
            send_err(
                p.id,
                ErrorCode::Quarantined,
                0,
                format!("model {:?} is quarantined; reload to clear", p.model),
            );
            return;
        }
        // Validate shape against the current generation so malformed
        // requests fail fast instead of poisoning a batch.
        let loaded = slot.current();
        let medoids = loaded.model.medoid_points();
        if p.queries.kind() != medoids.kind() {
            send_err(
                p.id,
                ErrorCode::BadRequest,
                0,
                format!(
                    "query storage {} does not match the model's {} medoids",
                    p.queries.kind(),
                    medoids.kind()
                ),
            );
            return;
        }
        if p.queries.dim() != loaded.model.dim() {
            send_err(
                p.id,
                ErrorCode::BadRequest,
                0,
                format!(
                    "query dimension {:?} does not match the model's {:?}",
                    p.queries.dim(),
                    loaded.model.dim()
                ),
            );
            return;
        }
        if p.queries.is_empty() {
            // Nothing to dispatch; answer directly (parity with
            // `predict` on empty input).
            let _ = tx.send(Response::Assignments {
                id: p.id,
                assign: Vec::new(),
                dists: Vec::new(),
            });
            return;
        }
        let deadline = (p.deadline_ms > 0)
            .then(|| Instant::now() + Duration::from_millis(u64::from(p.deadline_ms)));
        let pending = PendingRequest {
            id: p.id,
            slot: Arc::clone(slot),
            queries: p.queries,
            deadline,
            admitted: Instant::now(),
            reply: tx.clone(),
        };
        match self.batcher.submit(pending) {
            Submit::Queued => {
                self.stats.admitted.fetch_add(1, Ordering::Relaxed);
            }
            Submit::Shed(req) => {
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                let (code, msg) = if self.is_shutting_down() {
                    (ErrorCode::ShuttingDown, "the server is draining".to_string())
                } else {
                    (
                        ErrorCode::Overloaded,
                        format!(
                            "admission queue full; retry in {} ms",
                            self.admission.retry_after_ms
                        ),
                    )
                };
                let retry = if code == ErrorCode::Overloaded {
                    self.admission.retry_after_ms
                } else {
                    0
                };
                send_err(req.id, code, retry, msg);
            }
        }
    }
}

/// Concatenate a coalesced batch's queries into one `Points` for a
/// single backend dispatch. Returns `None` for a single-request batch
/// (the caller uses the original, skipping the copy). Row kernels are
/// per-query independent, so assignments on the concatenation are
/// bitwise-identical to per-request dispatches.
fn concat_queries(batch: &[PendingRequest]) -> Option<Points> {
    if batch.len() == 1 {
        return None;
    }
    match &batch[0].queries {
        Points::Dense(first) => {
            let dim = first.cols();
            let mut values = Vec::new();
            let mut rows = 0;
            for req in batch {
                let Points::Dense(m) = &req.queries else {
                    unreachable!("the batcher only merges same-kind queries")
                };
                values.extend_from_slice(m.as_slice());
                rows += m.rows();
            }
            Some(Points::Dense(Matrix::from_vec(values, rows, dim)))
        }
        Points::Sparse(first) => {
            let cols = first.cols();
            let mut indptr = vec![0usize];
            let mut indices = Vec::new();
            let mut values = Vec::new();
            let mut rows = 0;
            for req in batch {
                let Points::Sparse(m) = &req.queries else {
                    unreachable!("the batcher only merges same-kind queries")
                };
                let (ip, ix, vs) = m.parts();
                let base = *indptr.last().unwrap();
                indptr.extend(ip.iter().skip(1).map(|p| base + p));
                indices.extend_from_slice(ix);
                values.extend_from_slice(vs);
                rows += m.rows();
            }
            let csr = CsrMatrix::try_from_parts(rows, cols, indptr, indices, values)
                .expect("concatenating valid CSR blocks preserves the invariants");
            Some(Points::Sparse(csr))
        }
        Points::Trees(_) => unreachable!("tree queries have no wire form"),
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

// ---- SIGHUP (unix) -----------------------------------------------------

#[cfg(unix)]
static SIGHUP_PENDING: AtomicBool = AtomicBool::new(false);

/// Install the SIGHUP → reload-all handler (unix only; a no-op
/// elsewhere). The handler only flips a flag; the actual reload runs on
/// the next reader/dispatcher tick via [`Server::poll_reload`].
pub fn install_sighup_handler() {
    #[cfg(unix)]
    {
        const SIGHUP: i32 = 1;
        extern "C" fn on_sighup(_signum: i32) {
            SIGHUP_PENDING.store(true, Ordering::SeqCst);
        }
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(SIGHUP, on_sighup);
        }
    }
}

fn take_pending_sighup() -> bool {
    #[cfg(unix)]
    {
        SIGHUP_PENDING.swap(false, Ordering::SeqCst)
    }
    #[cfg(not(unix))]
    {
        false
    }
}

// ---- TCP ---------------------------------------------------------------

/// Accept TCP connections until shutdown, one reader thread per client.
/// After shutdown, waits up to ~5 s for connection threads to finish
/// (idle clients holding sockets open are abandoned to process exit).
pub fn serve_tcp(server: &Arc<Server>, addr: &str) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    eprintln!("serve: listening on {}", listener.local_addr()?);
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    while !server.is_shutting_down() {
        server.poll_reload();
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nodelay(true).ok();
                let server = Arc::clone(server);
                handles.push(thread::spawn(move || {
                    // Sniff before speaking: Prometheus scrapers open with
                    // "GET ", protocol clients with the "BQ" magic. The
                    // sniff peeks (consumes nothing), so the protocol
                    // reader still sees the full stream.
                    if looks_like_http(&stream) {
                        let _ = answer_http_metrics(stream);
                        return;
                    }
                    let Ok(write_half) = stream.try_clone() else { return };
                    server.handle_connection(stream, write_half);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e),
        }
        handles.retain(|h| !h.is_finished());
    }
    let drain_deadline = Instant::now() + Duration::from_secs(5);
    while handles.iter().any(|h| !h.is_finished()) && Instant::now() < drain_deadline {
        thread::sleep(Duration::from_millis(20));
    }
    for h in handles.into_iter().filter(|h| h.is_finished()) {
        let _ = h.join();
    }
    Ok(())
}

/// Decide whether an accepted connection is a plain-HTTP scraper: peek
/// (never consume) the first bytes and look for `"GET "`. The binary
/// protocol opens with the `"BQ"` magic, so one byte usually decides; a
/// peer that sends nothing within the sniff window is treated as a
/// protocol client (the frame reader will handle it either way).
fn looks_like_http(stream: &std::net::TcpStream) -> bool {
    let mut buf = [0u8; 4];
    let deadline = Instant::now() + Duration::from_millis(500);
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let is_http = loop {
        match stream.peek(&mut buf) {
            Ok(n) if n >= 4 => break &buf == b"GET ",
            Ok(n) if n >= 1 && buf[0] != b'G' => break false,
            Ok(0) => break false,
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => break false,
        }
        if Instant::now() >= deadline {
            break false;
        }
        thread::sleep(Duration::from_millis(5));
    };
    stream.set_read_timeout(None).ok();
    is_http
}

/// Answer one plain-HTTP request: `GET /metrics` gets the Prometheus
/// text exposition of the process metrics, anything else a 404. HTTP/1.0
/// close-after-response semantics — exactly enough for a scraper.
fn answer_http_metrics(mut stream: std::net::TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
    // Read the request head (capped) until the blank line; the body of a
    // GET is empty, so this terminates or times out.
    let mut head: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 8192 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let request_line = std::str::from_utf8(&head)
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("");
    let path = request_line.split_whitespace().nth(1).unwrap_or("");
    let (status, body) = if path == "/metrics" {
        ("200 OK", crate::obs::global().render_prometheus())
    } else {
        ("404 Not Found", "not found\n".to_string())
    };
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::model::Fit;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn registry_with_model(tag: &str) -> (Registry, PathBuf) {
        let dir =
            std::env::temp_dir().join(format!("bp_server_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ds = synthetic::gmm(&mut Rng::seed_from(9), 30, 5, 3, 3.0);
        let model = Fit::banditpam().k(3).seed(9).fit(&ds).unwrap();
        let path = dir.join("m.bpmodel");
        model.save(&path).unwrap();
        (Registry::open(&[("m".into(), path)]).unwrap(), dir)
    }

    #[test]
    fn stats_snapshot_is_valid_json_with_every_counter() {
        let stats = ServeStats::default();
        stats.admitted.store(3, Ordering::Relaxed);
        stats.panics.store(1, Ordering::Relaxed);
        let snap = stats.snapshot_json();
        let parsed = json::Json::parse(&snap).unwrap();
        assert_eq!(parsed.get("admitted").and_then(|j| j.as_usize()), Some(3));
        assert_eq!(parsed.get("panics").and_then(|j| j.as_usize()), Some(1));
        assert_eq!(parsed.get("shed").and_then(|j| j.as_usize()), Some(0));
        assert_eq!(parsed.get("reloads").and_then(|j| j.as_usize()), Some(0));
    }

    #[test]
    fn server_starts_drains_and_joins() {
        let (registry, dir) = registry_with_model("lifecycle");
        let server = Server::new(registry, ServeOptions::default());
        assert!(!server.is_shutting_down());
        server.begin_shutdown();
        server.join();
        // join is idempotent
        server.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn panic_text_extracts_both_payload_shapes() {
        let p: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_text(p.as_ref()), "static str");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_text(p.as_ref()), "owned");
        let p: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_text(p.as_ref()), "opaque panic payload");
    }

    #[test]
    fn concat_queries_merges_sparse_blocks_correctly() {
        let a = CsrMatrix::try_from_parts(2, 4, vec![0, 1, 3], vec![0, 1, 2], vec![
            1.0, 2.0, 3.0,
        ])
        .unwrap();
        let b = CsrMatrix::try_from_parts(1, 4, vec![0, 2], vec![0, 3], vec![4.0, 5.0])
            .unwrap();
        let (registry, dir) = registry_with_model("concat");
        let slot = Arc::clone(registry.get("m").unwrap());
        let (tx, _rx) = mpsc::channel();
        let batch = vec![
            PendingRequest {
                id: 1,
                slot: Arc::clone(&slot),
                queries: Points::Sparse(a.clone()),
                deadline: None,
                admitted: Instant::now(),
                reply: tx.clone(),
            },
            PendingRequest {
                id: 2,
                slot,
                queries: Points::Sparse(b.clone()),
                deadline: None,
                admitted: Instant::now(),
                reply: tx,
            },
        ];
        let merged = concat_queries(&batch).unwrap();
        let Points::Sparse(m) = merged else { unreachable!() };
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(0), (&[0u32][..], &[1.0f32][..]));
        assert_eq!(m.row(1), (&[1u32, 2][..], &[2.0f32, 3.0][..]));
        assert_eq!(m.row(2), (&[0u32, 3][..], &[4.0f32, 5.0][..]));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Accept one connection on an ephemeral listener while a client
    /// thread writes `payload`; returns the sniffed verdict and the
    /// (still-open) server-side stream for follow-up reads.
    fn sniff(payload: &'static [u8]) -> (bool, std::net::TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            s.write_all(payload).unwrap();
            s
        });
        let (stream, _) = listener.accept().unwrap();
        let verdict = looks_like_http(&stream);
        // Keep the client socket alive until the sniff finishes.
        drop(client.join().unwrap());
        (verdict, stream)
    }

    #[test]
    fn http_sniff_recognizes_get_and_preserves_bytes() {
        let (verdict, mut stream) = sniff(b"GET /metrics HTTP/1.0\r\n\r\n");
        assert!(verdict);
        // Peek must not have consumed anything: the full request line is
        // still readable.
        let mut buf = [0u8; 4];
        stream.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"GET ");
    }

    #[test]
    fn http_sniff_rejects_protocol_magic() {
        let (verdict, mut stream) = sniff(b"BQ\x01\x00\x00\x00\x00\x00");
        assert!(!verdict);
        let mut buf = [0u8; 2];
        stream.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"BQ");
    }

    #[test]
    fn metrics_endpoint_serves_prometheus_text() {
        crate::obs::global().counter("serve_sniff_test_total").add(3);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /metrics HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
            let mut text = String::new();
            s.read_to_string(&mut text).unwrap();
            text
        });
        let (stream, _) = listener.accept().unwrap();
        answer_http_metrics(stream).unwrap();
        let text = client.join().unwrap();
        assert!(text.starts_with("HTTP/1.0 200 OK\r\n"), "got: {text}");
        assert!(text.contains("Content-Type: text/plain; version=0.0.4"));
        assert!(text.contains("# TYPE serve_sniff_test_total counter"));
        assert!(text.contains("serve_sniff_test_total 3"));
    }

    #[test]
    fn metrics_endpoint_404s_unknown_paths() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /nope HTTP/1.0\r\n\r\n").unwrap();
            let mut text = String::new();
            s.read_to_string(&mut text).unwrap();
            text
        });
        let (stream, _) = listener.accept().unwrap();
        answer_http_metrics(stream).unwrap();
        let text = client.join().unwrap();
        assert!(text.starts_with("HTTP/1.0 404 Not Found\r\n"), "got: {text}");
    }
}
