//! Admission control: a bounded queue that coalesces small concurrent
//! predict requests into one backend dispatch per model.
//!
//! Three jobs:
//!
//! * **Backpressure** — [`Batcher::submit`] sheds (returns the request to
//!   the caller for an `Overloaded` response) once the queue holds
//!   `max_queue_requests` requests or `max_queue_points` query points.
//!   Load-shedding at admission keeps the tail latency of accepted
//!   requests bounded instead of letting the queue grow without limit.
//! * **Coalescing** — [`Batcher::next_batch`] pops the oldest request and
//!   greedily merges queued requests for the *same model generation and
//!   storage/dim* (up to `max_batch_points` points) into one batch, so a
//!   swarm of small requests costs one `block_vs` dispatch instead of
//!   many. Row kernels are per-query independent, so a coalesced batch
//!   is bitwise-identical to serving each request alone.
//! * **Drain** — after [`Batcher::shutdown`], `next_batch` keeps handing
//!   out queued work until the queue is empty, then returns `None`;
//!   nothing accepted is dropped.
//!
//! Deadlines ride along: each request carries its admission deadline and
//! the dispatcher expires it at dispatch time (`DeadlineExceeded`), not
//! here — a queue scan per tick would be O(n) for no benefit.

use super::protocol::Response;
use super::registry::ModelSlot;
use crate::data::Points;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Admission-control knobs (defaults are sized for the bench workload:
/// a few thousand points in flight, 50 ms retry hint).
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Shed when the queue already holds this many requests.
    pub max_queue_requests: usize,
    /// Shed when the queue already holds this many query points.
    pub max_queue_points: usize,
    /// Stop coalescing a batch beyond this many points.
    pub max_batch_points: usize,
    /// The retry hint carried by `Overloaded` responses.
    pub retry_after_ms: u32,
    /// Consecutive batch panics before a model is quarantined.
    pub quarantine_threshold: u32,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            max_queue_requests: 1024,
            max_queue_points: 65536,
            max_batch_points: 4096,
            retry_after_ms: 50,
            quarantine_threshold: 3,
        }
    }
}

/// An admitted predict request waiting for dispatch. Holds a clone of
/// its connection's reply sender, so the writer thread's channel stays
/// open until every in-flight request has been answered (the clean-drain
/// guarantee).
pub struct PendingRequest {
    pub id: u64,
    pub slot: Arc<ModelSlot>,
    pub queries: Points,
    /// Absolute expiry; checked at dispatch, `None` = no deadline.
    pub deadline: Option<Instant>,
    /// When the request was admitted; the dispatcher turns this into the
    /// `serve_queue_us` latency histogram (admission → dispatch).
    pub admitted: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// Outcome of [`Batcher::submit`].
pub enum Submit {
    /// Admitted; the dispatcher will answer through `reply`.
    Queued,
    /// Shed by backpressure; the request is handed back so the caller
    /// can answer `Overloaded` itself.
    Shed(PendingRequest),
}

struct QueueState {
    queue: VecDeque<PendingRequest>,
    /// Total query points across `queue` (the second shed limit).
    points: usize,
    shutdown: bool,
}

/// The bounded admission queue shared by connection readers (producers)
/// and the dispatcher (single consumer).
pub struct Batcher {
    state: Mutex<QueueState>,
    work: Condvar,
    max_queue_requests: usize,
    max_queue_points: usize,
    max_batch_points: usize,
    /// Queue-depth gauge, mirrored on every admit/pop (stats scrape reads
    /// the gauge without taking the queue lock).
    obs_depth: Arc<crate::obs::Gauge>,
}

impl Batcher {
    pub fn new(cfg: &AdmissionConfig) -> Batcher {
        Batcher {
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                points: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            max_queue_requests: cfg.max_queue_requests.max(1),
            max_queue_points: cfg.max_queue_points.max(1),
            max_batch_points: cfg.max_batch_points.max(1),
            obs_depth: crate::obs::global().gauge("serve_queue_depth"),
        }
    }

    /// Admit or shed one request. Sheds when either bound is already
    /// full; an admitted request is only bounded by `max_queue_points`
    /// in aggregate, so a single oversized request can still enter an
    /// empty queue rather than being unservable.
    pub fn submit(&self, req: PendingRequest) -> Submit {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Submit::Shed(req);
        }
        if st.queue.len() >= self.max_queue_requests
            || (!st.queue.is_empty() && st.points + req.queries.len() > self.max_queue_points)
        {
            return Submit::Shed(req);
        }
        st.points += req.queries.len();
        st.queue.push_back(req);
        self.obs_depth.set(st.queue.len() as u64);
        drop(st);
        self.work.notify_one();
        Submit::Queued
    }

    /// Block for the next batch: the oldest request plus every queued
    /// request that can ride along (same model generation via
    /// `Arc::ptr_eq` on the slot, same storage kind and dimension), up
    /// to `max_batch_points`. Requests that cannot ride along keep
    /// their queue order. Returns `None` only after [`Batcher::shutdown`]
    /// once the queue has fully drained.
    pub fn next_batch(&self) -> Option<Vec<PendingRequest>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(head) = st.queue.pop_front() {
                st.points -= head.queries.len();
                let mut batch = vec![head];
                let mut batch_points = batch[0].queries.len();
                let mut i = 0;
                while i < st.queue.len() {
                    let cand = &st.queue[i];
                    let mergeable = Arc::ptr_eq(&cand.slot, &batch[0].slot)
                        && cand.queries.kind() == batch[0].queries.kind()
                        && cand.queries.dim() == batch[0].queries.dim()
                        && batch_points + cand.queries.len() <= self.max_batch_points;
                    if mergeable {
                        let req = st.queue.remove(i).unwrap();
                        st.points -= req.queries.len();
                        batch_points += req.queries.len();
                        batch.push(req);
                    } else {
                        i += 1;
                    }
                }
                self.obs_depth.set(st.queue.len() as u64);
                return Some(batch);
            }
            if st.shutdown {
                return None;
            }
            st = self.work.wait(st).unwrap();
        }
    }

    /// Stop admitting work and wake the dispatcher so it can drain the
    /// queue and exit.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.work.notify_all();
    }

    /// Queue depth in requests (stats only).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::Registry;
    use super::*;
    use crate::data::synthetic;
    use crate::model::Fit;
    use crate::util::matrix::Matrix;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn test_slot(tag: &str) -> (Arc<ModelSlot>, PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("bp_batcher_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ds = synthetic::gmm(&mut Rng::seed_from(5), 24, 4, 2, 3.0);
        let model = Fit::banditpam().k(2).seed(5).fit(&ds).unwrap();
        let path = dir.join("m.bpmodel");
        model.save(&path).unwrap();
        let reg = Registry::open(&[("m".into(), path)]).unwrap();
        (Arc::clone(reg.get("m").unwrap()), dir)
    }

    fn dense_req(
        id: u64,
        slot: &Arc<ModelSlot>,
        n: usize,
        dim: usize,
        tx: &mpsc::Sender<Response>,
    ) -> PendingRequest {
        PendingRequest {
            id,
            slot: Arc::clone(slot),
            queries: Points::Dense(Matrix::zeros(n, dim)),
            deadline: None,
            admitted: Instant::now(),
            reply: tx.clone(),
        }
    }

    #[test]
    fn coalesces_same_shape_requests_up_to_the_point_cap() {
        let (slot, dir) = test_slot("coalesce");
        let cfg = AdmissionConfig { max_batch_points: 5, ..Default::default() };
        let b = Batcher::new(&cfg);
        let (tx, _rx) = mpsc::channel();
        for id in 0..4 {
            // 2 points each; the cap of 5 fits the head plus one rider.
            assert!(matches!(b.submit(dense_req(id, &slot, 2, 4, &tx)), Submit::Queued));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mismatched_dims_do_not_merge_and_keep_their_order() {
        let (slot, dir) = test_slot("dims");
        let b = Batcher::new(&AdmissionConfig::default());
        let (tx, _rx) = mpsc::channel();
        b.submit(dense_req(1, &slot, 1, 4, &tx));
        b.submit(dense_req(2, &slot, 1, 7, &tx));
        b.submit(dense_req(3, &slot, 1, 4, &tx));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sheds_on_request_and_point_bounds() {
        let (slot, dir) = test_slot("shed");
        let cfg = AdmissionConfig {
            max_queue_requests: 2,
            max_queue_points: 10,
            ..Default::default()
        };
        let b = Batcher::new(&cfg);
        let (tx, _rx) = mpsc::channel();
        assert!(matches!(b.submit(dense_req(1, &slot, 1, 4, &tx)), Submit::Queued));
        assert!(matches!(b.submit(dense_req(2, &slot, 1, 4, &tx)), Submit::Queued));
        // request bound
        match b.submit(dense_req(3, &slot, 1, 4, &tx)) {
            Submit::Shed(req) => assert_eq!(req.id, 3),
            Submit::Queued => panic!("expected shed"),
        }
        b.next_batch().unwrap();
        // point bound: queue holds 0 points now; admit 8, then 3 more breaks 10
        assert!(matches!(b.submit(dense_req(4, &slot, 8, 4, &tx)), Submit::Queued));
        assert!(matches!(b.submit(dense_req(5, &slot, 3, 4, &tx)), Submit::Shed(_)));
        // but an oversized request enters an *empty* queue
        b.next_batch().unwrap();
        assert!(matches!(b.submit(dense_req(6, &slot, 99, 4, &tx)), Submit::Queued));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shutdown_drains_then_yields_none_and_sheds_new_work() {
        let (slot, dir) = test_slot("drain");
        let b = Batcher::new(&AdmissionConfig::default());
        let (tx, _rx) = mpsc::channel();
        b.submit(dense_req(1, &slot, 1, 4, &tx));
        b.shutdown();
        assert!(matches!(b.submit(dense_req(2, &slot, 1, 4, &tx)), Submit::Shed(_)));
        assert_eq!(b.next_batch().unwrap()[0].id, 1);
        assert!(b.next_batch().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn next_batch_blocks_until_work_arrives() {
        let (slot, dir) = test_slot("block");
        let b = Arc::new(Batcher::new(&AdmissionConfig::default()));
        let (tx, _rx) = mpsc::channel();
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || b.next_batch().map(|batch| batch[0].id))
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        b.submit(dense_req(77, &slot, 1, 4, &tx));
        assert_eq!(consumer.join().unwrap(), Some(77));
        std::fs::remove_dir_all(&dir).ok();
    }
}
